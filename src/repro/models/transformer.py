"""Model assembly: pattern-based decoder covering all 10 assigned archs.

A config's layer stack is ``prefix`` (unscanned leading layers, e.g. Kimi's
dense first layer) followed by ``pattern`` repeated R times and executed
under ``jax.lax.scan`` over stacked parameters — one HLO block body per
pattern position regardless of depth, which keeps 80-layer compiles cheap.

Block kinds: attn | local | global | dense | attn_moe | mamba | mamba_moe
| rwkv.  ``forward`` returns final *hidden states* (the LM head + loss are
applied chunked in train/steps.py to bound logits memory); ``lm_logits``
maps hidden -> logits for serving.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import base as cfgbase
from repro.models import attention, common, mamba, mlp, moe, rwkv6


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _block_init(key, cfg, kind: str, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": common.rmsnorm_init(d, dtype)}
    if kind in ("attn", "local", "global", "dense", "attn_moe"):
        p["attn"] = attention.init(ks[0], cfg, dtype)
    elif kind in ("mamba", "mamba_moe"):
        p["mamba"] = mamba.init(ks[0], cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = rwkv6.init(ks[0], cfg, dtype)
        return p  # rwkv keeps its own ln2/channel-mix internally
    else:
        raise ValueError(kind)
    p["ln2"] = common.rmsnorm_init(d, dtype)
    if kind.endswith("_moe"):
        p["moe"] = moe.init(ks[1], cfg, dtype)
    else:
        p["mlp"] = mlp.init(ks[1], d, cfgbase.eff_d_ff(cfg), dtype)
    if cfg.post_block_norm:
        p["ln1_post"] = common.rmsnorm_init(d, dtype)
        p["ln2_post"] = common.rmsnorm_init(d, dtype)
    return p


def init_params(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)
    params: Dict[str, Any] = {}
    if cfg.embed_inputs:
        if cfg.num_codebooks > 1:
            tables = jax.random.normal(
                keys[0], (cfg.num_codebooks, cfg.vocab_size, cfg.d_model),
                dtype) * 0.02
            params["embed"] = {"table": tables}
        else:
            params["embed"] = common.embed_init(keys[0], cfg.vocab_size,
                                                cfg.d_model, dtype)
    # prefix (unscanned)
    if cfg.prefix:
        pkeys = jax.random.split(keys[1], len(cfg.prefix))
        params["prefix"] = [
            _block_init(pkeys[i], cfg, kind, dtype)
            for i, kind in enumerate(cfg.prefix)]
    # scanned pattern blocks: stack R inits per position
    r = cfg.num_pattern_repeats
    blocks = {}
    bkeys = jax.random.split(keys[2], len(cfg.pattern))
    for i, kind in enumerate(cfg.pattern):
        stack_keys = jax.random.split(bkeys[i], r)
        blocks[f"pos{i}"] = jax.vmap(
            lambda k: _block_init(k, cfg, kind, dtype))(stack_keys)
    params["blocks"] = blocks
    params["final_norm"] = common.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        if cfg.num_codebooks > 1:
            params["lm_head"] = {"w": jax.random.normal(
                keys[3], (cfg.num_codebooks, cfg.d_model, cfg.vocab_size),
                dtype) / jnp.sqrt(cfg.d_model)}
        else:
            params["lm_head"] = common.linear_init(
                keys[3], cfg.d_model, cfg.vocab_size, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _residual(x, y, params, which, cfg):
    if cfg.post_block_norm:
        y = common.rmsnorm_apply(params[f"{which}_post"], y, cfg.norm_eps)
    return x + y


def block_apply(params, cfg, kind, x, cos, sin, *, mode="train",
                cache=None, cache_len=None):
    """Returns (x, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    h = common.rmsnorm_apply(params["ln1"], x, cfg.norm_eps)
    if kind == "rwkv":
        st = cache or {}
        y, tm_state = rwkv6.time_mix(
            params["rwkv"], cfg, h,
            state=(st.get("tm_shift"), st.get("wkv")) if cache else None,
            mode=mode)
        x = x + y
        h2 = common.rmsnorm_apply(params["rwkv"]["ln_x2"], x, cfg.norm_eps)
        y2, cm_shift = rwkv6.channel_mix(params["rwkv"], cfg, h2,
                                         state=st.get("cm_shift") if cache else None)
        x = x + y2
        new_cache = {"tm_shift": tm_state[0], "wkv": tm_state[1],
                     "cm_shift": cm_shift}
        return x, new_cache, aux

    if kind in ("attn", "local", "global", "dense", "attn_moe"):
        y, new_kv = attention.apply(params["attn"], cfg, h, cos, sin,
                                    kind=kind, mode=mode, cache=cache,
                                    cache_len=cache_len)
        x = _residual(x, y, params, "ln1", cfg)
        new_cache = new_kv
    else:  # mamba family
        y, new_state = mamba.apply(params["mamba"], cfg, h, mode=mode,
                                   state=cache)
        x = _residual(x, y, params, "ln1", cfg)
        new_cache = new_state

    h = common.rmsnorm_apply(params["ln2"], x, cfg.norm_eps)
    if kind.endswith("_moe"):
        y, aux = moe.apply(params["moe"], cfg, h)
    else:
        y = mlp.apply(params["mlp"], h, act=cfg.act, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    x = _residual(x, y, params, "ln2", cfg)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _embed(params, cfg, batch):
    if not cfg.embed_inputs:
        x = batch["embeds"]
    elif cfg.num_codebooks > 1:
        toks = batch["tokens"]                         # (B, S, ncb)
        tbl = params["embed"]["table"]                 # (ncb, V, D)
        x = sum(jnp.take(tbl[c], toks[..., c], axis=0)
                for c in range(cfg.num_codebooks))
    else:
        x = common.embed_apply(params["embed"], batch["tokens"])
    if getattr(cfg, "embed_scale", False):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def _rope(cfg, batch, x):
    if not any(k in ("attn", "local", "global", "dense", "attn_moe")
               for k in cfg.prefix + cfg.pattern):
        return None, None
    b, s = x.shape[:2]
    pos = batch.get("positions")
    if cfg.mrope:
        if pos is None:
            p1 = jnp.broadcast_to(jnp.arange(s)[None, :, None], (b, s, 3))
            pos = p1
        return common.mrope_cos_sin(pos, cfg.head_dim, cfg.rope_theta,
                                    cfg.mrope_sections)
    if pos is None:
        pos = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    return common.rope_cos_sin(pos, cfg.head_dim, cfg.rope_theta)


def forward(params, cfg, batch, *, mode: str = "train",
            cache: Optional[dict] = None, cache_len=None):
    """Returns (hidden (B,S,D), new_cache, aux_loss)."""
    x = _embed(params, cfg, batch).astype(common.dtype_of(cfg))
    cos, sin = _rope(cfg, batch, x)
    aux_total = jnp.zeros((), jnp.float32)

    # --- prefix layers -----------------------------------------------------
    new_prefix_cache = []
    for i, kind in enumerate(cfg.prefix):
        c = cache["prefix"][i] if cache is not None else None
        x, nc, aux = block_apply(params["prefix"][i], cfg, kind, x, cos, sin,
                                 mode=mode, cache=c, cache_len=cache_len)
        new_prefix_cache.append(nc)
        aux_total = aux_total + aux

    # --- scanned pattern ---------------------------------------------------
    def body(carry, xs):
        x, aux_total = carry
        block_params, blk_cache = xs
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            c = blk_cache[f"pos{i}"] if blk_cache is not None else None
            x, nc, aux = block_apply(block_params[f"pos{i}"], cfg, kind, x,
                                     cos, sin, mode=mode, cache=c,
                                     cache_len=cache_len)
            new_cache[f"pos{i}"] = nc if mode != "train" else None
            aux_total = aux_total + aux
        return (x, aux_total), new_cache

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body, prevent_cse=False)

    blk_cache = cache["blocks"] if cache is not None else None
    (x, aux_total), new_blk_cache = jax.lax.scan(
        body, (x, aux_total), (params["blocks"], blk_cache))

    x = common.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    new_cache = ({"prefix": new_prefix_cache, "blocks": new_blk_cache}
                 if (mode != "train") else None)
    return x, new_cache, aux_total


def lm_logits(params, cfg, hidden):
    """hidden (B,S,D) -> logits (B,S,V) or (B,S,ncb,V)."""
    if cfg.num_codebooks > 1:
        w = params["lm_head"]["w"]                     # (ncb, D, V)
        logits = jnp.einsum("bsd,cdv->bscv", hidden, w.astype(hidden.dtype))
    elif cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", hidden,
                            params["embed"]["table"].astype(hidden.dtype))
    else:
        logits = common.linear_apply(params["lm_head"], hidden)
    if cfg.logit_softcap:
        logits = common.softcap(logits, cfg.logit_softcap)
    return logits


# ---------------------------------------------------------------------------
# Cache construction (decode)
# ---------------------------------------------------------------------------

def _block_cache(cfg, kind, batch: int, max_len: int, dtype):
    if kind in ("attn", "local", "global", "dense", "attn_moe"):
        shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
        return (jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))
    if kind in ("mamba", "mamba_moe"):
        return mamba.init_state(cfg, batch, dtype)
    if kind == "rwkv":
        return rwkv6.init_state(cfg, batch, dtype)
    raise ValueError(kind)


def init_cache(cfg, batch: int, max_len: int):
    dtype = common.dtype_of(cfg)
    prefix = [_block_cache(cfg, k, batch, max_len, dtype) for k in cfg.prefix]
    r = cfg.num_pattern_repeats
    blocks = {}
    for i, kind in enumerate(cfg.pattern):
        one = _block_cache(cfg, kind, batch, max_len, dtype)
        blocks[f"pos{i}"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (r,) + a.shape).copy(), one)
    return {"prefix": prefix, "blocks": blocks}

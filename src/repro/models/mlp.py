"""Gated MLP (SwiGLU/GeGLU) with optional BinaryNet quantization."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def init(key, d_model: int, d_ff: int, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": common.linear_init(k1, d_model, d_ff, dtype=dtype),
        "wg": common.linear_init(k2, d_model, d_ff, dtype=dtype),
        "wo": common.linear_init(k3, d_ff, d_model, dtype=dtype),
    }


def apply(params, x: jax.Array, *, act: str = "silu", quant: str = "none",
          bf16_grads: bool = False) -> jax.Array:
    h = common.linear_apply(params["wi"], x, quant=quant, bf16_grads=bf16_grads)
    g = common.linear_apply(params["wg"], x, quant=quant, bf16_grads=bf16_grads)
    h = common.act_fn(act)(g) * h
    return common.linear_apply(params["wo"], h, quant=quant, bf16_grads=bf16_grads)

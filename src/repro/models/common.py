"""Shared model components: norms, RoPE (+M-RoPE), projections, embeddings.

All modules are functional: ``*_init`` returns a param pytree, ``*_apply``
consumes it.  Projections honor ``quant="binary"`` (the paper's technique,
STE fake-quant in the differentiable path) so any architecture can be
binarized by config.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as _np

from repro.core import binarize


# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

def dtype_of(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.zeros((d,), dtype)}   # gemma-style (1 + scale)


def rmsnorm_apply(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Linear (optionally binary)
# ---------------------------------------------------------------------------

def linear_init(key, d_in: int, d_out: int, *, bias: bool = False,
                dtype=jnp.float32):
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) / jnp.sqrt(d_in)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


@jax.custom_vjp
def _matmul_bf16_grads(x, w):
    return jnp.einsum("...k,kn->...n", x, w)


def _mm_bf16_fwd(x, w):
    return _matmul_bf16_grads(x, w), (x, w)


def _mm_bf16_bwd(res, g):
    """Backward with the cotangent forced to bf16 BEFORE the grad matmuls.

    XLA's allow_excess_precision (default on CPU and TPU) elides
    f32->bf16->f32 convert pairs, so without this the entire activation-
    gradient stream — including every TP all-reduce and FSDP
    reduce-scatter on it — runs in f32: 2x the wire bytes and 2x the HBM
    traffic of the bwd pass (measured, EXPERIMENTS.md §Perf).  Casting at
    a dot-input boundary is safe from elision (XLA never changes dot
    operand dtypes); this is Megatron-style bf16 grad collectives.  dw is
    accumulated back to f32 inside the optimizer update."""
    x, w = res
    g16 = g.astype(jnp.bfloat16)
    dx = jnp.einsum("...n,kn->...k", g16, w.astype(jnp.bfloat16))
    # contract leading dims via dot_general WITHOUT reshape — a reshape of
    # the sharded (B,S,d) activation forces an SPMD re-gather (measured:
    # +249 GB all-gather on kimi; the refuted first attempt in §Perf).
    lead = tuple(range(x.ndim - 1))
    dw = jax.lax.dot_general(x.astype(jnp.bfloat16), g16,
                             ((lead, lead), ((), ())))
    return dx.astype(x.dtype), dw.astype(w.dtype)


_matmul_bf16_grads.defvjp(_mm_bf16_fwd, _mm_bf16_bwd)


def linear_apply(params, x: jax.Array, *, quant: str = "none",
                 bf16_grads: bool = False) -> jax.Array:
    w = params["w"]
    if quant == "binary":
        # BinaryNet W1A1 with STE; 1/sqrt(K) keeps activations in range so the
        # surrounding norms play the chip's BN-comparator role.
        xb = binarize.ste_sign(x)
        wb = binarize.ste_sign(w)
        y = jnp.einsum("...k,kn->...n", xb, wb) * (1.0 / jnp.sqrt(x.shape[-1]))
        y = y.astype(x.dtype)
    elif bf16_grads:
        y = _matmul_bf16_grads(x, w.astype(x.dtype))
    else:
        y = jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions: (..., S) int -> cos/sin (..., S, head_dim//2)."""
    freqs = rope_freqs(head_dim, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                  sections) -> tuple:
    """Qwen2-VL multimodal RoPE.

    positions: (B, S, 3) — temporal/height/width position ids.  The head_dim/2
    frequency slots are split into `sections` (t, h, w); each section rotates
    by its own position stream.  Text tokens carry t == h == w, reducing to
    1-D RoPE exactly.
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    freqs = rope_freqs(head_dim, theta)                    # (hd/2,)
    ang_3 = positions[..., None, :].astype(jnp.float32) * freqs[None, None, :, None]
    # ang_3: (B, S, hd/2, 3) -> pick section owner per frequency slot
    # (static section layout -> host-side repeat)
    sec_id = jnp.asarray(_np.repeat(_np.arange(3), _np.asarray(sections)))
    ang = jnp.take_along_axis(
        ang_3, sec_id[None, None, :, None], axis=-1)[..., 0]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (B, S, D/2) -> rotated x (rotate-half form)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(x: jax.Array, cap: float) -> jax.Array:
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed_apply(params, tokens: jax.Array) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]

"""RWKV-6 "Finch" block: data-dependent-decay linear attention (attn-free).

Faithful structure: ddlerp token-shift (LoRA-modulated), a per-channel
data-dependent decay w_t = exp(-exp(w0 + lora(x))), the bonus-u WKV
recurrence with (head, hs, hs) matrix state, per-head group norm, and the
squared-ReLU channel-mix.  The recurrence state is O(H * hs^2) per sequence
— independent of length — which is why rwkv6 runs the `long_500k` shape.

Paper-technique note (DESIGN.md §4): the decay path must stay continuous;
`quant="binary"` binarizes only the r/k/v/g/o and channel-mix projections.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import eff_d_ff
from repro.models import common

_MIX_KEYS = ("w", "k", "v", "r", "g")


def init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    rc = cfg.rwkv
    hs = rc.head_size
    nh = d // hs
    ks = jax.random.split(key, 12)
    u = jnp.zeros((nh, hs), jnp.float32)
    p = {
        # token-shift ddlerp
        "mu_x": jnp.full((d,), 0.5, dtype),
        "mu": {k: jnp.full((d,), 0.5, dtype) for k in _MIX_KEYS},
        "mix_w1": jax.random.normal(ks[0], (d, 5 * rc.mix_lora), dtype) * 0.01,
        "mix_w2": jax.random.normal(ks[1], (5, rc.mix_lora, d), dtype) * 0.01,
        # data-dependent decay
        "w0": jnp.full((d,), -5.0, jnp.float32),
        "w1": jax.random.normal(ks[2], (d, rc.decay_lora), dtype) * 0.01,
        "w2": jax.random.normal(ks[3], (rc.decay_lora, d), dtype) * 0.01,
        "u": u,
        # projections
        "wr": common.linear_init(ks[4], d, d, dtype=dtype),
        "wk": common.linear_init(ks[5], d, d, dtype=dtype),
        "wv": common.linear_init(ks[6], d, d, dtype=dtype),
        "wg": common.linear_init(ks[7], d, d, dtype=dtype),
        "wo": common.linear_init(ks[8], d, d, dtype=dtype),
        "ln_x": common.rmsnorm_init(d, dtype),
        # channel mix (with its own pre-norm; block ln1 covers time-mix)
        "ln_x2": common.rmsnorm_init(d, dtype),
        "cm_mu_k": jnp.full((d,), 0.5, dtype),
        "cm_mu_r": jnp.full((d,), 0.5, dtype),
        "cm_wk": common.linear_init(ks[9], d, eff_d_ff(cfg), dtype=dtype),
        "cm_wv": common.linear_init(ks[10], eff_d_ff(cfg), d, dtype=dtype),
        "cm_wr": common.linear_init(ks[11], d, d, dtype=dtype),
    }
    return p


def _shifted(x, shift_state):
    """Previous-token stream. shift_state: (B,1,d) last token of prior chunk."""
    if shift_state is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = shift_state.astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _wkv_chunked(rh, kh, vh, wh, u, S0, chunk: int, sub_chunk: int = 16):
    """GLA-style chunked WKV: identical math to the per-token scan, but the
    (B,H,hs,hs) state round-trips HBM once per CHUNK instead of once per
    token, and the chunk-crossing terms run as (C,C) masked matmuls on the
    MXU.

    Derivation (per channel i, decay applied to history at step t):
        S_t = diag(w_t) S_{t-1} + k_t (x) v_t
        y_t = r_t . S_{t-1} + (r_t*u*k_t).sum v_t
    With P_t = prod_{s<=t} w_s (la = cumsum log w), r~_t = r_t * P_{t-1}:
        y      = r~ @ S_in + intra-chunk causal term + bonus-diag
        S_out  = P_last o S_in + sum_s exp(la_last - la_s) k_s (x) v_s
    The intra-chunk pair (t, s<t) needs exp(la_{t-1} - la_s) per channel.
    The naive factored form r~ @ (k exp(-la))^T overflows fp32 once a
    channel decays past e^-88 within a chunk (the seed clamped la at -20,
    which made strongly-decayed channels *wrong*, not just clamped).  The
    FLA-style fix: split the chunk into sub-chunks of ``sub_chunk`` and
    *rebase* the factored exponents at each target sub-chunk's entry
    decay E_i = la at its first step:

        exp(la_{t-1} - la_s) = exp(la_{t-1} - E_i) * exp(E_i - la_s)

    For any source s *before* sub-chunk i both factors are <= 1 (la is
    non-increasing), so cross-sub-chunk scores run as plain (c, C)
    matmuls with no overflow and no clamp; only pairs *inside* a
    sub-chunk form the pairwise exponent exactly, materializing a
    (c, c, hs) decay tensor instead of the old (C, C, hs) — a
    ``chunk/sub_chunk`` memory reduction at identical accuracy (matches
    the per-token scan on any decay range, tests/test_rwkv_chunked.py).
    A ``sub_chunk`` that does not divide ``chunk`` falls back to one
    exact sub-chunk spanning the whole chunk.
    """
    b, s, nh, hs = rh.shape
    n = s // chunk
    sub = sub_chunk if (sub_chunk and chunk % sub_chunk == 0) else chunk
    m = chunk // sub
    # (n, B, H, C, hs) chunk-major layout
    def chunked(t):
        return t.reshape(b, n, chunk, nh, hs).transpose(1, 0, 3, 2, 4)
    rc_, kc, vc, wc = chunked(rh), chunked(kh), chunked(vh), chunked(wh)

    # wc = exp(-exp(wraw)) in (0,1); log w <= 0, floored against log(0)
    logw = jnp.log(jnp.maximum(wc, 1e-30))                 # (n,B,H,C,hs) <= 0
    la = jnp.cumsum(logw, axis=3)                          # cumulative decay
    la_prev = jnp.concatenate([jnp.zeros_like(la[..., :1, :]),
                               la[..., :-1, :]], axis=3)   # la_{t-1}
    r_tld = rc_ * jnp.exp(la_prev)                         # r~ (factors <= 1)
    k_out = kc * jnp.exp(la[..., -1:, :] - la)             # for S_out (<=1)
    p_last = jnp.exp(la[..., -1, :])                       # (n,B,H,hs)

    sub_mask = jnp.tril(jnp.ones((sub, sub), jnp.bool_), -1)
    # cross mask: target sub-chunk i sees sources strictly before its entry
    cross_mask = (jnp.arange(chunk)[None, :]
                  < (jnp.arange(m) * sub)[:, None]).astype(rh.dtype)

    def body(S, inp):
        r_t, v_t, k_o, p_l, r_raw, k_raw, la_c, la_p = inp
        bb, hh = r_raw.shape[0], r_raw.shape[1]
        y_state = jnp.einsum("bhci,bhij->bhcj", r_t, S)

        def subs(t):                                   # (B,H,m,c,hs)
            return t.reshape(bb, hh, m, sub, hs)
        rr, kr, vr = subs(r_raw), subs(k_raw), subs(v_t)
        la_r, la_pr = subs(la_c), subs(la_p)
        # exact per-pair decay inside each sub-chunk: exponent always <= 0
        diff = la_pr[..., :, None, :] - la_r[..., None, :, :]  # (B,H,m,c,c,hs)
        decay = jnp.exp(jnp.where(sub_mask[None, None, None, :, :, None],
                                  diff, -jnp.inf))
        scores_d = jnp.einsum("bhmti,bhmtsi,bhmsi->bhmts", rr, decay, kr)
        y_intra = jnp.einsum("bhmts,bhmsj->bhmtj", scores_d, vr)
        if m > 1:
            # cross-sub-chunk pairs: rebase at the target sub-chunk entry
            # E_i; both factors <= 1 for every *used* (masked-in) pair, so
            # the scores are plain matmuls (the minimum() only clamps
            # masked-out columns, where la may exceed E_i).
            e_i = la_pr[..., :, 0, :]                      # (B,H,m,hs)
            r_reb = rr * jnp.exp(la_pr - e_i[..., :, None, :])
            k_reb = k_raw[:, :, None, :, :] * jnp.exp(jnp.minimum(
                e_i[..., :, None, :] - la_c[..., None, :, :], 0.0))
            scores_x = jnp.einsum("bhmti,bhmsi->bhmts", r_reb, k_reb)
            scores_x = scores_x * cross_mask[None, None, :, None, :]
            y_intra = y_intra + jnp.einsum("bhmts,bhsj->bhmtj",
                                           scores_x, v_t)
        y_intra = y_intra.reshape(bb, hh, chunk, hs)
        y_bonus = jnp.einsum("bhci,bhci->bhc", r_raw * u[None, :, None, :],
                             k_raw)[..., None] * v_t
        S = p_l[..., :, None] * S + jnp.einsum("bhci,bhcj->bhij", k_o, v_t)
        return S, y_state + y_intra + y_bonus

    S, ys = jax.lax.scan(body, S0, (r_tld, vc, k_out, p_last,
                                    rc_, kc, la, la_prev))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, nh, hs)  # (B,S,H,hs)
    return S, y


def time_mix(params, cfg, x, *, state=None, mode="train"):
    """x: (B,S,d). state=(shift (B,1,d), wkv (B,H,hs,hs)). -> (y, state)."""
    b, s, d = x.shape
    rc = cfg.rwkv
    hs = rc.head_size
    nh = d // hs
    shift0 = state[0] if state is not None else None
    xs = _shifted(x, shift0)
    dx = xs - x
    xxx = x + dx * params["mu_x"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("bsd,dm->bsm", xxx, params["mix_w1"].astype(x.dtype)))
    lora = lora.reshape(b, s, 5, rc.mix_lora)
    mods = jnp.einsum("bsfm,fmd->bsfd", lora, params["mix_w2"].astype(x.dtype))
    feeds = {k: x + dx * (params["mu"][k].astype(x.dtype) + mods[:, :, i])
             for i, k in enumerate(_MIX_KEYS)}

    decay_in = jnp.tanh(jnp.einsum("bsd,dm->bsm", feeds["w"],
                                   params["w1"].astype(x.dtype)))
    wraw = params["w0"] + jnp.einsum("bsm,md->bsd", decay_in,
                                     params["w2"].astype(x.dtype)).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wraw))                            # (B,S,d) in (0,1)

    r = common.linear_apply(params["wr"], feeds["r"], quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    k = common.linear_apply(params["wk"], feeds["k"], quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    v = common.linear_apply(params["wv"], feeds["v"], quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    g = jax.nn.silu(common.linear_apply(params["wg"], feeds["g"], quant=cfg.quant, bf16_grads=cfg.bf16_grads))

    rh = r.reshape(b, s, nh, hs).astype(jnp.float32)
    kh = k.reshape(b, s, nh, hs).astype(jnp.float32)
    vh = v.reshape(b, s, nh, hs).astype(jnp.float32)
    wh = w.reshape(b, s, nh, hs)
    u = params["u"]                                        # (H, hs)

    def step(S, inp):
        rt, kt, vt, wt = inp                               # (B,H,hs) each
        kv = kt[..., :, None] * vt[..., None, :]           # (B,H,hs,hs)
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y

    S0 = (state[1] if state is not None
          else jnp.zeros((b, nh, hs, hs), jnp.float32))
    chunk = rc.chunk
    if s == 1 and mode == "decode":
        S, y = step(S0, (rh[:, 0], kh[:, 0], vh[:, 0], wh[:, 0]))
        y = y[:, None]
    elif chunk and s % chunk == 0:
        S, y = _wkv_chunked(rh, kh, vh, wh, u, S0, chunk,
                            sub_chunk=getattr(rc, "sub_chunk", 16))
    else:
        S, ys = jax.lax.scan(
            step, S0, (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
                       vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3)),
            unroll=rc.scan_unroll)
        y = ys.transpose(1, 0, 2, 3)                       # (B,S,H,hs)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = common.rmsnorm_apply(params["ln_x"], y, cfg.norm_eps) * g
    out = common.linear_apply(params["wo"], y, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    new_state = (x[:, -1:], S)
    return out, new_state


def channel_mix(params, cfg, x, *, state=None):
    """Squared-ReLU channel mix. state: (B,1,d) shift."""
    xs = _shifted(x, state)
    dx = xs - x
    xk = x + dx * params["cm_mu_k"].astype(x.dtype)
    xr = x + dx * params["cm_mu_r"].astype(x.dtype)
    k = common.linear_apply(params["cm_wk"], xk, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    k = jnp.square(jax.nn.relu(k))
    kv = common.linear_apply(params["cm_wv"], k, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    gate = jax.nn.sigmoid(common.linear_apply(params["cm_wr"], xr, quant=cfg.quant, bf16_grads=cfg.bf16_grads))
    return gate * kv, x[:, -1:]


def init_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    rc = cfg.rwkv
    nh = d // rc.head_size
    return {
        "tm_shift": jnp.zeros((batch, 1, d), dtype),
        "wkv": jnp.zeros((batch, nh, rc.head_size, rc.head_size), jnp.float32),
        "cm_shift": jnp.zeros((batch, 1, d), dtype),
    }

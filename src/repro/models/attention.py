"""GQA attention: chunked (flash-style) causal/sliding attention + decode.

Prefill/train uses an online-softmax over KV chunks with a *static* chunk
schedule: query chunk ``i`` only visits the KV chunks its causal/window
horizon allows, so the compiled HLO does no masked-out matmul work (this is
what keeps the compute roofline term honest at 32k).

Decode attends a single new query against the cache (no chunking needed —
the score tensor is (B, H, S) only).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models import common

NEG_INF = -2.3819763e38  # bf16-safe large negative


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init(key, cfg, dtype=jnp.float32):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.linear_init(ks[0], d, h * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wk": common.linear_init(ks[1], d, kv * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wv": common.linear_init(ks[2], d, kv * dh, bias=cfg.qkv_bias, dtype=dtype),
        "wo": common.linear_init(ks[3], h * dh, d, dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.rmsnorm_init(dh, dtype)
        p["k_norm"] = common.rmsnorm_init(dh, dtype)
    return p


# ---------------------------------------------------------------------------
# Core chunked attention
# ---------------------------------------------------------------------------

def _attend_chunk(q, k, v, softcap, scale, *, q0=0, k0=0, causal=False,
                  window=None, k_valid=None, score_dtype=jnp.float32):
    """q: (B,cq,H,D) k/v: (B,ck,KH,D) -> scores (B,cq,KH,G,ck).

    Masks are built from broadcasted iotas fused into the select — a
    materialized (cq,ck) pred array would otherwise be hoisted into the
    layer-scan carry and charged S^2 bytes per layer (seen in the smollm
    §Perf profile).

    ``score_dtype=bf16`` keeps the whole S^2-sized chain (scores, exp'd
    probs and their autodiff mirrors) in bf16 — the dominant memory-
    roofline term at 4k+.  Softmax is still max-subtracted, so bf16's
    8-bit mantissa only quantizes the probabilities (~fp8-attention
    numerics; validated in tests/test_attention.py)."""
    b, cq, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, cq, kh, g, d)
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k,
                   preferred_element_type=score_dtype) * jnp.asarray(
                       scale, score_dtype)
    # NOTE: score_dtype=bf16 measured WORSE on the XLA-CPU lowering (extra
    # convert materialization at fusion boundaries) — kept for the TPU
    # path experiments; default f32.
    if softcap is not None:
        s = common.softcap(s, softcap)
    if causal or window is not None or k_valid is not None:
        qi = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + q0
        ki = jax.lax.broadcasted_iota(jnp.int32, s.shape, 4) + k0
        ok = None
        if causal:
            ok = ki <= qi
        if window is not None:
            w_ok = ki > qi - window
            ok = w_ok if ok is None else ok & w_ok
        if k_valid is not None:
            v_ok = ki < k_valid
            ok = v_ok if ok is None else ok & v_ok
        s = jnp.where(ok, s, NEG_INF)
    return s  # (B, cq, KH, G, ck)


def chunked_attention(q, k, v, *, causal: bool = True,
                      window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      chunk_q: int = 1024, chunk_k: int = 1024,
                      scale: Optional[float] = None,
                      probs_bf16: bool = False):
    """q: (B,S,H,D), k/v: (B,S,KH,D) -> (B,S,H,D).  Causal within the same
    sequence (q and k aligned at position 0).

    ``probs_bf16`` stores the exp'd probabilities in bf16 for the p@v
    matmul (running max/denominator stay f32) — halves the S^2 HBM term,
    the dominant memory-roofline cost at 4k+ (§Perf)."""
    b, s, h, d = q.shape
    kh = k.shape[2]
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    cq = min(chunk_q, s)
    ck = min(chunk_k, s)
    # pad S to chunk multiples
    sp = (-s) % cq
    if sp:
        q = jnp.pad(q, ((0, 0), (0, sp), (0, 0), (0, 0)))
    skp = (-k.shape[1]) % ck
    if skp:
        k = jnp.pad(k, ((0, 0), (0, skp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skp), (0, 0), (0, 0)))
    nq, nk = q.shape[1] // cq, k.shape[1] // ck
    g = h // kh

    outs = []
    for i in range(nq):
        qi = q[:, i * cq:(i + 1) * cq]
        q_lo, q_hi = i * cq, i * cq + cq - 1
        # static KV chunk range this query chunk can see
        j_hi = min(nk - 1, q_hi // ck) if causal else nk - 1
        j_lo = 0
        if window is not None:
            j_lo = max(0, (q_lo - window) // ck)
        acc = jnp.zeros((b, cq, kh, g, d), jnp.float32)
        m = jnp.full((b, cq, kh, g), NEG_INF, jnp.float32)
        l = jnp.zeros((b, cq, kh, g), jnp.float32)
        for j in range(j_lo, j_hi + 1):
            kj = k[:, j * ck:(j + 1) * ck]
            vj = v[:, j * ck:(j + 1) * ck]
            need_mask = (causal and j * ck + ck - 1 > q_lo) or \
                        (window is not None and j * ck < q_lo - window + cq) or \
                        (sp and i == nq - 1) or (skp and j == nk - 1)
            sc = _attend_chunk(
                qi, kj, vj, softcap, scale, q0=q_lo, k0=j * ck,
                causal=causal and need_mask,
                window=window if need_mask else None,
                k_valid=(k.shape[1] - skp) if (need_mask and skp
                                               and j == nk - 1) else None)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            if probs_bf16:
                # measured-best variant (§Perf): probs cast to bf16 for the
                # p@v matmul only.  An all-bf16 score chain measured WORSE
                # on the XLA-CPU lowering (extra convert materialization);
                # the full fix is the Pallas flash kernel (TPU path).
                pv = jnp.einsum("bqhgk,bkhd->bqhgd", p.astype(jnp.bfloat16),
                                vj.astype(jnp.bfloat16),
                                preferred_element_type=jnp.float32)
            else:
                pv = jnp.einsum("bqhgk,bkhd->bqhgd", p,
                                vj.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            l = l * alpha + p.sum(axis=-1)
            m = m_new
        out = acc / jnp.maximum(l[..., None], 1e-37)
        outs.append(out.reshape(b, cq, h, d))
    out = jnp.concatenate(outs, axis=1)[:, :s]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None,
                     scale: Optional[float] = None):
    """q: (B,1,H,D); caches: (B,L,KH,D); cache_len: scalar count of valid
    positions INCLUDING the token at cache_len-1 (the one just written)."""
    b, _, h, d = q.shape
    kh = k_cache.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, kh, g, d)
    # split-K decode: cache is sequence-sharded over the model axis; scores
    # stay L-sharded, softmax/psum handled by SPMD (FlashDecoding layout).
    s = jnp.einsum("bhgd,blhd->bhgl", qg, k_cache).astype(jnp.float32) * scale
    s = shd.constrain(s, ("dp", None, None, "sp"))
    if softcap is not None:
        s = common.softcap(s, softcap)
    lpos = jnp.arange(k_cache.shape[1])
    mask = lpos < cache_len
    if window is not None:
        mask &= lpos > cache_len - 1 - window
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgl,blhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full block-level apply
# ---------------------------------------------------------------------------

def apply(params, cfg, x, cos, sin, *, kind: str = "attn",
          mode: str = "train", cache=None, cache_len=None,
          chunk_q: int = 1024, chunk_k: int = 1024):
    """Returns (y, new_kv) — new_kv is (k, v) for cache building/updating."""
    b, s, d = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    quant = cfg.quant
    bfg = cfg.bf16_grads
    q = common.linear_apply(params["wq"], x, quant=quant,
                            bf16_grads=bfg).reshape(b, s, h, dh)
    k = common.linear_apply(params["wk"], x, quant=quant,
                            bf16_grads=bfg).reshape(b, s, kv, dh)
    v = common.linear_apply(params["wv"], x, quant=quant,
                            bf16_grads=bfg).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = common.rmsnorm_apply(params["q_norm"], q, cfg.norm_eps)
        k = common.rmsnorm_apply(params["k_norm"], k, cfg.norm_eps)
    q = common.apply_rope(q, cos, sin)
    k = common.apply_rope(k, cos, sin)

    window = cfg.sliding_window if kind == "local" else None
    if mode in ("train", "prefill"):
        y = chunked_attention(q, k, v, causal=True, window=window,
                              softcap=cfg.attn_softcap,
                              chunk_q=chunk_q, chunk_k=chunk_k,
                              probs_bf16=cfg.attn_probs_bf16)
        if mode == "prefill":  # cache leaves are sequence-sharded
            k = shd.constrain(k, ("dp", "sp", None, None))
            v = shd.constrain(v, ("dp", "sp", None, None))
        new_kv = (k, v)
    else:  # decode: write (k, v) at cache_len-? position = cache_len
        kc, vc = cache
        idx = cache_len  # position of the new token
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, idx, 0, 0))
        kc = shd.constrain(kc, ("dp", "sp", None, None))
        vc = shd.constrain(vc, ("dp", "sp", None, None))
        y = decode_attention(q, kc, vc, idx + 1, window=window,
                             softcap=cfg.attn_softcap)
        new_kv = (kc, vc)
    y = y.reshape(b, s, h * dh)
    return common.linear_apply(params["wo"], y, quant=quant,
                               bf16_grads=bfg), new_kv

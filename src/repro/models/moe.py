"""Mixture-of-Experts: top-k router + two execution paths.

* ``dense`` — every expert on every token, combined by router weights.
  Exact (no capacity drops); O(E/k) overcompute.  Reference/oracle path and
  the default for tiny smoke configs.
* ``ep`` — expert parallelism over the mesh ``model`` axis via shard_map:
  sort-based capacity dispatch -> all_to_all -> grouped per-expert matmul ->
  all_to_all return -> weighted combine.  This is the DeepSeek/GShard-style
  schedule adapted to TPU ICI: the dispatch buffers are the dominant
  collective bytes at large E (visible in the roofline's all-to-all term).

Sequence enters sequence-sharded over the model axis (SP), so each device
dispatches only its local tokens — dispatch traffic per device is
T_local * k * d_model, independent of the expert count.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import context as dctx
from repro.models import common


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init(key, cfg, dtype=jnp.float32):
    from repro.configs.base import eff_d_expert
    m = cfg.moe
    d = cfg.d_model
    fe = eff_d_expert(cfg)
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": jax.random.normal(ks[0], (d, m.num_experts), jnp.float32) * scale,
        "wi": jax.random.normal(ks[1], (m.num_experts, d, fe), dtype) * scale,
        "wg": jax.random.normal(ks[2], (m.num_experts, d, fe), dtype) * scale,
        "wo": jax.random.normal(ks[3], (m.num_experts, fe, d), dtype)
              / jnp.sqrt(fe),
    }
    if m.num_shared_experts:
        fs = fe * m.num_shared_experts
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": common.linear_init(k1, d, fs, dtype=dtype),
            "wg": common.linear_init(k2, d, fs, dtype=dtype),
            "wo": common.linear_init(k3, fs, d, dtype=dtype),
        }
    return p


def _route(x2d, router_w, m):
    """x2d: (T, D) -> gates (T, k), sel (T, k), aux_loss (scalar, f32)."""
    logits = (x2d.astype(jnp.float32) @ router_w)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, sel = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss + router z-loss
    me = probs.mean(axis=0)
    onehot = jax.nn.one_hot(sel, m.num_experts, dtype=jnp.float32).sum(axis=1)
    ce = onehot.mean(axis=0) / m.top_k
    lb = m.num_experts * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return gates, sel, m.router_aux_weight * lb + 1e-4 * z


def _expert_ffn(h_tokens, wi, wg, wo, act):
    """h_tokens: (E, C, D); w*: (E, D, F)/(E, F, D) -> (E, C, D)."""
    hi = jnp.einsum("ecd,edf->ecf", h_tokens, wi)
    hg = jnp.einsum("ecd,edf->ecf", h_tokens, wg)
    h = common.act_fn(act)(hg) * hi
    return jnp.einsum("ecf,efd->ecd", h, wo)


# ---------------------------------------------------------------------------
# Dense path (reference)
# ---------------------------------------------------------------------------

def apply_dense(params, cfg, x):
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    gates, sel, aux = _route(xf, params["router"], m)
    hi = jnp.einsum("td,edf->tef", xf, params["wi"].astype(x.dtype))
    hg = jnp.einsum("td,edf->tef", xf, params["wg"].astype(x.dtype))
    h = common.act_fn(cfg.act)(hg) * hi
    y_all = jnp.einsum("tef,efd->ted", h, params["wo"].astype(x.dtype))
    mask = jax.nn.one_hot(sel, m.num_experts, dtype=jnp.float32)  # (T,k,E)
    comb = jnp.einsum("tk,tke->te", gates, mask).astype(x.dtype)
    y = jnp.einsum("te,ted->td", comb, y_all)
    y = y + _shared(params, cfg, xf)
    return y.reshape(b, s, d), aux


def _shared(params, cfg, xf):
    if "shared" not in params:
        return 0.0
    h = common.linear_apply(params["shared"]["wi"], xf, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    g = common.linear_apply(params["shared"]["wg"], xf, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    return common.linear_apply(params["shared"]["wo"],
                               common.act_fn(cfg.act)(g) * h, quant=cfg.quant, bf16_grads=cfg.bf16_grads)


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map + all_to_all)
# ---------------------------------------------------------------------------

def _ep_local(xf, router_w, wi, wg, wo, *, cfg, n_shards, ep_axis):
    """Per-device body. xf: (T_loc, D); wi/wg/wo: local (E_loc, ...) shards."""
    m = cfg.moe
    t, d = xf.shape
    e, k = m.num_experts, m.top_k
    e_loc = e // n_shards
    cap = int(-(-t * k * m.capacity_factor // e))  # per (device, expert)

    gates, sel, aux = _route(xf, router_w, m)
    fe = sel.reshape(-1)                               # (T*k,) expert ids
    ft = jnp.arange(t * k) // k                        # token ids
    fg = gates.reshape(-1)
    order = jnp.argsort(fe)                            # stable
    fe_s, ft_s, fg_s = fe[order], ft[order], fg[order]
    counts = jnp.bincount(fe, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[fe_s]
    valid = pos < cap
    slot = jnp.where(valid, fe_s * cap + pos, e * cap)  # sentinel drops
    buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[slot].set(xf[ft_s])[:-1]

    # dispatch: rows e_loc*j .. e_loc*(j+1) go to shard j
    buf = buf.reshape(n_shards, e_loc * cap, d)
    if m.dispatch_fp8:
        # DeepSeek-V3-style fp8 dispatch: halves the dominant a2a wire term;
        # post-norm activations are O(1) so e4m3's +-448 range is ample.
        recv = jax.lax.all_to_all(buf.astype(jnp.float8_e4m3fn), ep_axis,
                                  split_axis=0, concat_axis=0,
                                  tiled=True).astype(xf.dtype)
    else:
        recv = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                                  tiled=True)
    tok = recv.reshape(n_shards, e_loc, cap, d).transpose(1, 0, 2, 3)
    tok = tok.reshape(e_loc, n_shards * cap, d)
    y = _expert_ffn(tok, wi.astype(xf.dtype), wg.astype(xf.dtype),
                    wo.astype(xf.dtype), cfg.act)
    y = y.reshape(e_loc, n_shards, cap, d).transpose(1, 0, 2, 3)
    y = y.reshape(n_shards, e_loc * cap, d)
    back = jax.lax.all_to_all(y, ep_axis, split_axis=0, concat_axis=0,
                              tiled=True).reshape(e * cap, d)

    gathered = back[jnp.minimum(slot, e * cap - 1)]    # (T*k, D)
    w = (fg_s * valid).astype(xf.dtype)[:, None]
    out = jnp.zeros((t, d), xf.dtype).at[ft_s].add(gathered * w)
    out = out + _shared_local(xf, cfg)
    return out, jax.lax.pmean(aux, ep_axis)


def _shared_local(xf, cfg):
    return 0.0  # shared experts are handled outside the shard_map (TP path)


def apply_ep(params, cfg, x, mesh):
    """x: (B, S, D) batch-sharded + seq-sharded over 'model' (SP)."""
    m = cfg.moe
    b, s, d = x.shape
    dp = dctx.data_axes(mesh)
    n_shards = mesh.shape["model"]
    assert m.num_experts % n_shards == 0, (m.num_experts, n_shards)

    def body(xloc, router_w, wi, wg, wo):
        bl, sl, _ = xloc.shape
        out, aux = _ep_local(xloc.reshape(-1, d), router_w, wi, wg, wo,
                             cfg=cfg, n_shards=n_shards, ep_axis="model")
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(bl, sl, d), aux

    out, aux = dctx.shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, "model", None), P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(dp, "model", None), P()),
    )(x, params["router"], params["wi"], params["wg"], params["wo"])
    if "shared" in params:
        xf = x.reshape(-1, d)
        out = out + _shared(params, cfg, xf).reshape(b, s, d)
    return out, aux


# ---------------------------------------------------------------------------
# Decode path: tokens are few (B x 1) — replicate tokens over the model axis,
# each shard runs its local experts on the tokens routed to them, psum.
# No all_to_all: dispatch traffic is just the output psum (B x D per layer).
# ---------------------------------------------------------------------------

def _ep_decode_local(xf, router_w, wi, wg, wo, *, cfg, n_shards, ep_axis):
    m = cfg.moe
    t, d = xf.shape
    e, k = m.num_experts, m.top_k
    e_loc = e // n_shards
    shard = jax.lax.axis_index(ep_axis)
    e_off = shard * e_loc
    cap = max(1, int(-(-t * k * max(m.capacity_factor, 4.0) // e)))

    gates, sel, aux = _route(xf, router_w, m)
    fe = sel.reshape(-1) - e_off                      # local expert ids
    ft = jnp.arange(t * k) // k
    fg = gates.reshape(-1)
    local = (fe >= 0) & (fe < e_loc)
    fe_key = jnp.where(local, fe, e_loc)              # sentinel bucket
    order = jnp.argsort(fe_key)
    fe_s, ft_s, fg_s, loc_s = (fe_key[order], ft[order], fg[order], local[order])
    counts = jnp.bincount(fe_key, length=e_loc + 1)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[fe_s]
    valid = loc_s & (pos < cap)
    slot = jnp.where(valid, fe_s * cap + pos, e_loc * cap)
    buf = jnp.zeros((e_loc * cap + 1, d), xf.dtype).at[slot].set(xf[ft_s])[:-1]
    y = _expert_ffn(buf.reshape(e_loc, cap, d), wi.astype(xf.dtype),
                    wg.astype(xf.dtype), wo.astype(xf.dtype), cfg.act)
    y = y.reshape(e_loc * cap, d)
    gathered = y[jnp.minimum(slot, e_loc * cap - 1)]
    w = (fg_s * valid).astype(xf.dtype)[:, None]
    out = jnp.zeros((t, d), xf.dtype).at[ft_s].add(gathered * w)
    out = jax.lax.psum(out, ep_axis)
    return out, jax.lax.pmean(aux, ep_axis)


def apply_ep_decode(params, cfg, x, mesh):
    m = cfg.moe
    b, s, d = x.shape
    dp = dctx.data_axes(mesh)
    n_shards = mesh.shape["model"]
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    bspec = dp if b % dp_size == 0 else None

    def body(xloc, router_w, wi, wg, wo):
        bl, sl, _ = xloc.shape
        out, aux = _ep_decode_local(xloc.reshape(-1, d), router_w, wi, wg, wo,
                                    cfg=cfg, n_shards=n_shards, ep_axis="model")
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)
        return out.reshape(bl, sl, d), aux

    out, aux = dctx.shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(bspec, None, None), P()),
    )(x, params["router"], params["wi"], params["wg"], params["wo"])
    if "shared" in params:
        out = out + _shared(params, cfg, x.reshape(-1, d)).reshape(b, s, d)
    return out, aux


def apply(params, cfg, x):
    """Dispatch on impl + ambient mesh + shape."""
    m = cfg.moe
    mesh = dctx.current_mesh()
    impl = m.impl
    n = dctx.model_axis_size(mesh)
    ep_ok = (mesh is not None and n > 1 and m.num_experts % n == 0
             and m.num_experts >= n)
    if impl == "auto":
        impl = "ep" if ep_ok else "dense"
    if impl == "ep" and ep_ok:
        dp_size = 1
        for a in dctx.data_axes(mesh):
            dp_size *= mesh.shape[a]
        if (x.shape[1] % n == 0 and x.shape[1] >= n
                and x.shape[0] % dp_size == 0):
            return apply_ep(params, cfg, x, mesh)
        return apply_ep_decode(params, cfg, x, mesh)
    return apply_dense(params, cfg, x)

"""Mamba (S6) block for the Jamba hybrid — selective state-space mixer.

Projections/conv are computed for the whole sequence in parallel; only the
(B, d_inner, d_state) recurrence runs under ``lax.scan``.  The per-step
state is tiny, so the scan is memory-light even at 500k tokens — this is
what makes the hybrid's `long_500k` shape feasible where full attention is
not.  Decode carries (conv_state, ssm_state) explicitly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def _dims(cfg):
    mc = cfg.mamba
    d_inner = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, mc.d_state, mc.d_conv


def init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    di, dtr, ds, dc = _dims(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": common.linear_init(ks[0], d, 2 * di, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (dc, di), dtype) / jnp.sqrt(dc),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": common.linear_init(ks[2], di, dtr + 2 * ds, dtype=dtype),
        "dt_proj": {"w": jax.random.normal(ks[3], (dtr, di), dtype) / jnp.sqrt(dtr),
                    "b": jnp.log(jnp.expm1(0.01)) * jnp.ones((di,), dtype)},
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": common.linear_init(ks[4], di, d, dtype=dtype),
        "dt_norm": common.rmsnorm_init(dtr, dtype),   # Jamba's extra norms
        "b_norm": common.rmsnorm_init(ds, dtype),
        "c_norm": common.rmsnorm_init(ds, dtype),
    }
    return p


def _causal_conv(x, w, b, state=None):
    """x: (B,S,di); w: (dc,di) depthwise causal. state: (B,dc-1,di) or None."""
    dc = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(dc))
    new_state = xp[:, -(dc - 1):, :] if dc > 1 else None
    return out + b, new_state


def _ssm_inputs(params, cfg, xc):
    """Shared projections: xc (B,S,di) -> dt (B,S,di), B/C (B,S,ds)."""
    di, dtr, ds, _ = _dims(cfg)
    proj = common.linear_apply(params["x_proj"], xc, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    dt, Bm, Cm = jnp.split(proj, [dtr, dtr + ds], axis=-1)
    dt = common.rmsnorm_apply(params["dt_norm"], dt, cfg.norm_eps)
    Bm = common.rmsnorm_apply(params["b_norm"], Bm, cfg.norm_eps)
    Cm = common.rmsnorm_apply(params["c_norm"], Cm, cfg.norm_eps)
    dt = jnp.einsum("...r,rd->...d", dt, params["dt_proj"]["w"].astype(dt.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_proj"]["b"].astype(jnp.float32))
    return dt, Bm.astype(jnp.float32), Cm.astype(jnp.float32)


def apply(params, cfg, x, *, mode="train", state=None):
    """x: (B,S,d_model). Returns (y, new_state); state = (conv, ssm)."""
    b, s, d = x.shape
    di, dtr, ds, dc = _dims(cfg)
    xz = common.linear_apply(params["in_proj"], x, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = state[0] if state is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"].astype(x.dtype),
                                params["conv_b"].astype(x.dtype), conv_state)
    xc = jax.nn.silu(xc)
    dt, Bm, Cm = _ssm_inputs(params, cfg, xc)
    A = -jnp.exp(params["A_log"])                       # (di, ds)
    xf = xc.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                           # (B,di),(B,di),(B,ds),(B,ds)
        dA = jnp.exp(dtt[:, :, None] * A[None])         # (B,di,ds)
        dBx = (dtt * xt)[:, :, None] * bt[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    h0 = state[1] if state is not None else jnp.zeros((b, di, ds), jnp.float32)
    if s == 1 and mode == "decode":
        h, y = step(h0, (xf[:, 0], dt[:, 0], Bm[:, 0], Cm[:, 0]))
        y = y[:, None]
    else:
        h, ys = jax.lax.scan(
            step, h0,
            (xf.transpose(1, 0, 2), dt.transpose(1, 0, 2),
             Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2)),
            unroll=cfg.mamba.scan_unroll)
        y = ys.transpose(1, 0, 2)
    y = y + xf * params["D"][None, None, :]
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = common.linear_apply(params["out_proj"], y, quant=cfg.quant, bf16_grads=cfg.bf16_grads)
    new_state = (new_conv, h)
    return out, new_state


def init_state(cfg, batch: int, dtype=jnp.float32):
    di, dtr, ds, dc = _dims(cfg)
    return (jnp.zeros((batch, dc - 1, di), dtype),
            jnp.zeros((batch, di, ds), jnp.float32))

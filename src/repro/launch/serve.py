"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Static-batch continuous serving: a pool of B slots, each holding one
request; prefill fills a slot's cache, decode advances every live slot
one token per step, finished slots are refilled from the queue (standard
static batching — the chip-tier analogue is the always-on detector
example's window stream).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.data import tokens as dtok
from repro.models import transformer
from repro.train import serve, steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--scaled", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4, help="slot count")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = cfg.scaled().with_(dtype="float32", param_dtype="float32")
    if not cfg.embed_inputs or cfg.num_codebooks > 1:
        print(f"note: {args.arch} uses a modality stub; serving token IDs")

    max_len = args.prompt_len + args.gen_len
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(serve.build_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(serve.build_decode_step(cfg))

    # request queue: deterministic synthetic prompts
    def prompt(rid):
        b = dtok.batch_for_step(cfg, rid, global_batch=1,
                                seq_len=args.prompt_len)
        return b["tokens"]

    served = 0
    t0 = time.time()
    key = jax.random.PRNGKey(42)
    while served < args.requests:
        n = min(args.batch, args.requests - served)
        toks = jnp.concatenate([prompt(served + i) for i in range(n)])
        pos = jnp.broadcast_to(jnp.arange(args.prompt_len)[None], toks.shape[:2])
        logits, cache = prefill(params, {"tokens": toks, "positions": pos})
        cur = serve.sample(key, logits, args.temperature)
        outs = [cur]
        for t in range(args.gen_len - 1):
            key, sk = jax.random.split(key)
            logits, cache = decode(params, cache, cur,
                                   jnp.asarray(args.prompt_len + t, jnp.int32))
            cur = serve.sample(sk, logits, args.temperature)
            outs.append(cur)
        gen = jnp.concatenate(outs, axis=1)
        for i in range(n):
            ids = gen[i].reshape(-1)[: args.gen_len]
            print(f"req {served + i}: {[int(x) for x in ids][:12]}...")
        served += n
    dt = time.time() - t0
    print(f"\n{served} requests, {served * args.gen_len} tokens in {dt:.1f}s "
          f"({served * args.gen_len / dt:.1f} tok/s host-sim)")


if __name__ == "__main__":
    main()

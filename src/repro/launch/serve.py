"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Static-batch continuous serving: a pool of B slots, each holding one
request; prefill fills a slot's cache, decode advances every live slot
one token per step, finished slots are refilled from the queue (standard
static batching — the chip-tier analogue is the always-on detector
example's window stream).

The request queue is the chip-tier scheduler's
:class:`repro.serving.queue.FrameQueue` — both serving stacks (the
BinarEye frame service and this LM batcher) now share one queue
mechanism: requests enqueue on a lane, ``next_batch`` pulls up to a
static batch in FIFO order, and a multi-model deployment gets the same
round-robin fairness contract the chip server property-tests.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.data import tokens as dtok
from repro.models import transformer
from repro.serving.queue import FrameQueue, FrameRequest
from repro.train import serve, steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--scaled", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4, help="slot count")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = cfg.scaled().with_(dtype="float32", param_dtype="float32")
    if not cfg.embed_inputs or cfg.num_codebooks > 1:
        print(f"note: {args.arch} uses a modality stub; serving token IDs")

    max_len = args.prompt_len + args.gen_len
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(serve.build_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(serve.build_decode_step(cfg))

    # the shared scheduler: one lane per served model (a single lane
    # here; a multi-arch deployment adds lanes and inherits round-robin
    # fairness), deterministic synthetic prompts as the request payload.
    # Requests are admitted lazily, a batch ahead of the serve loop, so
    # a long stream never materializes every prompt up front.
    queue = FrameQueue([args.arch])
    next_rid = 0

    def admit():
        nonlocal next_rid
        while next_rid < args.requests and queue.pending() < args.batch:
            prompt = dtok.batch_for_step(cfg, next_rid, global_batch=1,
                                         seq_len=args.prompt_len)["tokens"]
            queue.submit(FrameRequest(rid=next_rid, program=args.arch,
                                      frame=prompt))
            next_rid += 1

    served = 0
    t0 = time.time()
    key = jax.random.PRNGKey(42)
    while True:
        admit()
        pulled = queue.next_batch(args.batch)
        if pulled is None:
            break
        _, reqs = pulled
        toks = jnp.concatenate([r.frame for r in reqs])
        pos = jnp.broadcast_to(jnp.arange(args.prompt_len)[None], toks.shape[:2])
        logits, cache = prefill(params, {"tokens": toks, "positions": pos})
        cur = serve.sample(key, logits, args.temperature)
        outs = [cur]
        for t in range(args.gen_len - 1):
            key, sk = jax.random.split(key)
            logits, cache = decode(params, cache, cur,
                                   jnp.asarray(args.prompt_len + t, jnp.int32))
            cur = serve.sample(sk, logits, args.temperature)
            outs.append(cur)
        gen = jnp.concatenate(outs, axis=1)
        for i, r in enumerate(reqs):
            ids = gen[i].reshape(-1)[: args.gen_len]
            print(f"req {r.rid}: {[int(x) for x in ids][:12]}...")
        served += len(reqs)
    dt = time.time() - t0
    print(f"\n{served} requests, {served * args.gen_len} tokens in {dt:.1f}s "
          f"({served * args.gen_len / dt:.1f} tok/s host-sim)")


if __name__ == "__main__":
    main()

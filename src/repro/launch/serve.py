"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Static-batch continuous serving: a pool of B slots, each holding one
request; prefill fills a slot's cache, decode advances every live slot
one token per step, finished slots are refilled from the queue (standard
static batching — the chip-tier analogue is the always-on detector
example's window stream).

The request queue is the chip-tier scheduler's
:class:`repro.serving.queue.FrameQueue` — both serving stacks (the
BinarEye frame service and this LM batcher) now share one queue
mechanism: requests enqueue on a lane, ``next_batch`` pulls a batch in
FIFO order, and a multi-model deployment gets the same round-robin
fairness contract the chip server property-tests.  The pull size is no
longer fixed: admissions are timestamped, the queue's EWMA arrival-rate
estimator (the same one the chip tier's continuous policy uses) sizes
each pull to what ``--slo-ms`` of arrivals should deliver, and
``--rate`` paces synthetic admission to make the estimate meaningful
(unpaced admission measures a near-infinite rate and degrades to the
full ``--batch``, the old behaviour).

All timing runs through ONE injectable monotonic clock (``clock=``,
default ``time.perf_counter``): admission pacing, queue timestamps and
the final throughput figure share a single time domain.  The previous
mix of ``time.time()`` (non-monotonic wall clock — NTP can step it
backwards, skewing reported frames/s) and ``time.perf_counter()``
(monotonic, but a different epoch) is gone; tests inject a virtual
clock + sleep and never touch wall time.
"""

from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.data import tokens as dtok
from repro.models import transformer
from repro.serving.queue import FrameQueue, FrameRequest
from repro.train import serve, steps


def main(argv=None, *, clock=time.perf_counter, sleep=time.sleep):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--scaled", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4, help="slot count")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--rate", type=float, default=None,
                    help="simulated request arrival rate (req/s): paces "
                         "admission so the queue's EWMA rate estimator "
                         "sees realistic gaps (unpaced when omitted)")
    ap.add_argument("--slo-ms", type=float, default=200.0,
                    help="per-request latency SLO the batch sizing "
                         "targets: each pull takes what --rate arrivals "
                         "should deliver within half the SLO")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = cfg.scaled().with_(dtype="float32", param_dtype="float32")
    if not cfg.embed_inputs or cfg.num_codebooks > 1:
        print(f"note: {args.arch} uses a modality stub; serving token IDs")

    max_len = args.prompt_len + args.gen_len
    params = transformer.init_params(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(serve.build_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(serve.build_decode_step(cfg))

    # the shared scheduler: one lane per served model (a single lane
    # here; a multi-arch deployment adds lanes and inherits round-robin
    # fairness), deterministic synthetic prompts as the request payload.
    # Requests are admitted lazily, a batch ahead of the serve loop, so
    # a long stream never materializes every prompt up front.
    queue = FrameQueue([args.arch])
    next_rid = 0
    t_start = clock()

    def admit():
        nonlocal next_rid
        while next_rid < args.requests and queue.pending() < args.batch:
            if args.rate:
                # paced admission: request rid arrives at rid/rate; wait
                # for it only when the queue is empty (otherwise serve
                # what's already here and come back)
                due = t_start + next_rid / args.rate
                wait = due - clock()
                if wait > 0:
                    if queue.pending():
                        return
                    sleep(wait)
            prompt = dtok.batch_for_step(cfg, next_rid, global_batch=1,
                                         seq_len=args.prompt_len)["tokens"]
            queue.submit(FrameRequest(rid=next_rid, program=args.arch,
                                      frame=prompt, t_submit=clock()))
            next_rid += 1

    def pull_size() -> int:
        # the chip tier's continuous-batching target: what the measured
        # arrival rate should deliver inside half the SLO, clamped to
        # the slot pool; full batch until the estimator has a signal
        rate = queue.arrival_rate(args.arch)
        if rate <= 0.0:
            return args.batch
        want = math.ceil(rate * (args.slo_ms / 1e3) * 0.5)
        return max(1, min(want, args.batch))

    served = 0
    t0 = clock()
    key = jax.random.PRNGKey(42)
    while True:
        admit()
        pulled = queue.next_batch(pull_size())
        if pulled is None:
            break
        _, reqs = pulled
        toks = jnp.concatenate([r.frame for r in reqs])
        pos = jnp.broadcast_to(jnp.arange(args.prompt_len)[None], toks.shape[:2])
        logits, cache = prefill(params, {"tokens": toks, "positions": pos})
        cur = serve.sample(key, logits, args.temperature)
        outs = [cur]
        for t in range(args.gen_len - 1):
            key, sk = jax.random.split(key)
            logits, cache = decode(params, cache, cur,
                                   jnp.asarray(args.prompt_len + t, jnp.int32))
            cur = serve.sample(sk, logits, args.temperature)
            outs.append(cur)
        gen = jnp.concatenate(outs, axis=1)
        for i, r in enumerate(reqs):
            ids = gen[i].reshape(-1)[: args.gen_len]
            print(f"req {r.rid}: {[int(x) for x in ids][:12]}...")
        served += len(reqs)
    dt = clock() - t0
    tps = served * args.gen_len / dt if dt > 0 else 0.0
    print(f"\n{served} requests, {served * args.gen_len} tokens in {dt:.1f}s "
          f"({tps:.1f} tok/s host-sim)")


if __name__ == "__main__":
    main()

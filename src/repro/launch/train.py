"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

The production entry point tying the whole substrate together: arch
registry -> mesh -> sharded train state -> deterministic data -> jitted
step -> async checkpointing with restart-on-relaunch.  On this CPU
container it is exercised with ``--scaled`` (the reduced same-family
configs); on a real pod the same flags drive the full configs
(the dry-run proves every full (arch x shape) compiles on the
production meshes).

Fault tolerance: checkpoints are written asynchronously every
``--ckpt-every`` steps; relaunching with the same ``--ckpt-dir`` resumes
from the latest step (data order is a pure function of step, so the
stream realigns exactly).  SIGTERM (preemption) triggers a final
synchronous save.
"""

from __future__ import annotations

import argparse
import os
import signal
import sys
import time

import jax

from repro.checkpoint import ckpt
from repro.configs.registry import ARCH_IDS, get_config
from repro.data import tokens as dtok
from repro.optim import optimizers as opt
from repro.train import steps


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--scaled", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--quant", default=None, help="binary = paper technique")
    ap.add_argument("--width-mult", type=float, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.scaled:
        cfg = cfg.scaled()
    over = {}
    if args.quant:
        over["quant"] = args.quant
    if args.width_mult:
        over["width_mult"] = args.width_mult
    if args.scaled:
        over.update(dtype="float32", param_dtype="float32", loss_chunk=64)
    if over:
        cfg = cfg.with_(**over)

    optimizer = opt.make(cfg.optimizer,
                         opt.cosine_schedule(args.lr, warmup=20,
                                             total=args.steps))
    start = 0
    state = steps.create_state(cfg, jax.random.PRNGKey(0), optimizer)
    writer = None
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(os.path.join(args.ckpt_dir,
                                              f"ckpt_{latest}"), state)
            start = latest
            print(f"resumed from step {latest}")
        writer = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=3)

    stop = {"now": False}
    signal.signal(signal.SIGTERM, lambda *a: stop.update(now=True))

    train_step = jax.jit(steps.build_train_step(cfg, optimizer),
                         donate_argnums=0)
    batch_fn = (dtok.vlm_batch_for_step if not cfg.embed_inputs
                else dtok.batch_for_step)
    t0 = time.time()
    for i in range(start, args.steps):
        batch = batch_fn(cfg, i, global_batch=args.global_batch,
                         seq_len=args.seq_len)
        state, metrics = train_step(state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            dt = time.time() - t0
            tok_s = args.global_batch * args.seq_len * args.log_every / max(dt, 1e-9)
            print(f"step {i:5d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"tok/s {tok_s:,.0f}", flush=True)
            t0 = time.time()
        if writer and ((i + 1) % args.ckpt_every == 0 or stop["now"]):
            writer.save(state, i + 1)
        if stop["now"]:
            writer and writer.wait()
            print(f"preempted at step {i + 1}; checkpoint saved")
            sys.exit(0)
    if writer:
        writer.save(state, args.steps)
        writer.wait()
    print("done")


if __name__ == "__main__":
    main()

"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax call.

All builders stick to the version-stable ``jax.make_mesh(shape, axes)``
surface: the ``axis_types`` kwarg (and ``jax.sharding.AxisType``) only
exists on newer JAX, and Auto is its default there anyway — passing it
explicitly crashed every mesh construction (including restore-after-fault
recovery, see ``checkpoint.ckpt.make_mesh``) on older runtimes.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; 2x16x16 = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / smoke runs): (1, N)."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))


def make_mesh_for(n_devices: int, model: int = 1):
    assert n_devices % model == 0
    return jax.make_mesh((n_devices // model, model), ("data", "model"))

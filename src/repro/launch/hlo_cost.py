"""Trip-count-aware cost analysis of optimized HLO text.

``compiled.cost_analysis()`` counts every ``while``-loop body ONCE — a
``jax.lax.scan`` over 61 layers under-reports flops/bytes/collectives by
61x (verified empirically: scan flops == unrolled/trip_count).  Every
model here scans its layers (and rwkv/mamba scan time, and the CE loss
scans vocab chunks), so the naive numbers are useless for a roofline.

This module re-derives the three roofline inputs from the optimized HLO
*text*, scaling each computation by the product of the trip counts of
the ``while`` loops enclosing it.  XLA annotates every counted loop with
``backend_config={"known_trip_count":{"n":"61"}}``; loops without the
annotation fall back to parsing the condition's comparison constant.

Counted per instruction (mirroring HloCostAnalysis conventions):

  flops:
    dot          2 * prod(output_shape) * prod(lhs contracting dims)
    convolution  2 * prod(output_shape) * prod(kernel spatial) * C_in/groups
    elementwise  prod(output_shape)   (1 flop/elem; transcendentals too)
    reduce       prod(input_shape)
  bytes ("bytes accessed"):
    real ops     sum(operand bytes) + output bytes; fusions charge call-site
                 operands/outputs only (internal traffic is free), EXCEPT
                 parameters consumed only by (dynamic-)slice ops inside the
                 fusion, which charge the slice size — this is what keeps a
                 layer-scan from charging the whole stacked weight array on
                 every iteration.
  collective wire bytes per chip (ring algorithms, n = replica group size):
    all-gather      out_bytes * (n-1)/n
    all-reduce      2 * bytes * (n-1)/n
    reduce-scatter  in_bytes * (n-1)/n
    all-to-all      bytes * (n-1)/n
    collective-permute  bytes

Validated in tests/test_hlo_cost.py against ``cost_analysis()`` on
unrolled programs (where the official numbers are trustworthy).
"""

from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

# ops that move no data / do no math at runtime
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id", "iota",
    "rng-get-and-update-state", "opt-barrier", "domain",
}
# ops whose result is a view / trivial move: bytes yes, flops no
_MOVE_OPS = {
    "copy", "reshape", "transpose", "broadcast", "slice", "dynamic-slice",
    "dynamic-update-slice", "concatenate", "pad", "reverse", "gather",
    "scatter", "select", "convert", "reduce-precision", "copy-start",
    "copy-done",
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# --------------------------------------------------------------------------
# shape parsing
# --------------------------------------------------------------------------
_SHAPE_ONE = re.compile(r"([a-z0-9]+)\[([\d,]*)\](?:\{[^}]*\})?")


def parse_shape(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'(f32[8,16]{1,0}, s32[])' or 'bf16[4,4]' -> [(dtype, dims), ...]."""
    out = []
    for m in _SHAPE_ONE.finditer(s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((dt, dims))
    return out


def shape_bytes(s: str) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims) for dt, dims in parse_shape(s))


def shape_elems(s: str) -> int:
    return sum(math.prod(dims) for _, dims in parse_shape(s))


# --------------------------------------------------------------------------
# HLO text parsing
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Instr:
    name: str
    shape: str          # result shape text (may be a tuple)
    op: str
    operands: List[str]  # operand %names (in-computation)
    attrs: str           # everything after the closing paren of operands
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


# "  %name = shape op(operands), attrs".  Tuple shapes contain nested parens
# AND /*index=N*/ comments (with '='), so the shape is scanned manually.
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OP_RE = re.compile(r"\s*([\w\-]+)\(")
# computation headers sit at column 0 and end with '{'; the arg list may
# contain nested parens (tuple-typed params), so match only the name.
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _scan_parens(line: str, start: int) -> Tuple[str, int]:
    """Return (text including balanced parens starting at `start`, end idx)."""
    depth = 0
    for i in range(start, len(line)):
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return line[start:i + 1], i + 1
    return line[start:], len(line)


def _split_args(line: str, start: int) -> Tuple[str, str]:
    """Return (inside parens, after parens) starting at the '(' at `start`."""
    depth = 0
    for i in range(start, len(line)):
        c = line[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                return line[start + 1:i], line[i + 1:]
    return line[start + 1:], ""


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            if line[:1].isspace() or not line.rstrip().endswith("{"):
                continue
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry = m.group(1)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _NAME_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        pos = m.end()
        # scan the result shape: a balanced (...) tuple or a single token
        if pos < len(line) and line[pos] == "(":
            shape, rest_start = _scan_parens(line, pos)
        else:
            sp = line.find(" ", pos)
            if sp < 0:
                continue
            shape, rest_start = line[pos:sp], sp
        mo = _OP_RE.match(line, rest_start)
        if not mo:
            continue
        op = mo.group(1)
        args, attrs = _split_args(line, mo.end() - 1)
        operands = _OPERAND_RE.findall(args)
        cur.instrs.append(Instr(name, shape, op, operands, attrs, line))
        cur.by_name[name] = cur.instrs[-1]
    if cur is not None:  # unterminated (shouldn't happen)
        comps[cur.name] = cur
    return comps, entry


_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*{\s*"n"\s*:\s*"(\d+)"')
_CALLS_RE = re.compile(r"(?:calls|body|condition|branch_computations|to_apply)="
                       r"(?:%([\w\.\-]+)|\{([^}]*)\})")


def _trip_count(instr: Instr, comps: Dict[str, Computation]) -> int:
    m = _TRIP_RE.search(instr.attrs)
    if m:
        return int(m.group(1))
    # fallback: look for compare-against-constant in the condition computation
    mc = re.search(r"condition=%([\w\.\-]+)", instr.attrs)
    if mc and mc.group(1) in comps:
        cond = comps[mc.group(1)]
        consts = [int(x) for i in cond.instrs if i.op == "constant"
                  for x in re.findall(r"constant\((\d+)\)", i.raw)]
        if consts:
            return max(consts)
    return 1


def _called(instr: Instr) -> List[str]:
    out = []
    for m in _CALLS_RE.finditer(instr.attrs):
        if m.group(1):
            out.append(m.group(1))
        else:
            out += [c.strip().lstrip("%") for c in m.group(2).split(",") if c.strip()]
    return out


# --------------------------------------------------------------------------
# cost model
# --------------------------------------------------------------------------
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, o: "Cost") -> "Cost":
        self.flops += o.flops
        self.bytes += o.bytes
        for k in self.coll:
            self.coll[k] += o.coll[k]
        return self

    def scaled(self, f: float) -> "Cost":
        return Cost(self.flops * f, self.bytes * f,
                    {k: v * f for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _operand_shape(comp: Computation, name: str) -> Optional[str]:
    i = comp.by_name.get(name)
    return i.shape if i else None


def _dot_flops(comp: Computation, i: Instr) -> float:
    out_elems = shape_elems(i.shape)
    m = _CONTRACT_RE.search(i.attrs)
    contract = 1
    if m and i.operands:
        lhs_shape = _operand_shape(comp, i.operands[0])
        if lhs_shape:
            parsed = parse_shape(lhs_shape)
            if parsed:
                dims = parsed[0][1]
                for d in (int(x) for x in m.group(1).split(",") if x):
                    if d < len(dims):
                        contract *= dims[d]
    return 2.0 * out_elems * contract


_WINDOW_RE = re.compile(r"window=\{size=([\dx]+)")
_CONV_DIMS_RE = re.compile(r"dim_labels=([\w\?]+)_([\w\?]+)->([\w\?]+)")


def _conv_flops(comp: Computation, i: Instr) -> float:
    out_elems = shape_elems(i.shape)
    kernel = 1
    m = _WINDOW_RE.search(i.attrs)
    if m:
        for d in m.group(1).split("x"):
            kernel *= int(d)
    cin = 1
    if len(i.operands) > 1:
        rhs = _operand_shape(comp, i.operands[1])
        dm = _CONV_DIMS_RE.search(i.attrs)
        if rhs and dm:
            parsed = parse_shape(rhs)
            if parsed:
                # rhs dim_labels e.g. "01io": 'i' = input-feature position
                pos = dm.group(2).find("i")
                if 0 <= pos < len(parsed[0][1]):
                    cin = parsed[0][1][pos]
    feature_group = 1
    fg = re.search(r"feature_group_count=(\d+)", i.attrs)
    if fg:
        feature_group = int(fg.group(1))
    return 2.0 * out_elems * kernel * cin / feature_group


def _group_size(i: Instr, default: int) -> int:
    """Replica-group size for a collective (last dim of replica_groups)."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", i.attrs)
    if m:
        return max(1, len(m.group(1).split(",")))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", i.attrs)
    if m:  # [num_groups, group_size]<=...
        return max(1, int(m.group(2)))
    return default


def _instr_cost(comp: Computation, i: Instr,
                comps: Dict[str, Computation],
                memo: Dict[str, Cost], n_chips: int) -> Cost:
    op = i.op
    if op in _FREE_OPS:
        return Cost()
    out_bytes = shape_bytes(i.shape)
    in_bytes = sum(shape_bytes(_operand_shape(comp, o) or "")
                   for o in i.operands)

    if op == "while":
        body, cond = None, None
        mb = re.search(r"body=%([\w\.\-]+)", i.attrs)
        mcnd = re.search(r"condition=%([\w\.\-]+)", i.attrs)
        if mb:
            body = mb.group(1)
        if mcnd:
            cond = mcnd.group(1)
        trips = _trip_count(i, comps)
        c = Cost()
        if body in comps:
            c += _comp_cost(comps[body], comps, memo, n_chips).scaled(trips)
        if cond in comps:
            c += _comp_cost(comps[cond], comps, memo, n_chips).scaled(trips)
        return c

    if op == "conditional":
        branches = [_comp_cost(comps[b], comps, memo, n_chips)
                    for b in _called(i) if b in comps]
        if not branches:
            return Cost(0, in_bytes + out_bytes)
        # charge the most expensive branch
        best = max(branches, key=lambda c: c.flops + c.bytes)
        return best

    if op in ("call", "async-start"):
        c = Cost()
        for b in _called(i):
            if b in comps:
                c += _comp_cost(comps[b], comps, memo, n_chips)
        return c

    if op == "fusion":
        c = Cost(0.0, 0.0)
        called = [b for b in _called(i) if b in comps]
        for b in called:
            sub = comps[b]
            # flops from the fused expression, bytes from the call site —
            # except params consumed only by slices (charge slice size).
            fc = _comp_cost(sub, comps, memo, n_chips)
            c.flops += fc.flops
            for k in c.coll:
                c.coll[k] += fc.coll[k]
            c.bytes += _fusion_bytes(sub, comp, i)
        if not called:
            c.bytes = in_bytes + out_bytes
        return c

    for kind in _COLLECTIVES:
        if op == kind or op.startswith(kind + "-"):
            if op.endswith("-done"):
                return Cost()  # counted at -start
            n = _group_size(i, n_chips)
            ratio = (n - 1) / n if n > 1 else 0.0
            if kind == "all-gather":
                wire = out_bytes * ratio
            elif kind == "all-reduce":
                wire = 2.0 * out_bytes * ratio
            elif kind == "reduce-scatter":
                wire = in_bytes * ratio
            elif kind == "all-to-all":
                wire = in_bytes * ratio
            else:  # collective-permute
                wire = out_bytes
            c = Cost(0.0, in_bytes + out_bytes)
            c.coll[kind] = wire
            return c

    if op == "dynamic-update-slice":
        # XLA aliases the updatee in place: traffic = update read + write
        # (+ indices), NOT the full buffer.  Without this, a scan that
        # stacks per-step outputs charges T x the whole stacked array.
        upd = (shape_bytes(_operand_shape(comp, i.operands[1]) or "")
               if len(i.operands) > 1 else 0)
        idx = sum(shape_bytes(_operand_shape(comp, o) or "")
                  for o in i.operands[2:])
        return Cost(0.0, 2.0 * upd + idx)

    if op == "dot":
        return Cost(_dot_flops(comp, i), in_bytes + out_bytes)
    if op == "convolution":
        return Cost(_conv_flops(comp, i), in_bytes + out_bytes)
    if op in ("reduce", "reduce-window"):
        return Cost(max(in_bytes and shape_elems(
            _operand_shape(comp, i.operands[0]) or "") or 0, 0),
            in_bytes + out_bytes)
    if op == "custom-call":
        # Pallas kernels / library calls: bytes only (flops unknown here;
        # kernels register analytic flops separately via kernels/ops.py).
        return Cost(0.0, in_bytes + out_bytes)
    if op in _MOVE_OPS:
        return Cost(0.0, in_bytes + out_bytes)
    if op == "rng" or op.startswith("rng-"):
        return Cost(shape_elems(i.shape), in_bytes + out_bytes)
    if op in ("sort", "top-k"):
        n = shape_elems(i.shape)
        return Cost(n * max(1, math.log2(max(n, 2))), in_bytes + out_bytes)
    # default: elementwise / unary math — 1 flop per output element
    return Cost(shape_elems(i.shape), in_bytes + out_bytes)


def _fusion_bytes(sub: Computation, caller: Computation, call: Instr) -> float:
    """Call-site bytes for a fusion, (dynamic-)slice/update-slice aware.

    A scanned layer reads its *slice* of the stacked weights (charge the
    slice, not the stack) and stacks its per-step output in place via
    dynamic-update-slice (charge the update region, not the stack).
    """
    # output: if the root is a DUS (possibly through a bitcast), the
    # buffer is updated in place — charge the update region only.
    root = None
    for ins in sub.instrs:
        if "ROOT" in ins.raw.split("=")[0]:
            root = ins
    if root is None and sub.instrs:
        root = sub.instrs[-1]
    out_charged = shape_bytes(call.shape)
    seen = set()
    while root is not None and root.name not in seen:
        seen.add(root.name)
        if root.op == "dynamic-update-slice" and len(root.operands) > 1:
            upd = sub.by_name.get(root.operands[1])
            out_charged = shape_bytes(upd.shape) if upd else out_charged
            break
        if root.op in ("bitcast", "copy", "reshape") and root.operands:
            root = sub.by_name.get(root.operands[0])
            continue
        break
    total = out_charged
    # map param index -> how it is consumed inside the fusion
    param_use: Dict[int, List[Tuple[Instr, int]]] = {}
    param_idx: Dict[str, int] = {}
    for ins in sub.instrs:
        if ins.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", ins.raw)
            if m:
                param_idx[ins.name] = int(m.group(1))
    for ins in sub.instrs:
        for argpos, o in enumerate(ins.operands):
            if o in param_idx:
                param_use.setdefault(param_idx[o], []).append((ins, argpos))
    for pos, opname in enumerate(call.operands):
        op_shape = _operand_shape(caller, opname)
        full = shape_bytes(op_shape or "")
        uses = param_use.get(pos, [])
        if uses and all(u.op in ("dynamic-slice", "slice") for u, _ in uses):
            sliced = sum(shape_bytes(u.shape) for u, _ in uses)
            total += min(full, sliced)
        elif uses and all(u.op == "dynamic-update-slice" and ap == 0
                          for u, ap in uses):
            # in-place updatee buffer: aliased, read only where overwritten
            total += 0
        else:
            total += full
    return float(total)


def _comp_cost(comp: Computation, comps: Dict[str, Computation],
               memo: Dict[str, Cost], n_chips: int) -> Cost:
    if comp.name in memo:
        c = memo[comp.name]
        return Cost(c.flops, c.bytes, dict(c.coll))
    total = Cost()
    for i in comp.instrs:
        total += _instr_cost(comp, i, comps, memo, n_chips)
    memo[comp.name] = Cost(total.flops, total.bytes, dict(total.coll))
    return total


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ModuleCost:
    flops: float               # per partition (per chip under SPMD)
    bytes: float               # per partition bytes accessed
    coll_wire_bytes: float     # per chip, ring-model wire bytes
    coll_breakdown: Dict[str, float]

    def as_dict(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "coll_wire_bytes": self.coll_wire_bytes,
                "coll_breakdown": self.coll_breakdown}


def analyze_text(hlo_text: str, n_chips: int = 1) -> ModuleCost:
    comps, entry = parse_module(hlo_text)
    if entry is None:
        # fall back: pick the computation not called by any other
        called = set()
        for c in comps.values():
            for i in c.instrs:
                called.update(_called(i))
        roots = [n for n in comps if n not in called]
        entry = roots[0] if roots else next(iter(comps), None)
    if entry is None:
        return ModuleCost(0.0, 0.0, 0.0, {k: 0.0 for k in _COLLECTIVES})
    memo: Dict[str, Cost] = {}
    # fusions/whiles recurse; compute entry only (sub-comps reached via calls)
    c = _comp_cost(comps[entry], comps, memo, n_chips)
    return ModuleCost(c.flops, c.bytes, c.coll_bytes, dict(c.coll))

"""Chip-tier serving driver: ``python -m repro.launch.chip_serve [...]``.

Continuous static-batch frame service over one or more resident BinarEye
programs: synthetic frame streams are enqueued per program, the
:class:`~repro.serving.ChipServer` dispatches fixed-size batches through
each program's compiled packed :class:`InferencePlan` (round-robin across
programs — the chip's S-mode recombination across concurrent tasks), and
the run closes with the host throughput plus the chip-model bill
(µJ/frame, frames/s, average power analogue) from ``chip/energy.py``.

Examples::

    PYTHONPATH=src python -m repro.launch.chip_serve --programs mnist5
    PYTHONPATH=src python -m repro.launch.chip_serve \
        --programs mnist5,face_detector --requests 48 --batch 8 --shard

``--shard`` serves over all local devices (one packed-weight replica per
device, frames scattered on the batch axis); on a 1-device host it
degrades to the plain jit path, and under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` it exercises the
real N-way scatter on CPU.
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.chip import interpreter, networks
from repro.distributed import sharding
from repro.serving import ChipServer


def build_artifact(program, seed: int, warm_bn: bool):
    """Packed deployment artifact for a program: init (+ optional one-batch
    BN warm so thresholds are realistic), fold, bit-pack."""
    key = jax.random.PRNGKey(seed)
    params = interpreter.init_params(key, program)
    if warm_bn:
        io = program.instrs[0]
        imgs = jax.random.randint(
            jax.random.fold_in(key, 1),
            (4, io.height, io.width, io.in_channels), 0, 2 ** io.bits)
        _, params = interpreter.forward_train(params, program, imgs)
    return interpreter.fold_params(params, program, packed=True)


def frame_stream(program, n: int, seed: int):
    """Deterministic synthetic frames shaped for the program's IO layer."""
    io = program.instrs[0]
    key = jax.random.PRNGKey(seed)
    return np.asarray(jax.random.randint(
        key, (n, io.height, io.width, io.in_channels), 0, 2 ** io.bits))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--programs", default="mnist5",
                    help="comma-separated names from networks.REGISTRY")
    ap.add_argument("--requests", type=int, default=24,
                    help="total frames across all programs")
    ap.add_argument("--batch", type=int, default=8, help="static batch size")
    ap.add_argument("--shard", action="store_true",
                    help="serve over all local devices (frames scattered)")
    ap.add_argument("--donate", action="store_true",
                    help="donate streamed frame buffers to the computation")
    ap.add_argument("--megakernel", action="store_true",
                    help="serve through the whole-network VMEM-resident "
                         "megakernel (weight image resident, zero HBM "
                         "traffic between layers)")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffer submission: stage batch N+1 while "
                         "batch N runs, block only on fetch")
    ap.add_argument("--prefetch-depth", type=int, default=None,
                    help="pipeline submission to depth k with async host "
                         "result fetch (implies --prefetch; default 1 "
                         "when --prefetch is set)")
    ap.add_argument("--shared", action="store_true",
                    help="shared-array dispatch: programs whose S-modes "
                         "tile the 256-channel array exactly run as ONE "
                         "composite pallas_call per batch (true sub-array "
                         "sharing instead of interleaved dispatches)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure-and-cache the best kernel tile sizes "
                         "for each resident program on this backend "
                         "before serving (persisted in the autotune "
                         "cache, see kernels/autotune.py)")
    ap.add_argument("--no-warm-bn", action="store_true",
                    help="skip the one-batch BN warm (faster, cruder "
                         "thresholds)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    names = [n.strip() for n in args.programs.split(",") if n.strip()]
    unknown = [n for n in names if n not in networks.REGISTRY]
    if unknown:
        ap.error(f"unknown programs {unknown}; have "
                 f"{sorted(networks.REGISTRY)}")

    programs = {n: networks.REGISTRY[n]() for n in names}
    print(f"folding deployment artifacts for {names} ...")
    artifacts = {n: build_artifact(p, args.seed + i, not args.no_warm_bn)
                 for i, (n, p) in enumerate(programs.items())}

    if args.autotune:
        from repro.kernels import autotune
        for n, p in programs.items():
            plan = interpreter.compile_plan(p)
            frames = jax.numpy.asarray(frame_stream(p, args.batch, args.seed))
            if args.megakernel:
                image = interpreter.ensure_image(artifacts[n], p)
                entry = autotune.tune_mega(plan, image, frames)
                print(f"autotuned {n}: megakernel bb={entry['bb']} "
                      f"ft={entry['ft']} ({entry['us']:.0f} us)")
            else:
                packed = interpreter.ensure_packed(artifacts[n])
                entry = autotune.tune_staged_conv(plan, packed, frames)
                print(f"autotuned {n}: staged conv bf={entry['bf']} "
                      f"bb={entry['bb']} ({entry['us']:.0f} us)")
        if args.shared:
            # the shared path's hot kernel is the composite, keyed under
            # its own fingerprint — tune each group it will form
            from repro.serving.scheduler import plan_shared_groups
            for members in plan_shared_groups(programs):
                cplan, cimage = interpreter.pack_programs(
                    {m: programs[m] for m in members},
                    {m: artifacts[m] for m in members})
                frames = tuple(jax.numpy.asarray(
                    frame_stream(programs[m], args.batch, args.seed))
                    for m in members)
                entry = autotune.tune_composite(cplan, cimage, frames)
                print(f"autotuned {'+'.join(members)}: composite "
                      f"bb={entry['bb']} ft={entry['ft']} "
                      f"({entry['us']:.0f} us)")

    mesh = sharding.serve_mesh() if args.shard else None
    ndev = mesh.devices.size if mesh is not None else 1
    prefetch = (args.prefetch_depth if args.prefetch_depth is not None
                else int(args.prefetch))
    server = ChipServer(programs, artifacts, batch=args.batch, mesh=mesh,
                        donate_frames=args.donate,
                        megakernel=args.megakernel, prefetch=prefetch,
                        shared=args.shared)
    print(f"resident programs: {names}  (batch={args.batch}, "
          f"devices={ndev}, S-modes={[programs[n].s for n in names]}, "
          f"megakernel={args.megakernel}, prefetch={prefetch}, "
          f"shared={args.shared})")
    if args.shared:
        groups = server.shared_groups
        print("shared-array groups: "
              + (", ".join("+".join(g) for g in groups)
                 if groups else "none (S-modes do not tile the array)"))

    # interleaved synthetic streams: round-robin submission across programs
    per = {n: frame_stream(programs[n], -(-args.requests // len(names)),
                           args.seed + 100 + i)
           for i, n in enumerate(names)}
    idx = {n: 0 for n in names}
    submitted = 0
    while submitted < args.requests:
        n = names[submitted % len(names)]
        server.submit(n, per[n][idx[n]])
        idx[n] += 1
        submitted += 1

    results = server.drain()
    stats = server.stats()

    counts = {n: 0 for n in names}
    for r in results:
        counts[r.program] += 1
    print(f"\nserved {len(results)} frames in {stats.dispatches} dispatches "
          f"({stats.host_wall_s*1e3:.0f} ms host)")
    for n in names:
        rep = stats.chip.reports[n]
        print(f"  {n:>14}: {counts[n]:3d} served, {stats.padded[n]} padded "
              f"slots, {rep.i2l_energy_per_inference*1e6:.2f} uJ/frame, "
              f"S={programs[n].s}")
    print(f"host-sim throughput : {stats.host_frames_per_s:,.0f} frames/s")
    print(f"array utilization   : {stats.array_utilization:.2f} mean "
          f"occupied fraction over {stats.dispatches} dispatches "
          f"({stats.shared_dispatches} shared)")
    print(f"chip-model bill     : {stats.chip.uj_per_frame:.2f} uJ/frame, "
          f"{stats.chip.frames_per_s:,.0f} frames/s at Emin, "
          f"{stats.chip.power_w*1e3:.2f} mW avg "
          f"(paper: up to 1700 f/s, 0.9 mW I2L at S=4)")
    return results, stats


if __name__ == "__main__":
    main()

"""Chip-tier serving driver: ``python -m repro.launch.chip_serve [...]``.

Continuous static-batch frame service over one or more resident BinarEye
programs: synthetic frame streams are enqueued per program, the
:class:`~repro.serving.ChipServer` dispatches fixed-size batches through
each program's compiled packed :class:`InferencePlan` (round-robin across
programs — the chip's S-mode recombination across concurrent tasks), and
the run closes with the host throughput plus the chip-model bill
(µJ/frame, frames/s, average power analogue) from ``chip/energy.py``.

Examples::

    PYTHONPATH=src python -m repro.launch.chip_serve --programs mnist5
    PYTHONPATH=src python -m repro.launch.chip_serve \
        --programs mnist5,face_detector --requests 48 --batch 8 --shard

``--shard`` serves over all local devices (one packed-weight replica per
device, frames scattered on the batch axis); on a 1-device host it
degrades to the plain jit path, and under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` it exercises the
real N-way scatter on CPU.

Two runtime modes on top of plain static serving:

* ``--policy operating-point`` serves program *families* — names in
  ``--programs`` may be family names from ``networks.FAMILIES`` (e.g.
  ``cifar10``), whose member variants are all compiled and served behind
  one lane by the energy-accuracy controller; ``--budget-uj-s`` caps the
  chip-model average power (uJ of I2L energy per second of chip time)
  and a tight budget forces visible downshifts::

      PYTHONPATH=src python -m repro.launch.chip_serve \
          --policy operating-point --programs cifar10 --budget-uj-s 400

* ``--cascade`` runs the paper's always-on hierarchy: the 0.92 uJ/f S=4
  face detector screens every frame and only logit-margin positives
  (``--margin``) escalate to the 14.4 uJ/f S=1 owner recognizer::

      PYTHONPATH=src python -m repro.launch.chip_serve --cascade

* ``--video`` serves a seeded always-on *video* stream through the
  delta-gated temporal pipeline: each batch slot carries one camera
  stream, the in-kernel popcount gate recomputes only the streams whose
  packed frame actually changed (``--delta-threshold``), and skipped
  frames answer from the resident last-logits cache at delta-compute-
  only cost.  ``--target-agreement A`` calibrates the cheapest
  threshold still agreeing with ungated labels at rate A on a held-out
  trace; ``--target-skip S`` instead picks the smallest threshold
  reaching skip ratio S::

      PYTHONPATH=src python -m repro.launch.chip_serve \
          --video --change-rate 0.2 --target-agreement 0.95

* ``--traffic {poisson,bursty,diurnal}`` replays a seeded arrival trace
  in real time instead of enqueueing everything up front — the streaming
  workload the paper's always-on figures assume.  ``--rate`` sets the
  arrival rate (frames/s), ``--slo-ms`` the per-lane latency SLO, and
  ``--policy continuous`` turns on the rolling admission window that
  autoscales the batch against the measured rate::

      PYTHONPATH=src python -m repro.launch.chip_serve \
          --traffic poisson --rate 200 --policy continuous --slo-ms 20
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.core.chip import energy, interpreter, networks
from repro.distributed import sharding
from repro.serving import CascadePipeline, ChipServer, make_trace, replay


def build_artifact(program, seed: int, warm_bn: bool):
    """Packed deployment artifact for a program: init (+ optional one-batch
    BN warm so thresholds are realistic), fold, bit-pack."""
    key = jax.random.PRNGKey(seed)
    params = interpreter.init_params(key, program)
    if warm_bn:
        io = program.instrs[0]
        imgs = jax.random.randint(
            jax.random.fold_in(key, 1),
            (4, io.height, io.width, io.in_channels), 0, 2 ** io.bits)
        _, params = interpreter.forward_train(params, program, imgs)
    return interpreter.fold_params(params, program, packed=True)


def frame_stream(program, n: int, seed: int):
    """Deterministic synthetic frames shaped for the program's IO layer."""
    io = program.instrs[0]
    key = jax.random.PRNGKey(seed)
    return np.asarray(jax.random.randint(
        key, (n, io.height, io.width, io.in_channels), 0, 2 ** io.bits))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--programs", default="mnist5",
                    help="comma-separated names from networks.REGISTRY")
    ap.add_argument("--requests", type=int, default=24,
                    help="total frames across all programs")
    ap.add_argument("--batch", type=int, default=8, help="static batch size")
    ap.add_argument("--shard", action="store_true",
                    help="serve over all local devices (frames scattered)")
    ap.add_argument("--donate", action="store_true",
                    help="donate streamed frame buffers to the computation")
    ap.add_argument("--megakernel", action="store_true",
                    help="serve through the whole-network VMEM-resident "
                         "megakernel (weight image resident, zero HBM "
                         "traffic between layers)")
    ap.add_argument("--prefetch", action="store_true",
                    help="double-buffer submission: stage batch N+1 while "
                         "batch N runs, block only on fetch")
    ap.add_argument("--prefetch-depth", type=int, default=None,
                    help="pipeline submission to depth k with async host "
                         "result fetch (implies --prefetch; default 1 "
                         "when --prefetch is set)")
    ap.add_argument("--shared", action="store_true",
                    help="shared-array dispatch: programs whose S-modes "
                         "tile the 256-channel array exactly run as ONE "
                         "composite pallas_call per batch (true sub-array "
                         "sharing instead of interleaved dispatches)")
    ap.add_argument("--autotune", action="store_true",
                    help="measure-and-cache the best kernel tile sizes "
                         "for each resident program on this backend "
                         "before serving (persisted in the autotune "
                         "cache, see kernels/autotune.py)")
    ap.add_argument("--policy",
                    choices=("static", "operating-point", "continuous"),
                    default="static",
                    help="dispatch policy: 'static' serves each lane "
                         "with its own program; 'operating-point' serves "
                         "program families (names in --programs may be "
                         "networks.FAMILIES entries) at the energy-"
                         "accuracy point the budget and backlog call for; "
                         "'continuous' adds the rolling admission window "
                         "that autoscales the batch against measured "
                         "arrival rate and --slo-ms (composes with the "
                         "operating-point controller when families are "
                         "served)")
    ap.add_argument("--traffic", choices=("poisson", "bursty", "diurnal"),
                    default=None,
                    help="replay a seeded arrival trace in real time "
                         "instead of enqueueing all frames up front")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="traffic arrival rate in frames/s (all lanes)")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="per-lane input-to-label latency SLO for the "
                         "continuous policy's admission window")
    ap.add_argument("--budget-uj-s", type=float, default=None,
                    help="operating-point controller energy budget: max "
                         "chip-model average power in uJ/s (uW); tight "
                         "budgets force downshifts to cheaper variants")
    ap.add_argument("--cascade", action="store_true",
                    help="run the always-on cascade demo: the S=4 face "
                         "detector screens every frame, logit-margin "
                         "positives escalate to the S=1 owner recognizer")
    ap.add_argument("--margin", type=float, default=0.0,
                    help="cascade escalation threshold on the detector's "
                         "logit margin")
    ap.add_argument("--fused", action="store_true",
                    help="serve the cascade as ONE fused kernel dispatch "
                         "per batch: escalation mask + recognizer drain "
                         "in-kernel (bit-exact vs the host cascade)")
    ap.add_argument("--target-recall", type=float, default=None,
                    metavar="R",
                    help="calibrate the escalation margin on a held-out "
                         "split instead of using --margin: the cheapest "
                         "margin whose escalations capture R of the "
                         "positive frames (detector-labelled)")
    ap.add_argument("--video", action="store_true",
                    help="serve a seeded video stream through the delta-"
                         "gated temporal pipeline: skip unchanged frames "
                         "in-kernel, answer them from the last-logits "
                         "cache (first --programs entry; batch = streams)")
    ap.add_argument("--delta-threshold", type=float, default=1.0,
                    help="packed-Hamming gate: a stream recomputes when "
                         "its frame delta vs the resident last frame "
                         "reaches this many bits (1 = skip only bit-"
                         "identical frames; -inf = gate off)")
    ap.add_argument("--target-agreement", type=float, default=None,
                    metavar="A",
                    help="calibrate the gate threshold on a held-out "
                         "video trace: the cheapest threshold whose "
                         "gated labels agree with ungated inference on "
                         "at least A of the frames")
    ap.add_argument("--target-skip", type=float, default=None, metavar="S",
                    help="calibrate the gate threshold for energy: the "
                         "smallest threshold reaching skip ratio S on a "
                         "held-out video trace")
    ap.add_argument("--change-rate", type=float, default=0.25,
                    help="video trace: per-stream probability a frame "
                         "differs from the previous one")
    ap.add_argument("--scene-every", type=int, default=0,
                    help="video trace: full scene change every N frames "
                         "(0 = never)")
    ap.add_argument("--no-warm-bn", action="store_true",
                    help="skip the one-batch BN warm (faster, cruder "
                         "thresholds)")
    ap.add_argument("--fleet", type=int, default=1,
                    help="serve through N replica hosts (disjoint "
                         "host-major sub-meshes of the local devices; "
                         "frames scatter in blocks of --batch)")
    ap.add_argument("--kill", default=None, metavar="REPLICA",
                    help="fault-inject: kill this replica (e.g. host0) "
                         "mid-stream; its frames migrate to survivors "
                         "(requires --fleet >= 2)")
    ap.add_argument("--kill-after", type=int, default=8,
                    help="fire the --kill injection once this many "
                         "frames have been served fleet-wide")
    ap.add_argument("--no-replace", action="store_true",
                    help="do not spawn a warm-started replacement for "
                         "the killed replica")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.cascade:
        return run_cascade(args)
    if args.video:
        return run_video(args)

    names = [n.strip() for n in args.programs.split(",") if n.strip()]
    families = {}
    if args.policy in ("operating-point", "continuous"):
        # family names expand to their member variants behind one lane
        expanded = []
        for n in names:
            if n in networks.FAMILIES:
                families[n] = networks.FAMILIES[n]
                expanded.extend(networks.FAMILIES[n])
            else:
                expanded.append(n)
        names = expanded
    unknown = [n for n in names if n not in networks.REGISTRY]
    if unknown:
        ap.error(f"unknown programs {unknown}; have "
                 f"{sorted(networks.REGISTRY)} and families "
                 f"{sorted(networks.FAMILIES)}")

    programs = {n: networks.REGISTRY[n]() for n in names}
    print(f"folding deployment artifacts for {names} ...")
    artifacts = {n: build_artifact(p, args.seed + i, not args.no_warm_bn)
                 for i, (n, p) in enumerate(programs.items())}

    if args.fleet > 1:
        return run_fleet(args, names, programs, artifacts, families)
    if args.kill:
        ap.error("--kill needs --fleet >= 2 (nowhere to migrate frames)")

    if args.autotune:
        from repro.kernels import autotune
        for n, p in programs.items():
            plan = interpreter.compile_plan(p)
            frames = jax.numpy.asarray(frame_stream(p, args.batch, args.seed))
            if args.megakernel:
                image = interpreter.ensure_image(artifacts[n], p)
                entry = autotune.tune_mega(plan, image, frames)
                print(f"autotuned {n}: megakernel bb={entry['bb']} "
                      f"ft={entry['ft']} ({entry['us']:.0f} us)")
            else:
                packed = interpreter.ensure_packed(artifacts[n])
                entry = autotune.tune_staged_conv(plan, packed, frames)
                print(f"autotuned {n}: staged conv bf={entry['bf']} "
                      f"bb={entry['bb']} ({entry['us']:.0f} us)")
        if args.shared:
            # the shared path's hot kernel is the composite, keyed under
            # its own fingerprint — tune each group it will form
            from repro.serving.scheduler import plan_shared_groups
            for members in plan_shared_groups(programs):
                cplan, cimage = interpreter.pack_programs(
                    {m: programs[m] for m in members},
                    {m: artifacts[m] for m in members})
                frames = tuple(jax.numpy.asarray(
                    frame_stream(programs[m], args.batch, args.seed))
                    for m in members)
                entry = autotune.tune_composite(cplan, cimage, frames)
                print(f"autotuned {'+'.join(members)}: composite "
                      f"bb={entry['bb']} ft={entry['ft']} "
                      f"({entry['us']:.0f} us)")

    mesh = sharding.serve_mesh() if args.shard else None
    ndev = mesh.devices.size if mesh is not None else 1
    prefetch = (args.prefetch_depth if args.prefetch_depth is not None
                else int(args.prefetch))
    server = ChipServer(programs, artifacts, batch=args.batch, mesh=mesh,
                        donate_frames=args.donate,
                        megakernel=args.megakernel, prefetch=prefetch,
                        shared=args.shared, policy=args.policy,
                        families=families or None,
                        budget_uj_s=args.budget_uj_s,
                        slo_ms=args.slo_ms)
    print(f"resident programs: {names}  (batch={args.batch}, "
          f"devices={ndev}, S-modes={[programs[n].s for n in names]}, "
          f"megakernel={args.megakernel}, prefetch={prefetch}, "
          f"shared={args.shared}, policy={args.policy})")
    if families:
        for fam, members in families.items():
            pts = energy.operating_points(
                {m: programs[m] for m in members}, networks.ACCURACY)
            print(f"family {fam}: " + " > ".join(
                f"{p.name}[{p.uj_per_frame:.2f}uJ/f @{p.accuracy:.1%}]"
                for p in pts)
                + (f"  (budget {args.budget_uj_s:,.0f} uJ/s)"
                   if args.budget_uj_s else "  (no budget)"))
    if args.shared:
        groups = server.shared_groups
        print("shared-array groups: "
              + (", ".join("+".join(g) for g in groups)
                 if groups else "none (S-modes do not tile the array)"))

    lanes = list(server.queue.lanes)
    geom_prog = {lane: programs[server.families.get(lane, (lane,))[0]]
                 for lane in lanes}
    per = {lane: frame_stream(geom_prog[lane],
                              -(-args.requests // len(lanes)),
                              args.seed + 100 + i)
           for i, lane in enumerate(lanes)}
    if args.traffic:
        # seeded arrival trace, replayed with real-time pacing: frames
        # hit the queue at their trace offsets and latency is measured
        # against the arrival process
        trace = make_trace(args.traffic, lanes, args.rate, args.requests,
                           seed=args.seed)
        print(f"replaying {args.traffic} trace: {len(trace)} frames at "
              f"{args.rate:,.0f} f/s mean over {len(lanes)} lane(s), "
              f"seed {args.seed}, SLO {args.slo_ms:.0f} ms "
              f"({trace.duration_s:.2f} s span)")
        results = replay(server, trace, per)
    else:
        # interleaved synthetic streams: round-robin submission up front
        idx = {lane: 0 for lane in lanes}
        submitted = 0
        while submitted < args.requests:
            lane = lanes[submitted % len(lanes)]
            server.submit(lane, per[lane][idx[lane]])
            idx[lane] += 1
            submitted += 1
        results = server.drain()
    stats = server.stats()

    counts = {lane: 0 for lane in lanes}
    for r in results:
        counts[r.program] += 1
    print(f"\nserved {len(results)} frames in {stats.dispatches} dispatches "
          f"({stats.host_wall_s*1e3:.0f} ms host)")
    for lane in lanes:
        members = server.families.get(lane, (lane,))
        uj = [stats.chip.reports[m].i2l_energy_per_inference * 1e6
              for m in members]
        print(f"  {lane:>14}: {counts[lane]:3d} served, "
              f"{stats.padded[lane]} padded slots, "
              + (f"{uj[0]:.2f} uJ/frame, S={programs[lane].s}"
                 if len(members) == 1 else
                 f"{min(uj):.2f}-{max(uj):.2f} uJ/frame across "
                 f"{len(members)} operating points"))
    if stats.policy == "operating-point":
        vd = {v: n for v, n in stats.variant_dispatches.items() if n}
        print(f"operating points    : {vd} "
              f"(downshift ratio {stats.downshift_ratio:.2f}, "
              f"energy {stats.energy_uj:,.0f} uJ"
              + (f" under budget {stats.budget_uj_s:,.0f} uJ/s)"
                 if stats.budget_uj_s else ", no budget)"))
    print(f"host-sim throughput : {stats.host_frames_per_s:,.0f} frames/s")
    if stats.p99_ms > 0.0:
        slo = args.slo_ms
        met = sum(1 for e in server.latency_trace()
                  if e["latency_ms"] <= slo) / max(1, len(server.latency_trace()))
        print(f"input-to-label      : p50 {stats.p50_ms:.2f} / "
              f"p95 {stats.p95_ms:.2f} / p99 {stats.p99_ms:.2f} ms "
              f"({met:.1%} within the {slo:.0f} ms SLO)")
        print(f"padding ratio       : {stats.padding_ratio:.3f} burned "
              f"slots per billed slot")
    print(f"array utilization   : {stats.array_utilization:.2f} mean "
          f"occupied fraction over {stats.dispatches} dispatches "
          f"({stats.shared_dispatches} shared)")
    print(f"chip-model bill     : {stats.chip.uj_per_frame:.2f} uJ/frame, "
          f"{stats.chip.frames_per_s:,.0f} frames/s at Emin, "
          f"{stats.chip.power_w*1e3:.2f} mW avg "
          f"(paper: up to 1700 f/s, 0.9 mW I2L at S=4)")
    return results, stats


def run_fleet(args, names, programs, artifacts, families):
    """Serve through a :class:`~repro.serving.ServeFleet`: N replica
    hosts over disjoint sub-meshes, optional mid-stream fault injection
    (``--kill host0``) with survivor migration and a warm-started
    replacement host."""
    from repro.serving import FaultInjector, ServeFleet

    prefetch = (args.prefetch_depth if args.prefetch_depth is not None
                else int(args.prefetch))
    injector = (FaultInjector(args.kill, after_served=args.kill_after)
                if args.kill else None)
    fleet = ServeFleet(programs, artifacts, replicas=args.fleet,
                       batch=args.batch, injector=injector,
                       replace=not args.no_replace,
                       donate_frames=args.donate,
                       megakernel=args.megakernel, prefetch=prefetch,
                       shared=args.shared, policy=args.policy,
                       families=families or None,
                       budget_uj_s=args.budget_uj_s, slo_ms=args.slo_ms)
    ndev = sum(len(d) for d in fleet._devices.values())
    print(f"serve fleet: {args.fleet} replicas over {ndev} device(s), "
          f"batch={args.batch}, policy={args.policy}"
          + (f", kill {args.kill} after {args.kill_after} frames "
             f"(replace={not args.no_replace})" if args.kill else ""))

    lanes = list(fleet.lanes)
    fam_map = dict(families or {})
    geom_prog = {lane: programs[fam_map.get(lane, (lane,))[0]]
                 for lane in lanes}
    per = {lane: frame_stream(geom_prog[lane],
                              -(-args.requests // len(lanes)),
                              args.seed + 100 + i)
           for i, lane in enumerate(lanes)}
    if args.traffic:
        trace = make_trace(args.traffic, lanes, args.rate, args.requests,
                           seed=args.seed)
        print(f"replaying {args.traffic} trace: {len(trace)} frames at "
              f"{args.rate:,.0f} f/s over {len(lanes)} lane(s)")
        results = replay(fleet, trace, per)
    else:
        idx = {lane: 0 for lane in lanes}
        for submitted in range(args.requests):
            lane = lanes[submitted % len(lanes)]
            fleet.submit(lane, per[lane][idx[lane]])
            idx[lane] += 1
            if submitted % args.batch == args.batch - 1:
                fleet.step()       # interleave serving so a --kill lands
        results = fleet.drain()    # mid-stream, not after admission
        results = sorted(results, key=lambda r: r.rid)

    st = fleet.stats()
    print(f"\nfleet served {st.total_served} frames in {st.dispatches} "
          f"dispatches across {len(st.replicas)} replica(s)")
    for name, rs in sorted(st.replicas.items()):
        mark = " (FAILED)" if name in st.failed_replicas else ""
        print(f"  {name:>10}{mark}: {sum(rs.served.values()):3d} served, "
              f"{sum(rs.padded.values())} padded, "
              f"{rs.dispatches} dispatches")
    if st.failed_replicas:
        print(f"failover            : {st.migrated_frames} frames migrated "
              f"(+{st.refired_frames} refired), recovery "
              + (f"{st.recovery_ms:.1f} ms" if st.recovery_ms is not None
                 else "n/a (replacement served no frames)"))
    print(f"billing             : {st.billed} billed == "
          f"{st.total_served} served + {sum(st.padded.values())} padded "
          f"(padding ratio {st.padding_ratio:.3f})")
    if st.p99_ms > 0.0:
        print(f"input-to-label      : p50 {st.p50_ms:.2f} / "
              f"p95 {st.p95_ms:.2f} / p99 {st.p99_ms:.2f} ms (merged)")
    print(f"host-sim throughput : {st.host_frames_per_s:,.0f} frames/s")
    print(f"chip-model bill     : {st.chip.uj_per_frame:.2f} uJ/frame, "
          f"{st.chip.frames_per_s:,.0f} frames/s ({len(st.replicas)} "
          f"chips in parallel), {st.chip.power_w*1e3:.2f} mW total")
    ws = st.warm_start
    print(f"warm-start cache    : {ws['hits']} hits / {ws['misses']} "
          f"misses, {ws['build_s']*1e3:.0f} ms building")
    return results, st


def run_cascade(args):
    """The paper's always-on hierarchy: S=4 face detector on every frame,
    logit-margin positives escalate to the S=1 owner recognizer.

    ``--fused`` serves it as one in-kernel cascade dispatch per batch;
    ``--target-recall R`` calibrates the margin on a held-out split
    (detector-labelled positives as the recall ground truth) instead of
    taking ``--margin`` verbatim.
    """
    det_name, rec_name = "face_detector", "owner_detector"
    programs = {det_name: networks.face_detector(),
                rec_name: networks.owner_detector()}
    print(f"folding deployment artifacts for cascade "
          f"{det_name} -> {rec_name} ...")
    artifacts = {n: build_artifact(p, args.seed + i, not args.no_warm_bn)
                 for i, (n, p) in enumerate(programs.items())}
    prefetch = (args.prefetch_depth if args.prefetch_depth is not None
                else int(args.prefetch))
    server = ChipServer(programs, artifacts, batch=args.batch,
                        megakernel=args.megakernel, prefetch=prefetch)
    casc = CascadePipeline(server, det_name, rec_name,
                           positive_class=1, margin=args.margin,
                           fused=args.fused)
    if args.target_recall is not None:
        # held-out calibration split (disjoint seed from the served
        # stream); with no labelled data in the demo, the detector's own
        # positives are the recall ground truth
        cal = frame_stream(programs[det_name], max(args.requests, 32),
                           args.seed + 200)
        plan = interpreter.compile_plan(programs[det_name])
        _, cal_labels = plan.forward(
            interpreter.ensure_packed(artifacts[det_name]), cal)
        margin = casc.calibrate(cal, np.asarray(cal_labels) == 1,
                                args.target_recall)
        print(f"calibrated margin   : {margin:+.1f} (target recall "
              f"{args.target_recall:.2f} on {len(cal)} held-out frames)")
    frames = frame_stream(programs[det_name], args.requests, args.seed + 100)
    casc.submit_many(frames)
    results = casc.drain()
    rep = casc.report()
    stats = server.stats()
    mode = ("fused in-kernel escalation, "
            f"{casc.fused_dispatches} dispatches" if args.fused
            else "host-side escalation")
    print(f"\ncascade served {len(results)} frames "
          f"({rep.escalated} escalated, rate {rep.escalation_rate:.2f}, "
          f"margin >= {casc.margin:+.1f}, {mode})")
    print(f"detector stage      : {rep.detector_uj:.2f} uJ/frame x "
          f"{rep.frames} frames (+{stats.padded[det_name]} padded)")
    print(f"recognizer stage    : {rep.recognizer_uj:.2f} uJ/frame x "
          f"{rep.escalated} frames (+{stats.padded[rec_name]} padded)")
    print(f"cascade bill        : {rep.uj_per_frame:.2f} uJ/frame vs "
          f"{rep.uj_per_frame_recognizer_only:.2f} recognizer-on-every-"
          f"frame ({rep.savings:.2f}x saved; paper: 0.92 -> 14.4 uJ/f)")
    return results, rep


def run_video(args):
    """Always-on video through the delta-gated temporal pipeline: one
    camera stream per batch slot over a seeded content trace
    (``traffic.video_trace``), in-kernel popcount gating against the
    resident last frame, skipped frames answered from the last-logits
    cache and billed at delta-compute-only cost.

    ``--target-agreement`` / ``--target-skip`` calibrate the threshold
    on a disjoint-seed held-out trace (agreement vs ungated labels, or a
    skip-ratio energy contract) instead of taking ``--delta-threshold``
    verbatim.
    """
    from repro.serving import temporal
    from repro.serving.traffic import video_trace

    if args.target_agreement is not None and args.target_skip is not None:
        raise SystemExit("--target-agreement and --target-skip are "
                         "mutually exclusive")
    name = args.programs.split(",")[0].strip()
    if name not in networks.REGISTRY:
        raise SystemExit(f"unknown program {name!r}; have "
                         f"{sorted(networks.REGISTRY)}")
    program = networks.REGISTRY[name]()
    io = program.instrs[0]
    print(f"folding deployment artifact for {name} ...")
    artifact = build_artifact(program, args.seed, not args.no_warm_bn)
    prefetch = (args.prefetch_depth if args.prefetch_depth is not None
                else int(args.prefetch))
    server = ChipServer({name: program}, {name: artifact}, batch=args.batch,
                        megakernel=args.megakernel, prefetch=prefetch)
    # fine-grained drain chunks: recompute work scales with the changed
    # count instead of rounding every dispatch up to a full batch
    pipe = temporal.TemporalPipeline(server, name,
                                     threshold=args.delta_threshold,
                                     rb=max(1, args.batch // 4))
    steps = -(-args.requests // args.batch)
    shape = (io.height, io.width, io.in_channels)
    if args.target_agreement is not None or args.target_skip is not None:
        cal = video_trace(shape, max(steps, 8), streams=args.batch,
                          seed=args.seed + 200,
                          change_rate=args.change_rate,
                          scene_change_every=args.scene_every,
                          levels=2 ** io.bits)
        if args.target_agreement is not None:
            thr = pipe.calibrate(cal.frames, args.target_agreement)
            print(f"calibrated threshold: {thr:.0f} bits (target "
                  f"agreement {args.target_agreement:.2f} on "
                  f"{len(cal) * cal.streams} held-out frames)")
        else:
            thr = temporal.threshold_for_skip(cal.frames, args.target_skip,
                                              program=program)
            pipe.threshold = thr
            print(f"calibrated threshold: {thr:.0f} bits (target skip "
                  f"{args.target_skip:.2f} on {len(cal) * cal.streams} "
                  f"held-out frames)")
    trace = video_trace(shape, steps, streams=args.batch,
                        seed=args.seed + 100, change_rate=args.change_rate,
                        scene_change_every=args.scene_every,
                        levels=2 ** io.bits)
    print(f"video stream        : {args.batch} streams x {steps} frames "
          f"(change rate {args.change_rate:.2f}, "
          f"{trace.change_ratio:.2f} actually changed, seed "
          f"{args.seed + 100}), gate >= {pipe.threshold:.0f} bits")
    for t in range(len(trace)):
        for s in range(trace.streams):
            pipe.submit(trace.frames[t, s])
    results = pipe.drain()
    rep = pipe.report()
    stats = server.stats()
    print(f"\ntemporal served {len(results)} frames in "
          f"{pipe.gated_dispatches} gated dispatches: {rep.computed} "
          f"computed (+{rep.computed_padded} drain padding), "
          f"{rep.skipped} skipped (skip ratio {rep.skip_ratio:.2f})")
    print(f"host-sim throughput : {stats.host_frames_per_s:,.0f} frames/s")
    print(f"temporal bill       : {rep.uj_per_frame:.3f} uJ/frame "
          f"({rep.delta_uj:.3f} delta toll on every frame) vs "
          f"{rep.uj_per_frame_ungated:.3f} ungated "
          f"({rep.savings:.2f}x saved)")
    return results, rep


if __name__ == "__main__":
    main()

"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs            / (chips * 197e12 FLOP/s bf16)
  memory     = HLO_bytes_accessed   / (chips * 819e9  B/s HBM)
  collective = sum(per-collective bytes / (chips * links_used * 50e9 B/s))

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (jax reports per-PARTITION shapes under SPMD, so
sizes are per-chip already).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# --- hardware constants (TPU v5e-like, per chip) ----------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW = 50e9                # B/s per link; chips have multiple links but a
                             # collective is bottlenecked by its slowest hop,
                             # we charge 1 link per collective conservatively.

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind from optimized HLO."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        # match "  <shape> <name> = <shape> op-name(...)" instruction lines
        m = re.match(r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([\w\-]+)", ls)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                # exclude *-start/done duplicates: count only -start or bare
                if op.endswith("-done"):
                    break
                out[kind] += _shape_bytes(shape_str)
                break
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # whole-program (all chips)
    hlo_bytes: float            # whole-program bytes accessed
    coll_bytes_per_chip: float  # per chip
    coll_breakdown: Dict[str, int]
    model_flops: float          # 6 * N_active * D tokens (train) etc.
    bytes_per_chip_peak: float  # memory_analysis peak
    compile_ok: bool = True

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful FLOPs / (chips * peak * max-term)  — the MFU bound."""
        t = max(self.t_compute, self.t_memory, self.t_collective)
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> str:
        return (f"{self.arch:18s} {self.shape:12s} {self.mesh:9s} "
                f"tc={self.t_compute:9.4f}s tm={self.t_memory:9.4f}s "
                f"tx={self.t_collective:9.4f}s  dom={self.bottleneck:10s} "
                f"useful={self.useful_flops_ratio:6.2%} "
                f"roofline={self.roofline_fraction:6.2%}")


def analyze(compiled, lowered_text: str, *, arch: str, shape: str,
            mesh_name: str, chips: int, model_flops: float) -> Roofline:
    """Roofline terms from trip-count-aware HLO text analysis.

    ``compiled.cost_analysis()`` counts every while-loop body ONCE, so a
    61-layer ``lax.scan`` under-reports flops 61x (verified empirically).
    ``hlo_cost.analyze_text`` re-derives flops / bytes / collective wire
    bytes scaling loop bodies by their ``known_trip_count``.  All numbers
    it returns are per-partition == per-chip under SPMD.
    """
    from repro.launch import hlo_cost
    mc = hlo_cost.analyze_text(lowered_text, n_chips=chips)
    # whole-program totals (roofline divides by chips again)
    flops = mc.flops * chips
    byts = mc.bytes * chips
    coll = {k: int(v) for k, v in mc.coll_breakdown.items()}
    mem = compiled.memory_analysis()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes + mem.generated_code_size_in_bytes)
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=byts,
        coll_bytes_per_chip=float(mc.coll_wire_bytes),
        coll_breakdown=coll, model_flops=model_flops,
        bytes_per_chip_peak=float(peak))


def model_flops_for(cfg, shape, n_active_params: int) -> float:
    """6*N*D for train, 2*N*D for inference steps (per whole step)."""
    tokens = shape.global_batch * (shape.seq_len if shape.step != "decode" else 1)
    mult = 6.0 if shape.step == "train" else 2.0
    return mult * n_active_params * tokens

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be executed as a fresh process (``python -m repro.launch.dryrun``):
the first two lines below force 512 host platform devices BEFORE any other
import so ``jax.make_mesh((2,16,16))`` can build the production mesh on
this CPU-only container.  Smoke tests / benches import other modules and
see 1 device.

Per cell this script:
  1. builds the jitted step (train/prefill/decode) with in/out shardings,
  2. ``.lower()`` on ShapeDtypeStruct inputs (no allocation),
  3. ``.compile()`` — sharding mismatches / unsupported collectives fail here,
  4. records memory_analysis + cost_analysis + parsed collective bytes
     (launch/roofline.py) to a JSON cell file for EXPERIMENTS.md.
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import shapes as shp            # noqa: E402
from repro.configs.base import active_param_count  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.distributed import context as dctx, sharding as shd  # noqa: E402
from repro.launch import mesh as mesh_lib, roofline  # noqa: E402
from repro.models import transformer               # noqa: E402
from repro.optim import optimizers as opt          # noqa: E402
from repro.train import serve, steps               # noqa: E402


def build_optimizer(cfg):
    lr = opt.cosine_schedule(3e-4, warmup=100, total=10000)
    return opt.make(cfg.optimizer, lr)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: jax.sharding.NamedSharding(mesh, s),
                        spec_tree,
                        is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec))


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               overrides=None, dump_hlo: str = None):
    overrides = dict(overrides or {})
    rwkv_over = {k[5:]: overrides.pop(k) for k in list(overrides)
                 if k.startswith("rwkv_")}
    moe_over = {k[4:]: overrides.pop(k) for k in list(overrides)
                if k.startswith("moe_")}
    mamba_over = {k[6:]: overrides.pop(k) for k in list(overrides)
                  if k.startswith("mamba_")}
    cfg = get_config(arch, **overrides)
    import dataclasses
    if rwkv_over and cfg.rwkv is not None:
        cfg = cfg.with_(rwkv=dataclasses.replace(cfg.rwkv, **rwkv_over))
    if moe_over and cfg.moe is not None:
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, **moe_over))
    if mamba_over and cfg.mamba is not None:
        cfg = cfg.with_(mamba=dataclasses.replace(cfg.mamba, **mamba_over))
    shape = shp.SHAPES[shape_name]
    ok, reason = shp.cell_supported(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "SKIPPED", "reason": reason}

    batch_structs = shp.input_specs(cfg, shape)
    chips = mesh.devices.size
    t0 = time.time()

    with dctx.mesh_context(mesh):
        if shape.step == "train":
            optimizer = build_optimizer(cfg)
            step_fn = steps.build_train_step(cfg, optimizer)
            st_specs = steps.state_specs(cfg, mesh, optimizer)
            b_specs = shd.batch_specs(cfg, mesh, batch_structs)
            st_shapes = steps.state_shape(cfg, optimizer)
            jitted = jax.jit(step_fn,
                             in_shardings=(_named(mesh, st_specs),
                                           _named(mesh, b_specs)),
                             donate_argnums=(0,))
            lowered = jitted.lower(st_shapes, batch_structs)
        elif shape.step == "prefill":
            step_fn = serve.build_prefill_step(cfg)
            p_shapes = jax.eval_shape(
                lambda k: transformer.init_params(k, cfg),
                jax.random.PRNGKey(0))
            p_specs = shd.param_specs(cfg, mesh, p_shapes)
            b_specs = shd.batch_specs(cfg, mesh, batch_structs)
            jitted = jax.jit(step_fn, in_shardings=(_named(mesh, p_specs),
                                                    _named(mesh, b_specs)))
            lowered = jitted.lower(p_shapes, batch_structs)
        else:  # decode
            step_fn = serve.build_decode_step(cfg)
            p_shapes = jax.eval_shape(
                lambda k: transformer.init_params(k, cfg),
                jax.random.PRNGKey(0))
            p_specs = shd.param_specs(cfg, mesh, p_shapes)
            cache_shapes = jax.eval_shape(
                lambda: transformer.init_cache(cfg, shape.global_batch,
                                               shape.seq_len))
            c_specs = shd.cache_specs(cfg, mesh, cache_shapes)
            tok = list(batch_structs.values())[0]
            tok_spec = shd.batch_specs(cfg, mesh, {"t": tok})["t"]
            jitted = jax.jit(
                step_fn,
                in_shardings=(_named(mesh, p_specs), _named(mesh, c_specs),
                              jax.sharding.NamedSharding(mesh, tok_spec),
                              None),
                donate_argnums=(1,))
            lowered = jitted.lower(p_shapes, cache_shapes, tok,
                                   jax.ShapeDtypeStruct((), jnp.int32))
        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1

    mf = roofline.model_flops_for(cfg, shape, active_param_count(cfg))
    hlo_text = compiled.as_text()
    if dump_hlo:
        import gzip
        with gzip.open(dump_hlo, "wt") as f:
            f.write(hlo_text)
    rl = roofline.analyze(compiled, hlo_text, arch=arch,
                          shape=shape_name, mesh_name=mesh_name, chips=chips,
                          model_flops=mf)
    mem = compiled.memory_analysis()
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "OK", "chips": chips,
        "lower_s": round(lower_s, 1), "compile_s": round(compile_s, 1),
        "hlo_flops": rl.hlo_flops, "hlo_bytes": rl.hlo_bytes,
        "coll_bytes_per_chip": rl.coll_bytes_per_chip,
        "coll_breakdown": rl.coll_breakdown,
        "model_flops": rl.model_flops,
        "t_compute": rl.t_compute, "t_memory": rl.t_memory,
        "t_collective": rl.t_collective, "bottleneck": rl.bottleneck,
        "useful_flops_ratio": rl.useful_flops_ratio,
        "roofline_fraction": rl.roofline_fraction,
        "bytes_per_chip": {
            "argument": mem.argument_size_in_bytes / chips,
            "output": mem.output_size_in_bytes / chips,
            "temp": mem.temp_size_in_bytes / chips,
            "alias": mem.alias_size_in_bytes / chips,
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["pod", "multipod", "both"])
    ap.add_argument("--out", default="benchmarks/results")
    ap.add_argument("--quant", default=None, help="e.g. 'binary'")
    ap.add_argument("--width-mult", type=float, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--dump-hlo", action="store_true",
                    help="save gzipped optimized HLO per cell (for profiling)")
    ap.add_argument("--rwkv-chunk", type=int, default=None,
                    help="GLA-style chunked WKV (perf knob)")
    ap.add_argument("--rwkv-unroll", type=int, default=None,
                    help="unroll factor for the per-token WKV scan")
    ap.add_argument("--mamba-unroll", type=int, default=None,
                    help="unroll factor for the selective-scan recurrence")
    ap.add_argument("--moe-fp8-dispatch", action="store_true",
                    help="fp8 dispatch a2a for EP MoE (perf knob)")
    ap.add_argument("--attn-probs-bf16", action="store_true",
                    help="bf16 attention probabilities (perf knob)")
    ap.add_argument("--bf16-grads", action="store_true",
                    help="Megatron-style bf16 grad collectives (perf knob)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    shape_names = list(shp.SHAPES) if args.shape == "all" else [args.shape]
    mesh_names = {"pod": ["pod"], "multipod": ["multipod"],
                  "both": ["pod", "multipod"]}[args.mesh]
    overrides = {}
    if args.quant:
        overrides["quant"] = args.quant
    if args.width_mult:
        overrides["width_mult"] = args.width_mult
    if args.rwkv_chunk:
        overrides["rwkv_chunk"] = args.rwkv_chunk
    if args.rwkv_unroll:
        overrides["rwkv_scan_unroll"] = args.rwkv_unroll
    if args.mamba_unroll:
        overrides["mamba_scan_unroll"] = args.mamba_unroll
    if args.moe_fp8_dispatch:
        overrides["moe_dispatch_fp8"] = True
    if args.attn_probs_bf16:
        overrides["attn_probs_bf16"] = True
    if args.bf16_grads:
        overrides["bf16_grads"] = True

    os.makedirs(args.out, exist_ok=True)
    meshes = {}
    for mn in mesh_names:
        meshes[mn] = mesh_lib.make_production_mesh(multi_pod=(mn == "multipod"))

    results = []
    for arch in archs:
        for sn in shape_names:
            for mn in mesh_names:
                cell_id = f"{arch}__{sn}__{mn}{args.tag}"
                path = os.path.join(args.out, f"dryrun_{cell_id}.json")
                hlo_path = (os.path.join(args.out, f"hlo_{cell_id}.txt.gz")
                            if args.dump_hlo else None)
                try:
                    res = lower_cell(arch, sn, meshes[mn], mn, overrides,
                                     dump_hlo=hlo_path)
                except Exception as e:  # a failing cell is a bug: record it
                    res = {"arch": arch, "shape": sn, "mesh": mn,
                           "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                results.append(res)
                with open(path, "w") as f:
                    json.dump(res, f, indent=1)
                line = (f"[{res['status']:7s}] {arch:18s} {sn:12s} {mn:8s}"
                        + (f" dom={res.get('bottleneck','-'):10s}"
                           f" roofline={res.get('roofline_fraction', 0):.2%}"
                           f" compile={res.get('compile_s', 0):.0f}s"
                           if res["status"] == "OK" else
                           f" {res.get('reason', res.get('error', ''))[:90]}"))
                print(line, flush=True)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n{len(results)} cells: "
          f"{sum(r['status']=='OK' for r in results)} ok, "
          f"{sum(r['status']=='SKIPPED' for r in results)} skipped, "
          f"{n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()

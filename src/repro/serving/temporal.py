"""Temporal serving: delta-gated always-on video on top of ChipServer.

An always-on camera feed is mostly *still*: between scene changes the
thermometer-coded frame a stream submits differs from its previous frame
by a handful of packed bits, and recomputing the whole network buys
nothing the cached answer doesn't already hold.  The paper's always-on
budget (Sec. IV) is exactly this regime — the chip that wins is the one
that spends full-inference energy only when the scene actually moved.

:class:`TemporalPipeline` is that runtime.  Each step pulls one batch
from its lane and runs the **delta-gated megakernel**
(:meth:`Executor.delta_for` -> ``kernels.megakernel.delta_forward``):
the kernel popcount-XORs every stream's packed frame against a resident
last-frame buffer, compacts the streams whose Hamming delta reaches the
gate threshold into an in-kernel change queue, recomputes the network
over *only those*, and scatters fresh logits merged with the resident
last-logits buffer — skipped streams emit their cached answer from the
same dispatch, bit-exact with the frame that produced it.

Accounting follows the launch-ledger discipline of the rest of the
serving tier, split by what the chip actually ran:

* the **server ledger** bills full-network inferences only — the slots
  the kernel's change queue drained (changed streams + drain-chunk
  padding, from the kernel's own scalar report).  ``billed == served +
  padded`` still holds per lane; skipped frames never hit the array and
  never appear in it.
* the **pipeline ledger** (:meth:`TemporalPipeline.report` ->
  :func:`energy.temporal_report`) bills every frame the delta-compute
  toll (one IO pass: the frame must stream in to be compared) and adds
  full inference energy for the computed slots — the honest
  uJ/frame-of-video figure, with the skip ratio that produced it.

**Activity coupling**: the pipeline keeps an EWMA of the changed
fraction per step and feeds it to
:meth:`OperatingPointPolicy.set_activity` when its lane is a program
family under an operating-point policy — a quiet scene both skips
frames *and* downshifts the frames it does compute to a cheaper
operating point, compounding the two scaling axes.  Variant switches
reset the gate state for the incoming variant (its packed geometry and
logits are its own), forcing one full recompute dispatch.

**Threshold calibration** (:func:`calibrate_delta_threshold`): like the
cascade's :func:`~repro.serving.cascade.calibrate_margin`, run the
*ungated* network offline over a held-out video trace and pick the
cheapest (largest) threshold whose gated labels still agree with the
ungated oracle at a target rate — the threshold becomes an agreement
contract.  :func:`threshold_for_skip` solves the dual problem: the
smallest threshold achieving a target skip ratio (an energy contract).

Gate-state alignment: batch slot ``i`` carries stream ``i``'s state, so
steady submission should be round-robin across streams (``video_trace``
order).  A misaligned slot only ever *recomputes more* — a skip at
threshold ``t`` certifies the packed frames differ by fewer than ``t``
bits, whichever stream wrote the reference — so labels stay within the
gate contract; alignment is an efficiency concern, not a correctness
one.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.chip import energy, interpreter
from repro.serving.policy import OperatingPointPolicy
from repro.serving.queue import FrameResult
from repro.serving.server import ChipServer


# ---------------------------------------------------------------------------
# threshold calibration: agreement and skip contracts
# ---------------------------------------------------------------------------

def _packed_streams(frames, program) -> Tuple[np.ndarray, np.ndarray]:
    """Normalize a video trace to ``(T, S, H, W, C)`` int frames and the
    matching packed thermometer codes ``(T, S, H, W, C_packed)`` uint32
    (exactly the kernel's in-gate packing)."""
    io = program.instrs[0]
    arr = np.asarray(frames)
    if arr.ndim == 4:                       # single stream: (T, H, W, C)
        arr = arr[:, None]
    if arr.ndim != 5:
        raise ValueError(
            f"expected (T, S, H, W, C) or (T, H, W, C) frames, "
            f"got shape {arr.shape}")
    t, s = arr.shape[:2]
    flat = jnp.asarray(arr.reshape((t * s,) + arr.shape[2:]), jnp.int32)
    packed = np.asarray(binarize.thermometer_pack(
        flat, io.bits, io.in_channels, io.channels))
    return arr, packed.reshape((t, s) + packed.shape[1:])


def _hamming(a: np.ndarray, b: np.ndarray) -> int:
    """Packed Hamming distance — the host reference for the kernel's
    popcount gate."""
    x = np.ascontiguousarray(np.bitwise_xor(a, b))
    return int(np.unpackbits(x.view(np.uint8)).sum())


def simulate_gate(packed: np.ndarray,
                  threshold: float) -> Tuple[np.ndarray, np.ndarray]:
    """Host reference of the stateful gate over a packed trace.

    Per stream: frame 0 always computes (cold state); frame ``t``
    recomputes iff its Hamming delta against the *last computed* frame
    reaches ``threshold`` — the reference advances only on recompute,
    exactly the kernel's resident last-frame rule.  Returns
    ``(recompute, ref)``: a ``(T, S)`` bool mask and the ``(T, S)``
    index of the frame whose (cached or fresh) answer each step emits.
    """
    t, s = packed.shape[:2]
    rec = np.zeros((t, s), dtype=bool)
    ref = np.zeros((t, s), dtype=np.int64)
    for si in range(s):
        last = 0
        for ti in range(t):
            if ti == 0 or _hamming(packed[ti, si],
                                   packed[last, si]) >= threshold:
                rec[ti, si] = True
                last = ti
            ref[ti, si] = last
    return rec, ref


def _candidate_thresholds(packed: np.ndarray) -> List[float]:
    """Thresholds worth trying: 1 (skip only bit-identical frames), every
    consecutive-frame delta the trace contains, and one past the largest
    (skip everything after the cold frame)."""
    deltas = {_hamming(packed[ti, si], packed[ti - 1, si])
              for ti in range(1, packed.shape[0])
              for si in range(packed.shape[1])}
    cands = {1.0} | {float(d) for d in deltas if d > 0}
    cands.add(max(cands) + 1.0)
    return sorted(cands)


def calibrate_delta_threshold(frames, target_agreement: float = 0.95, *,
                              program, artifact,
                              interpret: Optional[bool] = None) -> float:
    """The cheapest gate threshold meeting a label-agreement target.

    Runs ``program`` (with its deployment ``artifact``) *ungated* over a
    held-out video trace — ``(T, S, H, W, C)`` or single-stream
    ``(T, H, W, C)`` — to get oracle labels, then simulates the stateful
    gate at every candidate threshold, cheapest (largest = fewest
    recomputes) first, and returns the first whose emitted labels (the
    cached label of each stream's last computed frame) agree with the
    oracle on at least ``target_agreement`` of all frames.  Threshold 1
    skips only bit-identical packed frames, whose cached labels are
    bit-exact — so the search always terminates with agreement 1.0.
    """
    if not 0.0 < target_agreement <= 1.0:
        raise ValueError(
            f"target_agreement must be in (0, 1], got {target_agreement}")
    arr, packed = _packed_streams(frames, program)
    t, s = packed.shape[:2]
    plan = interpreter.compile_plan(program)
    _, labels = plan.forward(
        interpreter.ensure_packed(artifact),
        jnp.asarray(arr.reshape((t * s,) + arr.shape[2:]), jnp.int32),
        interpret=interpret)
    oracle = np.asarray(labels).reshape(t, s)
    cols = np.arange(s)[None, :]
    for thr in sorted(_candidate_thresholds(packed), reverse=True):
        _, ref = simulate_gate(packed, thr)
        agreement = float((oracle[ref, cols] == oracle).mean())
        if agreement >= target_agreement:
            return float(thr)
    return 1.0          # unreachable: threshold 1 agrees exactly


def threshold_for_skip(frames, target_skip: float, *, program) -> float:
    """The smallest gate threshold achieving a skip-ratio target on a
    held-out video trace — the least aggressive gate that still delivers
    the energy contract.  Raises when the trace can't reach the target
    even skipping everything but each stream's cold frame."""
    if not 0.0 <= target_skip < 1.0:
        raise ValueError(
            f"target_skip must be in [0, 1), got {target_skip}")
    _, packed = _packed_streams(frames, program)
    best = 0.0
    for thr in _candidate_thresholds(packed):
        rec, _ = simulate_gate(packed, thr)
        best = max(best, 1.0 - float(rec.mean()))
        if best >= target_skip:
            return float(thr)
    raise ValueError(
        f"target_skip {target_skip} unreachable on this trace "
        f"(max achievable {best:.3f}: cold frames always compute)")


# ---------------------------------------------------------------------------
# the pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TemporalResult:
    """The gated answer for one submitted frame."""
    rid: int                    # pipeline-level request id (arrival order)
    label: int                  # fresh if computed, else the cached label
    computed: bool              # did this frame's stream recompute?
    delta: int                  # packed Hamming delta vs the gate reference
    variant: str                # operating point that produced/cached label
    logits: np.ndarray


class TemporalPipeline:
    """Delta-gated serving for one always-on video lane.

    Wraps a :class:`ChipServer` lane: frames enqueue through the
    ordinary queue, but each step pulls one batch and runs it through
    the in-kernel delta gate instead of the plain serve path — per-slot
    last-frame/last-logits state lives in pipeline-held device buffers
    that round-trip through the kernel (resident state, exactly like the
    chip keeping the previous frame on-SRAM).

    ``threshold`` is the packed-Hamming gate (``delta >= threshold``
    recomputes; 1 skips only bit-identical frames; ``-inf`` recomputes
    everything — the gated path then matches the plain megakernel
    bit-exactly).  The first dispatch after construction, :meth:`reset`,
    or an operating-point switch forces ``-inf`` (cold state holds no
    cacheable answer).

    A single-variant lane serves under any policy.  A program-family
    lane requires an :class:`OperatingPointPolicy`: each step reports
    the activity EWMA via ``set_activity`` and asks the policy to pick
    the operating point, so quiet scenes downshift under the same budget
    machinery as ordinary serving (spend commits for the slots the gate
    actually computed).
    """

    def __init__(self, server: ChipServer, lane: str, *,
                 threshold: float = 1.0, rb: Optional[int] = None,
                 check_every: int = 1, activity_alpha: float = 0.5):
        if lane not in server.queue.lanes:
            raise KeyError(f"lane {lane!r} not resident on the server "
                           f"(have {sorted(server.queue.lanes)})")
        if math.isnan(threshold):
            raise ValueError("threshold must not be NaN")
        if not 0.0 < activity_alpha <= 1.0:
            raise ValueError(
                f"activity_alpha must be in (0, 1], got {activity_alpha}")
        self.variants = server._lane_variants[lane]
        if len(self.variants) > 1 and not isinstance(
                server.policy, OperatingPointPolicy):
            raise ValueError(
                f"lane {lane!r} is a program family; temporal serving "
                "over a family needs an OperatingPointPolicy to pick the "
                "operating point per dispatch")
        self.server = server
        self.lane = lane
        self.threshold = threshold
        self.rb = rb
        self.check_every = check_every
        self.activity_alpha = activity_alpha
        # cold scenes look "active" until measured: start the EWMA at 1
        # so a fresh pipeline never downshifts on no evidence
        self._activity = 1.0
        self._variant = (server.policy.variant_order(lane)[0]
                         if len(self.variants) > 1 else self.variants[0])
        # the gated dispatch unit compiles eagerly (resident programs
        # load their weights before serving) through the warm-start cache
        server.executor.delta_for(self._variant, rb=rb,
                                  check_every=check_every)
        # per-variant resident state: variant -> (last_frames, last_logits)
        # device buffers; absence = cold (next dispatch forces recompute)
        self._state: Dict[str, tuple] = {}
        self._rid: Dict[int, int] = {}             # server rid -> pipeline rid
        self._next_rid = 0
        self.other_results: List[FrameResult] = []  # non-lane server results
        self._submitted = 0
        self._frames_total = 0
        self._computed = 0
        self._computed_padded = 0
        self._skipped = 0
        self.gated_dispatches = 0
        # variant -> [frames, computed, computed_padded] for the bill
        self._per_variant: Dict[str, List[int]] = {}

    # -- request side -------------------------------------------------------

    def submit(self, frame) -> int:
        """Enqueue one frame; returns its pipeline request id (arrival
        order).  Submit round-robin across streams so batch slot ``i``
        keeps carrying stream ``i``'s gate state."""
        rid = self._next_rid
        self._next_rid += 1
        srid = self.server.submit(self.lane, frame)
        self._rid[srid] = rid
        self._submitted += 1
        return rid

    def submit_many(self, frames) -> List[int]:
        return [self.submit(f) for f in frames]

    # -- dispatch side ------------------------------------------------------

    def _pick_variant(self, size: int) -> str:
        """Ask the operating-point policy for this dispatch's variant
        (family lanes only), after reporting the scene-activity EWMA; a
        switch drops the incoming variant's gate state (it caches the
        *other* operating point's logits and packing)."""
        if len(self.variants) == 1:
            return self._variant
        pol = self.server.policy
        pol.set_activity(self.lane, self._activity)
        variant = pol._choose(self.lane, self.server.queue.pending(self.lane),
                              size, pol.spent_uj, pol.chip_time_s)
        if variant != self._variant:
            self._state.pop(variant, None)        # cold-start the newcomer
            self._variant = variant
        return variant

    def _step_gated(self, reqs) -> List[TemporalResult]:
        """One gated dispatch: a batch through the delta kernel; every
        frame in it finalizes immediately (skipped slots carry their
        cached answer from the same kernel)."""
        srv = self.server
        t0 = srv.clock()
        size = srv.batch
        n = len(reqs)
        variant = self._pick_variant(size)
        unit = srv.executor.delta_for(variant, rb=self.rb,
                                      check_every=self.check_every)
        frames = srv.executor.pad_frames(reqs, srv._geom[self.lane], size)
        state = self._state.get(variant)
        if state is None:
            last, llog = unit["plan"].init_state(size)
            ctrl = interpreter.DeltaPlan.delta_ctrl(float("-inf"), n)
        else:
            last, llog = state
            ctrl = interpreter.DeltaPlan.delta_ctrl(self.threshold, n)
        (lg, lb, new_last, new_llog, queue, counts,
         deltas) = unit["fn"](unit["image"], frames, last, llog, ctrl)
        self._state[variant] = (new_last, new_llog)
        lg, lb = np.asarray(lg), np.asarray(lb)
        queue, counts = np.asarray(queue), np.asarray(counts)
        deltas = np.asarray(deltas)
        changed, slots = int(counts[0]), int(counts[1])
        # bill at launch like ChipServer._launch, but only what the chip
        # ran the network on: the slots the change queue drained (changed
        # streams + drain-chunk padding, from the kernel's own report).
        # Skipped frames never hit the array; their delta-compute toll is
        # billed in report() via energy.temporal_report.
        srv._served[self.lane] += changed
        srv._padded[self.lane] += slots - changed
        srv._vserved[variant] += changed
        srv._vpadded[variant] += slots - changed
        srv._billed += slots
        srv._dispatches += 1
        srv._util_sum += 1.0 / srv.programs[variant].s
        pol = srv.policy
        pol.variant_dispatches[variant] = (
            pol.variant_dispatches.get(variant, 0) + 1)
        if isinstance(pol, OperatingPointPolicy):
            # commit budget spend for the computed slots only — the gate's
            # savings are real savings against the energy budget
            pol.spent_uj += slots * pol._e1[variant]
            pol.chip_time_s += slots * pol._t1[variant]
        self.gated_dispatches += 1
        self._frames_total += n
        self._computed += changed
        self._computed_padded += slots - changed
        self._skipped += n - changed
        pv = self._per_variant.setdefault(variant, [0, 0, 0])
        pv[0] += n
        pv[1] += changed
        pv[2] += slots - changed
        a = self.activity_alpha
        self._activity = a * (changed / n) + (1.0 - a) * self._activity
        fresh = {int(g) for g in queue[:changed]}
        out = []
        for i, r in enumerate(reqs):
            out.append(TemporalResult(
                rid=self._rid.pop(r.rid), label=int(lb[i]),
                computed=i in fresh, delta=int(deltas[i]),
                variant=variant, logits=lg[i]))
        srv._host_wall_s += srv.clock() - t0
        return out

    def step(self) -> List[TemporalResult]:
        """One dispatch; returns the gated results it finalized.  When
        the lane has nothing queued, steps the server for other resident
        lanes (their results land in :attr:`other_results`); [] when
        there was nothing to run."""
        reqs = self.server.queue.take(self.lane, self.server.batch)
        if reqs:
            return self._step_gated(reqs)
        self.other_results.extend(self.server.step())
        return []

    def drain(self) -> List[TemporalResult]:
        """Serve until every submitted frame has an answer; results in
        finalization order."""
        out: List[TemporalResult] = []
        self.server.policy.set_flush(True)       # non-gated lanes too
        try:
            while True:
                got = self.step()
                out.extend(got)
                if not got and self.server.queue.pending() == 0:
                    return out
        finally:
            self.server.policy.set_flush(False)

    def reset(self) -> None:
        """Drop all resident gate state (scene change / stream restart):
        the next dispatch per variant recomputes everything."""
        self._state.clear()
        self._activity = 1.0

    # -- accounting ---------------------------------------------------------

    @property
    def submitted(self) -> int:
        return self._submitted

    @property
    def frames(self) -> int:
        return self._frames_total

    @property
    def computed(self) -> int:
        return self._computed

    @property
    def skipped(self) -> int:
        return self._skipped

    @property
    def skip_ratio(self) -> float:
        return self._skipped / self._frames_total if self._frames_total else 0.0

    @property
    def activity(self) -> float:
        """EWMA of the changed fraction per dispatch (1.0 until the
        first dispatch lands)."""
        return self._activity

    def calibrate(self, frames, target_agreement: float = 0.95) -> float:
        """Calibrate ``self.threshold`` on a held-out video trace via
        :func:`calibrate_delta_threshold` (the pipeline's own current
        operating point); returns — and adopts — the chosen threshold."""
        ex = self.server.executor
        self.threshold = calibrate_delta_threshold(
            frames, target_agreement,
            program=self.server.programs[self._variant],
            artifact=ex._raw_artifacts[self._variant],
            interpret=ex._interpret)
        return self.threshold

    def report(self) -> energy.TemporalReport:
        """The chip-model energy bill for everything served so far
        (:func:`energy.temporal_report`): every frame pays the
        delta-compute toll, computed slots pay full inference energy.
        A family lane's bill sums per-variant — each variant's frames at
        its own operating point's rates."""
        per = [(v, energy.temporal_report(
                    self.server.programs[v], fr, comp, computed_padded=cpad,
                    f_hz=self.server.f_hz))
               for v, (fr, comp, cpad) in sorted(self._per_variant.items())]
        if not per:
            return energy.temporal_report(
                self.server.programs[self._variant], 0, 0,
                f_hz=self.server.f_hz)
        if len(per) == 1:
            return per[0][1]
        frames = sum(r.frames for _, r in per)
        computed = sum(r.computed for _, r in per)
        cpad = sum(r.computed_padded for _, r in per)
        skipped = frames - computed
        total_uj = sum(r.frames * r.delta_uj
                       + (r.computed + r.computed_padded) * r.full_uj
                       for _, r in per)
        ungated_uj = sum(r.frames * r.full_uj for _, r in per)
        per_frame = total_uj / frames
        ungated = ungated_uj / frames
        return energy.TemporalReport(
            frames=frames, computed=computed, computed_padded=cpad,
            skipped=skipped, skip_ratio=skipped / frames,
            delta_uj=sum(r.frames * r.delta_uj for _, r in per) / frames,
            full_uj=ungated, uj_per_frame=per_frame,
            uj_per_frame_ungated=ungated,
            savings=ungated / per_frame if per_frame else float("inf"))

"""Deterministic traffic generation + trace replay for serving benches.

BinarEye's headline workloads are *streaming*: an always-on camera feeds
frames at whatever rate the scene produces, and the chip's 0.92-14.4
uJ/f operating points are quoted per frame of that stream.  Measuring
our serving stack the same way needs arrival processes, not offline
batches — this module provides seeded, reproducible ones:

* :func:`poisson_trace` — homogeneous Poisson arrivals (exponential
  gaps), the null model of independent frame sources;
* :func:`bursty_trace` — a 2-state Markov-modulated Poisson process
  (MMPP): a calm state and a burst state with geometric dwell times,
  the camera-pan / motion-trigger pattern that stresses the admission
  window;
* :func:`diurnal_trace` — Poisson thinned by a sinusoidal envelope, the
  slow rate drift an always-on deployment sees over a day (compressed
  into the trace duration).

Every generator returns an :class:`ArrivalTrace`: lane-tagged arrival
offsets (seconds from trace start), fully determined by ``(kind, seed,
rate, ...)`` so the committed bench trace is reproducible bit-for-bit on
any host.  Traces serialize to JSON (:func:`save_trace` /
:func:`load_trace`) — the bench commits its trace parameters and CI can
re-derive the identical arrival sequence.

:func:`replay` feeds a trace into a :class:`~repro.serving.server.
ChipServer` with real-time pacing: each frame is submitted at its trace
offset (``t_submit`` stamped with the *due* time, so queueing delay is
measured against the arrival process, not the replay loop's jitter), and
the server is stepped opportunistically between arrivals.  Pass
``speed`` to time-compress a trace, or a :class:`VirtualClock` (plus its
``sleep``) to replay deterministically in tests without wall-clock
waits.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

TRAFFIC_KINDS = ("poisson", "bursty", "diurnal")


@dataclasses.dataclass(frozen=True)
class ArrivalTrace:
    """A seeded arrival process realised over one or more lanes.

    ``t`` holds arrival offsets in seconds from trace start (sorted
    ascending); ``lane[i]`` names the lane frame ``i`` arrives on.
    ``meta`` records the generator parameters — enough to regenerate the
    trace exactly.
    """
    kind: str
    seed: int
    t: np.ndarray                       # float64 offsets, sorted
    lane: Tuple[str, ...]               # lane name per arrival
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in TRAFFIC_KINDS:
            raise ValueError(
                f"kind must be one of {TRAFFIC_KINDS}, got {self.kind!r}")
        if len(self.t) != len(self.lane):
            raise ValueError(
                f"{len(self.t)} arrival times vs {len(self.lane)} lane tags")
        if len(self.t) and np.any(np.diff(self.t) < 0):
            raise ValueError("arrival times must be sorted ascending")

    def __len__(self) -> int:
        return len(self.t)

    @property
    def duration_s(self) -> float:
        return float(self.t[-1]) if len(self.t) else 0.0

    @property
    def mean_rate(self) -> float:
        """Realised arrivals/s over the trace span."""
        if len(self.t) < 2 or self.duration_s <= 0.0:
            return 0.0
        return (len(self.t) - 1) / self.duration_s


def _spread(rng: np.random.Generator, n: int,
            lanes: Sequence[str],
            weights: Optional[Sequence[float]]) -> Tuple[str, ...]:
    """Tag each arrival with a lane, i.i.d. by ``weights`` (uniform when
    omitted) — a mixed program population over one arrival process."""
    lanes = tuple(lanes)
    if not lanes:
        raise ValueError("need at least one lane")
    if weights is None:
        p = None
    else:
        w = np.asarray(weights, dtype=np.float64)
        if len(w) != len(lanes) or np.any(w < 0) or w.sum() <= 0:
            raise ValueError(f"bad lane weights {weights} for {lanes}")
        p = w / w.sum()
    idx = rng.choice(len(lanes), size=n, p=p)
    return tuple(lanes[i] for i in idx)


def poisson_trace(lanes: Sequence[str], rate: float, n: int, *,
                  seed: int = 0,
                  weights: Optional[Sequence[float]] = None) -> ArrivalTrace:
    """Homogeneous Poisson arrivals: ``n`` frames at ``rate``/s total."""
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    t -= t[0]                            # first arrival at offset 0
    return ArrivalTrace(kind="poisson", seed=seed, t=t,
                        lane=_spread(rng, n, lanes, weights),
                        meta=dict(rate=rate, n=n,
                                  lanes=list(lanes),
                                  weights=list(weights) if weights else None))


def bursty_trace(lanes: Sequence[str], rate: float, n: int, *,
                 seed: int = 0, burst_factor: float = 8.0,
                 p_enter: float = 0.05, p_exit: float = 0.25,
                 weights: Optional[Sequence[float]] = None) -> ArrivalTrace:
    """2-state MMPP: calm arrivals at a base rate, bursts at
    ``burst_factor`` times it; state flips per arrival with the given
    geometric probabilities.  The base rate is derived so the *mean*
    rate over states matches ``rate``.
    """
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if burst_factor < 1.0:
        raise ValueError(f"burst_factor must be >= 1, got {burst_factor}")
    if not (0.0 < p_enter < 1.0 and 0.0 < p_exit < 1.0):
        raise ValueError(
            f"transition probs must be in (0, 1), got {p_enter}, {p_exit}")
    rng = np.random.default_rng(seed)
    # stationary burst occupancy and the base rate matching the mean
    pi_b = p_enter / (p_enter + p_exit)
    base = rate / (1.0 - pi_b + pi_b * burst_factor)
    gaps = np.empty(n)
    burst = False
    for i in range(n):
        r = base * (burst_factor if burst else 1.0)
        gaps[i] = rng.exponential(1.0 / r)
        flip = rng.random()
        burst = (flip < p_enter) if not burst else (flip >= p_exit)
    t = np.cumsum(gaps)
    t -= t[0]
    return ArrivalTrace(kind="bursty", seed=seed, t=t,
                        lane=_spread(rng, n, lanes, weights),
                        meta=dict(rate=rate, n=n, burst_factor=burst_factor,
                                  p_enter=p_enter, p_exit=p_exit,
                                  lanes=list(lanes),
                                  weights=list(weights) if weights else None))


def diurnal_trace(lanes: Sequence[str], rate: float, n: int, *,
                  seed: int = 0, period_s: float = 10.0,
                  depth: float = 0.8,
                  weights: Optional[Sequence[float]] = None) -> ArrivalTrace:
    """Poisson arrivals thinned by a sinusoidal envelope — peak rate
    ``rate``, trough ``rate * (1 - depth)``, one full cycle every
    ``period_s`` (a day compressed to the trace duration).  Thinning a
    peak-rate Poisson stream by the envelope is the standard exact
    non-homogeneous construction.
    """
    if rate <= 0:
        raise ValueError(f"rate must be positive, got {rate}")
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if not 0.0 <= depth < 1.0:
        raise ValueError(f"depth must be in [0, 1), got {depth}")
    if period_s <= 0:
        raise ValueError(f"period_s must be positive, got {period_s}")
    rng = np.random.default_rng(seed)
    kept: List[float] = []
    t = 0.0
    while len(kept) < n:
        t += rng.exponential(1.0 / rate)
        envelope = 1.0 - depth * 0.5 * (
            1.0 + np.sin(2.0 * np.pi * t / period_s))
        if rng.random() < envelope:
            kept.append(t)
    arr = np.asarray(kept)
    arr -= arr[0]
    return ArrivalTrace(kind="diurnal", seed=seed, t=arr,
                        lane=_spread(rng, n, lanes, weights),
                        meta=dict(rate=rate, n=n, period_s=period_s,
                                  depth=depth, lanes=list(lanes),
                                  weights=list(weights) if weights else None))


_GENERATORS: Dict[str, Callable[..., ArrivalTrace]] = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
}


def make_trace(kind: str, lanes: Sequence[str], rate: float, n: int, *,
               seed: int = 0, **kwargs) -> ArrivalTrace:
    """Dispatch on ``kind`` — the CLI entry point's one-liner."""
    try:
        gen = _GENERATORS[kind]
    except KeyError:
        raise ValueError(
            f"unknown traffic kind {kind!r} (have {TRAFFIC_KINDS})")
    return gen(lanes, rate, n, seed=seed, **kwargs)


# ---------------------------------------------------------------------------
# video content: seeded frame sequences for the delta-gated temporal path
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VideoTrace:
    """A seeded multi-stream video *content* trace.

    Where :class:`ArrivalTrace` answers "when do frames arrive", this
    answers "what do the frames look like" — the signal the delta-gated
    serving path (``serving/temporal.py``) keys on.  ``frames`` is
    time-major: ``frames[t, s]`` is stream ``s``'s frame at step ``t``,
    so submitting step-by-step round-robin keeps each stream pinned to
    its batch slot.  ``changed[t, s]`` is the pixel-exact ground truth
    "does frame t differ from frame t-1 on stream s" (step 0 is always
    True: there is no predecessor to coast on).  ``meta`` records the
    generator parameters — enough to regenerate the trace exactly.
    """
    seed: int
    frames: np.ndarray                  # (T, streams, H, W, C) int32
    changed: np.ndarray                 # (T, streams) bool
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.frames.ndim != 5:
            raise ValueError(
                f"frames must be (T, streams, H, W, C), "
                f"got shape {self.frames.shape}")
        if self.changed.shape != self.frames.shape[:2]:
            raise ValueError(
                f"changed must be {self.frames.shape[:2]}, "
                f"got {self.changed.shape}")

    def __len__(self) -> int:
        return self.frames.shape[0]

    @property
    def streams(self) -> int:
        return self.frames.shape[1]

    @property
    def change_ratio(self) -> float:
        """Realised fraction of (step, stream) frames that changed."""
        return float(self.changed.mean()) if self.changed.size else 0.0


def video_trace(shape: Tuple[int, int, int], n: int, *, streams: int = 1,
                seed: int = 0, change_rate: float = 0.5,
                scene_change_every: int = 0, patch: int = 4,
                levels: int = 16) -> VideoTrace:
    """Seeded always-on camera content: static background + moving patch
    + optional scene-change events.

    Per stream: a random static background; each step the frame either
    *repeats bit-identically* (probability ``1 - change_rate`` — the
    quiet-scene case the delta gate skips) or the background reappears
    with a ``patch`` x ``patch`` block shifted by half the intensity
    range at a fresh random position (local motion).  Every
    ``scene_change_every`` steps (0 = never) the whole background
    regenerates — the scene-change event that must flush cached labels.
    ``shape`` is (H, W, C); ``levels`` is the pixel intensity range
    (``2 ** io.bits`` for a given program).  Deterministic in ``seed``;
    ``changed`` is computed pixel-exactly from the emitted frames, so it
    is ground truth even when two motion events coincide.
    """
    h, w, c = shape
    if n < 1:
        raise ValueError(f"n must be >= 1, got {n}")
    if streams < 1:
        raise ValueError(f"streams must be >= 1, got {streams}")
    if not 0.0 <= change_rate <= 1.0:
        raise ValueError(
            f"change_rate must be in [0, 1], got {change_rate}")
    if levels < 2:
        raise ValueError(f"levels must be >= 2, got {levels}")
    if scene_change_every < 0:
        raise ValueError(f"scene_change_every must be >= 0, "
                         f"got {scene_change_every}")
    rng = np.random.default_rng(seed)
    ph, pw = min(patch, h), min(patch, w)
    frames = np.empty((n, streams, h, w, c), dtype=np.int32)
    changed = np.zeros((n, streams), dtype=bool)
    bg = rng.integers(0, levels, (streams, h, w, c), dtype=np.int32)
    for t in range(n):
        for s in range(streams):
            scene_cut = t > 0 and scene_change_every and (
                t % scene_change_every == 0)
            if scene_cut:
                bg[s] = rng.integers(0, levels, (h, w, c), dtype=np.int32)
            if t == 0 or scene_cut:
                frames[t, s] = bg[s]
            elif rng.random() < change_rate:
                f = bg[s].copy()
                y = int(rng.integers(0, h - ph + 1))
                x = int(rng.integers(0, w - pw + 1))
                f[y:y + ph, x:x + pw] = (
                    f[y:y + ph, x:x + pw] + levels // 2) % levels
                frames[t, s] = f
            else:
                frames[t, s] = frames[t - 1, s]    # quiet: bit-identical
            changed[t, s] = t == 0 or not np.array_equal(
                frames[t, s], frames[t - 1, s])
    return VideoTrace(seed=seed, frames=frames, changed=changed,
                      meta=dict(kind="video", shape=list(shape), n=n,
                                streams=streams, change_rate=change_rate,
                                scene_change_every=scene_change_every,
                                patch=patch, levels=levels))


# ---------------------------------------------------------------------------
# serialization: the committed bench trace must be host-independent
# ---------------------------------------------------------------------------

def save_trace(trace: ArrivalTrace, path: str) -> None:
    with open(path, "w") as f:
        json.dump(dict(kind=trace.kind, seed=trace.seed,
                       t=[float(x) for x in trace.t],
                       lane=list(trace.lane), meta=trace.meta), f)


def load_trace(path: str) -> ArrivalTrace:
    with open(path) as f:
        d = json.load(f)
    return ArrivalTrace(kind=d["kind"], seed=d["seed"],
                        t=np.asarray(d["t"], dtype=np.float64),
                        lane=tuple(d["lane"]), meta=d.get("meta", {}))


# ---------------------------------------------------------------------------
# replay: feed a trace into a running server with arrival-time pacing
# ---------------------------------------------------------------------------

class VirtualClock:
    """A manually-advanced clock + matching sleep, for deterministic
    replay in tests: pass ``clock=vc, sleep=vc.sleep`` and simulated
    time advances only when the replay loop sleeps."""

    def __init__(self, start: float = 1.0):
        self.now = start

    def __call__(self) -> float:
        return self.now

    def sleep(self, dt: float) -> None:
        if dt > 0:
            self.now += dt

    def advance(self, dt: float) -> None:
        self.now += dt


def replay(server, trace: ArrivalTrace,
           frames: Mapping[str, Any], *, speed: float = 1.0,
           clock: Optional[Callable[[], float]] = None,
           sleep: Optional[Callable[[float], None]] = None,
           ) -> List[Any]:
    """Replay ``trace`` against ``server`` in (scaled) real time.

    ``frames[lane]`` is an array of frames cycled per lane.  Each
    arrival is submitted no earlier than its trace offset (``speed > 1``
    compresses time) and stamped with its *due* time, so measured
    latency is relative to the arrival process.  Between arrivals the
    server is stepped so dispatches overlap admission; a final
    ``drain()`` collects the tail.  Returns all ``FrameResult``s.
    """
    if speed <= 0:
        raise ValueError(f"speed must be positive, got {speed}")
    import time as _time
    clock = clock if clock is not None else _time.perf_counter
    sleep = sleep if sleep is not None else _time.sleep
    counts: Dict[str, int] = {lane: 0 for lane in frames}
    results: List[Any] = []
    t0 = clock()
    for i in range(len(trace)):
        due = t0 + float(trace.t[i]) / speed
        while True:
            now = clock()
            if now >= due:
                break
            # serve whatever the policy will release, else wait it out
            got = server.step()
            if got:
                results.extend(got)
            else:
                sleep(max(0.0, min(due - clock(), 1e-3)))
        lane = trace.lane[i]
        bank = frames[lane]
        server.submit(lane, bank[counts[lane] % len(bank)], t_submit=due)
        counts[lane] += 1
    results.extend(server.drain())
    return results

"""Cascaded always-on pipelines: cheap detector -> expensive recognizer.

The paper's flagship deployment (Sec. IV / Table 1): an always-on chip
runs the 0.92 uJ/frame S=4 face *detector* on every frame and only wakes
the 14.4 uJ/frame S=1 owner *recognizer* when a face is actually there —
the energy-accuracy hierarchy that makes an always-on budget feasible.
:class:`CascadePipeline` is that runtime on top of :class:`ChipServer`:

* every submitted frame enters the **detector** lane;
* a detector result whose logit margin (positive-class logit minus the
  best other logit) reaches ``margin`` **escalates**: the frame is
  resubmitted to the **recognizer** lane, whose label becomes the
  cascade's final answer (bit-exact vs running the recognizer offline
  on that frame — tested).  At the default ``margin=0.0`` this is
  exactly "the detector said ``positive_class``"; raising the margin
  trades recognizer energy for recall, lowering it (down to ``-inf`` =
  recognize everything) trades the other way;
* everything else finalizes with the detector's (negative) label.

Both stages run through the ordinary serving mechanism, so they batch,
pad, bill, prefetch and (when their S-modes allow) share the array like
any other lanes.  Escalations are **deferred**: promoted frames buffer
inside the pipeline until a full recognizer batch accumulates (the
trailing remainder flushes at drain) — without this, escalations drip
into the recognizer lane one or two per detector dispatch and static-
batch padding burns most of the expensive stage's energy; with it the
recognizer wakes only for (almost) full batches, which is exactly how a
real always-on hierarchy amortizes its wake-ups.
:meth:`CascadePipeline.report` bills the whole cascade with
:func:`energy.cascade_report`: detector energy on every frame plus
recognizer energy on the escalated fraction — strictly below running the
recognizer on every frame whenever the escalation rate is under
``1 - det_uj/rec_uj`` (~94% for the paper's 0.92 -> 14.4 uJ pair).

**Fused mode** (``CascadePipeline(..., fused=True)``) moves the whole
hierarchy into the kernel tier: detector + recognizer share ONE
composite SRAM image (``interpreter.pack_cascade``), the escalation
decision is made *inside* the kernel, and the recognizer drains the
in-kernel escalation queue through bounded-iteration control flow —
one dispatch per detector batch, no host round-trip, no deferred
buffering, no recognizer re-submission.  Labels are bit-exact vs the
host cascade for every margin (the kernel compares the integer logit
margin against ``ceil(margin)`` — equivalent for integer logits — see
``CascadePlan.margin_ctrl``); the energy bill is identical in shape
(detector on every slot, recognizer on the escalated count the kernel
reports back, plus its drain-chunk padding).  Fused dispatches are
compiled lazily through :meth:`Executor.cascade_for` and the warm-start
cache, like any composite.

**Margin calibration** (:func:`calibrate_margin`): instead of picking
the escalation margin by eyeball, run the detector offline on a
held-out labelled split and choose the *cheapest* (highest) margin
whose escalations still capture ``target_recall`` of the positive
frames — the margin becomes a recall contract, and energy-vs-recall is
a tunable curve.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.chip import energy, interpreter
from repro.serving.queue import FrameResult
from repro.serving.server import ChipServer


def margins_of(logits, positive_class: int = 1) -> np.ndarray:
    """Vectorized escalation margins: positive-class logit minus the best
    competing logit, float64, one per row of ``logits``."""
    lg = np.asarray(logits, dtype=np.float64)
    pos = lg[:, positive_class]
    rest = np.delete(lg, positive_class, axis=1).max(axis=1)
    return pos - rest


def margin_for_recall(margins, labels, target_recall: float) -> float:
    """The cheapest escalation margin meeting a recall target.

    ``margins`` are detector logit margins on a held-out split,
    ``labels`` boolean "this frame must escalate" ground truth.  Returns
    the largest threshold ``thr`` such that at least
    ``ceil(target_recall * P)`` of the ``P`` positive frames satisfy
    ``margin >= thr`` — highest threshold = fewest escalations = the
    cheapest operating point on the energy-vs-recall curve.  With no
    positives (or a zero target) every threshold meets the target, so
    the cheapest is ``+inf`` (escalate nothing).
    """
    m = np.asarray(margins, dtype=np.float64)
    y = np.asarray(labels, dtype=bool)
    if m.shape != y.shape:
        raise ValueError(f"margins {m.shape} and labels {y.shape} disagree")
    pos = np.sort(m[y])[::-1]
    k = int(math.ceil(target_recall * len(pos)))
    if k <= 0:
        return float("inf")
    if k > len(pos):
        raise ValueError(
            f"target_recall {target_recall} asks for {k} of "
            f"{len(pos)} positive frames")
    return float(pos[k - 1])


def calibrate_margin(frames, labels, target_recall: float = 0.95, *,
                     detector, artifact, positive_class: int = 1,
                     interpret: Optional[bool] = None) -> float:
    """Calibrate the escalation margin on a held-out split.

    Runs ``detector`` (an ISA program, with its deployment ``artifact``)
    offline over ``frames``, computes the logit margins, and returns the
    cheapest margin capturing ``target_recall`` of the frames whose
    ``labels`` mark them positive (:func:`margin_for_recall`).  Replaces
    margin-by-heuristic (e.g. the bench's old median margin): the chosen
    margin carries a recall guarantee *on the calibration split*.
    """
    frames = np.asarray(frames)
    labels = np.asarray(labels, dtype=bool)
    if len(frames) != len(labels):
        raise ValueError(f"{len(frames)} frames vs {len(labels)} labels")
    plan = interpreter.compile_plan(detector)
    logits, _ = plan.forward(interpreter.ensure_packed(artifact), frames,
                             interpret=interpret)
    return margin_for_recall(margins_of(np.asarray(logits), positive_class),
                             labels, target_recall)


@dataclasses.dataclass(frozen=True)
class CascadeResult:
    """The cascade's final answer for one submitted frame."""
    rid: int                    # cascade-level request id (arrival order)
    label: int                  # recognizer label if escalated, else the
                                # detector's negative label
    escalated: bool
    detector_label: int
    detector_margin: float      # positive logit - best other logit
    logits: np.ndarray          # logits of the stage that produced label


class CascadePipeline:
    """Two-stage always-on cascade over a :class:`ChipServer`.

    ``detector`` and ``recognizer`` are resident lane names on
    ``server``; both must accept the same frame geometry.  ``margin``
    is the escalation threshold on the detector's logit margin (0.0 =
    escalate every positive-labelled frame).

    ``fused=True`` serves the hierarchy as ONE kernel dispatch per
    detector batch: frames still enqueue on the detector lane, but each
    step pulls a batch and runs it through the fused cascade kernel
    (``Executor.cascade_for``) — detector, in-kernel escalation mask,
    and recognizer-over-escalated-lanes in a single ``pallas_call``.
    Labels are bit-exact vs the host path for every margin; results
    finalize in the same step (no deferred recognizer batches).  Lanes
    outside the cascade still serve through the ordinary server path in
    either mode.
    """

    def __init__(self, server: ChipServer, detector: str, recognizer: str,
                 *, positive_class: int = 1, margin: float = 0.0,
                 fused: bool = False):
        for lane in (detector, recognizer):
            if lane not in server.queue.lanes:
                raise KeyError(f"lane {lane!r} not resident on the server "
                               f"(have {sorted(server.queue.lanes)})")
            if len(server._lane_variants[lane]) > 1:
                raise ValueError(
                    f"cascade stage {lane!r} is a program family; cascade "
                    "stages must be single-variant lanes (the energy bill "
                    "is per stage program)")
        if detector == recognizer:
            raise ValueError("detector and recognizer must be distinct lanes")
        gd = server._geom[detector]
        gr = server._geom[recognizer]
        if gd != gr:
            raise ValueError(
                f"cascade stages disagree on frame geometry: "
                f"detector {gd} vs recognizer {gr}")
        self.server = server
        self.detector = detector
        self.recognizer = recognizer
        self.positive_class = positive_class
        self.margin = margin
        self.fused = fused
        self._det_variant = server._lane_variants[detector][0]
        self._rec_variant = server._lane_variants[recognizer][0]
        # the fused dispatch unit compiles eagerly (like warm_composites:
        # resident programs load their weights before serving) and routes
        # through the executor's warm-start cache
        self._fused = (server.executor.cascade_for(
            self._det_variant, self._rec_variant,
            positive_class=positive_class) if fused else None)
        self.fused_dispatches = 0
        self._next_rid = 0
        self._frames: Dict[int, np.ndarray] = {}   # srid -> frame (det stage)
        self._det_rid: Dict[int, int] = {}         # det srid -> cascade rid
        self._rec_rid: Dict[int, int] = {}         # rec srid -> cascade rid
        self._det_info: Dict[int, tuple] = {}      # crid -> (label, margin)
        self._deferred: List[tuple] = []           # (crid, frame) awaiting a
                                                   # full recognizer batch
        self.other_results: List[FrameResult] = []  # results of server lanes
                                                    # outside the cascade
        self._submitted = 0
        self._escalated = 0

    # -- request side -------------------------------------------------------

    def submit(self, frame) -> int:
        """Enqueue one frame on the detector stage; returns its cascade
        request id (arrival order)."""
        rid = self._next_rid
        self._next_rid += 1
        srid = self.server.submit(self.detector, frame)
        self._det_rid[srid] = rid
        if not self.fused:       # fused dispatches gather frames in-kernel
            self._frames[srid] = np.asarray(frame)
        self._submitted += 1
        return rid

    def submit_many(self, frames) -> List[int]:
        return [self.submit(f) for f in frames]

    # -- dispatch side ------------------------------------------------------

    def _margin(self, logits: np.ndarray) -> float:
        """Positive-class logit minus the best competing logit."""
        pos = float(logits[self.positive_class])
        rest = np.delete(np.asarray(logits, dtype=np.float64),
                         self.positive_class)
        return pos - float(rest.max())

    def _route(self, r: FrameResult) -> Optional[CascadeResult]:
        """Process one server result: finalize, or escalate and return
        ``None`` (the recognizer's result will finalize later).  Results
        of lanes outside the cascade — the server may host other
        resident programs — pass through to :attr:`other_results`."""
        if r.rid not in self._det_rid and r.rid not in self._rec_rid:
            self.other_results.append(r)
            return None
        if r.rid in self._det_rid:
            crid = self._det_rid.pop(r.rid)
            frame = self._frames.pop(r.rid)
            m = self._margin(r.logits)
            if m >= self.margin:
                self._deferred.append((crid, frame))
                self._det_info[crid] = (r.label, m)
                self._escalated += 1
                self._flush(full_only=True)
                return None
            return CascadeResult(rid=crid, label=int(r.label),
                                 escalated=False, detector_label=int(r.label),
                                 detector_margin=m, logits=r.logits)
        crid = self._rec_rid.pop(r.rid)
        det_label, det_margin = self._det_info.pop(crid)
        return CascadeResult(rid=crid, label=int(r.label), escalated=True,
                             detector_label=det_label,
                             detector_margin=det_margin, logits=r.logits)

    def _flush(self, full_only: bool = False) -> None:
        """Submit deferred escalations to the recognizer lane — whole
        static batches only when ``full_only`` (the steady-state rule),
        everything when draining (the trailing partial batch)."""
        while len(self._deferred) >= self.server.batch or (
                self._deferred and not full_only):
            take = self._deferred[:self.server.batch]
            del self._deferred[:self.server.batch]
            for crid, frame in take:
                srid = self.server.submit(self.recognizer, frame)
                self._rec_rid[srid] = crid

    def _step_fused(self, reqs) -> List[CascadeResult]:
        """One fused dispatch: a detector batch through the in-kernel
        cascade; every frame in it finalizes immediately (escalated
        frames carry the recognizer's answer from the same kernel)."""
        srv = self.server
        t0 = srv.clock()
        size = srv.batch
        frames = srv.executor.pad_frames(reqs, srv._geom[self.detector],
                                         size)
        ctrl = interpreter.CascadePlan.margin_ctrl(self.margin, len(reqs))
        dl, dlab, rl, rlab, queue, counts = self._fused["fn"](
            self._fused["image"], frames, ctrl)
        dl, dlab = np.asarray(dl), np.asarray(dlab)
        rl, rlab = np.asarray(rl), np.asarray(rlab)
        queue, counts = np.asarray(queue), np.asarray(counts)
        esc, slots = int(counts[0]), int(counts[1])
        # bill both phases at launch like ChipServer._launch: detector
        # on every batch slot, recognizer on the slots the kernel
        # actually computed (escalated + drain-chunk padding, from the
        # kernel's own scalar report)
        n = len(reqs)
        srv._served[self.detector] += n
        srv._padded[self.detector] += size - n
        srv._vserved[self._det_variant] += n
        srv._vpadded[self._det_variant] += size - n
        srv._served[self.recognizer] += esc
        srv._padded[self.recognizer] += slots - esc
        srv._vserved[self._rec_variant] += esc
        srv._vpadded[self._rec_variant] += slots - esc
        srv._billed += size + slots
        srv._dispatches += 1
        # sequential phases: slot-weighted mean of the two occupancies
        sd = srv.programs[self._det_variant].s
        sr = srv.programs[self._rec_variant].s
        srv._util_sum += (size / sd + slots / sr) / (size + slots)
        self.fused_dispatches += 1
        self._escalated += esc
        rank = {int(p): k for k, p in enumerate(queue[:esc])}
        out = []
        for i, r in enumerate(reqs):
            crid = self._det_rid.pop(r.rid)
            m = self._margin(dl[i])
            k = rank.get(i)
            if k is None:
                out.append(CascadeResult(
                    rid=crid, label=int(dlab[i]), escalated=False,
                    detector_label=int(dlab[i]), detector_margin=m,
                    logits=dl[i]))
            else:
                out.append(CascadeResult(
                    rid=crid, label=int(rlab[k]), escalated=True,
                    detector_label=int(dlab[i]), detector_margin=m,
                    logits=rl[k]))
        srv._host_wall_s += srv.clock() - t0
        return out

    def step(self) -> List[CascadeResult]:
        """One dispatch; returns any cascade results it finalized.

        Host mode: one server dispatch (escalating detector hits
        finalize on a later recognizer dispatch).  Fused mode: one
        detector batch through the in-kernel cascade, every frame in it
        final; the server only steps for lanes outside the cascade.
        [] when there was nothing to run."""
        if self.fused:
            reqs = self.server.queue.take(self.detector, self.server.batch)
            if reqs:
                return self._step_fused(reqs)
            got = self.server.step()      # lanes outside the cascade
            return [c for c in map(self._route, got) if c is not None]
        got = self.server.step()
        if not got and self._deferred:
            self._flush()                  # trailing partial batch
            got = self.server.step()
        return [c for c in map(self._route, got) if c is not None]

    def drain(self) -> List[CascadeResult]:
        """Serve until every submitted frame (including frames escalated
        along the way) has a final answer; results in finalization
        order."""
        out: List[CascadeResult] = []
        if self.fused:
            self.server.policy.set_flush(True)   # non-cascade lanes too
            try:
                while True:
                    got = self.step()
                    out.extend(got)
                    if not got and self.server.queue.pending() == 0:
                        return out
            finally:
                self.server.policy.set_flush(False)
        while True:
            got = self.server.step()
            if not got:
                if self._deferred:
                    self._flush()          # trailing partial batch
                    continue
                if self.server.queue.pending() == 0:
                    return out
                continue
            out.extend(c for c in map(self._route, got) if c is not None)

    # -- accounting ---------------------------------------------------------

    @property
    def submitted(self) -> int:
        return self._submitted

    @property
    def escalated(self) -> int:
        return self._escalated

    def calibrate(self, frames, labels,
                  target_recall: float = 0.95) -> float:
        """Calibrate ``self.margin`` on a held-out labelled split via
        :func:`calibrate_margin` (the pipeline's own detector program
        and artifact); returns — and adopts — the chosen margin."""
        ex = self.server.executor
        self.margin = calibrate_margin(
            frames, labels, target_recall,
            detector=self.server.programs[self._det_variant],
            artifact=ex._raw_artifacts[self._det_variant],
            positive_class=self.positive_class,
            interpret=ex._interpret)
        return self.margin

    def report(self, include_padding: bool = True) -> energy.CascadeReport:
        """The chip-model energy bill for everything this cascade served
        so far (see :func:`energy.cascade_report`).  ``include_padding``
        bills the static-batch padding slots each stage actually burned
        on the server (the honest deployment figure).

        All four figures come from the server's *launch ledger* (billed
        at dispatch, ``billed == served + padded`` per stage): detector
        frames and escalations that actually hit the array.  A
        mid-stream report therefore never bills frames still queued or
        deferred, and the drain-time recognizer remainder's padding is
        billed exactly once — the escalation rate's denominator is the
        detector frames served, not the padded slot count."""
        det_prog = self.server.programs[self._det_variant]
        rec_prog = self.server.programs[self._rec_variant]
        stats = self.server.stats()
        frames = stats.served.get(self.detector, 0)
        escalated = stats.served.get(self.recognizer, 0)
        padded_det = stats.padded.get(self.detector, 0)
        padded_rec = stats.padded.get(self.recognizer, 0)
        if not include_padding:
            padded_det = padded_rec = 0
        return energy.cascade_report(
            det_prog, rec_prog, frames=frames,
            escalated=escalated, detector_padded=padded_det,
            recognizer_padded=padded_rec, f_hz=self.server.f_hz)

"""Cascaded always-on pipelines: cheap detector -> expensive recognizer.

The paper's flagship deployment (Sec. IV / Table 1): an always-on chip
runs the 0.92 uJ/frame S=4 face *detector* on every frame and only wakes
the 14.4 uJ/frame S=1 owner *recognizer* when a face is actually there —
the energy-accuracy hierarchy that makes an always-on budget feasible.
:class:`CascadePipeline` is that runtime on top of :class:`ChipServer`:

* every submitted frame enters the **detector** lane;
* a detector result whose logit margin (positive-class logit minus the
  best other logit) reaches ``margin`` **escalates**: the frame is
  resubmitted to the **recognizer** lane, whose label becomes the
  cascade's final answer (bit-exact vs running the recognizer offline
  on that frame — tested).  At the default ``margin=0.0`` this is
  exactly "the detector said ``positive_class``"; raising the margin
  trades recognizer energy for recall, lowering it (down to ``-inf`` =
  recognize everything) trades the other way;
* everything else finalizes with the detector's (negative) label.

Both stages run through the ordinary serving mechanism, so they batch,
pad, bill, prefetch and (when their S-modes allow) share the array like
any other lanes.  Escalations are **deferred**: promoted frames buffer
inside the pipeline until a full recognizer batch accumulates (the
trailing remainder flushes at drain) — without this, escalations drip
into the recognizer lane one or two per detector dispatch and static-
batch padding burns most of the expensive stage's energy; with it the
recognizer wakes only for (almost) full batches, which is exactly how a
real always-on hierarchy amortizes its wake-ups.
:meth:`CascadePipeline.report` bills the whole cascade with
:func:`energy.cascade_report`: detector energy on every frame plus
recognizer energy on the escalated fraction — strictly below running the
recognizer on every frame whenever the escalation rate is under
``1 - det_uj/rec_uj`` (~94% for the paper's 0.92 -> 14.4 uJ pair).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.chip import energy
from repro.serving.queue import FrameResult
from repro.serving.server import ChipServer


@dataclasses.dataclass(frozen=True)
class CascadeResult:
    """The cascade's final answer for one submitted frame."""
    rid: int                    # cascade-level request id (arrival order)
    label: int                  # recognizer label if escalated, else the
                                # detector's negative label
    escalated: bool
    detector_label: int
    detector_margin: float      # positive logit - best other logit
    logits: np.ndarray          # logits of the stage that produced label


class CascadePipeline:
    """Two-stage always-on cascade over a :class:`ChipServer`.

    ``detector`` and ``recognizer`` are resident lane names on
    ``server``; both must accept the same frame geometry.  ``margin``
    is the escalation threshold on the detector's logit margin (0.0 =
    escalate every positive-labelled frame).
    """

    def __init__(self, server: ChipServer, detector: str, recognizer: str,
                 *, positive_class: int = 1, margin: float = 0.0):
        for lane in (detector, recognizer):
            if lane not in server.queue.lanes:
                raise KeyError(f"lane {lane!r} not resident on the server "
                               f"(have {sorted(server.queue.lanes)})")
            if len(server._lane_variants[lane]) > 1:
                raise ValueError(
                    f"cascade stage {lane!r} is a program family; cascade "
                    "stages must be single-variant lanes (the energy bill "
                    "is per stage program)")
        if detector == recognizer:
            raise ValueError("detector and recognizer must be distinct lanes")
        gd = server._geom[detector]
        gr = server._geom[recognizer]
        if gd != gr:
            raise ValueError(
                f"cascade stages disagree on frame geometry: "
                f"detector {gd} vs recognizer {gr}")
        self.server = server
        self.detector = detector
        self.recognizer = recognizer
        self.positive_class = positive_class
        self.margin = margin
        self._next_rid = 0
        self._frames: Dict[int, np.ndarray] = {}   # srid -> frame (det stage)
        self._det_rid: Dict[int, int] = {}         # det srid -> cascade rid
        self._rec_rid: Dict[int, int] = {}         # rec srid -> cascade rid
        self._det_info: Dict[int, tuple] = {}      # crid -> (label, margin)
        self._deferred: List[tuple] = []           # (crid, frame) awaiting a
                                                   # full recognizer batch
        self.other_results: List[FrameResult] = []  # results of server lanes
                                                    # outside the cascade
        self._submitted = 0
        self._escalated = 0

    # -- request side -------------------------------------------------------

    def submit(self, frame) -> int:
        """Enqueue one frame on the detector stage; returns its cascade
        request id (arrival order)."""
        rid = self._next_rid
        self._next_rid += 1
        srid = self.server.submit(self.detector, frame)
        self._det_rid[srid] = rid
        self._frames[srid] = np.asarray(frame)
        self._submitted += 1
        return rid

    def submit_many(self, frames) -> List[int]:
        return [self.submit(f) for f in frames]

    # -- dispatch side ------------------------------------------------------

    def _margin(self, logits: np.ndarray) -> float:
        """Positive-class logit minus the best competing logit."""
        pos = float(logits[self.positive_class])
        rest = np.delete(np.asarray(logits, dtype=np.float64),
                         self.positive_class)
        return pos - float(rest.max())

    def _route(self, r: FrameResult) -> Optional[CascadeResult]:
        """Process one server result: finalize, or escalate and return
        ``None`` (the recognizer's result will finalize later).  Results
        of lanes outside the cascade — the server may host other
        resident programs — pass through to :attr:`other_results`."""
        if r.rid not in self._det_rid and r.rid not in self._rec_rid:
            self.other_results.append(r)
            return None
        if r.rid in self._det_rid:
            crid = self._det_rid.pop(r.rid)
            frame = self._frames.pop(r.rid)
            m = self._margin(r.logits)
            if m >= self.margin:
                self._deferred.append((crid, frame))
                self._det_info[crid] = (r.label, m)
                self._escalated += 1
                self._flush(full_only=True)
                return None
            return CascadeResult(rid=crid, label=int(r.label),
                                 escalated=False, detector_label=int(r.label),
                                 detector_margin=m, logits=r.logits)
        crid = self._rec_rid.pop(r.rid)
        det_label, det_margin = self._det_info.pop(crid)
        return CascadeResult(rid=crid, label=int(r.label), escalated=True,
                             detector_label=det_label,
                             detector_margin=det_margin, logits=r.logits)

    def _flush(self, full_only: bool = False) -> None:
        """Submit deferred escalations to the recognizer lane — whole
        static batches only when ``full_only`` (the steady-state rule),
        everything when draining (the trailing partial batch)."""
        while len(self._deferred) >= self.server.batch or (
                self._deferred and not full_only):
            take = self._deferred[:self.server.batch]
            del self._deferred[:self.server.batch]
            for crid, frame in take:
                srid = self.server.submit(self.recognizer, frame)
                self._rec_rid[srid] = crid

    def step(self) -> List[CascadeResult]:
        """One server dispatch; returns any cascade results it finalized
        (escalating detector hits finalize on a later recognizer
        dispatch).  [] when the server had nothing to run."""
        got = self.server.step()
        if not got and self._deferred:
            self._flush()                  # trailing partial batch
            got = self.server.step()
        return [c for c in map(self._route, got) if c is not None]

    def drain(self) -> List[CascadeResult]:
        """Serve until every submitted frame (including frames escalated
        along the way) has a final answer; results in finalization
        order."""
        out: List[CascadeResult] = []
        while True:
            got = self.server.step()
            if not got:
                if self._deferred:
                    self._flush()          # trailing partial batch
                    continue
                if self.server.queue.pending() == 0:
                    return out
                continue
            out.extend(c for c in map(self._route, got) if c is not None)

    # -- accounting ---------------------------------------------------------

    @property
    def submitted(self) -> int:
        return self._submitted

    @property
    def escalated(self) -> int:
        return self._escalated

    def report(self, include_padding: bool = True) -> energy.CascadeReport:
        """The chip-model energy bill for everything this cascade served
        so far (see :func:`energy.cascade_report`).  ``include_padding``
        bills the static-batch padding slots each stage actually burned
        on the server (the honest deployment figure)."""
        det_prog = self.server.programs[
            self.server._lane_variants[self.detector][0]]
        rec_prog = self.server.programs[
            self.server._lane_variants[self.recognizer][0]]
        stats = self.server.stats()
        padded_det = stats.padded.get(self.detector, 0)
        padded_rec = stats.padded.get(self.recognizer, 0)
        if not include_padding:
            padded_det = padded_rec = 0
        return energy.cascade_report(
            det_prog, rec_prog, frames=self._submitted,
            escalated=self._escalated, detector_padded=padded_det,
            recognizer_padded=padded_rec, f_hz=self.server.f_hz)

"""Chip-tier serving: multi-program static-batch execution of InferencePlans.

Mechanism/policy split (see :mod:`repro.serving.server` for the model and
``docs/serving.md`` for the chip analogy):

* queue    — per-lane FIFOs + round-robin pointer (:mod:`.queue`)
* policy   — static, operating-point, or continuous dispatch (:mod:`.policy`)
* executor — pad/dispatch/finish + prefetch pipeline (:mod:`.executor`)
* server   — the thin ``ChipServer`` composition (:mod:`.server`)
* fleet    — N-replica serve fleet with failover migration and
  warm-started replacement hosts (:mod:`.fleet`)
* cascade  — detector -> recognizer always-on pipelines (:mod:`.cascade`)
* traffic  — seeded arrival traces + replay for latency benches
  (:mod:`.traffic`)
"""

from repro.serving.cascade import (CascadePipeline,  # noqa: F401
                                   CascadeResult, calibrate_margin,
                                   margin_for_recall, margins_of)
from repro.serving.fleet import (  # noqa: F401
    FaultInjector,
    FleetStats,
    ServeFleet,
)
from repro.serving.policy import (  # noqa: F401
    ContinuousPolicy,
    Dispatch,
    DispatchPolicy,
    LaneDispatch,
    OperatingPointPolicy,
    PolicyContext,
    StaticPolicy,
)
from repro.serving.queue import (  # noqa: F401
    EwmaRate,
    FrameQueue,
    FrameRequest,
    FrameResult,
    plan_shared_groups,
)
from repro.serving.server import ChipServer, ServeStats  # noqa: F401
from repro.serving.traffic import (  # noqa: F401
    ArrivalTrace,
    VirtualClock,
    bursty_trace,
    diurnal_trace,
    load_trace,
    make_trace,
    poisson_trace,
    replay,
    save_trace,
)

"""Chip-tier serving: multi-program static-batch execution of InferencePlans.

Mechanism/policy split (see :mod:`repro.serving.server` for the model and
``docs/serving.md`` for the chip analogy):

* queue    — per-lane FIFOs + round-robin pointer (:mod:`.queue`)
* policy   — static, operating-point, or continuous dispatch (:mod:`.policy`)
* executor — pad/dispatch/finish + prefetch pipeline (:mod:`.executor`)
* server   — the thin ``ChipServer`` composition (:mod:`.server`)
* fleet    — N-replica serve fleet with failover migration and
  warm-started replacement hosts (:mod:`.fleet`)
* cascade  — detector -> recognizer always-on pipelines (:mod:`.cascade`)
* temporal — delta-gated always-on video serving: skip unchanged
  frames, downshift quiet scenes (:mod:`.temporal`)
* traffic  — seeded arrival traces + replay for latency benches, plus
  seeded video *content* traces for the temporal tier (:mod:`.traffic`)
"""

from repro.serving.cascade import (CascadePipeline,  # noqa: F401
                                   CascadeResult, calibrate_margin,
                                   margin_for_recall, margins_of)
from repro.serving.fleet import (  # noqa: F401
    FaultInjector,
    FleetStats,
    ServeFleet,
)
from repro.serving.policy import (  # noqa: F401
    ContinuousPolicy,
    Dispatch,
    DispatchPolicy,
    LaneDispatch,
    OperatingPointPolicy,
    PolicyContext,
    StaticPolicy,
)
from repro.serving.queue import (  # noqa: F401
    EwmaRate,
    FrameQueue,
    FrameRequest,
    FrameResult,
    plan_shared_groups,
)
from repro.serving.server import ChipServer, ServeStats  # noqa: F401
from repro.serving.temporal import (  # noqa: F401
    TemporalPipeline,
    TemporalResult,
    calibrate_delta_threshold,
    simulate_gate,
    threshold_for_skip,
)
from repro.serving.traffic import (  # noqa: F401
    ArrivalTrace,
    VideoTrace,
    VirtualClock,
    bursty_trace,
    diurnal_trace,
    load_trace,
    make_trace,
    poisson_trace,
    replay,
    save_trace,
    video_trace,
)

"""Chip-tier serving: multi-program static-batch execution of InferencePlans.

See :mod:`repro.serving.scheduler` for the S-mode batching model and
``docs/serving.md`` for the chip analogy.
"""

from repro.serving.scheduler import (  # noqa: F401
    ChipServer,
    FrameQueue,
    FrameRequest,
    FrameResult,
    ServeStats,
)

"""ServeFleet: N chip replicas, host-major scatter, failover migration.

One BinarEye die is a complete serving unit — weights in SRAM,
instructions in program memory, frames in and labels out.  A deployment
that needs more throughput (or availability) than one die runs a *board*
of them: identical images, each chip serving its share of the stream.
This module is the TPU-tier analogue: a :class:`ServeFleet` runs N
:class:`~repro.serving.server.ChipServer` replicas — "simulated hosts"
over disjoint sub-meshes of the serving device set
(:func:`repro.distributed.sharding.partition_serve_meshes`) — behind the
same ``submit/step/drain`` surface a single server exposes, so
:func:`repro.serving.traffic.replay` drives a fleet unmodified.

* **Scatter** — admitted frames route host-major: each lane hands out
  blocks of ``batch`` consecutive frames to the live replicas in
  rotation, so replicas receive whole dispatches, not interleaved
  singles.  Request ids are fleet-global (the fleet stamps them;
  replicas accept them via ``submit(rid=...)``) so results from
  different replicas never collide.
* **Failover** — a pluggable :class:`FaultInjector` kills a replica
  mid-replay.  The victim's unfinished frames (in-flight dispatches
  first, then its queued FIFO — order preserved) migrate to the
  survivors' lane *fronts* (:meth:`FrameQueue.requeue_front`): they are
  older than anything admitted after the failure, so they serve first
  and per-lane queue-entry order is preserved per replica.  Served
  labels stay bit-exact against the offline oracle with zero frame
  loss; energy the victim billed for abandoned in-flight work stays
  billed (it was burned on the array) and migrated in-flight frames are
  honestly re-billed by whoever serves them (``refired_frames``).
* **Replacement** — with ``replace=True`` a failed host is rebuilt on
  its own devices: the mesh comes back through the restore-after-fault
  path (:func:`repro.checkpoint.ckpt.make_mesh`) and the bring-up runs
  under :func:`repro.distributed.fault.retry_step` with deterministic
  exponential backoff (injectable sleep).  Because serve-fn builds go
  through the warm-start cache (:mod:`repro.kernels.cache`), a
  replacement on the same computation keys skips trace+compile — the
  kill-to-first-served-frame time is :attr:`ServeFleet.recovery_ms`,
  tracked in the bench as ``fleet_failover_recovery_ms`` /
  ``replica_warm_start_speedup``.
* **Stats** — :meth:`stats` merges per-replica books into
  :class:`FleetStats`: latency percentiles re-computed over the merged
  traces, served/padded/billed/energy summed (fleet-wide
  ``billed == served + padded`` holds because it holds per replica),
  and the chip-model bill aggregated by
  :func:`repro.core.chip.energy.fleet_report`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.checkpoint import ckpt
from repro.core.chip import energy, isa
from repro.distributed import fault, sharding
from repro.kernels import cache as warmcache
from repro.serving.queue import FrameRequest, FrameResult
from repro.serving.server import ChipServer, ServeStats


class FaultInjector:
    """Kill ``victim`` once the fleet has served ``after_served`` frames.

    The base injector fires exactly once, from :meth:`ServeFleet.step`
    (i.e. mid-replay when a traffic replay is driving the fleet).
    Subclass and override :meth:`poll` for richer schedules — return a
    live replica name to kill it now, ``None`` to do nothing.
    """

    def __init__(self, victim: str, after_served: int = 0):
        self.victim = victim
        self.after_served = after_served
        self.fired = False

    def poll(self, fleet: "ServeFleet") -> Optional[str]:
        if (not self.fired and fleet.total_served >= self.after_served
                and self.victim in fleet.live_replicas):
            self.fired = True
            return self.victim
        return None


@dataclasses.dataclass(frozen=True)
class FleetStats:
    """Fleet-level books: per-replica stats plus the merged bill."""
    replicas: Dict[str, ServeStats]   # replica name -> its own books
    served: Dict[str, int]            # lane -> frames served, fleet-wide
    padded: Dict[str, int]            # lane -> padding burned, fleet-wide
    dispatches: int
    host_wall_s: float                # sum of replica dispatch wall time
                                      # (replicas share this process)
    host_frames_per_s: float
    chip: energy.FleetReport          # chip-model bill, N dies in parallel
    billed: int                       # frame slots launched fleet-wide
    p50_ms: float = 0.0               # percentiles over the MERGED traces
    p95_ms: float = 0.0               # (not averaged per-replica numbers)
    p99_ms: float = 0.0
    padding_ratio: float = 0.0
    energy_uj: float = 0.0
    migrated_frames: int = 0          # orphans moved to survivors
    refired_frames: int = 0           # migrated frames that were in-flight
                                      # on the victim (billed twice)
    failed_replicas: Tuple[str, ...] = ()
    recovery_ms: Optional[float] = None   # kill -> replacement's first
                                          # served frame (None: no
                                          # replacement has served yet)
    warm_start: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def total_served(self) -> int:
        return sum(self.served.values())


class ServeFleet:
    """N ChipServer replicas behind one ``submit/step/drain`` surface.

    ``replicas`` names come out as ``host0..host{N-1}``; replacements
    append a generation suffix (``host1r1``).  ``devices`` (default: all
    local devices) are partitioned host-major into per-replica
    sub-meshes; with fewer devices than replicas the replicas share
    devices (simulation only).  All per-server options
    (``shared``/``policy``/``families``/``prefetch``/...) pass through
    ``**server_kw`` to every replica; every replica shares the fleet's
    injected ``clock``.

    ``injector`` arms a :class:`FaultInjector`; ``replace=True`` rebuilds
    a killed host (``retries``/``backoff_s``/``sleep`` parameterize the
    :func:`~repro.distributed.fault.retry_step` bring-up loop).
    """

    def __init__(self, programs: Mapping[str, isa.Program],
                 artifacts: Mapping[str, Any], *, replicas: int = 2,
                 batch: int = 8, devices=None,
                 injector: Optional[FaultInjector] = None,
                 replace: bool = False, retries: int = 2,
                 backoff_s: float = 0.0,
                 sleep=time.sleep, clock=time.perf_counter,
                 **server_kw):
        if replicas < 1:
            raise ValueError(f"need >= 1 replica, got {replicas}")
        self.clock = clock
        self.injector = injector
        self.replace = replace
        self._retries = retries
        self._backoff_s = backoff_s
        self._sleep = sleep
        self._programs = dict(programs)
        self._artifacts = dict(artifacts)
        self._server_kw = dict(server_kw, batch=batch, clock=clock)
        self.batch = batch
        meshes = sharding.partition_serve_meshes(replicas, devices)
        self.replicas: Dict[str, ChipServer] = {}
        self._devices: Dict[str, list] = {}
        self._live: List[str] = []
        for i, mesh in enumerate(meshes):
            name = f"host{i}"
            self.replicas[name] = ChipServer(
                self._programs, self._artifacts, mesh=mesh,
                **self._server_kw)
            self._devices[name] = list(mesh.devices.flatten())
            self._live.append(name)
        self.lanes = self.replicas[self._live[0]].queue.lanes
        # -- books ----------------------------------------------------------
        self._next_rid = 0
        self._routed: Dict[str, int] = {lane: 0 for lane in self.lanes}
        self._dead: Dict[str, ChipServer] = {}    # victims keep their books
        self._migrated = 0
        self._refired = 0
        self.retry_stats: Dict[str, Any] = {}     # retry_step's out-dict
        self._recovery: Optional[Dict[str, Any]] = None

    # -- surface (duck-types ChipServer for traffic.replay) -----------------

    @property
    def live_replicas(self) -> Tuple[str, ...]:
        return tuple(self._live)

    @property
    def failed_replicas(self) -> Tuple[str, ...]:
        return tuple(self._dead)

    @property
    def total_served(self) -> int:
        return sum(sum(s._served.values())
                   for s in list(self.replicas.values())
                   + list(self._dead.values()))

    def _route(self, lane: str) -> str:
        """Host-major block scatter: blocks of ``batch`` consecutive
        admissions on a lane go to one live replica, rotating."""
        i = self._routed[lane]
        self._routed[lane] = i + 1
        return self._live[(i // self.batch) % len(self._live)]

    def submit(self, program: str, frame,
               t_submit: Optional[float] = None) -> int:
        """Enqueue one frame; the fleet assigns the (global) request id
        and routes the frame to a live replica."""
        rid = self._next_rid
        target = self.replicas[self._route(program)]
        target.submit(program, frame, t_submit=t_submit, rid=rid)
        self._next_rid += 1
        return rid

    def submit_many(self, program: str, frames) -> List[int]:
        return [self.submit(program, f) for f in frames]

    def step(self) -> List[FrameResult]:
        """One fleet tick: poll the fault injector, then one dispatch on
        every live replica.  Results are the concatenation, replica
        order; [] once every replica is drained."""
        if self.injector is not None:
            victim = self.injector.poll(self)
            if victim is not None:
                self.fail(victim)
        out: List[FrameResult] = []
        for name in list(self._live):
            got = self.replicas[name].step()
            if got and self._recovery is not None and \
                    self._recovery["t_first"] is None and \
                    name == self._recovery["replica"]:
                self._recovery["t_first"] = self.clock()
            out.extend(got)
        return out

    def drain(self) -> List[FrameResult]:
        """Serve until every live replica's queue is empty."""
        out: List[FrameResult] = []
        flushed = set()

        def flush_live():
            # replacements spawned mid-drain must flush too
            for name in self._live:
                if name not in flushed:
                    self.replicas[name].policy.set_flush(True)
                    flushed.add(name)

        flush_live()
        try:
            while True:
                got = self.step()
                flush_live()
                out.extend(got)
                if got:
                    continue
                if not any(len(self.replicas[n].queue)
                           for n in self._live):
                    return out
        finally:
            for name in flushed:
                if name in self.replicas:
                    self.replicas[name].policy.set_flush(False)

    def close(self) -> None:
        for name in self._live:
            self.replicas[name].close()

    # -- failover -----------------------------------------------------------

    def fail(self, name: str) -> Dict[str, List[FrameRequest]]:
        """Kill replica ``name``: harvest its unfinished frames, migrate
        them to the survivors' lane fronts, and (with ``replace=True``)
        bring up a replacement host on the victim's devices.  Returns
        the migrated orphans by lane (order as re-enqueued)."""
        if name not in self.replicas or name in self._dead:
            raise KeyError(f"replica {name!r} not live "
                           f"(live: {self._live})")
        t_kill = self.clock()
        victim = self.replicas.pop(name)
        self._live.remove(name)
        orphans = victim.fail()
        self._dead[name] = victim        # its ledger stays in the bill
        for reqs in orphans.values():
            self._migrated += len(reqs)
        self._refired += victim.aborted_inflight
        if self.replace:
            self._spawn_replacement(name, t_kill)
        if not self._live:
            raise RuntimeError(
                f"replica {name!r} failed with no survivors; its "
                f"{sum(map(len, orphans.values()))} frames are lost")
        # older-than-anything-admitted-since: front of a survivor's lane,
        # one survivor per lane (rotating) so migration stays balanced
        # without interleaving a lane's orphans across hosts
        for i, (lane, reqs) in enumerate(sorted(orphans.items())):
            survivor = self.replicas[self._live[i % len(self._live)]]
            survivor.queue.requeue_front(lane, reqs)
        return orphans

    def _spawn_replacement(self, dead_name: str, t_kill: float) -> None:
        """Rebuild a host on the victim's devices via the
        restore-after-fault mesh path, retrying with backoff."""
        devs = self._devices[dead_name]
        gen = 1
        name = f"{dead_name}r{gen}"
        while name in self.replicas or name in self._dead:
            gen += 1
            name = f"{dead_name}r{gen}"

        def build() -> ChipServer:
            mesh = ckpt.make_mesh((len(devs),), (sharding.SERVE_AXIS,),
                                  devices=devs)
            return ChipServer(self._programs, self._artifacts, mesh=mesh,
                              **self._server_kw)

        self.retry_stats = {}
        replacement = fault.retry_step(
            build, retries=self._retries, backoff_s=self._backoff_s,
            sleep=self._sleep, stats=self.retry_stats)
        self.replicas[name] = replacement
        self._devices[name] = devs
        self._live.append(name)
        self._recovery = dict(replica=name, t_kill=t_kill, t_first=None)

    @property
    def recovery_ms(self) -> Optional[float]:
        """Kill-to-first-served-frame of the latest replacement replica
        (fleet clock); None until a replacement has served a frame."""
        if self._recovery is None or self._recovery["t_first"] is None:
            return None
        return (self._recovery["t_first"] - self._recovery["t_kill"]) * 1e3

    # -- accounting ---------------------------------------------------------

    def latency_trace(self) -> List[Dict[str, Any]]:
        """Merged per-frame traces of every replica (dead ones included),
        each record tagged with its serving replica, completion order
        within a replica preserved."""
        out: List[Dict[str, Any]] = []
        for name, server in list(self.replicas.items()) + \
                list(self._dead.items()):
            for rec in server.latency_trace():
                out.append(dict(rec, replica=name))
        return out

    def stats(self) -> FleetStats:
        """Merge every replica's books (victims included — their energy
        was spent) into the fleet bill."""
        per: Dict[str, ServeStats] = {}
        for name, server in list(self.replicas.items()) + \
                list(self._dead.items()):
            per[name] = server.stats()
        served: Dict[str, int] = {lane: 0 for lane in self.lanes}
        padded: Dict[str, int] = {lane: 0 for lane in self.lanes}
        dispatches = 0
        wall = 0.0
        billed = 0
        energy_uj = 0.0
        lats: List[float] = []
        for name, st in per.items():
            for lane in self.lanes:
                served[lane] += st.served.get(lane, 0)
                padded[lane] += st.padded.get(lane, 0)
            dispatches += st.dispatches
            wall += st.host_wall_s
            billed += sum(st.served.values()) + sum(st.padded.values())
            energy_uj += st.energy_uj
        for rec in self.latency_trace():
            lats.append(rec["latency_ms"])
        if lats:
            p50, p95, p99 = np.percentile(lats, [50, 95, 99])
        else:
            p50 = p95 = p99 = 0.0
        total = sum(served.values())
        pad_total = sum(padded.values())
        return FleetStats(
            replicas=per, served=served, padded=padded,
            dispatches=dispatches, host_wall_s=wall,
            host_frames_per_s=(total / wall) if wall else 0.0,
            chip=energy.fleet_report({n: st.chip for n, st in per.items()}),
            billed=billed,
            p50_ms=float(p50), p95_ms=float(p95), p99_ms=float(p99),
            padding_ratio=(pad_total / billed) if billed else 0.0,
            energy_uj=energy_uj,
            migrated_frames=self._migrated,
            refired_frames=self._refired,
            failed_replicas=self.failed_replicas,
            recovery_ms=self.recovery_ms,
            warm_start=warmcache.stats())

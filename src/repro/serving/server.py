"""ChipServer: the thin composition of queue + policy + executor.

BinarEye's serving story (paper Sec. IV): frames stream in continuously
and the chip recombines its 16 sub-arrays across programmable network
widths S in {1, 2, 4} — several *programs* can stay resident (weights in
SRAM, instructions in the 16-slot program memory) and the array is
re-pointed per batch, trading energy for accuracy per task.  The serving
package is the TPU analogue of that controller, split mechanism/policy:

* :mod:`repro.serving.queue` — per-lane FIFOs + the round-robin pointer
  (who is next);
* :mod:`repro.serving.policy` — which program variant serves the lane:
  :class:`StaticPolicy` (each lane its own program, shared-array groups
  composite) or :class:`OperatingPointPolicy` (program families served
  at the operating point an energy budget and the backlog call for);
* :mod:`repro.serving.executor` — pad/dispatch/materialize + the depth-k
  prefetch pipeline;
* :class:`ChipServer` (this module) — wires them together and keeps the
  books (served/padded/energy billing via ``energy.serve_report``).

All pre-split behaviour is preserved: ``megakernel=True`` runs dispatches
through the whole-network resident kernel, ``prefetch=k`` pipelines
submission to depth k, ``shared=True`` forms shared-array composite
groups at admission, and a ``mesh`` replicates weights per device while
frames scatter on the batch axis.  New: ``families=`` registers program
families (variant sets of one task) behind a single queue lane and
serves them through the operating-point controller (``policy=`` /
``budget_uj_s=``).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.chip import energy, interpreter, isa
from repro.serving.executor import Executor
from repro.serving.policy import (ContinuousPolicy, DispatchPolicy,
                                  OperatingPointPolicy, PolicyContext,
                                  StaticPolicy)
from repro.serving.queue import (FrameQueue, FrameRequest, FrameResult,
                                 plan_shared_groups)


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Host-side counters + the chip-model bill for what was served."""
    served: Dict[str, int]            # lane -> frames served
    padded: Dict[str, int]            # lane -> padding slots burned
    dispatches: int
    host_wall_s: float                # wall time inside dispatches
    host_frames_per_s: float
    chip: energy.ServeReport          # µJ/frame, frames/s, power analogue
    array_utilization: float = 0.0    # mean sum(1/S) of live sub-arrays
                                      # per dispatch (1.0 = full array)
    shared_dispatches: int = 0        # dispatches serving >= 2 programs
    policy: str = "static"
    variant_dispatches: Dict[str, int] = dataclasses.field(
        default_factory=dict)         # variant -> dispatches it ran
    energy_uj: float = 0.0            # chip-model energy billed, all lanes
    budget_uj_s: Optional[float] = None
    downshift_ratio: float = 0.0      # family dispatches served below the
                                      # top operating point
    p50_ms: float = 0.0               # input-to-label latency percentiles
    p95_ms: float = 0.0               # over timestamped frames (0.0 when
    p99_ms: float = 0.0               # nothing was stamped)
    padding_ratio: float = 0.0        # burned slots / billed slots

    @property
    def total_served(self) -> int:
        return sum(self.served.values())


class ChipServer:
    """Continuous static-batch serving of compiled ``InferencePlan``s.

    ``programs`` maps resident-program names to validated ISA programs;
    ``artifacts`` maps the same names to their packed deployment artifacts
    (``fold_params(..., packed=True)`` — float-folded artifacts are packed
    on admission).  ``batch`` is the static dispatch size; with a ``mesh``
    it must divide over the mesh's device count.  ``prefetch`` takes a
    pipeline depth (``True`` = 1); ``shared=True`` forms shared-array
    composite groups at admission.

    ``families`` maps a family (task) name to a sequence of resident
    program names that are variants of one task — same input geometry and
    class count, different operating points (see ``networks.FAMILIES``
    and ``interpreter.compile_family``).  Frames are submitted to the
    *family* name; the dispatch policy picks the served variant.  With
    ``families`` the policy defaults to the operating-point controller
    (``budget_uj_s`` caps the chip-model average power in uJ/s);
    ``policy`` accepts a :class:`DispatchPolicy` instance or the strings
    ``"static"`` / ``"operating-point"``.
    """

    def __init__(self, programs: Mapping[str, isa.Program],
                 artifacts: Mapping[str, Any], *, batch: int = 8,
                 mesh=None, donate_frames: bool = False,
                 interpret: Optional[bool] = None,
                 megakernel: bool = False, prefetch: bool | int = False,
                 shared: bool = False,
                 policy: Optional[DispatchPolicy | str] = None,
                 families: Optional[Mapping[str, Sequence[str]]] = None,
                 budget_uj_s: Optional[float] = None,
                 f_hz: float = energy.F_EMIN,
                 slo_ms: float = 50.0,
                 warm_start: bool = True,
                 clock=time.perf_counter):
        if set(programs) != set(artifacts):
            raise ValueError(
                f"programs {sorted(programs)} != artifacts {sorted(artifacts)}")
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if int(prefetch) < 0:
            raise ValueError(f"prefetch depth must be >= 0, got {prefetch}")
        ndev = mesh.devices.size if mesh is not None else 1
        if batch % ndev:
            raise ValueError(
                f"static batch {batch} must divide over the "
                f"{ndev}-device serving mesh")
        self.batch = batch
        self.mesh = mesh
        self.f_hz = f_hz
        self.prefetch = int(prefetch)        # pipeline depth, 0 = sync
        self.shared = shared
        self.slo_ms = slo_ms
        self.clock = clock                   # injectable for latency tests
        self.programs: Dict[str, isa.Program] = dict(programs)

        # -- lanes: families collapse their variants behind one lane -------
        self._families: Dict[str, Tuple[str, ...]] = {}
        if families:
            owned = {}
            for fam, members in families.items():
                members = tuple(members)
                if fam in self.programs:
                    raise ValueError(
                        f"family name {fam!r} collides with a resident "
                        "program name")
                missing = [m for m in members if m not in self.programs]
                if missing:
                    raise ValueError(
                        f"family {fam!r} members {missing} not resident")
                for m in members:
                    if m in owned:
                        raise ValueError(
                            f"program {m!r} belongs to families "
                            f"{owned[m]!r} and {fam!r}")
                    owned[m] = fam
                # validates shared geometry/classes across the variants
                interpreter.compile_family(
                    {m: self.programs[m] for m in members})
                self._families[fam] = members
        in_family = {m for ms in self._families.values() for m in ms}
        self._lanes: Tuple[str, ...] = tuple(self._families) + tuple(
            n for n in self.programs if n not in in_family)
        self._lane_variants: Dict[str, Tuple[str, ...]] = {
            **self._families,
            **{n: (n,) for n in self.programs if n not in in_family}}

        # -- mechanism ------------------------------------------------------
        self.executor = Executor(self.programs, artifacts, batch=batch,
                                 mesh=mesh, donate_frames=donate_frames,
                                 interpret=interpret, megakernel=megakernel,
                                 prefetch=self.prefetch,
                                 warm_start=warm_start, clock=clock)
        self.plans = self.executor.plans
        self.artifacts = self.executor.artifacts
        self.queue = FrameQueue(self._lanes)
        self._geom = {lane: self.executor.geometry(vs[0])
                      for lane, vs in self._lane_variants.items()}

        # -- policy ---------------------------------------------------------
        groups: Dict[str, Tuple[str, ...]] = {}
        self._groups_plan: Tuple[Tuple[str, ...], ...] = ()
        if shared:
            lane_progs = {n: self.programs[n] for n in self._lanes
                          if n in self.programs}
            self._groups_plan = plan_shared_groups(lane_progs)
            for members in self._groups_plan:
                for m in members:
                    groups[m] = members
            self.executor.warm_composites(self._groups_plan)
        self.policy = self._make_policy(policy, budget_uj_s)
        # static per-program chip reports: computed once, reused by stats()
        self._reports = {n: energy.analyze_net(p, f_hz)
                         for n, p in self.programs.items()}
        self.policy.bind(PolicyContext(
            batch=batch, lanes=self._lanes,
            variants=dict(self._lane_variants),
            programs=dict(self.programs), reports=dict(self._reports),
            groups=groups, quantum=ndev, clock=clock))

        # -- accounting -----------------------------------------------------
        self.failed = False                  # set by fail(); fleet skips us
        self.aborted_inflight = 0            # in-flight frames fail() dropped
        self._next_rid = 0
        self._dispatches = 0
        self._shared_dispatches = 0
        self._util_sum = 0.0
        self._served = {lane: 0 for lane in self._lanes}
        self._padded = {lane: 0 for lane in self._lanes}
        self._vserved = {name: 0 for name in self.programs}
        self._vpadded = {name: 0 for name in self.programs}
        self._host_wall_s = 0.0
        self._billed = 0                     # frame slots launched (served
                                             # + padded, across all lanes)
        self._latencies: List[float] = []    # stamped input-to-label, s
        self._trace: List[Dict[str, Any]] = []   # per-frame latency trace

    def _make_policy(self, policy, budget_uj_s) -> DispatchPolicy:
        if isinstance(policy, DispatchPolicy):
            return policy
        if policy is None:
            policy = "operating-point" if self._families else "static"
        if policy == "static":
            if self._families:
                raise ValueError(
                    "families need a variant-choosing policy; use "
                    "policy='operating-point' (or drop families=)")
            return StaticPolicy()
        if policy == "operating-point":
            return OperatingPointPolicy(budget_uj_s=budget_uj_s,
                                        shared=self.shared)
        if policy == "continuous":
            inner = (OperatingPointPolicy(budget_uj_s=budget_uj_s,
                                          shared=self.shared)
                     if self._families else StaticPolicy())
            return ContinuousPolicy(slo_ms=self.slo_ms, inner=inner)
        raise ValueError(f"unknown policy {policy!r} (have 'static', "
                         "'operating-point', 'continuous', or a "
                         "DispatchPolicy)")

    @property
    def shared_groups(self) -> Tuple[Tuple[str, ...], ...]:
        """The compiled shared-array groups (empty unless ``shared=True``
        and some resident S-modes tile the array exactly)."""
        return self._groups_plan

    @property
    def families(self) -> Dict[str, Tuple[str, ...]]:
        return dict(self._families)

    # -- request side -------------------------------------------------------

    def submit(self, program: str, frame,
               t_submit: Optional[float] = None,
               rid: Optional[int] = None) -> int:
        """Enqueue one frame on a lane (program or family name); returns
        its request id (arrival order).  ``t_submit`` overrides the
        admission timestamp (trace replay stamps the trace's arrival
        time); by default the server clock stamps *now*.  ``rid``
        overrides the locally-assigned id — a fleet hands out globally
        unique ids so results from different replicas never collide."""
        if program not in self._geom:
            raise KeyError(
                f"program {program!r} not resident "
                f"(have {sorted(self._geom)})")
        h, w, c = self._geom[program]
        frame = np.asarray(frame)
        if frame.shape != (h, w, c):
            raise ValueError(
                f"{program} expects frames of shape {(h, w, c)}, "
                f"got {frame.shape}")
        if rid is None:
            rid = self._next_rid
            self._next_rid += 1
        else:
            self._next_rid = max(self._next_rid, rid + 1)
        if t_submit is None:
            t_submit = self.clock()
        self.queue.submit(FrameRequest(rid=rid, program=program, frame=frame,
                                       t_submit=t_submit))
        return rid

    def submit_many(self, program: str, frames) -> List[int]:
        return [self.submit(program, f) for f in frames]

    # -- dispatch side ------------------------------------------------------

    def _launch(self) -> Optional[Dict[str, Any]]:
        """Consult the policy for the next dispatch, run it, and bill it.
        Serving counters are billed at launch — the energy is burned the
        moment the batch hits the array, synced or not."""
        dispatch = self.policy.select(self.queue)
        if dispatch is None:
            return None
        index = self._dispatches
        self._dispatches += 1
        handle = self.executor.launch(dispatch, index)
        size = dispatch.batch if dispatch.batch is not None else self.batch
        live = []
        for ld in dispatch.lanes:
            n = len(ld.requests)
            self._served[ld.lane] += n
            self._padded[ld.lane] += size - n
            self._vserved[ld.variant] += n
            self._vpadded[ld.variant] += size - n
            self._billed += size
            if n:
                live.append(self.programs[ld.variant])
        if dispatch.composite:
            self._shared_dispatches += 1
            self._util_sum += energy.array_occupancy(live)
        else:
            self._util_sum += 1.0 / self.programs[
                dispatch.lanes[0].variant].s
        return handle

    def step(self) -> List[FrameResult]:
        """One dispatch: pull a static batch, run its program(s), return
        results for the real (non-padding) frames.  [] once drained.

        With ``prefetch=k`` up to k batches are staged and dispatched
        *before* blocking on the oldest one, and finished results are
        pulled to the host by a background thread; batches still leave
        the queue in exactly the synchronous order, so fairness is
        untouched.

        All timing goes through ``self.clock`` — the injected clock is
        the server's single time domain (``_host_wall_s``, ``t_submit``,
        ``t_done`` and the latency trace all share it), so a
        ``VirtualClock`` replay never silently mixes in wall time.
        """
        t0 = self.clock()
        try:
            results = self.executor.step(self._launch)
        finally:
            self._host_wall_s += self.clock() - t0
        for r in results:
            if r.t_submit <= 0.0 or r.t_done <= 0.0:
                continue                     # unstamped: no latency account
            lat = r.t_done - r.t_submit
            self._latencies.append(lat)
            self._trace.append(dict(
                rid=r.rid, lane=r.program, variant=r.variant,
                dispatch=r.dispatch, t_submit=r.t_submit,
                t_done=r.t_done, latency_ms=lat * 1e3))
        return results

    def drain(self) -> List[FrameResult]:
        """Serve until the queue is empty; results in dispatch order.
        The policy is flushed for the duration: a continuous policy's
        admission window never holds the final ragged batches back."""
        out: List[FrameResult] = []
        self.policy.set_flush(True)
        try:
            while True:
                got = self.step()
                if not got and not len(self.queue):
                    return out
                out.extend(got)
        finally:
            self.policy.set_flush(False)

    def close(self) -> None:
        """Release the background fetch thread, syncing (and discarding —
        ``drain()`` first to collect them) any in-flight dispatches.  The
        server keeps working afterwards with prefetch degraded to
        synchronous fetch; safe to call more than once."""
        self.executor.close()

    def fail(self) -> Dict[str, List[FrameRequest]]:
        """Simulated host loss: kill this replica and hand back every
        frame it had not finished serving, grouped by lane with order
        preserved (in-flight dispatches oldest-first, then the queued
        FIFO).  The energy already billed for abandoned in-flight
        dispatches stays billed — it was burned the moment the batch hit
        the array — so this replica's ``billed == served + padded``
        ledger stays consistent; the migrated frames are re-billed by
        whoever serves them.  The server is unusable afterwards."""
        orphans: Dict[str, List[FrameRequest]] = {
            lane: [] for lane in self._lanes}
        inflight = self.executor.abort()        # in-flight, oldest first
        self.aborted_inflight = len(inflight)   # fleet's refired count
        for req in inflight:
            orphans[req.program].append(req)
        for lane in self._lanes:                # then the queued backlog
            while True:
                got = self.queue.take(lane, self.batch)
                if not got:
                    break
                orphans[lane].extend(got)
        self.failed = True
        return {lane: reqs for lane, reqs in orphans.items() if reqs}

    # -- accounting ---------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero the serving counters and latency books, keeping all
        compiled state — benches warm the jit caches through the real
        serve path, then measure from a clean ledger."""
        self._dispatches = 0
        self._shared_dispatches = 0
        self._util_sum = 0.0
        self._served = {lane: 0 for lane in self._lanes}
        self._padded = {lane: 0 for lane in self._lanes}
        self._vserved = {name: 0 for name in self.programs}
        self._vpadded = {name: 0 for name in self.programs}
        self._host_wall_s = 0.0
        self._billed = 0
        self._latencies = []
        self._trace = []
        for v in self.policy.variant_dispatches:
            self.policy.variant_dispatches[v] = 0

    def latency_trace(self) -> List[Dict[str, Any]]:
        """Per-frame admission-to-label records (stamped frames only), in
        completion order — the artifact CI uploads next to the bench
        JSON."""
        return list(self._trace)

    def stats(self) -> ServeStats:
        chip = energy.serve_report(self.programs, self._vserved,
                                   self._vpadded, f_hz=self.f_hz,
                                   reports=self._reports,
                                   billed=self._billed)
        total = sum(self._served.values())
        fps = total / self._host_wall_s if self._host_wall_s else 0.0
        util = self._util_sum / self._dispatches if self._dispatches else 0.0
        energy_uj = sum(
            (self._vserved[v] + self._vpadded[v])
            * self._reports[v].i2l_energy_per_inference * 1e6
            for v in self.programs)
        budget = getattr(self.policy, "budget_uj_s", None)
        vd = dict(self.policy.variant_dispatches)
        if self._latencies:
            p50, p95, p99 = np.percentile(self._latencies, [50, 95, 99])
        else:
            p50 = p95 = p99 = 0.0
        padded = sum(self._padded.values())
        ratio = padded / self._billed if self._billed else 0.0
        return ServeStats(served=dict(self._served),
                          padded=dict(self._padded),
                          dispatches=self._dispatches,
                          host_wall_s=self._host_wall_s,
                          host_frames_per_s=fps,
                          chip=chip,
                          array_utilization=util,
                          shared_dispatches=self._shared_dispatches,
                          policy=self.policy.name,
                          variant_dispatches=vd,
                          energy_uj=energy_uj,
                          budget_uj_s=budget,
                          downshift_ratio=self.policy.downshift_ratio(),
                          p50_ms=float(p50) * 1e3,
                          p95_ms=float(p95) * 1e3,
                          p99_ms=float(p99) * 1e3,
                          padding_ratio=ratio)

"""Back-compat shim: the pre-split serving monolith's import surface.

The 500-line scheduler was split into mechanism and policy (see the
package docstring in :mod:`repro.serving.server`):

* :mod:`repro.serving.queue` — ``FrameQueue`` / ``FrameRequest`` /
  ``FrameResult`` / ``plan_shared_groups`` (lanes + round-robin pointer
  + shared grouping);
* :mod:`repro.serving.policy` — ``DispatchPolicy`` / ``StaticPolicy`` /
  ``OperatingPointPolicy`` (what to run next);
* :mod:`repro.serving.executor` — ``Executor`` (pad/dispatch/finish +
  the depth-k prefetch pipeline);
* :mod:`repro.serving.server` — ``ChipServer`` / ``ServeStats`` (the
  thin composition).

Every pre-split name keeps importing from here; new code should import
from :mod:`repro.serving` (or the specific submodule) directly.
"""

from repro.serving.executor import Executor  # noqa: F401
from repro.serving.policy import (  # noqa: F401
    ContinuousPolicy,
    Dispatch,
    DispatchPolicy,
    LaneDispatch,
    OperatingPointPolicy,
    PolicyContext,
    StaticPolicy,
)
from repro.serving.queue import (  # noqa: F401
    FrameQueue,
    FrameRequest,
    FrameResult,
    plan_shared_groups,
)
from repro.serving.server import ChipServer, ServeStats  # noqa: F401

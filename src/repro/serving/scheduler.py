"""Chip-tier serving scheduler: S-mode multi-program static batching.

BinarEye's serving story (paper Sec. IV): frames stream in continuously
and the chip recombines its 16 sub-arrays across programmable network
widths S in {1, 2, 4} — several *programs* can stay resident (weights in
SRAM, instructions in the 16-slot program memory) and the array is
re-pointed per batch, trading energy for accuracy per task.  This module
is the TPU analogue of that controller:

* :class:`FrameQueue` — per-program FIFO lanes with a round-robin
  dispatch pointer.  A dispatch is always single-program (the array runs
  one instruction stream at a time), fairness comes from rotating the
  pointer across lanes with pending frames — no resident program starves.
* :class:`ChipServer` — holds the resident set: per program a compiled
  :class:`~repro.core.chip.interpreter.InferencePlan`, its packed
  deployment artifact (the SRAM contents), and a jit'd serve function.
  Each :meth:`ChipServer.step` pulls one static batch from the queue,
  pads it to the fixed batch size (the chip's always-on pipeline doesn't
  idle; padding slots burn energy and are billed), runs the packed
  pipeline, and returns per-request results.

Multi-device: pass ``mesh`` (see ``distributed.sharding.serve_mesh``) to
replicate every program's packed weights per device and scatter the frame
batch on the batch axis via ``shard_map`` — the LD-once/CONV-many
schedule lifted to the device level.  Single device degrades to plain jit.

Two further deployment knobs mirror the chip's always-on pipelining:

* ``megakernel=True`` runs each dispatch through the whole-network
  resident Pallas kernel (``InferencePlan.forward_mega``): the program's
  full weight image stays VMEM-resident, feature maps never leave VMEM,
  and frame tiles double-buffer through the kernel grid.
* ``prefetch=True`` double-buffers *submission*: while batch N runs on
  the device, batch N+1 is already pulled from the queue, padded and
  dispatched; the host blocks only when fetching N's results — the TPU
  analogue of the chip loading the next image through the IO pads while
  the array convolves the current one.  Dispatch order (and hence the
  scheduler's fairness contract) is unchanged: batches are pulled from
  the ``FrameQueue`` in exactly the same order as the synchronous path.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chip import energy, interpreter, isa
from repro.distributed import sharding


@dataclasses.dataclass(frozen=True)
class FrameRequest:
    """One frame awaiting inference under a resident program."""
    rid: int                  # server-global request id (arrival order)
    program: str              # resident program name
    frame: Any                # (H, W, C) integer image


@dataclasses.dataclass(frozen=True)
class FrameResult:
    rid: int
    program: str
    label: int
    logits: np.ndarray
    dispatch: int             # index of the static batch that served it


class FrameQueue:
    """Per-program FIFO lanes + round-robin dispatch across non-empty lanes.

    The fairness contract (property-tested in tests/test_chip_serve.py):
    a lane is never dispatched twice while another lane has been waiting
    non-empty the whole time — the pointer advances past each served lane
    and only skips lanes that are empty at their turn.
    """

    def __init__(self, programs: Iterable[str]):
        self._order: List[str] = list(programs)
        if not self._order:
            raise ValueError("FrameQueue needs at least one resident program")
        if len(set(self._order)) != len(self._order):
            raise ValueError(f"duplicate program names: {self._order}")
        self._lanes: Dict[str, collections.deque] = {
            name: collections.deque() for name in self._order}
        self._rr = 0

    def submit(self, req: FrameRequest) -> None:
        if req.program not in self._lanes:
            raise KeyError(
                f"program {req.program!r} not resident "
                f"(have {self._order})")
        self._lanes[req.program].append(req)

    def pending(self, program: Optional[str] = None) -> int:
        if program is not None:
            return len(self._lanes[program])
        return sum(len(q) for q in self._lanes.values())

    def __len__(self) -> int:
        return self.pending()

    def next_batch(self, capacity: int) -> Optional[Tuple[str, List[FrameRequest]]]:
        """Up to ``capacity`` requests from the next non-empty lane in
        round-robin order; ``None`` once fully drained."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        n = len(self._order)
        for i in range(n):
            name = self._order[(self._rr + i) % n]
            lane = self._lanes[name]
            if lane:
                self._rr = (self._rr + i + 1) % n
                take = [lane.popleft()
                        for _ in range(min(capacity, len(lane)))]
                return name, take
        return None


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Host-side counters + the chip-model bill for what was served."""
    served: Dict[str, int]            # program -> frames served
    padded: Dict[str, int]            # program -> padding slots burned
    dispatches: int
    host_wall_s: float                # wall time inside dispatches
    host_frames_per_s: float
    chip: energy.ServeReport          # µJ/frame, frames/s, power analogue

    @property
    def total_served(self) -> int:
        return sum(self.served.values())


class ChipServer:
    """Continuous static-batch serving of compiled ``InferencePlan``s.

    ``programs`` maps resident-program names to validated ISA programs;
    ``artifacts`` maps the same names to their packed deployment artifacts
    (``fold_params(..., packed=True)`` — float-folded artifacts are packed
    on admission).  ``batch`` is the static dispatch size; with a ``mesh``
    it must divide over the mesh's device count.
    """

    def __init__(self, programs: Mapping[str, isa.Program],
                 artifacts: Mapping[str, Any], *, batch: int = 8,
                 mesh=None, donate_frames: bool = False,
                 interpret: Optional[bool] = None,
                 megakernel: bool = False, prefetch: bool = False,
                 f_hz: float = energy.F_EMIN):
        if set(programs) != set(artifacts):
            raise ValueError(
                f"programs {sorted(programs)} != artifacts {sorted(artifacts)}")
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        ndev = mesh.devices.size if mesh is not None else 1
        if batch % ndev:
            raise ValueError(
                f"static batch {batch} must divide over the "
                f"{ndev}-device serving mesh")
        self.batch = batch
        self.mesh = mesh
        self.f_hz = f_hz
        self.prefetch = prefetch
        self.programs: Dict[str, isa.Program] = dict(programs)
        self.plans: Dict[str, interpreter.InferencePlan] = {}
        self.artifacts: Dict[str, Any] = {}
        self._fns: Dict[str, Any] = {}
        self._geom: Dict[str, Tuple[int, int, int]] = {}
        for name, prog in self.programs.items():
            isa.validate(prog)
            plan = interpreter.compile_plan(prog)
            if megakernel:
                art = interpreter.ensure_image(artifacts[name], prog)
            else:
                art = interpreter.ensure_packed(artifacts[name])
            if mesh is not None:
                art = sharding.replicate_artifact(mesh, art)
            io = prog.instrs[0]
            self.plans[name] = plan
            self.artifacts[name] = art
            self._geom[name] = (io.height, io.width, io.in_channels)
            self._fns[name] = plan.make_serve_fn(
                mesh=mesh, donate_frames=donate_frames, interpret=interpret,
                megakernel=megakernel,
                bb=min(8, batch // ndev))
        self._inflight: Optional[Dict[str, Any]] = None
        self.queue = FrameQueue(self.programs)
        # static per-program chip reports: computed once, reused by stats()
        self._reports = {n: energy.analyze_net(p, f_hz)
                         for n, p in self.programs.items()}
        self._next_rid = 0
        self._dispatches = 0
        self._served = {name: 0 for name in self.programs}
        self._padded = {name: 0 for name in self.programs}
        self._host_wall_s = 0.0

    # -- request side -------------------------------------------------------

    def submit(self, program: str, frame) -> int:
        """Enqueue one frame; returns its request id (arrival order)."""
        if program not in self._geom:
            raise KeyError(
                f"program {program!r} not resident "
                f"(have {sorted(self._geom)})")
        h, w, c = self._geom[program]
        frame = np.asarray(frame)
        if frame.shape != (h, w, c):
            raise ValueError(
                f"{program} expects frames of shape {(h, w, c)}, "
                f"got {frame.shape}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.submit(FrameRequest(rid=rid, program=program, frame=frame))
        return rid

    def submit_many(self, program: str, frames) -> List[int]:
        return [self.submit(program, f) for f in frames]

    # -- dispatch side ------------------------------------------------------

    def _launch(self) -> Optional[Dict[str, Any]]:
        """Pull + pad + dispatch one static batch; returns the in-flight
        handle (device arrays, not yet synced) or ``None`` when drained.
        Serving counters are billed at launch — the energy is burned the
        moment the batch hits the array, synced or not."""
        pulled = self.queue.next_batch(self.batch)
        if pulled is None:
            return None
        name, reqs = pulled
        n_real = len(reqs)
        frames = np.stack([r.frame for r in reqs])
        if n_real < self.batch:
            # static batch: the always-on pipeline doesn't idle — pad with
            # the last real frame and bill the burned slots.
            pad = np.broadcast_to(frames[-1],
                                  (self.batch - n_real,) + frames.shape[1:])
            frames = np.concatenate([frames, pad])
        frames = jnp.asarray(frames)
        if self.mesh is not None:
            frames = sharding.scatter_frames(self.mesh, frames)
        logits, labels = self._fns[name](self.artifacts[name], frames)
        self._served[name] += n_real
        self._padded[name] += self.batch - n_real
        dispatch = self._dispatches
        self._dispatches += 1
        return dict(name=name, reqs=reqs, logits=logits, labels=labels,
                    dispatch=dispatch)

    def _finish(self, handle: Dict[str, Any]) -> List[FrameResult]:
        """Block on an in-flight dispatch and materialize its results."""
        name, reqs = handle["name"], handle["reqs"]
        labels = np.asarray(jax.block_until_ready(handle["labels"]))
        logits = np.asarray(handle["logits"])
        return [FrameResult(rid=r.rid, program=name, label=int(labels[i]),
                            logits=logits[i], dispatch=handle["dispatch"])
                for i, r in enumerate(reqs)]

    def step(self) -> List[FrameResult]:
        """One dispatch: pull a static batch, run its program, return
        results for the real (non-padding) frames.  [] once drained.

        With ``prefetch=True`` the next batch is staged and dispatched
        *before* blocking on the current one, so host-side frame staging
        overlaps device execution; batches still leave the queue in
        exactly the synchronous order, so fairness is untouched.
        """
        t0 = time.perf_counter()
        try:
            if not self.prefetch:
                cur = self._launch()
                return [] if cur is None else self._finish(cur)
            cur, self._inflight = self._inflight, None
            if cur is None:
                cur = self._launch()
                if cur is None:
                    return []
            self._inflight = self._launch()    # stage N+1 while N runs
            return self._finish(cur)
        finally:
            self._host_wall_s += time.perf_counter() - t0

    def drain(self) -> List[FrameResult]:
        """Serve until the queue is empty; results in dispatch order."""
        out: List[FrameResult] = []
        while True:
            got = self.step()
            if not got:
                return out
            out.extend(got)

    # -- accounting ---------------------------------------------------------

    def stats(self) -> ServeStats:
        chip = energy.serve_report(self.programs, self._served,
                                   self._padded, f_hz=self.f_hz,
                                   reports=self._reports)
        total = sum(self._served.values())
        fps = total / self._host_wall_s if self._host_wall_s else 0.0
        return ServeStats(served=dict(self._served),
                          padded=dict(self._padded),
                          dispatches=self._dispatches,
                          host_wall_s=self._host_wall_s,
                          host_frames_per_s=fps,
                          chip=chip)

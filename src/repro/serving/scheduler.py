"""Chip-tier serving scheduler: S-mode multi-program static batching.

BinarEye's serving story (paper Sec. IV): frames stream in continuously
and the chip recombines its 16 sub-arrays across programmable network
widths S in {1, 2, 4} — several *programs* can stay resident (weights in
SRAM, instructions in the 16-slot program memory) and the array is
re-pointed per batch, trading energy for accuracy per task.  This module
is the TPU analogue of that controller:

* :class:`FrameQueue` — per-program FIFO lanes with a round-robin
  dispatch pointer.  A dispatch is always single-program (the array runs
  one instruction stream at a time), fairness comes from rotating the
  pointer across lanes with pending frames — no resident program starves.
* :class:`ChipServer` — holds the resident set: per program a compiled
  :class:`~repro.core.chip.interpreter.InferencePlan`, its packed
  deployment artifact (the SRAM contents), and a jit'd serve function.
  Each :meth:`ChipServer.step` pulls one static batch from the queue,
  pads it to the fixed batch size (the chip's always-on pipeline doesn't
  idle; padding slots burn energy and are billed), runs the packed
  pipeline, and returns per-request results.

Multi-device: pass ``mesh`` (see ``distributed.sharding.serve_mesh``) to
replicate every program's packed weights per device and scatter the frame
batch on the batch axis via ``shard_map`` — the LD-once/CONV-many
schedule lifted to the device level.  Single device degrades to plain jit.

Two further deployment knobs mirror the chip's always-on pipelining:

* ``megakernel=True`` runs each dispatch through the whole-network
  resident Pallas kernel (``InferencePlan.forward_mega``): the program's
  full weight image stays VMEM-resident, feature maps never leave VMEM,
  and frame tiles double-buffer through the kernel grid.
* ``prefetch=k`` pipelines *submission* to depth k (``True`` = 1): while
  batch N runs on the device, batches N+1..N+k are already pulled from
  the queue, padded and dispatched, and finished batches' results are
  fetched to host memory by a background thread — the host blocks only
  when a result is consumed before its fetch lands.  The TPU analogue of
  the chip loading the next image through the IO pads while the array
  convolves the current one.  Dispatch order (and hence the scheduler's
  fairness contract) is unchanged: batches are pulled from the
  ``FrameQueue`` in exactly the same order as the synchronous path.
* ``shared=True`` enables **true sub-array sharing**: resident programs
  whose S-modes tile the 256-channel array exactly (4xS4, 2xS2,
  2xS4+1xS2, ...) are compiled into a :class:`~repro.core.chip.
  interpreter.CompositePlan` at admission; when two or more of a group's
  FIFO lanes are backlogged, ONE composite dispatch serves all of them
  concurrently — the chip's recombined sub-arrays, not time-interleaved
  whole-array dispatches.  Each member's lane pads (and is billed)
  independently, per sub-array; a group member whose lane is idle burns
  its sub-array's slots like any padding (the always-on array never
  idles).  Results are bit-exact vs solo dispatch, fairness is
  preserved (serving a backlogged lane early never starves another),
  and ``stats().array_utilization`` reports the occupancy win.
"""

from __future__ import annotations

import collections
import concurrent.futures
import dataclasses
import time
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chip import energy, interpreter, isa
from repro.distributed import sharding


@dataclasses.dataclass(frozen=True)
class FrameRequest:
    """One frame awaiting inference under a resident program."""
    rid: int                  # server-global request id (arrival order)
    program: str              # resident program name
    frame: Any                # (H, W, C) integer image


@dataclasses.dataclass(frozen=True)
class FrameResult:
    rid: int
    program: str
    label: int
    logits: np.ndarray
    dispatch: int             # index of the static batch that served it


class FrameQueue:
    """Per-program FIFO lanes + round-robin dispatch across non-empty lanes.

    The solo fairness contract (:meth:`next_batch`, property-tested in
    tests/test_chip_serve.py): a lane is never dispatched twice while
    another lane has been waiting non-empty the whole time — the pointer
    advances past each served lane and only skips lanes that are empty at
    their turn.  :meth:`next_batch_shared` deliberately relaxes the
    "never twice" half for lanes *inside a shared-array group* (a
    composite dispatch serves every backlogged group member each time the
    pointer hits any of them), but keeps the no-starvation bound every
    consumer actually relies on: any lane non-empty before a dispatch is
    itself served within the next ``n_lanes`` dispatches, and no lane is
    ever served *later* than the solo schedule would have served it.
    """

    def __init__(self, programs: Iterable[str]):
        self._order: List[str] = list(programs)
        if not self._order:
            raise ValueError("FrameQueue needs at least one resident program")
        if len(set(self._order)) != len(self._order):
            raise ValueError(f"duplicate program names: {self._order}")
        self._lanes: Dict[str, collections.deque] = {
            name: collections.deque() for name in self._order}
        self._rr = 0

    def submit(self, req: FrameRequest) -> None:
        if req.program not in self._lanes:
            raise KeyError(
                f"program {req.program!r} not resident "
                f"(have {self._order})")
        self._lanes[req.program].append(req)

    def pending(self, program: Optional[str] = None) -> int:
        if program is not None:
            return len(self._lanes[program])
        return sum(len(q) for q in self._lanes.values())

    def __len__(self) -> int:
        return self.pending()

    def next_batch(self, capacity: int) -> Optional[Tuple[str, List[FrameRequest]]]:
        """Up to ``capacity`` requests from the next non-empty lane in
        round-robin order; ``None`` once fully drained."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        n = len(self._order)
        for i in range(n):
            name = self._order[(self._rr + i) % n]
            lane = self._lanes[name]
            if lane:
                self._rr = (self._rr + i + 1) % n
                take = [lane.popleft()
                        for _ in range(min(capacity, len(lane)))]
                return name, take
        return None

    def next_batch_shared(self, capacity: int,
                          groups: Mapping[str, Tuple[str, ...]]
                          ) -> Optional[Dict[str, List[FrameRequest]]]:
        """Round-robin like :meth:`next_batch`, but when the selected lane
        belongs to a shared-array group with >= 2 backlogged members, pull
        up to ``capacity`` from *every* backlogged member — one composite
        dispatch serves them all concurrently.  Lanes served early keep
        their round-robin position (they are simply empty — or shorter —
        when the pointer reaches them), so the no-starvation contract is
        untouched: a backlogged lane is only ever served *sooner*.
        Returns ``{name: requests}`` (single-entry for a solo dispatch),
        ``None`` once fully drained.
        """
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        n = len(self._order)
        for i in range(n):
            name = self._order[(self._rr + i) % n]
            if not self._lanes[name]:
                continue
            self._rr = (self._rr + i + 1) % n
            members = groups.get(name, (name,))
            backlogged = [m for m in members if self._lanes[m]]
            take_from = backlogged if len(backlogged) >= 2 else [name]
            out = {}
            for m in take_from:
                lane = self._lanes[m]
                out[m] = [lane.popleft()
                          for _ in range(min(capacity, len(lane)))]
            return out
        return None


def plan_shared_groups(programs: Mapping[str, isa.Program]
                       ) -> Tuple[Tuple[str, ...], ...]:
    """Partition resident programs into shared-array groups.

    First-fit-decreasing bin packing on sub-array width (256/S channels)
    into 256-channel bins; only bins that end *exactly* full with >= 2
    members become composite groups (the chip can only recombine
    sub-arrays that tile the array), everything else dispatches solo.
    Deterministic given admission order, so every server replica forms
    the same groups.
    """
    # stable sort: widest sub-arrays (smallest S) first, admission order
    # preserved within a width class
    items = sorted(programs.items(), key=lambda kv: kv[1].s)
    bins: List[Tuple[int, List[str]]] = []    # (free channels, members)
    for name, prog in items:
        width = isa.ARRAY_CHANNELS // prog.s
        for i, (free, members) in enumerate(bins):
            if width <= free:
                bins[i] = (free - width, members + [name])
                break
        else:
            bins.append((isa.ARRAY_CHANNELS - width, [name]))
    return tuple(tuple(members) for free, members in bins
                 if free == 0 and len(members) >= 2)


@dataclasses.dataclass(frozen=True)
class ServeStats:
    """Host-side counters + the chip-model bill for what was served."""
    served: Dict[str, int]            # program -> frames served
    padded: Dict[str, int]            # program -> padding slots burned
    dispatches: int
    host_wall_s: float                # wall time inside dispatches
    host_frames_per_s: float
    chip: energy.ServeReport          # µJ/frame, frames/s, power analogue
    array_utilization: float = 0.0    # mean sum(1/S) of live sub-arrays
                                      # per dispatch (1.0 = full array)
    shared_dispatches: int = 0        # dispatches serving >= 2 programs

    @property
    def total_served(self) -> int:
        return sum(self.served.values())


class ChipServer:
    """Continuous static-batch serving of compiled ``InferencePlan``s.

    ``programs`` maps resident-program names to validated ISA programs;
    ``artifacts`` maps the same names to their packed deployment artifacts
    (``fold_params(..., packed=True)`` — float-folded artifacts are packed
    on admission).  ``batch`` is the static dispatch size; with a ``mesh``
    it must divide over the mesh's device count.  ``prefetch`` takes a
    pipeline depth (``True`` = 1); ``shared=True`` forms shared-array
    composite groups (see the module docstring).
    """

    def __init__(self, programs: Mapping[str, isa.Program],
                 artifacts: Mapping[str, Any], *, batch: int = 8,
                 mesh=None, donate_frames: bool = False,
                 interpret: Optional[bool] = None,
                 megakernel: bool = False, prefetch: bool | int = False,
                 shared: bool = False,
                 f_hz: float = energy.F_EMIN):
        if set(programs) != set(artifacts):
            raise ValueError(
                f"programs {sorted(programs)} != artifacts {sorted(artifacts)}")
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        if int(prefetch) < 0:
            raise ValueError(f"prefetch depth must be >= 0, got {prefetch}")
        ndev = mesh.devices.size if mesh is not None else 1
        if batch % ndev:
            raise ValueError(
                f"static batch {batch} must divide over the "
                f"{ndev}-device serving mesh")
        self.batch = batch
        self.mesh = mesh
        self.f_hz = f_hz
        self.prefetch = int(prefetch)        # pipeline depth, 0 = sync
        self.shared = shared
        self.programs: Dict[str, isa.Program] = dict(programs)
        self.plans: Dict[str, interpreter.InferencePlan] = {}
        self.artifacts: Dict[str, Any] = {}
        self._fns: Dict[str, Any] = {}
        self._geom: Dict[str, Tuple[int, int, int]] = {}
        for name, prog in self.programs.items():
            isa.validate(prog)
            plan = interpreter.compile_plan(prog)
            if megakernel:
                art = interpreter.ensure_image(artifacts[name], prog)
            else:
                art = interpreter.ensure_packed(artifacts[name])
            if mesh is not None:
                art = sharding.replicate_artifact(mesh, art)
            io = prog.instrs[0]
            self.plans[name] = plan
            self.artifacts[name] = art
            self._geom[name] = (io.height, io.width, io.in_channels)
            self._fns[name] = plan.make_serve_fn(
                mesh=mesh, donate_frames=donate_frames, interpret=interpret,
                megakernel=megakernel)
        # shared-array groups: compiled composites over exact tilings
        self._groups: Dict[str, Tuple[str, ...]] = {}
        self._composites: Dict[Tuple[str, ...], Dict[str, Any]] = {}
        if shared:
            for members in plan_shared_groups(self.programs):
                cplan, cimage = interpreter.pack_programs(
                    {m: self.programs[m] for m in members},
                    {m: artifacts[m] for m in members})
                if mesh is not None:
                    cimage = sharding.replicate_artifact(mesh, cimage)
                cfn = cplan.make_serve_fn(mesh=mesh,
                                          donate_frames=donate_frames,
                                          interpret=interpret)
                self._composites[members] = dict(plan=cplan, image=cimage,
                                                 fn=cfn)
                for m in members:
                    self._groups[m] = members
        self._inflight: collections.deque = collections.deque()
        self._fetch_pool: Optional[concurrent.futures.ThreadPoolExecutor] = (
            concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="serve-fetch")
            if self.prefetch else None)
        self.queue = FrameQueue(self.programs)
        # static per-program chip reports: computed once, reused by stats()
        self._reports = {n: energy.analyze_net(p, f_hz)
                         for n, p in self.programs.items()}
        self._next_rid = 0
        self._dispatches = 0
        self._shared_dispatches = 0
        self._util_sum = 0.0
        self._served = {name: 0 for name in self.programs}
        self._padded = {name: 0 for name in self.programs}
        self._host_wall_s = 0.0

    @property
    def shared_groups(self) -> Tuple[Tuple[str, ...], ...]:
        """The compiled shared-array groups (empty unless ``shared=True``
        and some resident S-modes tile the array exactly)."""
        return tuple(self._composites)

    # -- request side -------------------------------------------------------

    def submit(self, program: str, frame) -> int:
        """Enqueue one frame; returns its request id (arrival order)."""
        if program not in self._geom:
            raise KeyError(
                f"program {program!r} not resident "
                f"(have {sorted(self._geom)})")
        h, w, c = self._geom[program]
        frame = np.asarray(frame)
        if frame.shape != (h, w, c):
            raise ValueError(
                f"{program} expects frames of shape {(h, w, c)}, "
                f"got {frame.shape}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.submit(FrameRequest(rid=rid, program=program, frame=frame))
        return rid

    def submit_many(self, program: str, frames) -> List[int]:
        return [self.submit(program, f) for f in frames]

    # -- dispatch side ------------------------------------------------------

    def _pad_frames(self, reqs: List[FrameRequest],
                    geom: Tuple[int, int, int]):
        """Stack a lane's pull into a full static batch (the always-on
        pipeline doesn't idle: short lanes pad with the last real frame,
        empty lanes with zeros; the burned slots are billed)."""
        if reqs:
            frames = np.stack([r.frame for r in reqs])
            if len(reqs) < self.batch:
                pad = np.broadcast_to(
                    frames[-1], (self.batch - len(reqs),) + frames.shape[1:])
                frames = np.concatenate([frames, pad])
        else:
            frames = np.zeros((self.batch,) + geom,
                              dtype=np.int32)
        return frames

    def _launch(self) -> Optional[Dict[str, Any]]:
        """Pull + pad + dispatch one static batch — solo or, with
        ``shared=True`` and >= 2 backlogged lanes of a composite group,
        one shared-array composite serving every backlogged member.
        Returns the in-flight handle (device arrays, not yet synced) or
        ``None`` when drained.  Serving counters are billed at launch —
        the energy is burned the moment the batch hits the array, synced
        or not."""
        # with shared=False the group map is empty, so this degrades to
        # exactly next_batch's solo pull (one lane per dispatch)
        pulled = self.queue.next_batch_shared(self.batch, self._groups)
        if pulled is None:
            return None

        dispatch = self._dispatches
        self._dispatches += 1
        if len(pulled) > 1:
            # composite dispatch: every group member's sub-array runs this
            # batch — backlogged lanes carry frames, the rest burn padding.
            members = self._groups[next(iter(pulled))]
            comp = self._composites[members]
            reqs_by = {m: pulled.get(m, []) for m in members}
            frames = []
            for m in members:
                f = jnp.asarray(self._pad_frames(reqs_by[m], self._geom[m]))
                if self.mesh is not None:
                    f = sharding.scatter_frames(self.mesh, f)
                frames.append(f)
            logits, labels = comp["fn"](comp["image"], tuple(frames))
            for m in members:
                self._served[m] += len(reqs_by[m])
                self._padded[m] += self.batch - len(reqs_by[m])
            self._shared_dispatches += 1
            self._util_sum += energy.array_occupancy(
                [self.programs[m] for m in members if reqs_by[m]])
            return dict(members=members, reqs=reqs_by, logits=logits,
                        labels=labels, dispatch=dispatch)

        (name, reqs), = pulled.items()
        frames = jnp.asarray(self._pad_frames(reqs, self._geom[name]))
        if self.mesh is not None:
            frames = sharding.scatter_frames(self.mesh, frames)
        logits, labels = self._fns[name](self.artifacts[name], frames)
        self._served[name] += len(reqs)
        self._padded[name] += self.batch - len(reqs)
        self._util_sum += 1.0 / self.programs[name].s
        return dict(name=name, reqs=reqs, logits=logits, labels=labels,
                    dispatch=dispatch)

    @staticmethod
    def _materialize(handle: Dict[str, Any]):
        """Sync an in-flight dispatch's device arrays to host numpy (runs
        on the fetch thread when prefetching)."""
        if "members" in handle:
            labels = tuple(np.asarray(jax.block_until_ready(l))
                           for l in handle["labels"])
            logits = tuple(np.asarray(l) for l in handle["logits"])
        else:
            labels = np.asarray(jax.block_until_ready(handle["labels"]))
            logits = np.asarray(handle["logits"])
        return logits, labels

    def _finish(self, handle: Dict[str, Any]) -> List[FrameResult]:
        """Block on an in-flight dispatch and materialize its results."""
        if "future" in handle:
            logits, labels = handle["future"].result()
        else:
            logits, labels = self._materialize(handle)
        if "members" in handle:
            out = []
            for mi, m in enumerate(handle["members"]):
                out.extend(
                    FrameResult(rid=r.rid, program=m,
                                label=int(labels[mi][i]),
                                logits=logits[mi][i],
                                dispatch=handle["dispatch"])
                    for i, r in enumerate(handle["reqs"][m]))
            return out
        name, reqs = handle["name"], handle["reqs"]
        return [FrameResult(rid=r.rid, program=name, label=int(labels[i]),
                            logits=logits[i], dispatch=handle["dispatch"])
                for i, r in enumerate(reqs)]

    def _fill_pipeline(self) -> None:
        """Launch dispatches until ``prefetch`` are in flight (or the
        queue drains), handing each to the background fetch thread."""
        while len(self._inflight) < self.prefetch:
            handle = self._launch()
            if handle is None:
                return
            if self._fetch_pool is not None:
                handle["future"] = self._fetch_pool.submit(
                    self._materialize, handle)
            self._inflight.append(handle)

    def step(self) -> List[FrameResult]:
        """One dispatch: pull a static batch, run its program(s), return
        results for the real (non-padding) frames.  [] once drained.

        With ``prefetch=k`` up to k batches are staged and dispatched
        *before* blocking on the oldest one, and finished results are
        pulled to the host by a background thread; batches still leave
        the queue in exactly the synchronous order, so fairness is
        untouched.
        """
        t0 = time.perf_counter()
        try:
            if not self.prefetch:
                cur = self._launch()
                return [] if cur is None else self._finish(cur)
            self._fill_pipeline()
            if not self._inflight:
                return []
            cur = self._inflight.popleft()
            self._fill_pipeline()              # stage N+1.. while N runs
            return self._finish(cur)
        finally:
            self._host_wall_s += time.perf_counter() - t0

    def drain(self) -> List[FrameResult]:
        """Serve until the queue is empty; results in dispatch order."""
        out: List[FrameResult] = []
        while True:
            got = self.step()
            if not got:
                return out
            out.extend(got)

    def close(self) -> None:
        """Release the background fetch thread, syncing (and discarding —
        ``drain()`` first to collect them) any in-flight dispatches.  The
        server keeps working afterwards with prefetch degraded to
        synchronous fetch; safe to call more than once."""
        while self._inflight:
            self._finish(self._inflight.popleft())
        if self._fetch_pool is not None:
            self._fetch_pool.shutdown(wait=True)
            self._fetch_pool = None

    def __del__(self):  # pragma: no cover - interpreter-exit ordering
        try:
            if getattr(self, "_fetch_pool", None) is not None:
                self._fetch_pool.shutdown(wait=False)
        except Exception:
            pass

    # -- accounting ---------------------------------------------------------

    def stats(self) -> ServeStats:
        chip = energy.serve_report(self.programs, self._served,
                                   self._padded, f_hz=self.f_hz,
                                   reports=self._reports)
        total = sum(self._served.values())
        fps = total / self._host_wall_s if self._host_wall_s else 0.0
        util = self._util_sum / self._dispatches if self._dispatches else 0.0
        return ServeStats(served=dict(self._served),
                          padded=dict(self._padded),
                          dispatches=self._dispatches,
                          host_wall_s=self._host_wall_s,
                          host_frames_per_s=fps,
                          chip=chip,
                          array_utilization=util,
                          shared_dispatches=self._shared_dispatches)

"""Dispatch policies: *what to run next* on the serving mechanism.

BinarEye's headline is not peak efficiency but *scalability*: one chip
trades 14.4 uJ/f at 86% CIFAR-10 accuracy down to 0.92 uJ/f at 94%
face-detect precision "depending on the task's requirements" (paper
Fig. 5 / Table 1).  The mechanism layer (``queue``/``executor``) can run
any of those operating points; this module owns the *choice*:

* :class:`DispatchPolicy` — the interface: given the queue, return the
  next :class:`Dispatch` (which lane(s), which resident program variant
  per lane, which frames).  The mechanism guarantees whatever the policy
  selects is executed and billed; the policy guarantees fairness (it must
  serve the round-robin head lane and advance the pointer past it —
  extra lanes may ride along, which only ever serves them *sooner*).
* :class:`StaticPolicy` — the one-member case of the interface: every
  lane is served by its own program, shared-array groups (PR 4) dispatch
  as composites when >= 2 members are backlogged.  This is bit-identical
  to the pre-policy scheduler.
* :class:`OperatingPointPolicy` — the paper's energy-accuracy controller:
  lanes are program *families* (one task compiled at several operating
  points, e.g. cifar9 at S=1/S=2/S=4/truncated depth — see
  ``networks.FAMILIES``), and the controller picks the served variant per
  dispatch from an energy budget (uJ/s of chip time, i.e. an average
  power envelope in µW) and the lane's backlog.  Downshifting a family
  frees sub-array lanes, which the policy exploits by co-dispatching
  other backlogged lanes whose chosen variants tile the array exactly
  (PR 4's composite packing, formed per dispatch instead of at
  admission).
* :class:`ContinuousPolicy` — rolling/continuous batching: frames are
  admitted into an in-flight dispatch window instead of being padded to
  the fixed lane batch.  The *batch size* autoscales against the lane's
  measured EWMA arrival rate and a per-lane latency SLO, and the
  dispatcher launches early-and-small when the oldest queued frame's
  deadline approaches.  It composes with the policies above through an
  ``inner`` policy: the continuous layer decides *when* to dispatch and
  *how many* frames, the inner policy decides *what* runs them (the
  operating-point controller autoscales the variant, this autoscales
  the batch).  Variable dispatch sizes are quantised onto a small
  bucket ladder so the jit/compile cache stays bounded and the autotune
  cache's nearest-batch tile lookup covers every size.

Budget semantics (property-tested in tests/test_policy.py): the
controller accounts every dispatch's chip-model energy and time at
*selection* (energy is committed the moment the batch hits the array)
and picks the most accurate variant whose inclusion keeps the average
power ``spent_uj / chip_time_s`` at or under ``budget_uj_s``.  When no
variant fits it pins to the cheapest (the always-on pipeline cannot
idle; the chip has a 0.92 uJ/f floor too), so for any feasible budget
(>= the cheapest variant's power) the spend never exceeds the budget
allowance by more than one dispatch.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Dict, Mapping, Optional, Tuple

from repro.core.chip import energy, isa
from repro.serving.queue import FrameQueue, FrameRequest


@dataclasses.dataclass(frozen=True)
class LaneDispatch:
    """One lane's share of a dispatch: the frames pulled from ``lane``
    and the resident program ``variant`` that will run them.  For static
    lanes ``variant == lane``; an empty ``requests`` tuple means the lane
    rides a composite as pure padding (its sub-array burns the batch)."""
    lane: str
    variant: str
    requests: Tuple[FrameRequest, ...]


@dataclasses.dataclass(frozen=True)
class Dispatch:
    """A policy decision: one batch per member lane, executed as one
    array pass (solo for a single lane, a shared-array composite for
    several).  ``batch`` is this dispatch's pad target — every member
    lane's pull is padded to it; ``None`` means the server's static
    batch (the pre-continuous behaviour)."""
    lanes: Tuple[LaneDispatch, ...]
    batch: Optional[int] = None

    @property
    def composite(self) -> bool:
        return len(self.lanes) > 1


@dataclasses.dataclass(frozen=True)
class PolicyContext:
    """Everything a policy may consult, bound once by the server."""
    batch: int                                  # max/static dispatch size
    lanes: Tuple[str, ...]                      # queue lanes (RR order)
    variants: Dict[str, Tuple[str, ...]]        # lane -> its variants
    programs: Dict[str, isa.Program]            # variant -> ISA program
    reports: Dict[str, energy.NetReport]        # variant -> chip model
    groups: Dict[str, Tuple[str, ...]]          # lane -> shared group
    quantum: int = 1                            # dispatch sizes must be
                                                # multiples of this (the
                                                # serve mesh device count)
    clock: Any = time.perf_counter              # the server's clock


class DispatchPolicy:
    """Base policy: subclasses implement :meth:`select`.

    ``bind`` is called once by the server before serving starts;
    ``variant_dispatches`` is read back into ``ServeStats`` so callers
    can see which operating points actually ran.
    """

    name = "policy"

    def __init__(self) -> None:
        self.ctx: Optional[PolicyContext] = None
        self.variant_dispatches: Dict[str, int] = {}
        self.flush = False              # drain mode: never hold frames back

    def bind(self, ctx: PolicyContext) -> None:
        self.ctx = ctx
        self.variant_dispatches = {v: 0 for v in ctx.programs}
        self.flush = False
        self._bound()

    def _bound(self) -> None:       # subclass hook
        pass

    def set_flush(self, flush: bool) -> None:
        """Drain mode: a flushing policy must dispatch whatever is queued
        rather than wait for its window/deadline conditions."""
        self.flush = flush

    def select(self, queue: FrameQueue) -> Optional[Dispatch]:
        raise NotImplementedError

    def select_sized(self, queue: FrameQueue,
                     size: int) -> Optional[Dispatch]:
        """Like :meth:`select` but with the dispatch pad target forced to
        ``size`` (the continuous layer's autoscaled batch).  Policies that
        support batch autoscaling override; the base implementation
        ignores ``size`` and keeps the static batch."""
        return self.select(queue)

    def _count(self, dispatch: Dispatch) -> Dispatch:
        for ld in dispatch.lanes:
            self.variant_dispatches[ld.variant] = (
                self.variant_dispatches.get(ld.variant, 0) + 1)
        return dispatch

    def variant_order(self, lane: str) -> Tuple[str, ...]:
        """The lane's variants, best operating point first — the order
        ``downshift_ratio`` measures against.  The base policy uses the
        registered declaration order; subclasses that re-rank (the
        operating-point controller sorts energy-descending) override."""
        return self.ctx.variants[lane]

    def downshift_ratio(self) -> float:
        """Over multi-variant (family) lanes: the fraction of dispatches
        served below the lane's top operating point."""
        if self.ctx is None:
            return 0.0
        total = below = 0
        for lane in self.ctx.lanes:
            order = self.variant_order(lane)
            if len(order) < 2:
                continue
            total += sum(self.variant_dispatches.get(v, 0) for v in order)
            below += sum(self.variant_dispatches.get(v, 0)
                         for v in order[1:])
        return below / total if total else 0.0


class StaticPolicy(DispatchPolicy):
    """Serve every lane with its own program; shared-array groups
    dispatch as composites when >= 2 members are backlogged (including
    idle members, whose sub-arrays burn their batch — the always-on
    array never idles).  Exactly the pre-policy scheduler."""

    name = "static"

    def select(self, queue: FrameQueue) -> Optional[Dispatch]:
        return self.select_sized(queue, self.ctx.batch)

    def select_sized(self, queue: FrameQueue,
                     size: int) -> Optional[Dispatch]:
        pulled = queue.next_batch_shared(size, self.ctx.groups)
        if pulled is None:
            return None
        if len(pulled) > 1:
            # composite dispatch: every group member's sub-array runs this
            # batch — backlogged lanes carry frames, the rest burn padding.
            members = self.ctx.groups[next(iter(pulled))]
            lanes = tuple(LaneDispatch(m, m, tuple(pulled.get(m, ())))
                          for m in members)
        else:
            (name, reqs), = pulled.items()
            lanes = (LaneDispatch(name, name, tuple(reqs)),)
        batch = None if size == self.ctx.batch else size
        return self._count(Dispatch(lanes, batch=batch))


class OperatingPointPolicy(DispatchPolicy):
    """The energy-accuracy operating-point controller (paper Fig. 5).

    Per family lane the variants are held energy-descending (= accuracy
    descending along the Pareto front, see ``energy.operating_points``);
    each dispatch picks the most accurate variant affordable under
    ``budget_uj_s`` and downshifts one extra step when the lane's backlog
    reaches ``backlog_high`` frames (catching up at a cheaper, faster
    point).  With ``shared=True`` other backlogged lanes whose chosen
    variants tile the 256-channel array exactly ride the same dispatch
    as an on-the-fly composite.

    A temporal runtime (``serving/temporal.py``) may additionally report
    each lane's *scene activity* — the fraction of its streams whose
    frame delta crossed the gate threshold — via :meth:`set_activity`;
    a lane whose activity sits below ``activity_low`` downshifts one
    extra step (a quiet scene needs neither the accuracy nor the energy
    of the top operating point).  Lanes that never report activity are
    untouched.
    """

    name = "operating-point"

    def __init__(self, budget_uj_s: Optional[float] = None,
                 backlog_high: Optional[int] = None,
                 shared: bool = False,
                 activity_low: float = 0.25) -> None:
        super().__init__()
        if budget_uj_s is not None and budget_uj_s <= 0:
            raise ValueError(
                f"budget_uj_s must be positive, got {budget_uj_s}")
        if not 0.0 <= activity_low <= 1.0:
            raise ValueError(
                f"activity_low must be in [0, 1], got {activity_low}")
        self.budget_uj_s = budget_uj_s
        self.backlog_high = backlog_high
        self.shared = shared
        self.activity_low = activity_low
        self.spent_uj = 0.0             # committed chip-model energy
        self.chip_time_s = 0.0          # committed chip-model time
        self._activity: Dict[str, float] = {}   # lane -> reported activity

    def _bound(self) -> None:
        ctx = self.ctx
        # binding attaches the policy to a fresh server: committed totals
        # reset (a reused instance must not carry another server's spend)
        self.spent_uj = 0.0
        self.chip_time_s = 0.0
        self._activity = {}
        self._backlog_high = (self.backlog_high if self.backlog_high
                              is not None else 4 * ctx.batch)
        # variants energy-descending per lane; one frame of variant v
        # costs e1[v] uJ and t1[v] seconds of chip time — a dispatch of
        # n frames commits n * e1 / n * t1, so variable-size dispatches
        # bill exactly what they run
        self._e1 = {v: r.i2l_energy_per_inference * 1e6
                    for v, r in ctx.reports.items()}
        self._t1 = {v: 1.0 / r.inferences_per_s
                    for v, r in ctx.reports.items()}
        self._order = {
            lane: tuple(sorted(vs, key=lambda v: -self._e1[v]))
            for lane, vs in ctx.variants.items()}

    def variant_order(self, lane: str) -> Tuple[str, ...]:
        return self._order[lane]

    def set_activity(self, lane: str, activity: float) -> None:
        """Report a lane's scene activity in [0, 1] — the fraction of
        its streams whose frame delta crossed the gate threshold (the
        temporal runtime's per-step signal, typically an EWMA).  Quiet
        lanes (below ``activity_low``) downshift one extra operating
        point on subsequent dispatches."""
        if lane not in self._order:
            raise KeyError(f"unknown lane {lane!r} "
                           f"(have {sorted(self._order)})")
        if not 0.0 <= activity <= 1.0:
            raise ValueError(
                f"activity must be in [0, 1], got {activity}")
        self._activity[lane] = activity

    def _choose(self, lane: str, pending: int, size: int,
                spent: float, time: float) -> str:
        """Most accurate affordable variant for ``lane`` at dispatch size
        ``size``, given committed totals ``(spent, time)``; backlog
        pressure and quiet-scene activity each downshift one more step;
        the cheapest variant is the unconditional floor."""
        order = self._order[lane]
        idx = len(order) - 1                      # floor: cheapest
        for i, v in enumerate(order):
            if self.budget_uj_s is None or (
                    (spent + size * self._e1[v])
                    <= self.budget_uj_s * (time + size * self._t1[v])):
                idx = i
                break
        if pending >= self._backlog_high:
            idx = min(idx + 1, len(order) - 1)    # catch-up downshift
        act = self._activity.get(lane)
        if act is not None and act < self.activity_low:
            idx = min(idx + 1, len(order) - 1)    # quiet-scene downshift
        return order[idx]

    def select(self, queue: FrameQueue) -> Optional[Dispatch]:
        return self.select_sized(queue, self.ctx.batch)

    def select_sized(self, queue: FrameQueue,
                     size: int) -> Optional[Dispatch]:
        lane = queue.first_backlogged()
        if lane is None:
            return None
        queue.advance_past(lane)
        spent, time = self.spent_uj, self.chip_time_s

        head = self._choose(lane, queue.pending(lane), size, spent, time)
        picks = [(lane, head)]
        occ = 1.0 / self.ctx.programs[head].s
        spent += size * self._e1[head]
        time += size * self._t1[head]

        if self.shared and occ < 1.0 - 1e-9:
            # riders: other backlogged lanes whose chosen variants fill
            # the freed sub-array lanes — commit only on an exact tiling
            for other in queue.rr_lanes():
                if other == lane or not queue.pending(other):
                    continue
                v = self._choose(other, queue.pending(other), size,
                                 spent, time)
                w = 1.0 / self.ctx.programs[v].s
                if occ + w > 1.0 + 1e-9:
                    continue
                picks.append((other, v))
                occ += w
                spent += size * self._e1[v]
                time += size * self._t1[v]
                if occ >= 1.0 - 1e-9:
                    break
            if occ < 1.0 - 1e-9 and len(picks) > 1:
                picks = picks[:1]                 # no exact tiling: solo
                spent = self.spent_uj + size * self._e1[head]
                time = self.chip_time_s + size * self._t1[head]

        self.spent_uj, self.chip_time_s = spent, time
        lanes = tuple(LaneDispatch(l, v, tuple(queue.take(l, size)))
                      for l, v in picks)
        batch = None if size == self.ctx.batch else size
        return self._count(Dispatch(lanes, batch=batch))


class ContinuousPolicy(DispatchPolicy):
    """Rolling/continuous batching: an SLO-bounded admission window.

    Instead of padding every dispatch to the fixed lane batch, the head
    lane's frames are admitted into an in-flight window and dispatched
    when one of three things happens:

    * the window reaches its *target size* — ``ceil(rate * slo_s *
      headroom)`` frames, the number the lane's EWMA arrival rate is
      expected to deliver inside the SLO budget (clamped to
      ``[min_batch, ctx.batch]``);
    * the oldest queued frame's **deadline approaches** — its queueing
      delay exceeds ``slo_s * deadline_frac`` — and the dispatcher
      launches early-and-small rather than blow the SLO waiting to fill
      the pad;
    * the server is **flushing** (drain), which disables waiting
      entirely.

    The dispatch size is then quantised up onto a bucket ladder
    ``{q, 2q, 4q, ... ctx.batch}`` (``q = ctx.quantum``, the serve mesh
    device count) so the executor's jit cache stays bounded at
    ``log2(batch)`` entries per program and the autotune cache's
    nearest-batch tile lookup covers every size.

    *What* runs the frames is delegated to ``inner`` (default
    :class:`StaticPolicy`): the operating-point controller autoscales
    the **variant**, this layer autoscales the **batch** — composition,
    not replacement.  ``variant_dispatches`` is shared with the inner
    policy so accounting (and ``downshift_ratio``) reflects what ran.

    Unstamped requests (``t_submit == 0``) carry no deadline, so they
    dispatch immediately — replay-style callers that never stamp get
    static-like behaviour at size ``min(pending, batch)``.
    """

    name = "continuous"

    def __init__(self, slo_ms: float = 50.0, min_batch: int = 1,
                 headroom: float = 0.5, deadline_frac: float = 0.5,
                 inner: Optional[DispatchPolicy] = None) -> None:
        super().__init__()
        if slo_ms <= 0:
            raise ValueError(f"slo_ms must be positive, got {slo_ms}")
        if min_batch < 1:
            raise ValueError(f"min_batch must be >= 1, got {min_batch}")
        if not 0.0 < headroom <= 1.0:
            raise ValueError(f"headroom must be in (0, 1], got {headroom}")
        if not 0.0 <= deadline_frac <= 1.0:
            raise ValueError(
                f"deadline_frac must be in [0, 1], got {deadline_frac}")
        self.slo_ms = slo_ms
        self.min_batch = min_batch
        self.headroom = headroom
        self.deadline_frac = deadline_frac
        self.inner = inner if inner is not None else StaticPolicy()

    def _bound(self) -> None:
        self.inner.bind(self.ctx)
        # one shared accounting dict: the inner policy does the counting
        # (it builds every Dispatch), this layer reads the same totals
        self.variant_dispatches = self.inner.variant_dispatches
        q = max(1, self.ctx.quantum)
        ladder = []
        s = q
        while s < self.ctx.batch:
            ladder.append(s)
            s *= 2
        ladder.append(self.ctx.batch)
        self._ladder = tuple(ladder)

    def set_flush(self, flush: bool) -> None:
        super().set_flush(flush)
        self.inner.set_flush(flush)

    def variant_order(self, lane: str) -> Tuple[str, ...]:
        return self.inner.variant_order(lane)

    def downshift_ratio(self) -> float:
        return self.inner.downshift_ratio()

    def _bucket(self, n: int) -> int:
        """Smallest ladder size >= n (the pad is billed, so round up as
        little as possible)."""
        for s in self._ladder:
            if s >= n:
                return s
        return self._ladder[-1]

    def _target(self, rate: float) -> int:
        """Window target: how many frames the lane's arrival rate should
        deliver within ``headroom`` of the SLO budget."""
        if rate <= 0.0:
            return self.min_batch
        want = math.ceil(rate * (self.slo_ms / 1e3) * self.headroom)
        return max(self.min_batch, min(want, self.ctx.batch))

    def select(self, queue: FrameQueue) -> Optional[Dispatch]:
        lane = queue.first_backlogged()
        if lane is None:
            return None
        pending = queue.pending(lane)
        if not self.flush:
            target = self._target(queue.arrival_rate(lane))
            oldest = queue.oldest_submit(lane)
            if oldest is None:
                deadline_near = True      # unstamped: no deadline to wait on
            else:
                waited = self.ctx.clock() - oldest
                deadline_near = waited >= (
                    self.slo_ms / 1e3) * self.deadline_frac
            if pending < target and not deadline_near:
                return None               # keep the window open
        size = self._bucket(min(pending, self.ctx.batch))
        return self.inner.select_sized(queue, size)

"""Dispatch policies: *what to run next* on the serving mechanism.

BinarEye's headline is not peak efficiency but *scalability*: one chip
trades 14.4 uJ/f at 86% CIFAR-10 accuracy down to 0.92 uJ/f at 94%
face-detect precision "depending on the task's requirements" (paper
Fig. 5 / Table 1).  The mechanism layer (``queue``/``executor``) can run
any of those operating points; this module owns the *choice*:

* :class:`DispatchPolicy` — the interface: given the queue, return the
  next :class:`Dispatch` (which lane(s), which resident program variant
  per lane, which frames).  The mechanism guarantees whatever the policy
  selects is executed and billed; the policy guarantees fairness (it must
  serve the round-robin head lane and advance the pointer past it —
  extra lanes may ride along, which only ever serves them *sooner*).
* :class:`StaticPolicy` — the one-member case of the interface: every
  lane is served by its own program, shared-array groups (PR 4) dispatch
  as composites when >= 2 members are backlogged.  This is bit-identical
  to the pre-policy scheduler.
* :class:`OperatingPointPolicy` — the paper's energy-accuracy controller:
  lanes are program *families* (one task compiled at several operating
  points, e.g. cifar9 at S=1/S=2/S=4/truncated depth — see
  ``networks.FAMILIES``), and the controller picks the served variant per
  dispatch from an energy budget (uJ/s of chip time, i.e. an average
  power envelope in µW) and the lane's backlog.  Downshifting a family
  frees sub-array lanes, which the policy exploits by co-dispatching
  other backlogged lanes whose chosen variants tile the array exactly
  (PR 4's composite packing, formed per dispatch instead of at
  admission).

Budget semantics (property-tested in tests/test_policy.py): the
controller accounts every dispatch's chip-model energy and time at
*selection* (energy is committed the moment the batch hits the array)
and picks the most accurate variant whose inclusion keeps the average
power ``spent_uj / chip_time_s`` at or under ``budget_uj_s``.  When no
variant fits it pins to the cheapest (the always-on pipeline cannot
idle; the chip has a 0.92 uJ/f floor too), so for any feasible budget
(>= the cheapest variant's power) the spend never exceeds the budget
allowance by more than one dispatch.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Tuple

from repro.core.chip import energy, isa
from repro.serving.queue import FrameQueue, FrameRequest


@dataclasses.dataclass(frozen=True)
class LaneDispatch:
    """One lane's share of a dispatch: the frames pulled from ``lane``
    and the resident program ``variant`` that will run them.  For static
    lanes ``variant == lane``; an empty ``requests`` tuple means the lane
    rides a composite as pure padding (its sub-array burns the batch)."""
    lane: str
    variant: str
    requests: Tuple[FrameRequest, ...]


@dataclasses.dataclass(frozen=True)
class Dispatch:
    """A policy decision: one static batch per member lane, executed as
    one array pass (solo for a single lane, a shared-array composite for
    several)."""
    lanes: Tuple[LaneDispatch, ...]

    @property
    def composite(self) -> bool:
        return len(self.lanes) > 1


@dataclasses.dataclass(frozen=True)
class PolicyContext:
    """Everything a policy may consult, bound once by the server."""
    batch: int                                  # static dispatch size
    lanes: Tuple[str, ...]                      # queue lanes (RR order)
    variants: Dict[str, Tuple[str, ...]]        # lane -> its variants
    programs: Dict[str, isa.Program]            # variant -> ISA program
    reports: Dict[str, energy.NetReport]        # variant -> chip model
    groups: Dict[str, Tuple[str, ...]]          # lane -> shared group


class DispatchPolicy:
    """Base policy: subclasses implement :meth:`select`.

    ``bind`` is called once by the server before serving starts;
    ``variant_dispatches`` is read back into ``ServeStats`` so callers
    can see which operating points actually ran.
    """

    name = "policy"

    def __init__(self) -> None:
        self.ctx: Optional[PolicyContext] = None
        self.variant_dispatches: Dict[str, int] = {}

    def bind(self, ctx: PolicyContext) -> None:
        self.ctx = ctx
        self.variant_dispatches = {v: 0 for v in ctx.programs}
        self._bound()

    def _bound(self) -> None:       # subclass hook
        pass

    def select(self, queue: FrameQueue) -> Optional[Dispatch]:
        raise NotImplementedError

    def _count(self, dispatch: Dispatch) -> Dispatch:
        for ld in dispatch.lanes:
            self.variant_dispatches[ld.variant] = (
                self.variant_dispatches.get(ld.variant, 0) + 1)
        return dispatch

    def variant_order(self, lane: str) -> Tuple[str, ...]:
        """The lane's variants, best operating point first — the order
        ``downshift_ratio`` measures against.  The base policy uses the
        registered declaration order; subclasses that re-rank (the
        operating-point controller sorts energy-descending) override."""
        return self.ctx.variants[lane]

    def downshift_ratio(self) -> float:
        """Over multi-variant (family) lanes: the fraction of dispatches
        served below the lane's top operating point."""
        if self.ctx is None:
            return 0.0
        total = below = 0
        for lane in self.ctx.lanes:
            order = self.variant_order(lane)
            if len(order) < 2:
                continue
            total += sum(self.variant_dispatches.get(v, 0) for v in order)
            below += sum(self.variant_dispatches.get(v, 0)
                         for v in order[1:])
        return below / total if total else 0.0


class StaticPolicy(DispatchPolicy):
    """Serve every lane with its own program; shared-array groups
    dispatch as composites when >= 2 members are backlogged (including
    idle members, whose sub-arrays burn their batch — the always-on
    array never idles).  Exactly the pre-policy scheduler."""

    name = "static"

    def select(self, queue: FrameQueue) -> Optional[Dispatch]:
        pulled = queue.next_batch_shared(self.ctx.batch, self.ctx.groups)
        if pulled is None:
            return None
        if len(pulled) > 1:
            # composite dispatch: every group member's sub-array runs this
            # batch — backlogged lanes carry frames, the rest burn padding.
            members = self.ctx.groups[next(iter(pulled))]
            lanes = tuple(LaneDispatch(m, m, tuple(pulled.get(m, ())))
                          for m in members)
        else:
            (name, reqs), = pulled.items()
            lanes = (LaneDispatch(name, name, tuple(reqs)),)
        return self._count(Dispatch(lanes))


class OperatingPointPolicy(DispatchPolicy):
    """The energy-accuracy operating-point controller (paper Fig. 5).

    Per family lane the variants are held energy-descending (= accuracy
    descending along the Pareto front, see ``energy.operating_points``);
    each dispatch picks the most accurate variant affordable under
    ``budget_uj_s`` and downshifts one extra step when the lane's backlog
    reaches ``backlog_high`` frames (catching up at a cheaper, faster
    point).  With ``shared=True`` other backlogged lanes whose chosen
    variants tile the 256-channel array exactly ride the same dispatch
    as an on-the-fly composite.
    """

    name = "operating-point"

    def __init__(self, budget_uj_s: Optional[float] = None,
                 backlog_high: Optional[int] = None,
                 shared: bool = False) -> None:
        super().__init__()
        if budget_uj_s is not None and budget_uj_s <= 0:
            raise ValueError(
                f"budget_uj_s must be positive, got {budget_uj_s}")
        self.budget_uj_s = budget_uj_s
        self.backlog_high = backlog_high
        self.shared = shared
        self.spent_uj = 0.0             # committed chip-model energy
        self.chip_time_s = 0.0          # committed chip-model time

    def _bound(self) -> None:
        ctx = self.ctx
        # binding attaches the policy to a fresh server: committed totals
        # reset (a reused instance must not carry another server's spend)
        self.spent_uj = 0.0
        self.chip_time_s = 0.0
        self._backlog_high = (self.backlog_high if self.backlog_high
                              is not None else 4 * ctx.batch)
        # variants energy-descending per lane; one full static batch of
        # variant v costs e[v] uJ and t[v] seconds of chip time
        self._e = {v: ctx.batch * r.i2l_energy_per_inference * 1e6
                   for v, r in ctx.reports.items()}
        self._t = {v: ctx.batch / r.inferences_per_s
                   for v, r in ctx.reports.items()}
        self._order = {
            lane: tuple(sorted(vs, key=lambda v: -self._e[v]))
            for lane, vs in ctx.variants.items()}

    def variant_order(self, lane: str) -> Tuple[str, ...]:
        return self._order[lane]

    def _choose(self, lane: str, pending: int,
                spent: float, time: float) -> str:
        """Most accurate affordable variant for ``lane``, given committed
        totals ``(spent, time)``; backlog pressure downshifts one more
        step; the cheapest variant is the unconditional floor."""
        order = self._order[lane]
        idx = len(order) - 1                      # floor: cheapest
        for i, v in enumerate(order):
            if self.budget_uj_s is None or (
                    (spent + self._e[v])
                    <= self.budget_uj_s * (time + self._t[v])):
                idx = i
                break
        if pending >= self._backlog_high:
            idx = min(idx + 1, len(order) - 1)    # catch-up downshift
        return order[idx]

    def select(self, queue: FrameQueue) -> Optional[Dispatch]:
        lane = queue.first_backlogged()
        if lane is None:
            return None
        queue.advance_past(lane)
        batch = self.ctx.batch
        spent, time = self.spent_uj, self.chip_time_s

        head = self._choose(lane, queue.pending(lane), spent, time)
        picks = [(lane, head)]
        occ = 1.0 / self.ctx.programs[head].s
        spent += self._e[head]
        time += self._t[head]

        if self.shared and occ < 1.0 - 1e-9:
            # riders: other backlogged lanes whose chosen variants fill
            # the freed sub-array lanes — commit only on an exact tiling
            for other in queue.rr_lanes():
                if other == lane or not queue.pending(other):
                    continue
                v = self._choose(other, queue.pending(other), spent, time)
                w = 1.0 / self.ctx.programs[v].s
                if occ + w > 1.0 + 1e-9:
                    continue
                picks.append((other, v))
                occ += w
                spent += self._e[v]
                time += self._t[v]
                if occ >= 1.0 - 1e-9:
                    break
            if occ < 1.0 - 1e-9 and len(picks) > 1:
                picks = picks[:1]                 # no exact tiling: solo
                spent = self.spent_uj + self._e[head]
                time = self.chip_time_s + self._t[head]

        self.spent_uj, self.chip_time_s = spent, time
        lanes = tuple(LaneDispatch(l, v, tuple(queue.take(l, batch)))
                      for l, v in picks)
        return self._count(Dispatch(lanes))

"""Frame queue mechanism: per-lane FIFOs + the round-robin pointer.

This is the *mechanism* half of the serving scheduler (policies live in
:mod:`repro.serving.policy`): lanes hold submitted frames in FIFO order
and a round-robin pointer rotates across non-empty lanes so no resident
task starves.  A :class:`~repro.serving.policy.DispatchPolicy` decides
*what to run* (which lane, which program variant, solo or shared); the
queue only answers "who is next" and "hand me their frames".

The primitives a policy composes:

* :meth:`FrameQueue.rr_lanes` / :meth:`first_backlogged` — lane names in
  round-robin order from the pointer;
* :meth:`FrameQueue.take` — pop up to ``capacity`` requests from a lane
  (never moves the pointer);
* :meth:`FrameQueue.advance_past` — advance the pointer past a served
  lane (the fairness-critical step: a policy that serves lane L must
  advance past L, and may serve *extra* lanes without moving the pointer
  — extra service is always sooner than the solo schedule, never later).

:meth:`next_batch` and :meth:`next_batch_shared` are the two canonical
compositions (solo round-robin, and PR 4's shared-array pull); the
static dispatch policy is built on them.

For latency-aware policies the queue additionally keeps two per-lane
signals, both derived purely from submission (no wall-clock reads of its
own): an EWMA **arrival-rate estimate** (:class:`EwmaRate`, updated from
each request's ``t_submit`` stamp) and the **oldest queued timestamp**
(:meth:`oldest_submit` — the admission deadline anchor).  Requests
without a timestamp (``t_submit == 0``) leave both signals untouched, so
pure-Python scheduling tests keep working unchanged.
"""

from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.chip import isa


@dataclasses.dataclass(frozen=True)
class FrameRequest:
    """One frame awaiting inference under a resident program (lane)."""
    rid: int                  # server-global request id (arrival order)
    program: str              # lane name (resident program or family)
    frame: Any                # (H, W, C) integer image
    t_submit: float = 0.0     # admission timestamp (server clock; 0 =
                              # unstamped, latency accounting skips it)


@dataclasses.dataclass(frozen=True)
class FrameResult:
    rid: int
    program: str              # the lane the request was submitted to
    label: int
    logits: np.ndarray
    dispatch: int             # index of the static batch that served it
    variant: str = ""         # resident program that actually ran it (==
                              # program for static lanes; a family lane's
                              # controller-chosen operating point)
    t_submit: float = 0.0     # admission timestamp carried from the request
    t_done: float = 0.0       # label available on the host (same clock)

    @property
    def latency_s(self) -> float:
        """Input-to-label latency; 0.0 when the request was unstamped."""
        if self.t_submit <= 0.0 or self.t_done <= 0.0:
            return 0.0
        return self.t_done - self.t_submit


class EwmaRate:
    """EWMA arrival-rate estimator over inter-arrival gaps.

    ``observe(t)`` feeds one arrival timestamp; :attr:`rate` is
    ``1 / ewma(dt)`` in arrivals/s, 0.0 until two timestamped arrivals
    have been seen.  Non-positive gaps (clock ties, unstamped requests
    replayed at t=0) are skipped so the estimate only ever reflects real
    spacing.  Purely deterministic given the observation sequence.
    """

    def __init__(self, alpha: float = 0.2):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = alpha
        self._last: Optional[float] = None
        self._dt: Optional[float] = None

    def observe(self, t: float) -> None:
        if self._last is not None:
            dt = t - self._last
            if dt > 0.0:
                self._dt = (dt if self._dt is None
                            else self.alpha * dt + (1 - self.alpha) * self._dt)
        self._last = t

    @property
    def rate(self) -> float:
        return 1.0 / self._dt if self._dt else 0.0


class FrameQueue:
    """Per-program FIFO lanes + round-robin dispatch across non-empty lanes.

    The solo fairness contract (:meth:`next_batch`, property-tested in
    tests/test_chip_serve.py): a lane is never dispatched twice while
    another lane has been waiting non-empty the whole time — the pointer
    advances past each served lane and only skips lanes that are empty at
    their turn.  :meth:`next_batch_shared` deliberately relaxes the
    "never twice" half for lanes *inside a shared-array group* (a
    composite dispatch serves every backlogged group member each time the
    pointer hits any of them), but keeps the no-starvation bound every
    consumer actually relies on: any lane non-empty before a dispatch is
    itself served within the next ``n_lanes`` dispatches, and no lane is
    ever served *later* than the solo schedule would have served it.
    """

    def __init__(self, programs: Iterable[str]):
        self._order: List[str] = list(programs)
        if not self._order:
            raise ValueError("FrameQueue needs at least one resident program")
        if len(set(self._order)) != len(self._order):
            raise ValueError(f"duplicate program names: {self._order}")
        self._lanes: Dict[str, collections.deque] = {
            name: collections.deque() for name in self._order}
        self._rates: Dict[str, EwmaRate] = {
            name: EwmaRate() for name in self._order}
        self._rr = 0

    def submit(self, req: FrameRequest) -> None:
        if req.program not in self._lanes:
            raise KeyError(
                f"program {req.program!r} not resident "
                f"(have {self._order})")
        if req.t_submit > 0.0:
            self._rates[req.program].observe(req.t_submit)
        self._lanes[req.program].append(req)

    def pending(self, program: Optional[str] = None) -> int:
        if program is not None:
            return len(self._lanes[program])
        return sum(len(q) for q in self._lanes.values())

    def __len__(self) -> int:
        return self.pending()

    # -- policy-facing primitives ------------------------------------------

    @property
    def lanes(self) -> Tuple[str, ...]:
        return tuple(self._order)

    def rr_lanes(self) -> List[str]:
        """All lane names, one full rotation starting at the pointer."""
        n = len(self._order)
        return [self._order[(self._rr + i) % n] for i in range(n)]

    def first_backlogged(self) -> Optional[str]:
        """The next non-empty lane in round-robin order (pointer unmoved)."""
        for name in self.rr_lanes():
            if self._lanes[name]:
                return name
        return None

    def arrival_rate(self, lane: str) -> float:
        """EWMA arrival rate for ``lane`` in frames/s (0.0 until two
        timestamped submissions have been observed)."""
        return self._rates[lane].rate

    def oldest_submit(self, lane: str) -> Optional[float]:
        """``t_submit`` of the lane's head request — the deadline anchor
        for SLO-aware dispatch.  ``None`` when the lane is empty or its
        head request is unstamped."""
        q = self._lanes[lane]
        if not q or q[0].t_submit <= 0.0:
            return None
        return q[0].t_submit

    def take(self, lane: str, capacity: int) -> List[FrameRequest]:
        """Pop up to ``capacity`` requests from ``lane`` (FIFO); the
        round-robin pointer is NOT moved — pair with
        :meth:`advance_past` for the lane the dispatch was *for*."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        q = self._lanes[lane]
        return [q.popleft() for _ in range(min(capacity, len(q)))]

    def advance_past(self, lane: str) -> None:
        """Move the round-robin pointer just past ``lane``."""
        self._rr = (self._order.index(lane) + 1) % len(self._order)

    def requeue_front(self, lane: str, reqs: Iterable[FrameRequest]) -> None:
        """Push requests back at the *front* of a lane, preserving their
        relative order (``reqs[0]`` becomes the new head).

        This is the failover-migration primitive: frames orphaned by a
        dead replica are older than anything a survivor admitted after
        the failure, so they re-enter at the head of the FIFO and are
        served first.  The arrival-rate estimator is NOT fed — these are
        re-arrivals of already-observed admissions, not new traffic."""
        q = self._lanes[lane]
        for req in reversed(list(reqs)):
            if req.program != lane:
                raise ValueError(
                    f"request rid={req.rid} belongs to lane "
                    f"{req.program!r}, not {lane!r}")
            q.appendleft(req)

    # -- canonical compositions --------------------------------------------

    def next_batch(self, capacity: int) -> Optional[Tuple[str, List[FrameRequest]]]:
        """Up to ``capacity`` requests from the next non-empty lane in
        round-robin order; ``None`` once fully drained."""
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        name = self.first_backlogged()
        if name is None:
            return None
        self.advance_past(name)
        return name, self.take(name, capacity)

    def next_batch_shared(self, capacity: int,
                          groups: Mapping[str, Tuple[str, ...]]
                          ) -> Optional[Dict[str, List[FrameRequest]]]:
        """Round-robin like :meth:`next_batch`, but when the selected lane
        belongs to a shared-array group with >= 2 backlogged members, pull
        up to ``capacity`` from *every* backlogged member — one composite
        dispatch serves them all concurrently.  Lanes served early keep
        their round-robin position (they are simply empty — or shorter —
        when the pointer reaches them), so the no-starvation contract is
        untouched: a backlogged lane is only ever served *sooner*.
        Returns ``{name: requests}`` (single-entry for a solo dispatch),
        ``None`` once fully drained.
        """
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        name = self.first_backlogged()
        if name is None:
            return None
        self.advance_past(name)
        members = groups.get(name, (name,))
        backlogged = [m for m in members if self._lanes[m]]
        take_from = backlogged if len(backlogged) >= 2 else [name]
        return {m: self.take(m, capacity) for m in take_from}


def plan_shared_groups(programs: Mapping[str, isa.Program]
                       ) -> Tuple[Tuple[str, ...], ...]:
    """Partition resident programs into shared-array groups.

    First-fit-decreasing bin packing on sub-array width (256/S channels)
    into 256-channel bins; only bins that end *exactly* full with >= 2
    members become composite groups (the chip can only recombine
    sub-arrays that tile the array), everything else dispatches solo.
    Deterministic given admission order, so every server replica forms
    the same groups.
    """
    # stable sort: widest sub-arrays (smallest S) first, admission order
    # preserved within a width class
    items = sorted(programs.items(), key=lambda kv: kv[1].s)
    bins: List[Tuple[int, List[str]]] = []    # (free channels, members)
    for name, prog in items:
        width = isa.ARRAY_CHANNELS // prog.s
        for i, (free, members) in enumerate(bins):
            if width <= free:
                bins[i] = (free - width, members + [name])
                break
        else:
            bins.append((isa.ARRAY_CHANNELS - width, [name]))
    return tuple(tuple(members) for free, members in bins
                 if free == 0 and len(members) >= 2)

"""Dispatch executor: the mechanism that runs a policy's decisions.

This module owns everything between a :class:`~repro.serving.policy.
Dispatch` decision and host-side results — no scheduling choices live
here:

* **launch** — pad each member lane's pull to the static batch (the
  always-on pipeline never idles; short lanes pad with the last real
  frame, empty lanes with zeros), scatter over the serving mesh if one
  is bound, and run the member's jit'd serve function.  A multi-lane
  dispatch runs as ONE shared-array composite ``pallas_call``
  (``interpreter.pack_programs``): composites are compiled lazily per
  ordered variant tuple and cached, so both admission-time groups
  (static policy) and per-dispatch tilings (operating-point controller
  downshifts) hit the same compile cache.
* **materialize / finish** — sync a dispatch's device arrays to host
  numpy and unpack them into per-request :class:`FrameResult`s.
* **depth-k prefetch pipeline** — :meth:`step` keeps up to ``prefetch``
  dispatches in flight before blocking on the oldest one, with finished
  results fetched to host memory by a background thread; the policy is
  still consulted in exactly the synchronous order, so pipelining never
  changes the schedule (property-tested).  At depth 1 the background
  thread is skipped: with a single in-flight handle the consumer pops it
  immediately, so a fetch thread adds handoff overhead without any
  overlap to win (the BENCH prefetch-anomaly fix).

Dispatches carry their own pad target (``Dispatch.batch``): a continuous
policy's early-and-small launches pad only to their bucket size, not the
full static batch, so the burned-slot bill shrinks with the window.
"""

from __future__ import annotations

import collections
import concurrent.futures
import time
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chip import interpreter, isa
from repro.distributed import sharding
from repro.kernels import cache as warmcache
from repro.serving.policy import Dispatch
from repro.serving.queue import FrameRequest, FrameResult


class Executor:
    """Launch/materialize/finish + the prefetch pipeline for one server.

    ``programs``/``artifacts`` are keyed by resident *variant* name (for
    a static server that is just the lane name).  ``artifacts`` holds the
    raw admission-time artifacts (any form); per-variant device operands
    and jit'd serve functions are built here.
    """

    def __init__(self, programs: Mapping[str, isa.Program],
                 artifacts: Mapping[str, Any], *, batch: int,
                 mesh=None, donate_frames: bool = False,
                 interpret: Optional[bool] = None,
                 megakernel: bool = False, prefetch: int = 0,
                 warm_start: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        self.batch = batch
        self.mesh = mesh
        self.prefetch = prefetch
        self.clock = clock
        self._donate = donate_frames
        self._interpret = interpret
        self._megakernel = megakernel
        # warm_start routes serve-fn builds through the keyed warm-start
        # cache (kernels/cache.py): a second executor asking for the same
        # (programs, mesh, options, backend) shares the already-jitted
        # function — and its compiled shapes — so a replacement fleet
        # replica skips trace+compile entirely.  Sharing is safe because
        # serve fns are pure of weights (the artifact is an argument).
        self._warm_start = warm_start
        self.programs: Dict[str, isa.Program] = dict(programs)
        self._raw_artifacts: Dict[str, Any] = dict(artifacts)
        self.plans: Dict[str, interpreter.InferencePlan] = {}
        self.artifacts: Dict[str, Any] = {}
        self._fns: Dict[str, Any] = {}
        self._geom: Dict[str, Tuple[int, int, int]] = {}
        for name, prog in self.programs.items():
            isa.validate(prog)
            plan = interpreter.compile_plan(prog)
            if megakernel:
                art = interpreter.ensure_image(artifacts[name], prog)
            else:
                art = interpreter.ensure_packed(artifacts[name])
            if mesh is not None:
                art = sharding.replicate_artifact(mesh, art)
            io = prog.instrs[0]
            self.plans[name] = plan
            self.artifacts[name] = art
            self._geom[name] = (io.height, io.width, io.in_channels)
            self._fns[name] = self._serve_fn(plan, (prog,))
        self._composites: Dict[Tuple[str, ...], Dict[str, Any]] = {}
        self._cascades: Dict[Tuple[str, str, int], Dict[str, Any]] = {}
        self._deltas: Dict[Tuple[str, Optional[int], int], Dict[str, Any]] = {}
        self._inflight: collections.deque = collections.deque()
        # background fetch only pays off at depth >= 2: with one handle
        # in flight the consumer blocks on it immediately, so a thread
        # handoff is pure overhead (see module docstring)
        self._fetch_pool: Optional[concurrent.futures.ThreadPoolExecutor] = (
            concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="serve-fetch")
            if self.prefetch >= 2 else None)

    def _serve_fn(self, plan, progs: Tuple[isa.Program, ...],
                  kind: str = "serve", **extra):
        """Build (or warm-start) the jit'd serve fn for ``plan``.
        ``extra`` kwargs pass through to ``plan.make_serve_fn`` — the
        caller must fold them into ``kind`` so the warm-start key
        distinguishes them."""
        # CompositePlan.make_serve_fn has no megakernel knob (a composite
        # IS one fused pallas_call already) — only single-program plans
        # take it.
        kw: Dict[str, Any] = dict(mesh=self.mesh,
                                  donate_frames=self._donate,
                                  interpret=self._interpret)
        if kind == "serve":
            kw["megakernel"] = self._megakernel
        kw.update(extra)
        build = lambda: plan.make_serve_fn(**kw)
        if not self._warm_start:
            return build()
        key = warmcache.serve_fn_key(
            progs, mesh=self.mesh,
            megakernel=self._megakernel and kind == "serve",
            donate_frames=self._donate, interpret=self._interpret,
            kind=kind)
        return warmcache.get_or_build(key, build)

    def geometry(self, variant: str) -> Tuple[int, int, int]:
        return self._geom[variant]

    # -- composite compilation ---------------------------------------------

    def composite_for(self, variants: Tuple[str, ...]) -> Dict[str, Any]:
        """The compiled shared-array composite for an ordered variant
        tuple (lazy; cached — admission-time groups and on-the-fly
        controller tilings share the cache)."""
        comp = self._composites.get(variants)
        if comp is None:
            cplan, cimage = interpreter.pack_programs(
                {v: self.programs[v] for v in variants},
                {v: self._raw_artifacts[v] for v in variants})
            if self.mesh is not None:
                cimage = sharding.replicate_artifact(self.mesh, cimage)
            cfn = self._serve_fn(
                cplan, tuple(self.programs[v] for v in variants),
                kind="composite")
            comp = dict(plan=cplan, image=cimage, fn=cfn)
            self._composites[variants] = comp
        return comp

    def cascade_for(self, detector: str, recognizer: str, *,
                    positive_class: int = 1) -> Dict[str, Any]:
        """The compiled fused detector->recognizer cascade for a variant
        pair (lazy; cached like :meth:`composite_for`).  The serve fn
        routes through the warm-start cache with the positive class in
        the key — cascades of the same pair at different positive
        classes trace different escalation masks."""
        key = (detector, recognizer, positive_class)
        casc = self._cascades.get(key)
        if casc is None:
            cplan, cimage = interpreter.pack_cascade(
                {v: self.programs[v] for v in (detector, recognizer)},
                {v: self._raw_artifacts[v] for v in (detector, recognizer)},
                detector=detector, recognizer=recognizer,
                positive_class=positive_class)
            if self.mesh is not None:
                cimage = sharding.replicate_artifact(self.mesh, cimage)
            cfn = self._serve_fn(
                cplan, (self.programs[detector], self.programs[recognizer]),
                kind=f"cascade.p{positive_class}")
            casc = dict(plan=cplan, image=cimage, fn=cfn)
            self._cascades[key] = casc
        return casc

    def delta_for(self, variant: str, *, rb: Optional[int] = None,
                  check_every: int = 1) -> Dict[str, Any]:
        """The compiled delta-gated serving unit for one resident
        variant (lazy; cached like :meth:`composite_for`): the variant's
        ``DeltaPlan`` + megakernel weight image + jit'd stateful serve
        fn ``(image, frames, last, llog, ctrl) -> gated outputs``.
        ``rb``/``check_every`` tune the recompute-drain chunking and are
        part of the cache key (distinct knobs -> distinct compiles)."""
        key = (variant, rb, check_every)
        dl = self._deltas.get(key)
        if dl is None:
            dplan, dimage = interpreter.pack_delta(
                self.programs[variant], self._raw_artifacts[variant],
                name=variant)
            if self.mesh is not None:
                dimage = sharding.replicate_artifact(self.mesh, dimage)
            dfn = self._serve_fn(
                dplan, (self.programs[variant],),
                kind="delta.r%s.c%d" % (rb or 0, check_every),
                rb=rb, check_every=check_every)
            dl = dict(plan=dplan, image=dimage, fn=dfn)
            self._deltas[key] = dl
        return dl

    def warm_composites(self, groups) -> None:
        """Precompile composites for admission-time groups (static
        shared serving compiles its groups up front, like the chip
        loading every resident program's weights before serving)."""
        for members in groups:
            self.composite_for(tuple(members))

    @property
    def compiled_composites(self) -> Tuple[Tuple[str, ...], ...]:
        return tuple(self._composites)

    # -- launch / materialize / finish --------------------------------------

    def pad_frames(self, reqs: List[FrameRequest],
                   geom: Tuple[int, int, int],
                   size: Optional[int] = None):
        """Stack a lane's pull into a batch of ``size`` (default: the
        static batch — the always-on pipeline doesn't idle: short lanes
        pad with the last real frame, empty lanes with zeros; the burned
        slots are billed)."""
        size = self.batch if size is None else size
        if reqs:
            frames = np.stack([r.frame for r in reqs])
            if len(reqs) < size:
                pad = np.broadcast_to(
                    frames[-1], (size - len(reqs),) + frames.shape[1:])
                frames = np.concatenate([frames, pad])
        else:
            frames = np.zeros((size,) + geom, dtype=np.int32)
        return frames

    def launch(self, dispatch: Dispatch, index: int) -> Dict[str, Any]:
        """Run one policy decision on the device; returns the in-flight
        handle (device arrays, not yet synced)."""
        size = dispatch.batch if dispatch.batch is not None else self.batch
        if dispatch.composite:
            variants = tuple(ld.variant for ld in dispatch.lanes)
            comp = self.composite_for(variants)
            frames = []
            for ld in dispatch.lanes:
                f = jnp.asarray(self.pad_frames(list(ld.requests),
                                                self._geom[ld.variant],
                                                size))
                if self.mesh is not None:
                    f = sharding.scatter_frames(self.mesh, f)
                frames.append(f)
            logits, labels = comp["fn"](comp["image"], tuple(frames))
            return dict(dispatch=dispatch, index=index, logits=logits,
                        labels=labels)
        ld, = dispatch.lanes
        frames = jnp.asarray(self.pad_frames(list(ld.requests),
                                             self._geom[ld.variant], size))
        if self.mesh is not None:
            frames = sharding.scatter_frames(self.mesh, frames)
        logits, labels = self._fns[ld.variant](self.artifacts[ld.variant],
                                               frames)
        return dict(dispatch=dispatch, index=index, logits=logits,
                    labels=labels)

    @staticmethod
    def materialize(handle: Dict[str, Any]):
        """Sync an in-flight dispatch's device arrays to host numpy (runs
        on the fetch thread when prefetching)."""
        if handle["dispatch"].composite:
            labels = tuple(np.asarray(jax.block_until_ready(l))
                           for l in handle["labels"])
            logits = tuple(np.asarray(l) for l in handle["logits"])
        else:
            labels = np.asarray(jax.block_until_ready(handle["labels"]))
            logits = np.asarray(handle["logits"])
        return logits, labels

    def finish(self, handle: Dict[str, Any]) -> List[FrameResult]:
        """Block on an in-flight dispatch and materialize its results."""
        if "future" in handle:
            logits, labels = handle["future"].result()
        else:
            logits, labels = self.materialize(handle)
        dispatch: Dispatch = handle["dispatch"]
        t_done = self.clock()        # label available on the host, now
        if dispatch.composite:
            out = []
            for mi, ld in enumerate(dispatch.lanes):
                out.extend(
                    FrameResult(rid=r.rid, program=ld.lane,
                                label=int(labels[mi][i]),
                                logits=logits[mi][i],
                                dispatch=handle["index"],
                                variant=ld.variant,
                                t_submit=r.t_submit, t_done=t_done)
                    for i, r in enumerate(ld.requests))
            return out
        ld, = dispatch.lanes
        return [FrameResult(rid=r.rid, program=ld.lane, label=int(labels[i]),
                            logits=logits[i], dispatch=handle["index"],
                            variant=ld.variant,
                            t_submit=r.t_submit, t_done=t_done)
                for i, r in enumerate(ld.requests)]

    # -- the prefetch pipeline ----------------------------------------------

    def _fill(self, launch_fn: Callable[[], Optional[Dict[str, Any]]]) -> None:
        """Launch dispatches until ``prefetch`` are in flight (or the
        queue drains), handing each to the background fetch thread."""
        while len(self._inflight) < self.prefetch:
            handle = launch_fn()
            if handle is None:
                return
            if self._fetch_pool is not None:
                handle["future"] = self._fetch_pool.submit(
                    self.materialize, handle)
            self._inflight.append(handle)

    def step(self, launch_fn: Callable[[], Optional[Dict[str, Any]]]
             ) -> List[FrameResult]:
        """One dispatch through the pipeline: synchronous when
        ``prefetch == 0``, else keep the pipeline filled and block only
        on the oldest in-flight dispatch."""
        if not self.prefetch:
            cur = launch_fn()
            return [] if cur is None else self.finish(cur)
        self._fill(launch_fn)
        if not self._inflight:
            return []
        cur = self._inflight.popleft()
        self._fill(launch_fn)                  # stage N+1.. while N runs
        return self.finish(cur)

    def abort(self) -> List[FrameRequest]:
        """Simulated host loss: drop every in-flight dispatch WITHOUT
        materializing results and hand back the orphaned requests,
        oldest dispatch first (the fleet re-enqueues them, in order, at
        the front of a survivor's lanes).  Device work already launched
        is abandoned — its energy was billed at launch and is genuinely
        burned, exactly like a chip losing power mid-frame."""
        orphans: List[FrameRequest] = []
        while self._inflight:
            handle = self._inflight.popleft()
            fut = handle.get("future")
            if fut is not None:
                fut.cancel()
            for ld in handle["dispatch"].lanes:
                orphans.extend(ld.requests)
        if self._fetch_pool is not None:
            self._fetch_pool.shutdown(wait=False, cancel_futures=True)
            self._fetch_pool = None
        return orphans

    def close(self) -> None:
        """Release the background fetch thread, syncing (and discarding)
        any in-flight dispatches; safe to call more than once."""
        while self._inflight:
            self.finish(self._inflight.popleft())
        if self._fetch_pool is not None:
            self._fetch_pool.shutdown(wait=True)
            self._fetch_pool = None

    def __del__(self):  # pragma: no cover - interpreter-exit ordering
        try:
            if getattr(self, "_fetch_pool", None) is not None:
                self._fetch_pool.shutdown(wait=False)
        except Exception:
            pass

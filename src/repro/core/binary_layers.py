"""BitLinear: the paper's W1A1 compute as a drop-in LM projection layer.

Training path: fake-quant with STE (BinaryNet semantics) — sign(x) . sign(W),
differentiable through both binarizations.  A learnable per-output scale g
plays the role the chip's BatchNorm-comparator plays (and folds into an
integer threshold the same way at deployment).

Inference path: bitpacked XNOR-popcount through the Pallas kernels — the
TPU analogue of the neuron array datapath.  Both paths agree exactly
(tests/test_binary_layers.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binarize
from repro.kernels import ops as kops


def init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32):
    w = jax.random.normal(key, (d_out, d_in), dtype) / jnp.sqrt(d_in)
    return {"w": w, "g": jnp.ones((d_out,), dtype)}


def apply_train(params, x: jax.Array) -> jax.Array:
    """STE fake-quant path (differentiable)."""
    xb = binarize.ste_sign(x)
    wb = binarize.ste_sign(params["w"])
    y = jnp.einsum("...k,nk->...n", xb, wb)
    return y * params["g"] * (1.0 / jnp.sqrt(x.shape[-1]).astype(y.dtype))


def apply_infer(params, x: jax.Array, interpret: bool | None = None) -> jax.Array:
    """Packed XNOR-popcount path (deployment)."""
    w_signs = binarize.hard_sign(params["w"])
    y = kops.binary_linear(x, w_signs, interpret=interpret).astype(jnp.float32)
    return y * params["g"] * (1.0 / jnp.sqrt(x.shape[-1]))

"""Core library: the paper's contribution (BinaryNet compute + the BinarEye
chip abstraction) as composable JAX modules."""

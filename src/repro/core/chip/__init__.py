"""Faithful functional + analytical model of the BinarEye chip:
ISA (programmable depth), neuron array (programmable width S),
interpreter (reprogrammable weights), energy model (Figs. 4-5, Table 1)."""
from repro.core.chip import energy, interpreter, isa, networks, neuron_array  # noqa: F401

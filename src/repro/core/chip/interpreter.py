"""Program interpreter: compiles a BinarEye ISA program into jit-able JAX fns.

Two modes mirror the chip's lifecycle:

* ``forward_train``  — BinaryNet training semantics (1st level of
  flexibility: reprogrammable weights).  Latent float weights, STE sign,
  BatchNorm before the sign activation.  Differentiable end to end.
* ``forward_infer``  — deployment semantics.  BN folded into the per-neuron
  integer threshold comparator; weights/activations are hard +/-1; the
  compute can run through the packed Pallas XNOR-popcount kernels
  (``use_kernels=True``) or the float reference path.  Both paths must agree
  bit-exactly (tested).
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.core import binarize
from repro.core.chip import isa, neuron_array as na

BN_EPS = 1e-4
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, program: isa.Program) -> Dict[str, Any]:
    """Latent float params for every instruction (Glorot-ish on latents)."""
    isa.validate(program)
    convs, fcs = [], []
    for (ins, in_h, in_w, in_c, *_rest) in isa.layer_geometry(program):
        if isinstance(ins, isa.ConvInstr):
            key, k1 = jax.random.split(key)
            fan_in = 4 * in_c
            w = jax.random.normal(k1, (ins.features, 2, 2, in_c)) / jnp.sqrt(fan_in)
            convs.append(dict(
                w=w,
                gamma=jnp.ones((ins.features,)),
                beta=jnp.zeros((ins.features,)),
                mean=jnp.zeros((ins.features,)),
                var=jnp.ones((ins.features,)),
            ))
        elif isinstance(ins, isa.FCInstr):
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (ins.out_features, ins.in_features))
            w = w / jnp.sqrt(ins.in_features)
            fcs.append(dict(w=w))
    return {"conv": convs, "fc": fcs}


# ---------------------------------------------------------------------------
# Training-mode forward (STE + BatchNorm)
# ---------------------------------------------------------------------------

def forward_train(params, program: isa.Program, images: jax.Array,
                  train: bool = True):
    """Returns (logits, new_params) — new_params carries updated BN stats."""
    new_conv = []
    ci = fi = 0
    x = None
    for ins in program.instrs:
        if isinstance(ins, isa.IOInstr):
            x = na.thermometer_encode(images, ins.bits, ins.channels)
        elif isinstance(ins, isa.ConvInstr):
            p = params["conv"][ci]
            wb = binarize.ste_sign(p["w"])
            s = na.conv2x2(x, wb)                      # (B, H-1, W-1, F) ints
            if train:
                mean = jnp.mean(s, axis=(0, 1, 2))
                var = jnp.var(s, axis=(0, 1, 2))
                new_p = dict(p)
                new_p["mean"] = BN_MOMENTUM * p["mean"] + (1 - BN_MOMENTUM) * mean
                new_p["var"] = BN_MOMENTUM * p["var"] + (1 - BN_MOMENTUM) * var
                new_conv.append(new_p)
            else:
                mean, var = p["mean"], p["var"]
                new_conv.append(p)
            bn = p["gamma"] * (s - mean) * jax.lax.rsqrt(var + BN_EPS) + p["beta"]
            x = binarize.ste_sign(bn)
            if ins.maxpool:
                x = na.maxpool2x2(x)
            ci += 1
        elif isinstance(ins, isa.FCInstr):
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            p = params["fc"][fi]
            wb = binarize.ste_sign(p["w"])
            s = na.fc(x, wb)
            if ins.final:
                x = s                                   # integer logits
            else:
                x = binarize.ste_sign(s)
            fi += 1
    return x, {"conv": new_conv, "fc": params["fc"]}


# ---------------------------------------------------------------------------
# Inference-mode forward (folded thresholds, optional Pallas kernels)
# ---------------------------------------------------------------------------

def fold_params(params, program: isa.Program):
    """Fold BN into integer comparator thresholds (what the chip stores)."""
    folded_convs = []
    for p in params["conv"]:
        tau, flip = binarize.fold_bn_to_threshold(
            p["gamma"], p["beta"], p["mean"], p["var"], eps=BN_EPS)
        folded_convs.append(dict(w=binarize.hard_sign(p["w"]), tau=tau, flip=flip))
    fcs = [dict(w=binarize.hard_sign(p["w"])) for p in params["fc"]]
    return {"conv": folded_convs, "fc": fcs}


def forward_infer(folded, program: isa.Program, images: jax.Array,
                  use_kernels: bool = False, interpret: bool | None = None):
    """Deployment forward. Returns (logits, labels)."""
    ci = fi = 0
    x = None
    for ins in program.instrs:
        if isinstance(ins, isa.IOInstr):
            x = na.thermometer_encode(images, ins.bits, ins.channels)
        elif isinstance(ins, isa.ConvInstr):
            p = folded["conv"][ci]
            if use_kernels:
                s = na.conv2x2_packed(x, p["w"], interpret=interpret)
            else:
                s = na.conv2x2(x, p["w"])
            x = na.comparator(s, p["tau"], p["flip"])
            if ins.maxpool:
                x = na.maxpool2x2(x)
            ci += 1
        elif isinstance(ins, isa.FCInstr):
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            p = folded["fc"][fi]
            if use_kernels:
                s = na.fc_packed(x, p["w"], interpret=interpret)
            else:
                s = na.fc(x, p["w"])
            x = s if ins.final else binarize.hard_sign(s)
            fi += 1
    return x, jnp.argmax(x, axis=-1)


def make_infer_fn(program: isa.Program, use_kernels: bool = False):
    """Bind the program (static) and jit: images, folded -> labels."""
    @functools.partial(jax.jit, static_argnames=())
    def fn(folded, images):
        return forward_infer(folded, program, images, use_kernels=use_kernels)
    return fn

"""Program interpreter: compiles a BinarEye ISA program into jit-able JAX fns.

Two modes mirror the chip's lifecycle:

* ``forward_train``  — BinaryNet training semantics (1st level of
  flexibility: reprogrammable weights).  Latent float weights, STE sign,
  BatchNorm before the sign activation.  Differentiable end to end.
* ``forward_infer``  — deployment semantics.  BN folded into the per-neuron
  integer threshold comparator; weights/activations are hard +/-1; the
  compute can run through the packed Pallas pipeline (``use_kernels=True``)
  or the float reference path.  Both paths must agree bit-exactly (tested).

Deployment is organized around :class:`InferencePlan` — the program's
geometry is resolved *once* at build time into a static pipeline of fused
packed stages, mirroring how the chip's controller walks its 16-slot
program memory.  The plan consumes the packed deployment artifact from
``fold_params(..., packed=True)``: uint32 weight words plus int32
comparator thresholds, exactly what the silicon's SRAMs hold.  At run
time feature maps stay bit-packed end to end — a single pack at the IO
thermometer encoding, fused conv->threshold->pool->repack per CNN layer
(``binary_conv2x2_block``), fused sign+pack hidden FCs
(``xnor_matmul(pack_out=True)``), and a single unpack-free int32 readout
at the final FC logits.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import binarize
from repro.core.chip import isa, neuron_array as na
from repro.kernels import autotune
from repro.kernels import ops as kops

BN_EPS = 1e-4
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, program: isa.Program) -> Dict[str, Any]:
    """Latent float params for every instruction (Glorot-ish on latents)."""
    isa.validate(program)
    convs, fcs = [], []
    for (ins, in_h, in_w, in_c, *_rest) in isa.layer_geometry(program):
        if isinstance(ins, isa.ConvInstr):
            key, k1 = jax.random.split(key)
            fan_in = 4 * in_c
            w = jax.random.normal(k1, (ins.features, 2, 2, in_c)) / jnp.sqrt(fan_in)
            convs.append(dict(
                w=w,
                gamma=jnp.ones((ins.features,)),
                beta=jnp.zeros((ins.features,)),
                mean=jnp.zeros((ins.features,)),
                var=jnp.ones((ins.features,)),
            ))
        elif isinstance(ins, isa.FCInstr):
            key, k1 = jax.random.split(key)
            w = jax.random.normal(k1, (ins.out_features, ins.in_features))
            w = w / jnp.sqrt(ins.in_features)
            fcs.append(dict(w=w))
    return {"conv": convs, "fc": fcs}


# ---------------------------------------------------------------------------
# Training-mode forward (STE + BatchNorm)
# ---------------------------------------------------------------------------

def forward_train(params, program: isa.Program, images: jax.Array,
                  train: bool = True):
    """Returns (logits, new_params) — new_params carries updated BN stats."""
    new_conv = []
    ci = fi = 0
    x = None
    for ins in program.instrs:
        if isinstance(ins, isa.IOInstr):
            x = na.thermometer_encode(images, ins.bits, ins.channels)
        elif isinstance(ins, isa.ConvInstr):
            p = params["conv"][ci]
            wb = binarize.ste_sign(p["w"])
            s = na.conv2x2(x, wb)                      # (B, H-1, W-1, F) ints
            if train:
                mean = jnp.mean(s, axis=(0, 1, 2))
                var = jnp.var(s, axis=(0, 1, 2))
                new_p = dict(p)
                new_p["mean"] = BN_MOMENTUM * p["mean"] + (1 - BN_MOMENTUM) * mean
                new_p["var"] = BN_MOMENTUM * p["var"] + (1 - BN_MOMENTUM) * var
                new_conv.append(new_p)
            else:
                mean, var = p["mean"], p["var"]
                new_conv.append(p)
            bn = p["gamma"] * (s - mean) * jax.lax.rsqrt(var + BN_EPS) + p["beta"]
            x = binarize.ste_sign(bn)
            if ins.maxpool:
                x = na.maxpool2x2(x)
            ci += 1
        elif isinstance(ins, isa.FCInstr):
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            p = params["fc"][fi]
            wb = binarize.ste_sign(p["w"])
            s = na.fc(x, wb)
            if ins.final:
                x = s                                   # integer logits
            else:
                x = binarize.ste_sign(s)
            fi += 1
    return x, {"conv": new_conv, "fc": params["fc"]}


# ---------------------------------------------------------------------------
# Inference-mode forward (folded thresholds, optional Pallas kernels)
# ---------------------------------------------------------------------------

def fold_params(params, program: isa.Program, *, packed: bool = False,
                image: bool = False):
    """Fold BN into comparator thresholds (what the chip stores).

    With ``packed=False`` (default) returns the float-domain folded form:
    +/-1 weight tensors plus float ``tau``/``flip`` per conv — the
    reference the packed path is tested bit-exact against.  With
    ``packed=True`` returns the deployment artifact consumed by
    :class:`InferencePlan` (see :func:`pack_folded` for the layout).
    With ``image=True`` (implies packed) returns the contiguous
    weight-image artifact the whole-network megakernel holds VMEM-resident
    — the SRAM image (see :func:`build_weight_image`).
    """
    folded_convs = []
    for p in params["conv"]:
        tau, flip = binarize.fold_bn_to_threshold(
            p["gamma"], p["beta"], p["mean"], p["var"], eps=BN_EPS)
        folded_convs.append(dict(w=binarize.hard_sign(p["w"]), tau=tau, flip=flip))
    fcs = [dict(w=binarize.hard_sign(p["w"])) for p in params["fc"]]
    folded = {"conv": folded_convs, "fc": fcs}
    if image:
        return build_weight_image(pack_folded(folded), program)
    return pack_folded(folded) if packed else folded


def pack_folded(folded) -> Dict[str, Any]:
    """Bit-pack a float-domain folded artifact into the deployment form.

    Layout (the TPU analogue of the chip's SRAM contents):
      conv[i]["w_words"]: (F, 4, ceil(C/32)) uint32 — taps (dy, dx)
          row-major, channels packed LSB-first (bit=1 encodes -1);
      conv[i]["tau"]:     (F,) int32 integer comparator thresholds
          (``s >= tau`` fires; the ceil of the folded float threshold);
      conv[i]["flip"]:    (F,) int32 comparator direction (gamma < 0);
      fc[i]["w_words"]:   (N, ceil(K/32)) uint32, K packed in the
          row-major flatten order of the preceding (H, W, F) map.
    """
    convs = []
    for p in folded["conv"]:
        f, _, _, c = p["w"].shape
        convs.append(dict(
            w_words=binarize.pack_signs(p["w"].reshape(f, 4, c), axis=-1),
            tau=binarize.threshold_to_int(p["tau"]),
            flip=p["flip"].astype(jnp.int32)))
    fcs = [dict(w_words=binarize.pack_signs(p["w"], axis=-1))
           for p in folded["fc"]]
    return {"conv": convs, "fc": fcs}


def _is_packed_artifact(folded) -> bool:
    stages = list(folded["conv"]) + list(folded["fc"])
    return bool(stages) and "w_words" in stages[0]


def _is_image_artifact(artifact) -> bool:
    return isinstance(artifact, dict) and "cw" in artifact and "fw" in artifact


def ensure_packed(artifact):
    """Admission helper: accept either artifact form, return the packed one.

    The public seam for consumers outside this module (the serving layer
    admits both float-folded and packed artifacts).
    """
    if _is_image_artifact(artifact):
        raise TypeError(
            "weight-image artifact cannot be unstacked back to the packed "
            "per-layer form; fold with packed=True (or keep both)")
    return artifact if _is_packed_artifact(artifact) else pack_folded(artifact)


def build_weight_image(packed, program: isa.Program) -> Dict[str, Any]:
    """Stack a packed per-layer artifact into one contiguous weight image.

    The megakernel's VMEM-resident operand set — the TPU analogue of the
    chip's weight/FC SRAM contents, loaded once and resident while frames
    stream:

      ``cw``: (n_conv, F, 4, Cw) uint32 conv weight words (every conv in a
          valid program has F = C = 256/S, so the stack is rectangular);
      ``ct``/``cf``: (n_conv, F) int32 comparator thresholds / directions;
      ``fw``: (n_fc, N_max, Kw_max) uint32 FC weight words, zero-padded to
          the widest layer (zero words encode +1 and are never read: the
          kernel slices each layer's true (N, Kw) statically).
    """
    isa.validate(program)
    f = isa.ARRAY_CHANNELS // program.s
    cww = f // binarize.PACK_WIDTH
    convs = packed["conv"]
    if convs:
        cw = jnp.stack([p["w_words"] for p in convs])
        ct = jnp.stack([p["tau"] for p in convs]).astype(jnp.int32)
        cf = jnp.stack([p["flip"] for p in convs]).astype(jnp.int32)
    else:                       # conv-less program: dummy slot, never read
        cw = jnp.zeros((1, f, 4, cww), jnp.uint32)
        ct = jnp.zeros((1, f), jnp.int32)
        cf = jnp.zeros((1, f), jnp.int32)
    fcs = packed["fc"]
    n_max = max(p["w_words"].shape[0] for p in fcs)
    kw_max = max(p["w_words"].shape[1] for p in fcs)
    fw = jnp.stack([
        jnp.pad(p["w_words"], ((0, n_max - p["w_words"].shape[0]),
                               (0, kw_max - p["w_words"].shape[1])))
        for p in fcs])
    return {"cw": cw, "ct": ct, "cf": cf, "fw": fw}


def ensure_image(artifact, program: isa.Program):
    """Admission helper: accept any artifact form, return the weight image."""
    if _is_image_artifact(artifact):
        return artifact
    return build_weight_image(ensure_packed(artifact), program)


# ---------------------------------------------------------------------------
# Compiled inference plan: the packed-domain pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class _IOStage:
    bits: int
    channels: int


@dataclasses.dataclass(frozen=True)
class _ConvStage:
    c: int                 # true input channel count
    features: int
    pool: bool


@dataclasses.dataclass(frozen=True)
class _FCStage:
    in_features: int
    out_features: int
    final: bool
    pack_out: bool         # hidden layer stays packed (out % 32 == 0)


@dataclasses.dataclass(frozen=True)
class InferencePlan:
    """A program compiled to a static pipeline of fused packed stages.

    Built once per program by :func:`compile_plan`; all geometry (map
    sizes, channel counts, pool flags, FC fan-in) is resolved at build
    time so the jitted forward is a straight-line chain of Pallas calls
    with no Python-level reinterpretation of the instruction stream.
    """
    program: isa.Program
    stages: Tuple[Any, ...]
    mega: Tuple[Any, ...] = ()   # static stage spec for the megakernel

    def forward(self, packed, images: jax.Array,
                interpret: bool | None = None,
                conv_tiles: Optional[Tuple[int, int]] = None):
        """Packed deployment forward. Returns (logits int32->f32, labels).

        ``conv_tiles`` overrides the fused conv kernel's (bf, bb) tile
        sizes; default is the autotune cache's entry for this (program,
        backend, batch), falling back to the kernel defaults when cold.
        """
        if conv_tiles is None:
            conv_tiles = autotune.conv_tiles(self.program, images.shape[0])
        bf, bb = conv_tiles
        ci = fi = 0
        x = logits = None
        for st in self.stages:
            if isinstance(st, _IOStage):
                x = na.thermometer_encode_packed(images, st.bits, st.channels)
            elif isinstance(st, _ConvStage):
                p = packed["conv"][ci]
                x = kops.binary_conv2x2_block(
                    x, p["w_words"], p["tau"], p["flip"], st.c,
                    pool=st.pool, bf=bf, bb=bb, interpret=interpret)
                ci += 1
            else:
                if x.ndim == 4:
                    # packed (B, H, W, F//32) words flatten directly into
                    # packed FC rows: F % 32 == 0 makes the word order the
                    # row-major channel order.
                    x = x.reshape(x.shape[0], -1)
                p = packed["fc"][fi]
                s = kops.xnor_matmul(x, p["w_words"], st.in_features,
                                     pack_out=st.pack_out,
                                     interpret=interpret)
                if st.final:
                    logits = s
                elif st.pack_out:
                    x = s
                else:   # odd-width hidden FC: threshold at 0, repack
                    x = binarize.pack_signs(
                        binarize.hard_sign(s.astype(jnp.float32)), axis=-1)
                fi += 1
        logits = logits.astype(jnp.float32)
        return logits, jnp.argmax(logits, axis=-1)

    def forward_mega(self, image, images: jax.Array,
                     interpret: bool | None = None,
                     bb: Optional[int] = None, ft: Optional[int] = None):
        """Whole-network megakernel forward: one resident ``pallas_call``.

        ``image`` is the weight-image artifact (``fold_params(...,
        image=True)`` / :func:`ensure_image`) — the full SRAM contents,
        VMEM-resident; inter-layer feature maps live in VMEM scratch and
        frame tiles of ``bb`` double-buffer through the grid, so the only
        HBM traffic is frames in, logits out (the chip's "no off-chip
        bandwidth" execution model).  Conv layers compute in f-tiles of
        ``ft`` neurons (0 = all F per chunk — the VMEM-headroom knob for
        wide S modes).  ``bb``/``ft`` left as ``None`` resolve through
        the persistent autotune cache (``kernels.autotune``), falling
        back to the historical defaults when cold.  Tile sizes are a pure
        schedule choice: bit-exact vs :meth:`forward` for every setting.
        """
        bb, ft = autotune.mega_tiles(self.program, images.shape[0],
                                     bb=bb, ft=ft)
        logits = kops.megakernel_forward(image, images, spec=self.mega,
                                         bb=bb, ft=ft, interpret=interpret)
        logits = logits.astype(jnp.float32)
        return logits, jnp.argmax(logits, axis=-1)

    def make_fn(self, interpret: bool | None = None,
                megakernel: bool = False, bb: Optional[int] = None,
                ft: Optional[int] = None):
        """jit: (artifact, images) -> (logits, labels).

        ``megakernel=True`` runs the whole-network resident kernel and
        expects the weight-image artifact; default is the staged pipeline
        on the packed per-layer artifact.
        """
        @jax.jit
        def fn(artifact, images):
            if megakernel:
                return self.forward_mega(artifact, images,
                                         interpret=interpret, bb=bb, ft=ft)
            return self.forward(artifact, images, interpret=interpret)
        return fn

    def make_serve_fn(self, mesh=None, donate_frames: bool = False,
                      interpret: bool | None = None,
                      megakernel: bool = False, bb: Optional[int] = None,
                      ft: Optional[int] = None):
        """Serving entry point: jit'd (artifact, frames) -> (logits, labels).

        The deployment-side twin of :meth:`make_fn`, with two extra knobs
        the offline path doesn't need:

        * ``mesh`` — a 1-axis device mesh (see ``distributed.sharding.
          serve_mesh``).  The packed artifact is kept fully replicated
          (one weight replica per device — the chip's LD-once schedule,
          per device) and the frame batch is scattered on the batch axis
          with ``shard_map``; each device runs the whole packed pipeline
          on its frame shard.  The batch size must be divisible by the
          mesh's device count.  A 1-device mesh (or ``None``) degrades
          to a plain jit.
        * ``donate_frames`` — donate the streamed frame buffer to the
          computation; a continuous serving loop re-stages frames every
          dispatch and never reads a dispatched buffer again, so the
          runtime may reuse it in place (a no-op on backends without
          buffer donation).

        ``megakernel=True`` swaps the staged stage chain for the resident
        whole-network kernel (artifact = the weight image); the sharding
        story is unchanged — the image replicates like the packed
        artifact, frames scatter on batch.
        """
        if megakernel:
            fwd = lambda image, frames: self.forward_mega(
                image, frames, interpret=interpret, bb=bb, ft=ft)
        else:
            fwd = lambda packed, frames: self.forward(packed, frames,
                                                      interpret=interpret)
        if mesh is not None and mesh.devices.size > 1:
            from jax.sharding import PartitionSpec as P
            from repro.distributed import context as dctx
            axis = mesh.axis_names[0]
            fwd = dctx.shard_map(fwd, mesh=mesh,
                                 in_specs=(P(), P(axis)),
                                 out_specs=(P(axis), P(axis)))
        donate = (1,) if donate_frames else ()
        return jax.jit(fwd, donate_argnums=donate)


@functools.lru_cache(maxsize=64)
def compile_plan(program: isa.Program) -> InferencePlan:
    """Resolve a program's geometry into a static packed-stage pipeline.

    Alongside the staged stage chain (one fused Pallas call per layer,
    kept as the fallback + oracle), the plan carries the megakernel's
    static stage spec — the same geometry lowered for the single
    resident ``pallas_call`` (``kernels.megakernel``).
    """
    stages = []
    mega = []
    for (ins, in_h, in_w, in_c, _oh, _ow, _oc) in isa.layer_geometry(program):
        if isinstance(ins, isa.IOInstr):
            stages.append(_IOStage(bits=ins.bits, channels=ins.channels))
            mega.append(("io", ins.height, ins.width, ins.in_channels,
                         ins.bits, ins.channels))
        elif isinstance(ins, isa.ConvInstr):
            if ins.features % binarize.PACK_WIDTH:
                raise isa.ProgramError(
                    f"packed plan needs conv F % {binarize.PACK_WIDTH} == 0, "
                    f"got {ins.features}")
            stages.append(_ConvStage(c=in_c, features=ins.features,
                                     pool=ins.maxpool))
            mega.append(("conv", in_h, in_w, in_c, ins.features,
                         ins.maxpool))
        else:
            pack_out = (not ins.final
                        and ins.out_features % binarize.PACK_WIDTH == 0)
            stages.append(_FCStage(in_features=ins.in_features,
                                   out_features=ins.out_features,
                                   final=ins.final, pack_out=pack_out))
            mega.append(("fc", ins.in_features, ins.out_features,
                         ins.final, pack_out))
    return InferencePlan(program=program, stages=tuple(stages),
                         mega=tuple(mega))


def compile_family(variants: Mapping[str, isa.Program]
                   ) -> Dict[str, InferencePlan]:
    """Compile a program *family*: one task at several operating points.

    Family members (e.g. cifar9 at S=1/S=2/S=4 and truncated depth, see
    ``networks.FAMILIES``) must be interchangeable per frame: identical
    IO geometry (height, width, raw channels, input precision) so any
    submitted frame can be served by any member, and an identical class
    count so their labels live in one space.  Validates both and returns
    ``{variant name: InferencePlan}`` — the serving layer's
    operating-point controller swaps among these per dispatch.
    """
    if not variants:
        raise ValueError("compile_family needs at least one variant")
    plans: Dict[str, InferencePlan] = {}
    ref_name = ref_io = ref_classes = None
    for name, prog in variants.items():
        isa.validate(prog)
        io = prog.instrs[0]
        geom = (io.height, io.width, io.in_channels, io.bits)
        classes = prog.instrs[-1].out_features
        if ref_io is None:
            ref_name, ref_io, ref_classes = name, geom, classes
        elif geom != ref_io:
            raise isa.ProgramError(
                f"family variants disagree on IO geometry: {ref_name} takes "
                f"(h, w, c, bits) = {ref_io}, {name} takes {geom} — one "
                "frame stream must be servable by every variant")
        elif classes != ref_classes:
            raise isa.ProgramError(
                f"family variants disagree on class count: {ref_name} has "
                f"{ref_classes}, {name} has {classes}")
        plans[name] = compile_plan(prog)
    return plans


# ---------------------------------------------------------------------------
# Composite plans: true sub-array sharing across resident programs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompositePlan:
    """Several programs compiled as ONE shared-array dispatch unit.

    The chip's S-mode recombination runs its sub-arrays *concurrently*:
    4xS4, 2xS2, 2xS4+1xS2, ... sub-arrays each execute their own program
    on their own frame stream in the same cycle.  A
    ``CompositePlan`` is the compiled form of that recombination: the
    members' weight images pack side-by-side on the F axis into one
    composite SRAM image (:func:`pack_programs`), each member's stages
    carry static F/N offsets into it, and :meth:`forward` runs every
    member's frames through ONE ``pallas_call`` per batch
    (``kernels.megakernel.composite_forward``) — bit-exact vs dispatching
    each member solo, but at full-array occupancy instead of 1/S.
    """
    names: Tuple[str, ...]
    programs: Tuple[isa.Program, ...]
    plans: Tuple[InferencePlan, ...]
    spec: Tuple[Any, ...]          # per-member stage specs with offsets

    @property
    def classes(self) -> Tuple[int, ...]:
        return tuple(sp[-1][2] for sp in self.spec)

    @property
    def n_groups(self) -> int:
        """Member-group count of the composite spec (per-group ``ft``
        tuples carry one entry per group)."""
        return len(kops.member_groups(self.spec))

    def forward(self, image, frames, interpret: bool | None = None,
                bb: Optional[int] = None, ft=None):
        """Shared dispatch: per-member frames -> per-member (logits, labels).

        ``frames`` is a mapping keyed by member name or a sequence in
        ``names`` order; member batches may be ragged (each is padded to
        the longest internally, padding trimmed on return).  Returns
        (logits, labels) as tuples in ``names`` order.  ``bb``/``ft``
        default through the autotune cache under the composite's own
        fingerprint; a per-group tuned entry resolves ``ft`` to a tuple
        with one f-tile per member group (pass an int or tuple
        explicitly to override).  Tile sizes are a pure schedule choice
        — bit-exact for every setting.
        """
        if isinstance(frames, Mapping):
            frames = tuple(frames[n] for n in self.names)
        else:
            frames = tuple(frames)
        batch = max(f.shape[0] for f in frames)
        bb, ft = autotune.composite_tiles(self.programs, batch, bb=bb, ft=ft,
                                          per_group=True,
                                          n_groups=self.n_groups)
        outs = kops.composite_forward(image, frames, spec=self.spec,
                                      bb=bb, ft=ft, interpret=interpret)
        logits = tuple(o.astype(jnp.float32) for o in outs)
        return logits, tuple(jnp.argmax(l, axis=-1) for l in logits)

    def make_serve_fn(self, mesh=None, donate_frames: bool = False,
                      interpret: bool | None = None,
                      bb: Optional[int] = None, ft: Optional[int] = None):
        """jit: (composite image, frames tuple) -> (logits, labels) tuples.

        Mirrors :meth:`InferencePlan.make_serve_fn`: with a ``mesh`` the
        composite image replicates per device and every member's frame
        batch scatters on its own batch axis; donation covers the whole
        frames tuple.
        """
        fwd = lambda image, frames: self.forward(image, frames,
                                                 interpret=interpret,
                                                 bb=bb, ft=ft)
        if mesh is not None and mesh.devices.size > 1:
            from jax.sharding import PartitionSpec as P
            from repro.distributed import context as dctx
            axis = mesh.axis_names[0]
            fwd = dctx.shard_map(fwd, mesh=mesh,
                                 in_specs=(P(), P(axis)),
                                 out_specs=(P(axis), P(axis)))
        donate = (1,) if donate_frames else ()
        return jax.jit(fwd, donate_argnums=donate)


def pack_programs(programs: Mapping[str, isa.Program],
                  artifacts: Mapping[str, Any], *,
                  exact_tiling: bool = True):
    """Compile a shared-array composite: (CompositePlan, composite image).

    ``programs`` maps member names to validated ISA programs whose
    S-modes must tile the 256-channel array exactly (sum of 256/S == 256
    — 4xS4, 2xS2, 2xS4+1xS2, ...); ``artifacts`` maps the same names to
    any admissible artifact form (float-folded / packed / weight image).
    ``exact_tiling=False`` lifts the tiling constraint — the image
    layout generalizes to any total F — for packs whose members execute
    *sequentially* within one dispatch (the fused cascade: detector then
    recognizer, never both at once) rather than concurrently; concurrent
    composites keep the exact-tiling contract.

    The composite weight image packs the members side-by-side on the F
    axis — the TPU analogue of loading several programs into disjoint
    sub-array rows of the one weight SRAM:

      ``cw``: (Lc, 256, 4, Cw_max) uint32, member m's conv-layer-i words
          at rows [f_off_m, f_off_m + 256/S_m); rows past a member's
          depth (or a member's unused trailing channel words) stay zero
          and are never read — the kernel slices statically per member;
      ``ct``/``cf``: (Lc, 256) int32 thresholds / directions, same rows;
      ``fw``: (Lf, N_total, Kw_max) uint32 FC words, members side-by-side
          on the N axis per FC ordinal.
    """
    names = tuple(programs)
    if not names:
        raise ValueError("pack_programs needs at least one program")
    progs = tuple(programs[n] for n in names)
    for p in progs:
        isa.validate(p)
    widths = [isa.ARRAY_CHANNELS // p.s for p in progs]
    if exact_tiling and len(progs) > 1 and sum(widths) != isa.ARRAY_CHANNELS:
        raise isa.ProgramError(
            f"S-modes {[p.s for p in progs]} do not tile the array "
            f"exactly: sum(256/S) = {sum(widths)} != {isa.ARRAY_CHANNELS}")
    plans = tuple(compile_plan(p) for p in progs)
    images = [ensure_image(artifacts[n], p) for n, p in zip(names, progs)]

    f_offs, off = [], 0
    for w in widths:
        f_offs.append(off)
        off += w
    ftot = off

    lc = max(img["cw"].shape[0] for img in images)
    kwc = max(img["cw"].shape[3] for img in images)
    cw = jnp.zeros((lc, ftot, 4, kwc), jnp.uint32)
    ct = jnp.zeros((lc, ftot), jnp.int32)
    cf = jnp.zeros((lc, ftot), jnp.int32)
    for img, fo in zip(images, f_offs):
        ncm, fm, _, kwm = img["cw"].shape
        cw = cw.at[:ncm, fo:fo + fm, :, :kwm].set(img["cw"])
        ct = ct.at[:ncm, fo:fo + fm].set(img["ct"])
        cf = cf.at[:ncm, fo:fo + fm].set(img["cf"])

    # FC rows: true (N, Kw) per member per FC ordinal, packed side-by-side
    fc_geoms = [[(st[2], -(-st[1] // binarize.PACK_WIDTH))
                 for st in plan.mega if st[0] == "fc"] for plan in plans]
    lf = max(len(g) for g in fc_geoms)
    n_offs, row = [], [0] * lf
    for g in fc_geoms:
        offs = []
        for li, (n, _kw) in enumerate(g):
            offs.append(row[li])
            row[li] += n
        n_offs.append(tuple(offs))
    n_tot = max(row)
    kw_tot = max(kw for g in fc_geoms for _n, kw in g)
    fw = jnp.zeros((lf, n_tot, kw_tot), jnp.uint32)
    for img, g, offs in zip(images, fc_geoms, n_offs):
        for li, ((n, kw), o) in enumerate(zip(g, offs)):
            fw = fw.at[li, o:o + n, :kw].set(img["fw"][li, :n, :kw])

    mspecs = []
    for plan, fo, offs in zip(plans, f_offs, n_offs):
        fi, st_out = 0, []
        for st in plan.mega:
            if st[0] == "io":
                st_out.append(st)
            elif st[0] == "conv":
                st_out.append(st + (fo,))
            else:
                st_out.append(st + (offs[fi],))
                fi += 1
        mspecs.append(tuple(st_out))

    cplan = CompositePlan(names=names, programs=progs, plans=plans,
                          spec=tuple(mspecs))
    return cplan, {"cw": cw, "ct": ct, "cf": cf, "fw": fw}


# ---------------------------------------------------------------------------
# Cascade plans: in-kernel detector -> recognizer escalation
# ---------------------------------------------------------------------------

_INT32_MIN = -(2 ** 31)
_INT32_MAX = 2 ** 31 - 1


@dataclasses.dataclass(frozen=True)
class CascadePlan:
    """A detector + recognizer pair compiled as ONE fused dispatch unit.

    The paper's always-on hierarchy with the control flow *inside* the
    kernel: both stages' weight images share one composite SRAM image
    (:func:`pack_cascade`), the detector runs over every frame tile, the
    escalation decision (positive-class logit margin >= threshold) is
    made in-kernel, and the recognizer drains only the escalated lanes
    through bounded-iteration control flow
    (``kernels.megakernel.cascade_forward``) — one dispatch, no host
    round-trip between the stages.  Unlike a :class:`CompositePlan` the
    two members run *sequentially* on the array (detector phase, then
    recognizer phase), so their S-modes need not tile the 256 channels.

    The escalation rule is bit-exact vs the host cascade's float rule:
    integer logits satisfy ``m >= margin  <=>  m >= ceil(margin)``, and
    :meth:`margin_ctrl` folds the host float margin into the int32
    threshold the kernel compares against (``+/-inf`` map to sentinels
    beyond any reachable margin — FC logit magnitudes are bounded by the
    fan-in, orders below 2^31).
    """
    detector: str
    recognizer: str
    programs: Tuple[isa.Program, ...]          # (det, rec)
    plans: Tuple[InferencePlan, ...]
    spec: Tuple[Any, ...]                      # 2-member composite spec
    positive_class: int = 1

    @property
    def classes(self) -> Tuple[int, int]:
        return tuple(sp[-1][2] for sp in self.spec)

    @property
    def n_groups(self) -> int:
        return len(kops.member_groups(self.spec))

    @staticmethod
    def margin_ctrl(margin: float, n_real: int):
        """Fold a host-side float escalation margin into the kernel's
        dynamic ``(1, 2)`` int32 control word ``[threshold, n_real]``.

        For integer margins m, ``m >= margin`` (the host rule, float)
        holds iff ``m >= ceil(margin)`` — so the ceil makes the integer
        compare bit-exact for *every* float margin.  ``-inf`` (escalate
        all) and ``+inf`` (escalate none) clamp to the int32 extremes,
        both unreachable by real margins.  ``n_real`` masks padding
        lanes out of escalation.
        """
        if math.isnan(margin):
            raise ValueError("escalation margin must not be NaN")
        thr = (_INT32_MIN if margin == float("-inf") else
               _INT32_MAX if margin == float("inf") else
               int(min(max(math.ceil(margin), _INT32_MIN), _INT32_MAX)))
        return jnp.array([[thr, int(n_real)]], jnp.int32)

    def forward_fused(self, image, frames: jax.Array, ctrl,
                      interpret: bool | None = None,
                      bb: Optional[int] = None, ft=None,
                      rb: Optional[int] = None, check_every: int = 1):
        """One fused dispatch: frames -> both stages' answers.

        ``ctrl`` is the dynamic control word from :meth:`margin_ctrl`
        (dynamic so margin sweeps and ragged batches never retrace).
        Returns ``(det_logits, det_labels, rec_logits, rec_labels,
        queue, counts)`` — logits float32, labels int; ``counts[0] = E``
        escalated frames, ``queue[:E]`` their ascending frame indices,
        ``rec_*[k]`` answering frame ``queue[k]`` (compacted);
        ``counts[1]`` the recognizer frame slots computed (>= E — the
        drain chunks' padding, billed by the serving layer).  ``bb``/
        ``ft`` resolve through the autotune cache under the pair's
        composite fingerprint; tile sizes and ``rb``/``check_every``
        are pure schedule choices — bit-exact for every setting.
        """
        batch = frames.shape[0]
        bb, ft = autotune.composite_tiles(self.programs, batch, bb=bb, ft=ft,
                                          per_group=True,
                                          n_groups=self.n_groups)
        det, rec, queue, counts = kops.cascade_forward(
            image, frames, ctrl, spec=self.spec, bb=bb,
            rb=0 if rb is None else rb, ft=ft, check_every=check_every,
            positive_class=self.positive_class, interpret=interpret)
        det_l = det.astype(jnp.float32)
        rec_l = rec.astype(jnp.float32)
        return (det_l, jnp.argmax(det_l, axis=-1),
                rec_l, jnp.argmax(rec_l, axis=-1), queue, counts)

    def make_serve_fn(self, mesh=None, donate_frames: bool = False,
                      interpret: bool | None = None,
                      bb: Optional[int] = None, ft: Optional[int] = None):
        """jit: (image, frames, ctrl) -> fused cascade outputs.

        The fused cascade does not shard: the in-kernel escalation queue
        compacts across the whole batch, so scattering frames over a
        mesh would split the queue mid-dispatch.  A 1-device mesh (or
        ``None``) serves on the default device; multi-device meshes are
        rejected — serve the cascade host-side (``CascadePipeline``
        without ``fused``) to shard the stages independently.
        """
        if mesh is not None and mesh.devices.size > 1:
            raise ValueError(
                "fused cascade dispatch does not shard over a multi-device "
                "mesh (the escalation queue is batch-global); use the "
                "host-side cascade for sharded stages")
        fwd = lambda image, frames, ctrl: self.forward_fused(
            image, frames, ctrl, interpret=interpret, bb=bb, ft=ft)
        donate = (1,) if donate_frames else ()
        return jax.jit(fwd, donate_argnums=donate)


def pack_cascade(programs: Mapping[str, isa.Program],
                 artifacts: Mapping[str, Any], *,
                 detector: str, recognizer: str,
                 positive_class: int = 1):
    """Compile a fused cascade pair: (CascadePlan, composite image).

    ``programs``/``artifacts`` are keyed like :func:`pack_programs`;
    ``detector``/``recognizer`` name the two members.  The stages must
    agree on frame geometry (one stream feeds both) and the detector
    must have >= 2 classes with ``positive_class`` among them.  The
    composite image is the ordinary side-by-side F-axis pack with the
    detector at offset 0 — built with ``exact_tiling=False`` because the
    stages run sequentially within the dispatch (see
    :func:`pack_programs`).
    """
    if detector == recognizer:
        raise isa.ProgramError(
            "cascade stages must be distinct programs, got "
            f"{detector!r} twice")
    for name in (detector, recognizer):
        if name not in programs:
            raise KeyError(f"cascade stage {name!r} missing from programs "
                           f"(have {sorted(programs)})")
    det_prog, rec_prog = programs[detector], programs[recognizer]
    iod, ior = det_prog.instrs[0], rec_prog.instrs[0]
    gd = (iod.height, iod.width, iod.in_channels, iod.bits)
    gr = (ior.height, ior.width, ior.in_channels, ior.bits)
    if gd != gr:
        raise isa.ProgramError(
            f"cascade stages disagree on frame geometry: detector takes "
            f"(h, w, c, bits) = {gd}, recognizer takes {gr} — one frame "
            "stream must feed both stages")
    ncd = det_prog.instrs[-1].out_features
    if ncd < 2:
        raise isa.ProgramError(
            f"detector needs >= 2 classes for a logit margin, got {ncd}")
    if not 0 <= positive_class < ncd:
        raise isa.ProgramError(
            f"positive_class {positive_class} out of range for the "
            f"detector's {ncd} classes")
    cplan, image = pack_programs(
        {detector: det_prog, recognizer: rec_prog},
        {detector: artifacts[detector], recognizer: artifacts[recognizer]},
        exact_tiling=False)
    plan = CascadePlan(detector=detector, recognizer=recognizer,
                       programs=cplan.programs, plans=cplan.plans,
                       spec=cplan.spec, positive_class=positive_class)
    return plan, image


# ---------------------------------------------------------------------------
# Delta plans: in-kernel frame-delta gating for always-on video streams
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DeltaPlan:
    """One program compiled for delta-gated always-on serving.

    The always-on workload BinarEye's headline numbers assume is *video*:
    consecutive frames of a quiet scene are nearly identical, so running
    the full network on every frame burns energy re-deriving the label it
    already has.  This plan pairs the program's whole-network megakernel
    with resident temporal state — each stream's last packed thermometer
    frame and its cached logits — and gates recompute *inside* the
    dispatch (``kernels.megakernel.delta_forward``): the packed Hamming
    distance ``popcount(cur XOR last)`` is compared per lane against a
    dynamic int32 threshold, changed lanes compact into the cascade's
    escalation-queue idiom and recompute, skipped lanes emit their cached
    logits at delta-compute-only cost.

    The gate is bit-exact vs a host reference: packed Hamming distances
    are integers, so ``d >= threshold  <=>  d >= ceil(threshold)``, and
    :meth:`delta_ctrl` folds host float thresholds into the kernel's
    int32 control word (``-inf`` recomputes everything — the forced
    first-dispatch / post-reset state — and ``+inf`` skips everything;
    both sentinels are beyond any reachable distance).  At threshold 0
    every live lane recomputes and the merged logits equal the plain
    megakernel's bit for bit.
    """
    name: str
    program: isa.Program
    plan: InferencePlan
    spec: Tuple[Any, ...]                      # 1-member composite spec

    @property
    def classes(self) -> int:
        return self.spec[0][-1][2]

    @property
    def geometry(self) -> Tuple[int, int, int]:
        io = self.spec[0][0]
        return io[1], io[2], io[3]

    @property
    def packed_words(self) -> Tuple[int, int, int]:
        """(H, W, channels//32): one stream's last-frame state shape."""
        io = self.spec[0][0]
        return io[1], io[2], io[5] // binarize.PACK_WIDTH

    @staticmethod
    def delta_ctrl(threshold: float, n_real: int):
        """Fold a host-side float change threshold into the kernel's
        dynamic ``(1, 2)`` int32 control word ``[threshold, n_real]``.

        Packed Hamming distances d are integers, so ``d >= threshold``
        (the host rule, float) holds iff ``d >= ceil(threshold)`` — the
        ceil makes the integer compare bit-exact for every float
        threshold.  ``-inf`` (recompute all — the cold-state dispatch)
        and ``+inf`` (skip all) clamp to the int32 extremes, both
        unreachable by real distances.  ``n_real`` masks padding lanes
        out of the change queue.
        """
        if math.isnan(threshold):
            raise ValueError("delta threshold must not be NaN")
        thr = (_INT32_MIN if threshold == float("-inf") else
               _INT32_MAX if threshold == float("inf") else
               int(min(max(math.ceil(threshold), _INT32_MIN), _INT32_MAX)))
        return jnp.array([[thr, int(n_real)]], jnp.int32)

    def init_state(self, n: int):
        """Cold per-stream state for ``n`` streams: zeroed last-frame
        words + zeroed cached logits.  Cold state is *not* a valid gate
        reference — pair the first dispatch with a ``-inf`` threshold
        (``delta_ctrl(float("-inf"), n)``) so every lane recomputes and
        the state warms from real frames."""
        h, w, cw = self.packed_words
        return (jnp.zeros((n, h, w, cw), jnp.uint32),
                jnp.zeros((n, self.classes), jnp.int32))

    def forward_delta(self, image, frames: jax.Array, last, llog, ctrl,
                      interpret: bool | None = None,
                      bb: Optional[int] = None, ft: Optional[int] = None,
                      rb: Optional[int] = None, check_every: int = 1):
        """One gated dispatch: advance every stream by one time step.

        ``ctrl`` is the dynamic control word from :meth:`delta_ctrl`
        (dynamic, so threshold sweeps and ragged batches never retrace).
        Returns ``(logits, labels, new_last, new_llog, queue, counts,
        deltas)``: ``logits`` (float32) / ``labels`` merge fresh answers
        for changed lanes with cached answers for skipped lanes;
        ``new_last`` / ``new_llog`` are the next dispatch's state;
        ``counts[0] = K`` changed lanes, ``queue[:K]`` their ascending
        indices, ``counts[1]`` the frame slots computed (>= K — drain-
        chunk padding, billed by the serving layer); ``deltas`` the
        per-lane packed Hamming distances.  ``bb``/``ft`` resolve
        through the autotune cache; tile sizes and ``rb``/
        ``check_every`` are pure schedule choices — bit-exact for every
        setting.
        """
        bb, ft = autotune.mega_tiles(self.program, frames.shape[0],
                                     bb=bb, ft=ft)
        logits, new_last, queue, counts, deltas = kops.delta_forward(
            image, frames, last, llog, ctrl, spec=self.spec, bb=bb,
            rb=0 if rb is None else rb, ft=ft, check_every=check_every,
            interpret=interpret)
        lf = logits.astype(jnp.float32)
        return (lf, jnp.argmax(lf, axis=-1), new_last, logits,
                queue, counts, deltas)

    def make_serve_fn(self, mesh=None, donate_frames: bool = False,
                      interpret: bool | None = None,
                      bb: Optional[int] = None, ft: Optional[int] = None,
                      rb: Optional[int] = None, check_every: int = 1):
        """jit: (image, frames, last, llog, ctrl) -> gated outputs.

        The gated dispatch does not shard: the change queue compacts
        across the whole batch and the last-frame/last-logits state is
        batch-global resident VMEM, so scattering frames over a mesh
        would split both mid-dispatch.  A 1-device mesh (or ``None``)
        serves on the default device; multi-device meshes are rejected —
        shard by running one :class:`DeltaPlan` per device over disjoint
        stream sets instead.
        """
        if mesh is not None and mesh.devices.size > 1:
            raise ValueError(
                "delta-gated dispatch does not shard over a multi-device "
                "mesh (the change queue and resident last-frame state are "
                "batch-global); run one DeltaPlan per device over "
                "disjoint stream sets instead")
        fwd = lambda image, frames, last, llog, ctrl: self.forward_delta(
            image, frames, last, llog, ctrl, interpret=interpret,
            bb=bb, ft=ft, rb=rb, check_every=check_every)
        donate = (1, 2, 3) if donate_frames else ()
        return jax.jit(fwd, donate_argnums=donate)


def pack_delta(program: isa.Program, artifact, *, name: str = "program"):
    """Compile a delta-gated serving unit: (DeltaPlan, weight image).

    The image is the program's own megakernel weight image
    (:func:`ensure_image`) and the spec is the one-member composite lift
    of ``InferencePlan.mega`` — the gated kernel shares the megakernel's
    member body, so the recompute path is bit-exact vs ``forward_mega``
    by construction.
    """
    isa.validate(program)
    io = program.instrs[0]
    if io.channels % binarize.PACK_WIDTH:
        raise isa.ProgramError(
            f"delta gating needs IO channels % {binarize.PACK_WIDTH} == 0 "
            f"(packed Hamming distance), got {io.channels}")
    plan = compile_plan(program)
    spec = (tuple(st if st[0] == "io" else st + (0,)
                  for st in plan.mega),)
    image = ensure_image(artifact, program)
    return (DeltaPlan(name=name, program=program, plan=plan, spec=spec),
            image)


def forward_infer(folded, program: isa.Program, images: jax.Array,
                  use_kernels: bool = False, interpret: bool | None = None):
    """Deployment forward. Returns (logits, labels).

    ``use_kernels=True`` routes through the compiled packed plan (packing
    the float artifact on the fly if needed); ``use_kernels=False`` is
    the float +/-1 reference path the plan is tested bit-exact against.
    """
    if use_kernels:
        return compile_plan(program).forward(ensure_packed(folded), images,
                                             interpret=interpret)

    ci = fi = 0
    x = None
    for ins in program.instrs:
        if isinstance(ins, isa.IOInstr):
            x = na.thermometer_encode(images, ins.bits, ins.channels)
        elif isinstance(ins, isa.ConvInstr):
            p = folded["conv"][ci]
            s = na.conv2x2(x, p["w"])
            x = na.comparator(s, p["tau"], p["flip"])
            if ins.maxpool:
                x = na.maxpool2x2(x)
            ci += 1
        elif isinstance(ins, isa.FCInstr):
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            p = folded["fc"][fi]
            s = na.fc(x, p["w"])
            x = s if ins.final else binarize.hard_sign(s)
            fi += 1
    return x, jnp.argmax(x, axis=-1)


def make_infer_fn(program: isa.Program, use_kernels: bool = False):
    """Bind the program (static) and jit: images, folded -> labels."""
    @functools.partial(jax.jit, static_argnames=())
    def fn(folded, images):
        return forward_infer(folded, program, images, use_kernels=use_kernels)
    return fn

"""Functional model of the 64-neuron / 4-sub-neuron BinarEye array.

Third level of flexibility (Fig. 3): the 256-wide array is operated at
width mode S in {1,2,4}: F = C = 256/S features/channels on S images in
parallel.  Arithmetically a mode-S layer is S independent (256/S)^2 x 2x2
binary convolutions occupying the same physical array, so the batch axis
IS the sub-neuron recombination axis — we model it directly as a batch of
S maps, which keeps the simulation exact while staying jit/vmap friendly.

Two compute paths:
  * float path: +/-1 floats, einsum — differentiable via STE, used in
    training and as reference;
  * packed path: the Pallas XNOR-popcount kernels from repro.kernels, the
    TPU analogue of the chip datapath (used for inference).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binarize
from repro.kernels import ops as kops


# ---------------------------------------------------------------------------
# IO layer: thermometer encoding of b-bit images into +/-1 channels
# ---------------------------------------------------------------------------

def thermometer_encode(images: jax.Array, bits: int, channels: int) -> jax.Array:
    """(B, H, W, C_in) integer images in [0, 2^bits) -> (B, H, W, channels) +/-1.

    Each color gets channels//C_in binary planes with uniformly spaced
    thresholds: plane i of color c is sign(x_c - t_i).  A binary dot
    product against these planes realizes a monotone piecewise-linear
    function of the pixel value — the chip's integer-input first layer
    built from nothing but XNORs (cost counted at the full array width,
    exactly like the silicon).  Leftover planes are constant +1 (bias).
    """
    b, h, w, cin = images.shape
    per = channels // cin
    levels = 2 ** bits
    # thresholds strictly inside (0, levels)
    t = (jnp.arange(per, dtype=jnp.float32) + 0.5) * (levels / per)
    x = images.astype(jnp.float32)[..., None]            # (B,H,W,Cin,1)
    planes = jnp.where(x >= t, 1.0, -1.0)                # (B,H,W,Cin,per)
    planes = planes.reshape(b, h, w, cin * per)
    pad = channels - cin * per
    if pad:
        planes = jnp.concatenate(
            [planes, jnp.ones((b, h, w, pad), planes.dtype)], axis=-1)
    return planes


def thermometer_encode_packed(images: jax.Array, bits: int,
                              channels: int) -> jax.Array:
    """Thermometer-encode straight into packed uint32 words.

    Bit-identical to ``pack_signs(thermometer_encode(...))`` but never
    materializes the +/-1 float planes: plane i of color c is -1 (bit 1)
    exactly when ``x_c < t_i``, and the constant +1 bias planes are bit 0,
    so the sign bits are computed from the integer pixels directly.  This
    is the *single* pack of the whole packed inference pipeline —
    everything downstream consumes and produces uint32 words.
    Returns (B, H, W, channels // 32) uint32 (channels is a multiple of
    32 for every array mode: 256/S with S in {1, 2, 4}).
    """
    return binarize.thermometer_pack(images, bits, images.shape[-1],
                                     channels)


# ---------------------------------------------------------------------------
# CONV: F x C x 2x2 stride-1 VALID, all neurons in parallel
# ---------------------------------------------------------------------------

def conv2x2(x: jax.Array, w: jax.Array) -> jax.Array:
    """Float path. x: (B, H, W, C) +/-1; w: (F, 2, 2, C) +/-1 -> (B, H-1, W-1, F)."""
    # 4 shifted contractions — identical structure to the chip's 2-bit/step
    # window reuse (and to the Pallas kernel).
    h, wd = x.shape[1], x.shape[2]
    out = 0.0
    for dy in range(2):
        for dx in range(2):
            patch = x[:, dy:h - 1 + dy, dx:wd - 1 + dx, :]
            out = out + jnp.einsum("byxc,fc->byxf", patch, w[:, dy, dx, :])
    return out


def conv2x2_packed(x_signs: jax.Array, w_signs: jax.Array,
                   interpret: bool | None = None) -> jax.Array:
    """Packed XNOR-popcount path via the batched Pallas kernel.

    The batch rides the kernel grid (weights resident across all images)
    rather than a per-image ``jax.vmap``.  Float +/-1 in/out compat
    wrapper — the fully packed pipeline lives in ``interpreter.
    InferencePlan`` / ``kernels.binary_conv2x2_block``.
    """
    c = x_signs.shape[-1]
    f = w_signs.shape[0]
    x_words = binarize.pack_signs(x_signs, axis=-1)              # (B,H,W,Cw)
    w_words = binarize.pack_signs(
        w_signs.reshape(f, 4, c), axis=-1)                       # (F,4,Cw)
    return kops.binary_conv2x2(x_words, w_words, c,
                               interpret=interpret).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Streamed max-pool and the binary comparator
# ---------------------------------------------------------------------------

def maxpool2x2(x: jax.Array) -> jax.Array:
    """2x2 stride-2 max-pool; odd trailing row/col dropped (as streamed HW)."""
    b, h, w, c = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, :h2 * 2, :w2 * 2, :].reshape(b, h2, 2, w2, 2, c)
    return x.max(axis=(2, 4))


def comparator(s: jax.Array, tau: jax.Array, flip: jax.Array) -> jax.Array:
    """Per-feature threshold comparator (folded BN+sign), +/-1 output."""
    return binarize.threshold_activation(s, tau, flip)


# ---------------------------------------------------------------------------
# FC layer
# ---------------------------------------------------------------------------

def fc(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (B, IN) +/-1; w: (OUT, IN) +/-1 -> (B, OUT) integer scores."""
    return jnp.einsum("bi,oi->bo", x, w)


def fc_packed(x_signs: jax.Array, w_signs: jax.Array,
              interpret: bool | None = None) -> jax.Array:
    xw = binarize.pack_signs(x_signs, axis=-1)
    ww = binarize.pack_signs(w_signs, axis=-1)
    return kops.xnor_matmul(xw, ww, x_signs.shape[-1],
                            interpret=interpret).astype(jnp.float32)

"""The BinarEye instruction set (2nd level of flexibility: programmable depth).

The chip's controller decodes custom instructions for input-output layers
(IO), CNN layers (CNN) and fully-connected layers (FC) from a 16-slot
program memory.  We reproduce that contract exactly:

  * <= 16 instructions per program
  * CNN layers are F x C x 2x2, stride 1, F = C = 256/S with S in {1,2,4},
    optional *streamed* 2x2/2 max-pool, feature maps up to 32x32
  * FC layers are binary, final layer <= 10 classes, total FC weights
    <= 5 kB SRAM
  * total CNN weights <= 259 kB SRAM; feature maps <= 32 kB per side

``assemble``/``disassemble`` give the packed 32-bit instruction words the
program memory would hold, so program storage is part of the model.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import numpy as np

# --- hardware constants (from the paper) -----------------------------------
NUM_NEURONS = 64
SUBNEURONS = 4
SUB_CHANNELS = 64                       # channels per sub-neuron dot product
ARRAY_CHANNELS = NUM_NEURONS * SUBNEURONS  # 256: full-array F=C at S=1
MAX_WH = 32
MAX_CLASSES = 10
MAX_INSTRUCTIONS = 16
WEIGHT_SRAM_BITS = 259 * 1024 * 8       # north+south weight SRAM
FC_SRAM_BITS = 5 * 1024 * 8             # FC weight SRAM
FEATURE_SRAM_BITS = 32 * 1024 * 8       # per side (west/east), ping-pong
VALID_S = (1, 2, 4)

_OP_IO, _OP_CNN, _OP_FC = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class IOInstr:
    """Load an image and thermometer-encode it into a binary feature map.

    The chip's first layer consumes a 7-bit RGB 32x32 input and processes
    it through the full 256-channel array (layer-1 cost is counted at
    C=256, matching the paper's 500M-op figure).  We realize the
    integer->binary interface as a thermometer code: ``channels`` binary
    planes per image, split evenly over the ``in_channels`` colors.
    """
    height: int
    width: int
    in_channels: int = 3       # raw image colors
    bits: int = 7              # input precision
    channels: int = ARRAY_CHANNELS  # encoded binary channels (= C of conv 1)


@dataclasses.dataclass(frozen=True)
class ConvInstr:
    """F x C x 2x2 stride-1 VALID conv + BN-threshold sign + optional pool."""
    height: int                # input map height
    width: int                 # input map width
    features: int              # F = C = 256/S
    maxpool: bool = False      # streamed 2x2 stride-2 max-pool after conv


@dataclasses.dataclass(frozen=True)
class FCInstr:
    in_features: int
    out_features: int
    final: bool = False        # final layer -> classification logits


Instr = Union[IOInstr, ConvInstr, FCInstr]


@dataclasses.dataclass(frozen=True)
class Program:
    """A BinarEye program: width mode S + instruction list."""
    s: int
    instrs: tuple

    @property
    def conv_instrs(self):
        return [i for i in self.instrs if isinstance(i, ConvInstr)]

    @property
    def fc_instrs(self):
        return [i for i in self.instrs if isinstance(i, FCInstr)]


class ProgramError(ValueError):
    pass


def validate(p: Program) -> None:
    """Enforce every hardware constraint of the chip."""
    if p.s not in VALID_S:
        raise ProgramError(f"S must be one of {VALID_S}, got {p.s}")
    if len(p.instrs) > MAX_INSTRUCTIONS:
        raise ProgramError(
            f"program memory holds {MAX_INSTRUCTIONS} instructions, got {len(p.instrs)}")
    if not p.instrs or not isinstance(p.instrs[0], IOInstr):
        raise ProgramError("program must start with an IO instruction")

    fcw = ARRAY_CHANNELS // p.s  # F = C = 256/S
    weight_bits = 0
    fc_bits = 0
    cur_h = cur_w = cur_c = None
    seen_fc = False
    for idx, ins in enumerate(p.instrs):
        if isinstance(ins, IOInstr):
            if idx != 0:
                raise ProgramError("IO instruction only allowed in slot 0")
            if ins.height > MAX_WH or ins.width > MAX_WH:
                raise ProgramError(f"input {ins.height}x{ins.width} exceeds {MAX_WH}x{MAX_WH}")
            if ins.channels != fcw:
                raise ProgramError(
                    f"IO encode channels {ins.channels} must equal 256/S = {fcw}")
            cur_h, cur_w, cur_c = ins.height, ins.width, ins.channels
        elif isinstance(ins, ConvInstr):
            if seen_fc:
                raise ProgramError("CNN instruction after FC instruction")
            if ins.features != fcw:
                raise ProgramError(f"conv F={ins.features} must equal 256/S={fcw}")
            if (ins.height, ins.width) != (cur_h, cur_w):
                raise ProgramError(
                    f"instr {idx}: expects {ins.height}x{ins.width}, "
                    f"pipeline provides {cur_h}x{cur_w}")
            if cur_h < 2 or cur_w < 2:
                raise ProgramError(f"instr {idx}: map too small for 2x2 conv")
            map_bits = cur_h * cur_w * cur_c
            if map_bits > FEATURE_SRAM_BITS:
                raise ProgramError(f"feature map {map_bits}b exceeds feature SRAM")
            weight_bits += ins.features * cur_c * 4
            cur_h, cur_w = cur_h - 1, cur_w - 1
            if ins.maxpool:
                cur_h, cur_w = cur_h // 2, cur_w // 2
            cur_c = ins.features
        elif isinstance(ins, FCInstr):
            expected = cur_h * cur_w * cur_c if not seen_fc else cur_c
            if ins.in_features != expected:
                raise ProgramError(
                    f"FC in_features {ins.in_features} != pipeline width {expected}")
            if ins.final and ins.out_features > MAX_CLASSES:
                raise ProgramError(f"final FC limited to {MAX_CLASSES} classes")
            fc_bits += ins.in_features * ins.out_features
            seen_fc = True
            cur_c = ins.out_features
            cur_h = cur_w = 1
        else:
            raise ProgramError(f"unknown instruction {ins!r}")
    if not isinstance(p.instrs[-1], FCInstr) or not p.instrs[-1].final:
        raise ProgramError("program must end with a final FC instruction")
    if weight_bits > WEIGHT_SRAM_BITS:
        raise ProgramError(f"CNN weights {weight_bits}b exceed weight SRAM "
                           f"({WEIGHT_SRAM_BITS}b)")
    if fc_bits > FC_SRAM_BITS:
        raise ProgramError(f"FC weights {fc_bits}b exceed FC SRAM ({FC_SRAM_BITS}b)")


# ---------------------------------------------------------------------------
# Binary encoding of the program memory
# ---------------------------------------------------------------------------
# word layout (LSB first):
#   IO:   op:2 | h:6 (2-7) | w:6 (8-13) | ch:11 (14-24) | in_ch:3 (25-27) |
#         bits:4 (28-31)
#   CNN:  op:2 | h:6 (2-7) | w:6 (8-13) | f:11 (14-24) | pool:1 (25)
#   FC:   op:2 | out:10 (2-11) | in:11 (14-24) | final:1 (25)
# The FC ``out`` field reuses the h/w bit range (spatial fields are
# meaningless for FC) and is 10 bits wide so a full-array hidden layer
# (out_features = 256, and headroom to 1023) round-trips — the original
# 4-bit field silently corrupted anything above 15 (e.g. mnist5's
# 64-wide hidden FC).  The IO word similarly gained an in_channels field
# and a 4-bit precision field (the original 3-bit field truncated
# mnist5's 8-bit input to 0 and dropped in_channels entirely).
_FC_OUT_MAX = 0x3FF
_FC_IN_MAX = 0x7FF
_IO_INCH_MAX = 0x7
_IO_BITS_MAX = 0xF


def _encode_instr(ins: Instr) -> int:
    if isinstance(ins, IOInstr):
        if ins.in_channels > _IO_INCH_MAX:
            raise ProgramError(
                f"IO in_channels {ins.in_channels} exceeds encodable "
                f"range ({_IO_INCH_MAX})")
        if ins.bits > _IO_BITS_MAX:
            raise ProgramError(
                f"IO bits {ins.bits} exceeds encodable range ({_IO_BITS_MAX})")
        return (_OP_IO | ins.height << 2 | ins.width << 8
                | ins.channels << 14 | ins.in_channels << 25
                | ins.bits << 28)
    if isinstance(ins, ConvInstr):
        return (_OP_CNN | ins.height << 2 | ins.width << 8
                | ins.features << 14 | int(ins.maxpool) << 25)
    if ins.in_features > _FC_IN_MAX:
        raise ProgramError(
            f"FC in_features {ins.in_features} exceeds encodable "
            f"range ({_FC_IN_MAX})")
    if ins.out_features > _FC_OUT_MAX:
        raise ProgramError(
            f"FC out_features {ins.out_features} exceeds encodable "
            f"range ({_FC_OUT_MAX})")
    return (_OP_FC | ins.in_features << 14
            | ins.out_features << 2 | int(ins.final) << 25)


def assemble(p: Program) -> np.ndarray:
    validate(p)
    words = [_encode_instr(ins) for ins in p.instrs]
    out = np.zeros(MAX_INSTRUCTIONS, np.uint32)
    out[:len(words)] = np.array(words, np.uint32)
    return out


def disassemble(words: np.ndarray, s: int) -> Program:
    instrs = []
    for w in words:
        w = int(w)
        if w == 0 and instrs:
            break
        op = w & 0x3
        if op == _OP_IO:
            instrs.append(IOInstr(height=(w >> 2) & 0x3F, width=(w >> 8) & 0x3F,
                                  channels=(w >> 14) & 0x7FF,
                                  in_channels=(w >> 25) & _IO_INCH_MAX,
                                  bits=(w >> 28) & _IO_BITS_MAX))
        elif op == _OP_CNN:
            instrs.append(ConvInstr(height=(w >> 2) & 0x3F, width=(w >> 8) & 0x3F,
                                    features=(w >> 14) & 0x7FF,
                                    maxpool=bool((w >> 25) & 1)))
        else:
            instrs.append(FCInstr(in_features=(w >> 14) & _FC_IN_MAX,
                                  out_features=(w >> 2) & _FC_OUT_MAX,
                                  final=bool((w >> 25) & 1)))
    return Program(s=s, instrs=tuple(instrs))


def layer_geometry(p: Program):
    """Yield (instr, in_h, in_w, in_c, out_h, out_w, out_c) per instruction."""
    validate(p)
    cur_h = cur_w = cur_c = None
    out = []
    for ins in p.instrs:
        if isinstance(ins, IOInstr):
            out.append((ins, ins.height, ins.width, ins.in_channels,
                        ins.height, ins.width, ins.channels))
            cur_h, cur_w, cur_c = ins.height, ins.width, ins.channels
        elif isinstance(ins, ConvInstr):
            oh, ow = cur_h - 1, cur_w - 1
            if ins.maxpool:
                oh, ow = oh // 2, ow // 2
            out.append((ins, cur_h, cur_w, cur_c, oh, ow, ins.features))
            cur_h, cur_w, cur_c = oh, ow, ins.features
        else:
            out.append((ins, 1, 1, ins.in_features, 1, 1, ins.out_features))
            cur_h = cur_w = 1
            cur_c = ins.out_features
    return out

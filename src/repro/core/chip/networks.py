"""The paper's benchmark networks as ISA programs.

* ``cifar9(S)`` — the 9-layer always-on benchmark net of Fig. 4/5 (8 CNN
  + 1 FC on a 32x32 7-bit RGB input).  Its published anchors pin the
  topology: layer 1 = 500M binary ops (32x32 -> 31x31 at C=256) and a
  2G-op total at S=1 (Table 1), which our 8-conv layout reproduces to
  within 1% (2.013G).  The conv weight footprint is 8 x 256x256x2x2 b =
  262 kB — the chip's 259 kB weight SRAM to within 1%, a strong hint this
  is the layout the SRAM was sized for.  Used for CIFAR-10 (S=1), owner
  detection (S=1), 7 face angles (S=2) and face detection (S=4).
* ``mnist5(S=4)`` — the "narrow 5-layer network" used for MNIST in
  Table 1 (exact topology unpublished; ours matches the energy scale).
"""

from __future__ import annotations

from repro.core.chip import isa


def cifar9(s: int = 1, classes: int = 10) -> isa.Program:
    f = isa.ARRAY_CHANNELS // s
    instrs = [isa.IOInstr(height=32, width=32, in_channels=3, bits=7, channels=f)]
    # (input size, maxpool): 32->31->30->29->28p14->13->12p6->5->4p2
    plan = [(32, False), (31, False), (30, False), (29, True),
            (14, False), (13, True), (6, False), (5, True)]
    for size, pool in plan:
        instrs.append(isa.ConvInstr(height=size, width=size, features=f,
                                    maxpool=pool))
    instrs.append(isa.FCInstr(in_features=2 * 2 * f, out_features=classes,
                              final=True))
    p = isa.Program(s=s, instrs=tuple(instrs))
    isa.validate(p)
    return p


def mnist5(s: int = 4, classes: int = 10) -> isa.Program:
    """Narrow 5-layer net (IO + 2 CNN + 2 FC) on a 2x-decimated 14x14 input.

    The paper gives only "a narrow 5-layer network" at S=4 with 0.20 uJ
    core / 0.21 uJ I2L.  The LD energy floor pins the topology: each
    LD-CONV phase costs ~79 nJ/image at S=4, so a 0.20 uJ core budget
    affords at most TWO conv layers; the 0.21 uJ I2L total then favors a
    cheap 14x14 input (MNIST decimated 2x at the sensor, standard for
    always-on wake-up pipelines).  This layout lands at 0.192/0.212 uJ —
    4%/1% from the published 0.20/0.21."""
    f = isa.ARRAY_CHANNELS // s
    instrs = [
        isa.IOInstr(height=14, width=14, in_channels=1, bits=8, channels=f),
        isa.ConvInstr(height=14, width=14, features=f, maxpool=True),   # ->6
        isa.ConvInstr(height=6, width=6, features=f, maxpool=True),     # ->2
        isa.FCInstr(in_features=2 * 2 * f, out_features=f, final=False),
        isa.FCInstr(in_features=f, out_features=classes, final=True),
    ]
    p = isa.Program(s=s, instrs=tuple(instrs))
    isa.validate(p)
    return p


def cifar9_truncated(s: int = 4, classes: int = 10) -> isa.Program:
    """Depth-truncated cifar9: the paper's 2nd flexibility level
    (programmable depth) as an operating point below the S=4 width floor.

    Drops the final conv layer (the 6x6->5x5 stage replaces the
    6->5->pool->2 tail), feeding the 5x5 map straight into the classifier
    FC.  Only encodable at S=4: the FC fan-in 5*5*(256/S) must fit the
    11-bit ISA field (1600 at S=4; 3200 at S=2 overflows), exactly the
    kind of depth/width coupling the real program memory imposes.
    """
    f = isa.ARRAY_CHANNELS // s
    instrs = [isa.IOInstr(height=32, width=32, in_channels=3, bits=7,
                          channels=f)]
    plan = [(32, False), (31, False), (30, False), (29, True),
            (14, False), (13, True), (6, False)]
    for size, pool in plan:
        instrs.append(isa.ConvInstr(height=size, width=size, features=f,
                                    maxpool=pool))
    instrs.append(isa.FCInstr(in_features=5 * 5 * f, out_features=classes,
                              final=True))
    p = isa.Program(s=s, instrs=tuple(instrs))
    isa.validate(p)
    return p


def face_detector() -> isa.Program:
    """Face detection runs the 9-layer net at the S=4 minimum-energy point
    (Table 1: 0.89 uJ core / 0.92 uJ I2L, 94.5% precision)."""
    return cifar9(s=4, classes=2)


def face_angles() -> isa.Program:
    """7-angle face tracking at S=2 (Table 1: 3.4/3.47 uJ)."""
    return cifar9(s=2, classes=7)


def owner_detector() -> isa.Program:
    """Owner recognition at S=1 (Table 1: 98.2%, 14.4 uJ I2L)."""
    return cifar9(s=1, classes=2)


REGISTRY = {
    "cifar9_s1": lambda: cifar9(1),
    "cifar9_s2": lambda: cifar9(2),
    "cifar9_s4": lambda: cifar9(4),
    "cifar9_s4t": cifar9_truncated,
    "mnist5": mnist5,
    "face_detector": face_detector,
    "face_angles": face_angles,
    "owner_detector": owner_detector,
}

# ---------------------------------------------------------------------------
# Program families: one task compiled at several operating points
# ---------------------------------------------------------------------------
# The paper's scalability story (Fig. 5): ONE task served anywhere on its
# energy-accuracy curve by re-pointing the resident program — width
# (S=1/2/4) and depth (truncated) are the knobs.  A family groups the
# registry programs that are variants of one task; the serving layer's
# operating-point controller (`serving.policy.OperatingPointPolicy`)
# switches among them per dispatch.  Family members must share input
# geometry and class count (`interpreter.compile_family` validates).
#
# ACCURACY holds the nominal task accuracy of each operating point —
# the paper's published anchors (Fig. 5 / Table 1: 86% CIFAR-10 at S=1,
# 98.2% owner recognition at S=1, 94.5% face-detect precision at S=4),
# with the unpublished points interpolated on Fig. 5's curve.  The repro
# doesn't train to these numbers; they parameterize the Pareto front the
# controller walks (`energy.operating_points`).

ACCURACY = {
    "cifar9_s1": 0.8605,       # Table 1: 86.05% CIFAR-10
    "cifar9_s2": 0.834,        # Fig. 5 mid-curve
    "cifar9_s4": 0.785,        # Fig. 5 minimum-energy width point
    "cifar9_s4t": 0.755,       # depth-truncated, below the width floor
    "owner_detector": 0.982,   # Table 1: 98.2% owner recognition
    "face_angles": 0.925,      # Table 1: 7-angle tracking
    "face_detector": 0.945,    # Table 1: 94.5% face-detect precision
    "mnist5": 0.976,           # Table 1 MNIST point
}

FAMILIES = {
    # CIFAR-10 classification across the full width+depth range
    "cifar10": ("cifar9_s1", "cifar9_s2", "cifar9_s4", "cifar9_s4t"),
    # the always-on face task: expensive owner recognizer, cheap detector
    "face": ("owner_detector", "face_detector"),
}


def family_programs(family: str):
    """``{variant name: Program}`` for a registered family, in the
    family's declared (most-accurate-first) order."""
    if family not in FAMILIES:
        raise KeyError(f"unknown family {family!r} (have {sorted(FAMILIES)})")
    return {name: REGISTRY[name]() for name in FAMILIES[family]}

"""Analytical latency/energy model of the BinarEye chip.

This is the paper's *evaluation* substrate: the paper reports
energy/throughput, not task accuracy, so reproducing Figs. 4-5 and Table 1
means reproducing this model.  Structure follows the silicon:

  * every CNN layer = ``phases`` LD-CONV phases, phases = (256/S)/64 = 4/S
  * CONV: 2 cycles per output position (one 2x2 step: fetch 2 feature
    bits + compute), all 64 neurons (128k binary ops) in parallel
  * LD: load 64 neurons x 1024 weight bits from SRAM into the local FFs
    once per phase — the flip-flop weight-reuse that defines the chip
  * IO: 1 cycle/pixel image load through the 1.8V pads
  * FC: sequential, sota-but-modest 1.5 TOPS/W (paper Sec. III-A)

Calibration: the free constants below were fitted to the paper's anchor
measurements (230 TOPS/W layer-1 core efficiency @ 6 MHz / 352 GOPS;
13.82 uJ core / 14.4 uJ I2L per 9-layer CIFAR net at S=1) and *validated*
against every other published point — S=2/S=4 energies, inf/s, power,
GOPS range — which land within ~7% (see EXPERIMENTS.md and
tests/test_chip_energy.py).
"""

from __future__ import annotations

import dataclasses
from typing import List

from repro.core.chip import isa

# --- timing constants (cycles) ---------------------------------------------
CONV_CYCLES_PER_POS = 2        # one 2x2 step: 2 fresh feature bits + compute
LD_CYCLES_PER_PHASE = 222      # 64 neurons x 1024 b over a wide bus + setup
IO_CYCLES_PER_PIXEL = 1        # image load
FC_MACS_PER_CYCLE = 64

# --- energy constants (fitted to the paper's anchors) -----------------------
# Solved exactly from the two primary anchors:
#   layer-1 core eff = 230 TOPS/W  ->  7688*e_cc +  888*e_lc = 2.191 uJ
#   9-layer core     = 13.82 uJ    -> 30720*e_cc + 7104*e_lc = 13.82 uJ
E_CONV_CYCLE = 120.5e-12       # J/cycle: 65536 binary MACs -> 1.8 fJ/op
E_LD_CYCLE = 1.424e-9          # J/cycle: ~295 weight bits/cycle SRAM->FF burst
E_IO_CYCLE = 50e-12            # J: pad + input SRAM write
P_STATIC = 90e-6               # W: leakage + always-on control (I2L domain)
FC_EFF = 1.5e12                # ops/J (paper: "sota efficiencies up to 1.5TOPS/W")

F_EMIN = 6e6                   # Hz at the 0.66 V minimum-energy point
F_MIN, F_MAX = 1.5e6, 48e6


@dataclasses.dataclass(frozen=True)
class LayerReport:
    name: str
    kind: str                 # io | cnn | fc
    ops: float                # binary ops (MAC = 2 ops), batch of S images
    cycles: float             # total cycles, batch of S images
    conv_cycles: float
    ld_cycles: float
    energy_j: float           # core energy (dynamic conv+ld; fc ops-based)

    def gops(self, f_hz: float = F_EMIN) -> float:
        return self.ops / self.cycles * f_hz / 1e9 if self.cycles else 0.0

    def tops_per_w(self) -> float:
        # core efficiency: dynamic energy only (paper's "Core* Eff.")
        return self.ops / self.energy_j / 1e12 if self.energy_j else 0.0


@dataclasses.dataclass(frozen=True)
class NetReport:
    layers: List[LayerReport]
    s: int
    ops_per_inference: float          # per image
    cycles_per_batch: float           # batch of S images
    core_energy_per_inference: float  # J / image (conv+ld+fc dynamic)
    i2l_energy_per_inference: float   # J / image, incl. IO + static
    inferences_per_s: float           # at F_EMIN
    power_w: float                    # at F_EMIN
    core_tops_per_w: float
    i2l_tops_per_w: float

    @property
    def edp_ujs(self) -> float:
        """Energy-delay product at Emin-frequency latency (uJ*s).

        Matches Table 1's S=2 (7e-3) and S=4 (5e-4) entries; the table's
        S=1 entry (1e-2) corresponds to fmax latency — see
        benchmarks/table1_comparison.py for both conventions."""
        delay = self.cycles_per_batch / F_EMIN / self.s
        return self.i2l_energy_per_inference * 1e6 * delay

    def edp_ujs_at(self, f_hz: float) -> float:
        delay = self.cycles_per_batch / f_hz / self.s
        return self.i2l_energy_per_inference * 1e6 * delay


def analyze_program(p: isa.Program) -> List[LayerReport]:
    """Per-instruction cycle/op/energy accounting for a batch of S images."""
    isa.validate(p)
    phases = (isa.ARRAY_CHANNELS // p.s) // isa.NUM_NEURONS    # 4/S
    reports = []
    for (ins, in_h, in_w, in_c, out_h, out_w, out_c) in isa.layer_geometry(p):
        if isinstance(ins, isa.IOInstr):
            cyc = ins.height * ins.width * IO_CYCLES_PER_PIXEL * p.s
            reports.append(LayerReport(
                name="IO", kind="io", ops=0.0, cycles=cyc,
                conv_cycles=0.0, ld_cycles=0.0, energy_j=cyc * E_IO_CYCLE))
        elif isinstance(ins, isa.ConvInstr):
            conv_h, conv_w = in_h - 1, in_w - 1   # pre-pool conv positions
            conv_cyc = phases * CONV_CYCLES_PER_POS * conv_h * conv_w
            ld_cyc = phases * LD_CYCLES_PER_PHASE
            # ops: F x C x 2x2 MACs x 2 ops, for the batch of S maps
            ops = ins.features * in_c * 4 * 2 * conv_h * conv_w * p.s
            energy = conv_cyc * E_CONV_CYCLE + ld_cyc * E_LD_CYCLE
            reports.append(LayerReport(
                name=f"CNN {in_h}x{in_w}x{in_c}->{out_h}x{out_w}x{out_c}"
                     + ("+pool" if ins.maxpool else ""),
                kind="cnn", ops=ops, cycles=conv_cyc + ld_cyc,
                conv_cycles=conv_cyc, ld_cycles=ld_cyc, energy_j=energy))
        else:
            macs = ins.in_features * ins.out_features
            cyc = -(-macs // FC_MACS_PER_CYCLE) * p.s
            ops = macs * 2 * p.s
            reports.append(LayerReport(
                name=f"FC {ins.in_features}->{ins.out_features}",
                kind="fc", ops=ops, cycles=cyc, conv_cycles=0.0,
                ld_cycles=0.0, energy_j=ops / FC_EFF))
    return reports


def analyze_net(p: isa.Program, f_hz: float = F_EMIN) -> NetReport:
    layers = analyze_program(p)
    total_cycles = sum(l.cycles for l in layers)
    t_batch = total_cycles / f_hz
    core_e_batch = sum(l.energy_j for l in layers if l.kind != "io")
    io_e_batch = sum(l.energy_j for l in layers if l.kind == "io")
    i2l_e_batch = core_e_batch + io_e_batch + P_STATIC * t_batch
    ops_batch = sum(l.ops for l in layers)
    inf_s = p.s / t_batch
    return NetReport(
        layers=layers,
        s=p.s,
        ops_per_inference=ops_batch / p.s,
        cycles_per_batch=total_cycles,
        core_energy_per_inference=core_e_batch / p.s,
        i2l_energy_per_inference=i2l_e_batch / p.s,
        inferences_per_s=inf_s,
        power_w=i2l_e_batch / t_batch,
        core_tops_per_w=ops_batch / core_e_batch / 1e12,
        i2l_tops_per_w=ops_batch / i2l_e_batch / 1e12,
    )


def peak_gops(p: isa.Program, f_hz: float = F_MAX) -> float:
    """Best layer throughput at f_hz (paper's Performance [GOPS] row)."""
    return max(l.gops(f_hz) for l in analyze_program(p) if l.kind == "cnn")


# ---------------------------------------------------------------------------
# TPU-side residency accounting: HBM traffic of staged vs megakernel runs
# ---------------------------------------------------------------------------
# The chip "requires no off-chip bandwidth": weights and feature maps never
# leave the SRAMs.  On the TPU mapping that property is a *choice*: the
# staged InferencePlan launches one Pallas call per layer, so every packed
# feature map (and every layer's weights, re-fetched per dispatch) crosses
# HBM between stages; the megakernel holds the weight image + feature maps
# VMEM-resident and its only HBM traffic is frames in, logits out.  This
# model bills both so the microbench/docs can quote the eliminated bytes —
# the TPU analogue of dropping the off-chip term from the access billing.

_WORD = 4                           # bytes per uint32/int32 lane


@dataclasses.dataclass(frozen=True)
class TrafficReport:
    """Per-batch HBM bytes for one compiled program, both execution modes."""
    batch: int
    staged_layers: List              # (layer name, bytes) per staged stage
    staged_bytes: int                # total staged HBM traffic / batch
    mega_bytes: int                  # megakernel: frames in + logits out
    weight_image_bytes: int          # the VMEM-resident SRAM image

    @property
    def reduction(self) -> float:
        return self.staged_bytes / self.mega_bytes if self.mega_bytes else 0.0


def hbm_traffic(p: isa.Program, batch: int = 1) -> TrafficReport:
    """Bill the HBM bytes a batch moves under each execution mode.

    Staged: per layer, read the packed input map + the layer's weights
    (re-fetched every dispatch) + write the packed output map.  Megakernel:
    read the raw frames + the weight image once, write the logits — zero
    inter-layer traffic (feature maps live in VMEM scratch, weights stay
    resident across the whole frame stream).
    """
    isa.validate(p)
    pw = 32                          # packed channels per word
    layers = []
    weight_bytes = 0
    frames_bytes = logits_bytes = 0
    for (ins, in_h, in_w, in_c, out_h, out_w, out_c) in isa.layer_geometry(p):
        if isinstance(ins, isa.IOInstr):
            frames_bytes = batch * in_h * in_w * ins.in_channels * _WORD
            out_map = batch * out_h * out_w * (out_c // pw) * _WORD
            layers.append(("IO", frames_bytes + out_map))
        elif isinstance(ins, isa.ConvInstr):
            w_b = ins.features * 4 * (in_c // pw) * _WORD
            thr_b = 2 * ins.features * _WORD           # tau + flip
            in_map = batch * in_h * in_w * (in_c // pw) * _WORD
            out_map = batch * out_h * out_w * (out_c // pw) * _WORD
            weight_bytes += w_b + thr_b
            layers.append((f"CNN {in_h}x{in_w}x{in_c}",
                           w_b + thr_b + in_map + out_map))
        else:
            kw = -(-ins.in_features // pw)
            w_b = ins.out_features * kw * _WORD
            in_b = batch * kw * _WORD
            if ins.final:
                out_b = batch * ins.out_features * _WORD     # int32 logits
                logits_bytes = out_b
            else:
                out_b = batch * -(-ins.out_features // pw) * _WORD
            weight_bytes += w_b
            layers.append((f"FC {ins.in_features}->{ins.out_features}",
                           w_b + in_b + out_b))
    staged = sum(b for _, b in layers)
    mega = frames_bytes + weight_bytes + logits_bytes
    return TrafficReport(batch=batch, staged_layers=layers,
                         staged_bytes=staged, mega_bytes=mega,
                         weight_image_bytes=weight_bytes)


def array_occupancy(programs) -> float:
    """Fraction of the 256-channel array a set of *concurrently* running
    programs occupies: each S-mode program claims a 256/S-channel
    sub-array, so occupancy = sum(1/S).  A solo S=4 dispatch runs at
    0.25; an exact shared-array tiling (4xS4, 2xS2, 2xS4+1xS2, ...) runs
    at 1.0 — the serving scheduler averages this over dispatches as its
    ``array_utilization`` figure.
    """
    occ = sum(1.0 / p.s for p in programs)
    if occ > 1.0 + 1e-9:
        raise isa.ProgramError(
            f"programs with S modes {[p.s for p in programs]} oversubscribe "
            f"the array: sum(1/S) = {occ:.2f} > 1")
    return occ


# ---------------------------------------------------------------------------
# Operating points: the energy-accuracy Pareto front of a program family
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class OperatingPoint:
    """One point on a family's energy-accuracy curve (paper Fig. 5)."""
    name: str
    s: int
    uj_per_frame: float         # I2L energy per inference, µJ
    frames_per_s: float         # at the analysis f_hz
    power_uj_s: float           # steady-state power analogue, µJ/s (= µW)
    accuracy: float             # nominal task accuracy (paper anchors)
    report: NetReport


def operating_points(programs, accuracy=None, f_hz: float = F_EMIN):
    """The Pareto-filtered operating points of a program family.

    ``programs`` maps variant names to validated ISA programs (e.g. one
    task compiled at S=1/S=2/S=4 and truncated depth — see
    ``networks.FAMILIES``); ``accuracy`` maps the same names to nominal
    task accuracies.  The accuracy scale must be consistent across the
    whole family for the Pareto sort to mean anything, so declared
    accuracies are used only when *every* program has one; otherwise the
    entire family falls back to an ops-count proxy (more binary ops =
    more accurate, which orders width/depth variants the way Fig. 5
    does).  Returns a tuple of :class:`OperatingPoint` sorted most
    accurate (and most expensive) first, with dominated points removed —
    a point survives only if it is strictly cheaper than every more
    accurate point, so walking the tuple front-to-back always trades
    accuracy for energy, exactly the downshift axis the serving
    controller moves along.
    """
    accuracy = dict(accuracy or {})
    anchored = all(name in accuracy for name in programs)
    pts = []
    for name, p in programs.items():
        rep = analyze_net(p, f_hz)
        acc = (accuracy[name] if anchored
               else rep.ops_per_inference)     # consistent ops proxy
        pts.append(OperatingPoint(
            name=name, s=p.s,
            uj_per_frame=rep.i2l_energy_per_inference * 1e6,
            frames_per_s=rep.inferences_per_s,
            power_uj_s=rep.power_w * 1e6,
            accuracy=acc, report=rep))
    pts.sort(key=lambda op: (-op.accuracy, op.uj_per_frame))
    front = []
    for op in pts:
        if not front or op.uj_per_frame < front[-1].uj_per_frame:
            front.append(op)
    return tuple(front)


# ---------------------------------------------------------------------------
# Cascade accounting: cheap detector screening an expensive recognizer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CascadeReport:
    """Energy bill for a two-stage always-on cascade.

    The paper's flagship deployment: the 0.92 uJ/f S=4 face detector
    screens every frame and only escalates positives to the 14.4 uJ/f
    S=1 recognizer, so the per-frame cost is ``det + rate * rec`` —
    strictly below recognizing every frame whenever the escalation rate
    stays under ``1 - det/rec``.  ``*_padded`` bill the static-batch
    slots each serving lane burned (the always-on array never idles).
    """
    frames: int                       # frames entering the cascade
    escalated: int                    # frames promoted to the recognizer
    escalation_rate: float
    detector_uj: float                # per-inference I2L energy, µJ
    recognizer_uj: float
    uj_per_frame: float               # cascade bill / submitted frame
    uj_per_frame_recognizer_only: float  # baseline: recognizer on every
                                         # frame, zero padding
    savings: float                    # baseline / cascade (>= 1 when the
                                      # cascade pays off)


def cascade_report(detector: isa.Program, recognizer: isa.Program,
                   frames: int, escalated: int, *,
                   detector_padded: int = 0, recognizer_padded: int = 0,
                   f_hz: float = F_EMIN,
                   reports: dict | None = None) -> CascadeReport:
    """Bill a detector->recognizer cascade: every submitted frame burns
    detector energy (plus the detector lane's padding), every escalated
    frame additionally burns recognizer energy (plus that lane's
    padding).  The baseline is the tightest competitor — the recognizer
    on every frame with zero padding — so ``savings >= 1`` is a real
    claim, not an artifact of batch fill."""
    if escalated > frames:
        raise ValueError(
            f"escalated {escalated} exceeds submitted frames {frames}")
    if reports is None:
        reports = {"det": analyze_net(detector, f_hz),
                   "rec": analyze_net(recognizer, f_hz)}
    det_uj = reports["det"].i2l_energy_per_inference * 1e6
    rec_uj = reports["rec"].i2l_energy_per_inference * 1e6
    total_uj = ((frames + detector_padded) * det_uj
                + (escalated + recognizer_padded) * rec_uj)
    per_frame = total_uj / frames if frames else 0.0
    baseline = rec_uj
    return CascadeReport(
        frames=frames, escalated=escalated,
        escalation_rate=escalated / frames if frames else 0.0,
        detector_uj=det_uj, recognizer_uj=rec_uj,
        uj_per_frame=per_frame,
        uj_per_frame_recognizer_only=baseline,
        savings=baseline / per_frame if per_frame else 0.0)


# ---------------------------------------------------------------------------
# Temporal accounting: delta-gated always-on video streams
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TemporalReport:
    """Energy bill for a delta-gated always-on video stream.

    The workload BinarEye's always-on figures assume: consecutive frames
    of a quiet scene are nearly identical, so the gated runtime charges
    *every* frame only the delta-gate cost (the IO layer — pads + input
    SRAM writes + the comparator's static share; the popcount gate rides
    on the encode the chip performs anyway) and the full network only for
    the frames whose packed Hamming distance crossed the threshold.  The
    ungated baseline is the full per-inference I2L energy on every frame
    with zero padding — the tightest competitor, so ``savings >= 1`` is a
    real claim, not an artifact of batch fill.
    """
    frames: int                       # frames entering the gate
    computed: int                     # frames that recomputed (changed)
    computed_padded: int              # drain-chunk padding slots burned
    skipped: int                      # frames served from cached logits
    skip_ratio: float                 # skipped / frames
    delta_uj: float                   # gate cost per frame (IO layer), µJ
    full_uj: float                    # full-network I2L per inference, µJ
    uj_per_frame: float               # gated bill / submitted frame
    uj_per_frame_ungated: float       # baseline: full network every frame
    savings: float                    # baseline / gated (>= 1 when gating
                                      # pays off)


def temporal_report(program: isa.Program, frames: int, computed: int, *,
                    computed_padded: int = 0, f_hz: float = F_EMIN,
                    report: NetReport | None = None) -> TemporalReport:
    """Bill a delta-gated stream: every submitted frame burns the gate
    (IO-layer energy + the static power burned over the IO cycles), and
    every recomputed frame — plus the drain chunks' padding slots —
    additionally burns the full per-inference I2L energy."""
    if computed > frames:
        raise ValueError(
            f"computed {computed} exceeds submitted frames {frames}")
    if computed_padded < 0:
        raise ValueError(f"computed_padded must be >= 0, "
                         f"got {computed_padded}")
    if report is None:
        report = analyze_net(program, f_hz)
    io = program.instrs[0]
    io_cycles = io.height * io.width * IO_CYCLES_PER_PIXEL
    delta_uj = (io_cycles * E_IO_CYCLE
                + P_STATIC * io_cycles / f_hz) * 1e6
    full_uj = report.i2l_energy_per_inference * 1e6
    total_uj = (frames * delta_uj
                + (computed + computed_padded) * full_uj)
    per_frame = total_uj / frames if frames else 0.0
    skipped = frames - computed
    return TemporalReport(
        frames=frames, computed=computed, computed_padded=computed_padded,
        skipped=skipped,
        skip_ratio=skipped / frames if frames else 0.0,
        delta_uj=delta_uj, full_uj=full_uj,
        uj_per_frame=per_frame,
        uj_per_frame_ungated=full_uj,
        savings=full_uj / per_frame if per_frame else 0.0)


# ---------------------------------------------------------------------------
# Serving-mix accounting: the chip time-shared across resident programs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ServeReport:
    """Energy/throughput bill for a multi-program serving mix.

    The chip's S-mode recombination lets several programs stay resident
    (each with its own width mode); serving interleaves them on the one
    physical array, so the mix-level figures are frame-weighted over the
    per-program :class:`NetReport`s: energy adds, time adds, throughput is
    the harmonic composition.  ``frames`` may include padding frames a
    static-batch scheduler burned — they cost energy but aren't *served*,
    which is exactly how the µJ per *served* frame should bill them.
    """
    frames: dict                      # program name -> served frame count
    padded: dict                      # program name -> padding frames burned
    reports: dict                     # program name -> NetReport
    uj_per_frame: float               # I2L energy / served frame, incl. pad
    frames_per_s: float               # served frames/s at the analysis f_hz
    power_w: float                    # average power over the mix

    @property
    def total_frames(self) -> int:
        return sum(self.frames.values())


def serve_report(programs: dict, frames: dict, padded: dict | None = None,
                 f_hz: float = F_EMIN,
                 reports: dict | None = None,
                 billed: int | None = None) -> ServeReport:
    """Bill a serving mix: ``programs``/``frames`` keyed by program name.

    Returns the frame-weighted µJ/frame and frames/s of running
    ``frames[name]`` inferences of each program (plus ``padded[name]``
    wasted batch slots — the scheduler's actual pad per dispatch, which
    with continuous batching varies per launch) back-to-back on one chip
    at ``f_hz``.  Pass precomputed ``reports`` ({name: NetReport} at the
    same ``f_hz``) to skip re-analysis — the per-program reports are
    static, so a serving loop polling its stats shouldn't rebuild them
    every call.  ``billed`` (the scheduler's count of launched frame
    slots) cross-checks the bill: served + padded must equal it exactly,
    or the accounting has drifted and the report raises.
    """
    padded = dict(padded or {})
    if reports is None:
        reports = {n: analyze_net(p, f_hz) for n, p in programs.items()}
    served = sum(frames.get(n, 0) for n in programs)
    if billed is not None:
        pad_total = sum(padded.get(n, 0) for n in programs)
        if served + pad_total != billed:
            raise ValueError(
                f"serve bill mismatch: {served} served + {pad_total} "
                f"padded != {billed} billed frame slots")
    burned = {n: frames.get(n, 0) + padded.get(n, 0) for n in programs}
    energy_j = sum(burned[n] * reports[n].i2l_energy_per_inference
                   for n in programs)
    time_s = sum(burned[n] / reports[n].inferences_per_s for n in programs)
    return ServeReport(
        frames={n: frames.get(n, 0) for n in programs},
        padded={n: padded.get(n, 0) for n in programs},
        reports=reports,
        uj_per_frame=(energy_j / served * 1e6) if served else 0.0,
        frames_per_s=(served / time_s) if time_s else 0.0,
        power_w=(energy_j / time_s) if time_s else 0.0,
    )


# ---------------------------------------------------------------------------
# Fleet accounting: N chips serving in parallel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Aggregated bill over a fleet of replicas (N chips in parallel).

    Energy adds across replicas; throughput adds too (the chips serve
    concurrently, unlike the time-shared single-chip mix where time
    adds); µJ per served frame is the fleet total energy over the fleet
    total served.  A dead replica's partial bill stays in — the energy
    it burned before failing (including abandoned in-flight dispatches)
    was really spent.
    """
    replicas: dict                    # replica name -> ServeReport
    frames: dict                      # program name -> served, fleet-wide
    padded: dict                      # program name -> padding, fleet-wide
    uj_per_frame: float               # fleet energy / fleet served frames
    frames_per_s: float               # sum of replica throughputs
    power_w: float                    # sum of replica average powers

    @property
    def total_frames(self) -> int:
        return sum(self.frames.values())


def fleet_report(reports: dict) -> FleetReport:
    """Aggregate per-replica :class:`ServeReport`s (``{replica name:
    ServeReport}``) into the fleet bill.  Per-replica energy is
    reconstructed from each report's burned slots x per-program µJ —
    exactly the quantity ``serve_report`` billed, so the fleet total is
    the sum of what each replica's own ledger already validated."""
    frames: dict = {}
    padded: dict = {}
    energy_j = 0.0
    fps = 0.0
    power = 0.0
    for rep in reports.values():
        for n in rep.frames:
            frames[n] = frames.get(n, 0) + rep.frames[n]
            padded[n] = padded.get(n, 0) + rep.padded.get(n, 0)
            energy_j += ((rep.frames[n] + rep.padded.get(n, 0))
                         * rep.reports[n].i2l_energy_per_inference)
        fps += rep.frames_per_s
        power += rep.power_w
    served = sum(frames.values())
    return FleetReport(
        replicas=dict(reports), frames=frames, padded=padded,
        uj_per_frame=(energy_j / served * 1e6) if served else 0.0,
        frames_per_s=fps, power_w=power)

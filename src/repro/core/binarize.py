"""Binarization primitives: sign/STE, bitpacking, BatchNorm->threshold folding.

This is the numerical heart of the BinarEye reproduction.  A BinaryNet
constrains weights and activations to {-1, +1} (Hubara et al., 2016).  The
chip evaluates the dot product of two +/-1 vectors of length K as

    dot(a, w) = K - 2 * popcount(xor(pack(a), pack(w)))

because xor of sign-bits counts the number of disagreeing positions.  We
adopt the convention  +1 -> bit 0,  -1 -> bit 1  (i.e. the bit is the sign
bit), so ``xor`` marks positions where the product is -1.

Training uses the straight-through estimator (STE): forward = sign(x),
backward = identity clipped to |x| <= 1 (the BinaryNet "hard tanh" STE).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

PACK_WIDTH = 32  # binary channels per uint32 lane
_PACK_DTYPE = jnp.uint32


# ---------------------------------------------------------------------------
# Sign + straight-through estimator
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_sign(x: jax.Array) -> jax.Array:
    """sign(x) in {-1, +1} with the BinaryNet straight-through gradient."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


def _ste_sign_fwd(x):
    return ste_sign(x), x


def _ste_sign_bwd(x, g):
    # dL/dx = dL/dy * 1{|x| <= 1}   (hard-tanh STE)
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


ste_sign.defvjp(_ste_sign_fwd, _ste_sign_bwd)


def hard_sign(x: jax.Array) -> jax.Array:
    """Non-differentiable sign in {-1, +1} (ties -> +1)."""
    return jnp.where(x >= 0, 1.0, -1.0).astype(x.dtype)


# ---------------------------------------------------------------------------
# Bitpacking:  +/-1 (or {0,1} sign bits) <-> uint32 words
# ---------------------------------------------------------------------------

def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m


def pack_signs(x: jax.Array, axis: int = -1) -> jax.Array:
    """Pack a +/-1 array into uint32 along ``axis`` (bit=1 means -1).

    The packed axis length becomes ceil(K / 32); K is padded with +1 (bit 0)
    so padding never flips an xor and popcount sees zeros there.
    """
    axis = axis % x.ndim
    k = x.shape[axis]
    kp = _round_up(k, PACK_WIDTH)
    if kp != k:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, kp - k)
        x = jnp.pad(x, pad, constant_values=1.0)  # +1 -> bit 0
    # move pack axis last
    x = jnp.moveaxis(x, axis, -1)
    bits = (x < 0).astype(_PACK_DTYPE)  # -1 -> 1
    bits = bits.reshape(x.shape[:-1] + (kp // PACK_WIDTH, PACK_WIDTH))
    shifts = jnp.arange(PACK_WIDTH, dtype=_PACK_DTYPE)
    words = jnp.sum(bits << shifts, axis=-1, dtype=_PACK_DTYPE)
    return jnp.moveaxis(words, -1, axis)


def pack_bit_lanes(bits: jax.Array) -> jax.Array:
    """Pack a (..., K) array of {0,1} sign bits into (..., K//32) uint32.

    The shared packing idiom for code that already *has* sign bits
    (Pallas kernel bodies, the packed thermometer encoder) — same
    LSB-first lane order as :func:`pack_signs`, which handles the
    +/-1-float and padding cases.  K must be a multiple of 32.
    """
    k = bits.shape[-1]
    assert k % PACK_WIDTH == 0, k
    lanes = bits.astype(_PACK_DTYPE).reshape(
        bits.shape[:-1] + (k // PACK_WIDTH, PACK_WIDTH))
    shifts = jnp.arange(PACK_WIDTH, dtype=_PACK_DTYPE)
    return jnp.sum(lanes << shifts, axis=-1, dtype=_PACK_DTYPE)


def unpack_signs(words: jax.Array, k: int, axis: int = -1,
                 dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`pack_signs`; returns +/-1 of length ``k``."""
    axis = axis % words.ndim
    words = jnp.moveaxis(words, axis, -1)
    shifts = jnp.arange(PACK_WIDTH, dtype=_PACK_DTYPE)
    bits = (words[..., None] >> shifts) & _PACK_DTYPE(1)
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * PACK_WIDTH,))
    signs = jnp.where(flat == 1, -1.0, 1.0).astype(dtype)[..., :k]
    return jnp.moveaxis(signs, -1, axis)


def xnor_dot_popcount(a_words: jax.Array, w_words: jax.Array, k: int) -> jax.Array:
    """Binary dot product from packed words: ``K - 2*popcount(a ^ w)``.

    a_words: (..., Kw) uint32;  w_words: (..., Kw) uint32 broadcastable.
    Returns int32 dot product of the underlying +/-1 vectors of length k.
    """
    x = jnp.bitwise_xor(a_words, w_words)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    return jnp.int32(k) - 2 * jnp.sum(pc, axis=-1)


def thermometer_pack(images: jax.Array, bits: int, cin: int,
                     channels: int) -> jax.Array:
    """Thermometer-encode integer pixels straight into packed uint32 words.

    The single source of truth for the chip's IO layer arithmetic, shared
    by ``neuron_array.thermometer_encode_packed`` (the staged pipeline)
    and the whole-network megakernel's in-kernel encode — one
    implementation so the two execution modes cannot drift apart.  Plane
    i of color c is -1 (bit 1) exactly when ``x_c < t_i``; leftover
    planes are constant +1 bias (bit 0).  ``channels`` must be a
    multiple of 32.  (..., H, W, cin) int -> (..., H, W, channels//32).
    """
    assert channels % PACK_WIDTH == 0, channels
    lead = images.shape[:-1]
    per = channels // cin
    levels = 2 ** bits
    t = (jnp.arange(per, dtype=jnp.float32) + 0.5) * (levels / per)
    neg = (images.astype(jnp.float32)[..., None] < t).astype(_PACK_DTYPE)
    neg = neg.reshape(lead + (cin * per,))
    pad = channels - cin * per
    if pad:                                              # +1 bias -> bit 0
        neg = jnp.concatenate(
            [neg, jnp.zeros(lead + (pad,), neg.dtype)], axis=-1)
    return pack_bit_lanes(neg)


# ---------------------------------------------------------------------------
# BatchNorm -> threshold folding (the chip's binary comparator)
# ---------------------------------------------------------------------------

def fold_bn_to_threshold(gamma, beta, mean, var, eps: float = 1e-5):
    """Fold BatchNorm + sign into an integer threshold on the popcount sum.

    sign(gamma * (s - mean)/sqrt(var+eps) + beta) ==
        (s >= tau)  if gamma > 0  else  (s <= tau),
    with tau = mean - beta*sqrt(var+eps)/gamma.

    Returns (tau, flip) where flip==True encodes the gamma<0 direction.
    The chip stores exactly this comparator threshold per neuron.
    """
    std = jnp.sqrt(var + eps)
    tau = mean - beta * std / gamma
    flip = gamma < 0
    return tau, flip


def threshold_activation(s: jax.Array, tau: jax.Array, flip: jax.Array) -> jax.Array:
    """Apply the folded comparator: +/-1 output."""
    ge = s >= tau
    out = jnp.where(jnp.logical_xor(ge, flip), 1.0, -1.0)
    return out.astype(jnp.float32)


def threshold_to_int(tau: jax.Array) -> jax.Array:
    """Quantize the folded float threshold to the int32 the chip stores.

    The conv sums ``s`` are integers (bounded by +/-4*C <= 1024, exactly
    representable in fp32), so ``s >= tau``  <=>  ``s >= ceil(tau)`` and
    the comparator needs only an integer register per neuron — this is
    the deployment form of the BN fold.  Inf thresholds (a neuron stuck
    off/on) saturate to the int32 range, preserving the always/never-fire
    behaviour for any reachable ``s``.
    """
    lo, hi = jnp.float32(-2**31), jnp.float32(2**31 - 256)
    return jnp.clip(jnp.ceil(tau), lo, hi).astype(jnp.int32)

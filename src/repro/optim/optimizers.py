"""Optimizers (optax-style pure functions, built in-repo per the scope rule).

* adamw     — fp32 m/v, decoupled weight decay, bias correction.
* adafactor — factored second moment (Shazeer & Stern 2018); the only
  optimizer whose state fits for the 1T-param Kimi config.
* sgdm      — momentum SGD (chip-net training).

All return ``(init_fn, update_fn)``; state is a pytree matching params
(sharded with the same specs, see distributed/sharding_rules.py).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params, step) -> (new_params, new_state)


def _tree_zeros_f32(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr_fn, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_f32(params), "v": _tree_zeros_f32(params)}

    def update(grads, state, params, step):
        grads, gn = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}, gn

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no momentum)
# ---------------------------------------------------------------------------

def adafactor(lr_fn, decay=0.8, eps=1e-30, clip_norm: float = 1.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    def _factored(shape):
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def mk(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(mk, params,
                                  is_leaf=lambda x: hasattr(x, "shape"))}

    def update(grads, state, params, step):
        grads, gn = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        t = (step + 1).astype(jnp.float32)
        beta = 1.0 - t ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in s:
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = (vr[..., :, None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1, keepdims=True)[..., None],
                                       eps))
                u = g * jax.lax.rsqrt(denom + eps)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                ns = {"v": v}
            # update clipping (RMS <= 1), as in the paper
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), ns

        flat, tdef = jax.tree.flatten(params)
        gflat = tdef.flatten_up_to(grads)
        sflat = tdef.flatten_up_to(state["v"])
        out = [upd(g, s, p) for g, s, p in zip(gflat, sflat, flat)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_params, {"v": new_v}, gn

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD with momentum
# ---------------------------------------------------------------------------

def sgdm(lr_fn, momentum=0.9, clip_norm: float = 0.0) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_f32(params)}

    def update(grads, state, params, step):
        gn = global_norm(grads)
        if clip_norm:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)

        def upd(g, m, p):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        out = jax.tree.map(upd, grads, state["m"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m}, gn

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Schedules + factory
# ---------------------------------------------------------------------------

def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def make(name: str, lr_fn, **kw) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor, "sgdm": sgdm}[name](lr_fn, **kw)

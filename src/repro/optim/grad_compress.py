"""Int8 gradient compression with error feedback (distributed-optimization
trick for cross-pod reduces).

At 1000+ nodes the pod-to-pod (DCN/ICI-expander) all-reduce of bf16 grads
dominates step time for FSDP models.  We quantize each gradient leaf to
int8 with a per-leaf fp32 scale before the cross-pod reduce and keep the
quantization residual in an error-feedback buffer (Seide et al. 2014;
1-bit Adam lineage) so the bias cancels over steps.

Usage (see train/steps.py): grads are reduced per-pod by pjit as usual;
``compress``/``decompress`` wrap only the explicit cross-pod psum when
``cross_pod_compression`` is on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress(g: jax.Array, err: jax.Array):
    """g + err -> (q int8, scale f32, new_err). Symmetric per-tensor scale."""
    g32 = g.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(g32))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g32 - deq


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum_tree(grads, err_state, axis_name: str):
    """Error-feedback int8 psum over `axis_name` for every leaf.

    int8 sums can overflow int8 range, so the wire format is int8 but the
    reduction runs in int32 (XLA converts once per leaf); scales are
    max-reduced so dequantization is conservative.
    """
    def one(g, err):
        q, scale, new_err = compress(g, err)
        scale = jax.lax.pmax(scale, axis_name)           # shared scale
        # requantize against the shared scale to keep the wire int8
        g32 = g.astype(jnp.float32) + err
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        new_err = g32 - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (total.astype(jnp.float32) * scale / n).astype(g.dtype), new_err

    out = jax.tree.map(one, grads, err_state)
    new_grads = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda o: o[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return new_grads, new_err

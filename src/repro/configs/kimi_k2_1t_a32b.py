"""Kimi K2 — trillion-param MoE (384 experts, top-8) [arXiv:2501.kimi2].

61 layers: 1 dense prefix layer + 60 MoE layers (DeepSeek-V3-style layout
with one shared expert).  Adafactor + full FSDP: 1T params do not fit
per-chip optimizer state otherwise.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8, d_head=128,
    d_ff=2048, vocab_size=163840,
    prefix=("dense",), pattern=("attn_moe",),
    moe=MoEConfig(num_experts=384, top_k=8, d_expert=2048,
                  num_shared_experts=1),
    optimizer="adafactor", fsdp=True, param_dtype="bfloat16",  rope_theta=5e4,
)

"""OLMoE-1B-7B — 64 experts, top-8 [arXiv:2409.02060]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe",
    num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16, d_head=128,
    d_ff=1024, vocab_size=50304,
    pattern=("attn_moe",),
    moe=MoEConfig(num_experts=64, top_k=8, d_expert=1024),
    qk_norm=True,
)

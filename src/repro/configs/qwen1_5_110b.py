"""Qwen1.5-110B — dense, QKV bias [hf:Qwen/Qwen1.5-110B family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, d_head=128,
    d_ff=49152, vocab_size=152064,
    pattern=("attn",), qkv_bias=True, fsdp=True, param_dtype="bfloat16",  rope_theta=1e6,
)

"""Architecture registry: ``--arch <id>`` -> ModelConfig.

The paper's own networks (BinarEye chip programs) live in
``repro.core.chip.networks.REGISTRY`` — they are ISA programs, not LM
configs, and are exercised by the chip benchmarks/examples.
"""

from __future__ import annotations

import importlib

_MODULES = {
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen1.5-110b": "qwen1_5_110b",
    "gemma2-2b": "gemma2_2b",
    "qwen3-8b": "qwen3_8b",
    "smollm-360m": "smollm_360m",
    "musicgen-medium": "musicgen_medium",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, **overrides):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    cfg = mod.CONFIG
    return cfg.with_(**overrides) if overrides else cfg

"""MusicGen-medium backbone — decoder-only over EnCodec tokens
[arXiv:2306.05284].

The EnCodec frontend is a STUB: input_specs() provides token ids for 4
codebooks (delay-pattern flattening assumed done upstream); the model sums
codebook embeddings and predicts 4 parallel heads.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio",
    num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24, d_head=64,
    d_ff=6144, vocab_size=2048,
    pattern=("attn",), act="gelu", num_codebooks=4,
)

"""Qwen2-VL-2B backbone — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, S, d_model) + 3-D M-RoPE position ids.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, d_head=128,
    d_ff=8960, vocab_size=151936,
    pattern=("attn",), qkv_bias=True,
    mrope=True, mrope_sections=(16, 24, 24),
    embed_inputs=False, rope_theta=1e6,
)

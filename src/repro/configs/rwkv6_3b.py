"""RWKV-6 "Finch" 3B — attention-free, data-dependent decay
[arXiv:2404.05892]."""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40, d_head=64,
    d_ff=8960, vocab_size=65536,
    pattern=("rwkv",),
    rwkv=RWKVConfig(head_size=64, decay_lora=64, mix_lora=32),
)

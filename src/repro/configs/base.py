"""Model / parallelism / quantization configuration system.

Every assigned architecture is a ``ModelConfig`` instance in its own module
(``repro/configs/<arch>.py``).  ``scaled()`` produces the reduced smoke-test
variant of the same family.  The paper's technique surfaces here as
``quant="binary"`` (BinaryNet W1A1 projections, STE-trained) and
``width_mult`` (the chip's S knob generalized to any width).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                    # per-expert FFN hidden dim
    num_shared_experts: int = 0      # DeepSeek/Kimi-style always-on experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-2  # load-balance loss weight
    impl: str = "auto"               # auto | dense | ep (expert-parallel a2a)
    # perf knob (§Perf): fp8 dispatch a2a (DeepSeek-V3 style) — halves the
    # dominant wire-bytes term of EP MoE; return path stays bf16.
    dispatch_fp8: bool = False


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None    # defaults to ceil(d_model/16)
    # perf knob (§Perf): unroll the selective-scan recurrence so the
    # (B, d_inner, d_state) state round-trips HBM once per `scan_unroll`
    # steps instead of every token (XLA fuses the unrolled chain).
    scan_unroll: int = 1


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64
    decay_lora: int = 64             # rank of the data-dependent decay LoRA
    mix_lora: int = 32               # rank of the ddlerp token-shift LoRA
    # perf knobs (EXPERIMENTS.md §Perf): the WKV recurrence is the memory-
    # roofline bottleneck of rwkv6 at train/prefill.
    scan_unroll: int = 1             # lax.scan unroll of the per-token path
    chunk: Optional[int] = None      # GLA-style chunked WKV (tokens/chunk)
    sub_chunk: int = 16              # FLA-style sub-chunks within a chunk:
    #   cross-sub-chunk decay runs as rebased (c, C) matmuls, the exact
    #   pairwise einsum only within a sub-chunk (must divide `chunk`;
    #   a non-divisor falls back to one exact sub-chunk = the full chunk)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                      # dense | moe | vlm | audio | hybrid | ssm
    # trunk
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None     # default d_model // num_heads
    # block structure: `pattern` is scanned `num_layers // len(pattern)` times
    # after `prefix` (unscanned leading layers). entries:
    #   attn | attn_moe | local | global | mamba | mamba_moe | rwkv | dense
    pattern: Tuple[str, ...] = ("attn",)
    prefix: Tuple[str, ...] = ()
    # attention details
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    sliding_window: Optional[int] = None   # for "local" pattern entries
    mrope: bool = False                    # Qwen2-VL multimodal RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w halves of d_head
    # embeddings / head
    tie_embeddings: bool = False
    embed_scale: bool = False              # gemma: multiply embeds by sqrt(d)
    num_codebooks: int = 1                 # MusicGen: EnCodec codebooks
    embed_inputs: bool = True              # False for VLM stub (precomputed embeds)
    # ffn / norm
    act: str = "silu"                      # silu | gelu
    norm_eps: float = 1e-6
    post_block_norm: bool = False          # gemma2 post-norms
    # sub-configs
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RWKVConfig] = None
    # the paper's technique
    quant: str = "none"                    # none | binary (W1A1 + STE)
    width_mult: float = 1.0                # BinarEye S-knob generalization
    # numerics / training
    dtype: str = "bfloat16"                # activation/compute dtype
    param_dtype: str = "float32"           # bfloat16 for the FSDP giants
    attn_probs_bf16: bool = False          # bf16 exp'd probs (perf knob, §Perf)
    bf16_grads: bool = False               # Megatron-style bf16 grad collectives
    remat: bool = True
    loss_chunk: int = 1024                 # CE computed over seq chunks
    optimizer: str = "adamw"               # adamw | adafactor | sgdm
    # parallelism
    fsdp: bool = False                     # shard params/opt over data axes
    seq_shard_attn: bool = False           # shard seq over model axis in attn I/O

    # ---- derived ----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.num_heads

    @property
    def num_pattern_repeats(self) -> int:
        n = self.num_layers - len(self.prefix)
        assert n % len(self.pattern) == 0, (self.name, n, self.pattern)
        return n // len(self.pattern)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def scaled(self, layers: int = None, width: int = 64) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        factor = max(1, self.d_model // width)
        def shrink(x, lo=8):
            return max(lo, int(x) // factor)
        n_pat = len(self.pattern)
        nl = layers if layers is not None else len(self.prefix) + n_pat
        nl = max(nl, len(self.prefix) + n_pat)
        nl = len(self.prefix) + ((nl - len(self.prefix) + n_pat - 1) // n_pat) * n_pat
        heads = max(2, self.num_heads // 8)
        kv = max(1, min(heads, self.num_kv_heads // 8 or 1))
        while heads % kv:
            kv -= 1
        moe = None
        if self.moe:
            moe = dataclasses.replace(
                self.moe, num_experts=min(8, self.moe.num_experts),
                top_k=min(2, self.moe.top_k), d_expert=shrink(self.moe.d_expert),
                num_shared_experts=min(1, self.moe.num_shared_experts))
        mamba = self.mamba and dataclasses.replace(self.mamba, d_state=8)
        rwkv = self.rwkv and dataclasses.replace(
            self.rwkv, head_size=16, decay_lora=8, mix_lora=8)
        d_model = shrink(self.d_model, lo=32)
        d_model = max(d_model, heads * 8)
        # head_dim must be even (RoPE) and divide d_model exactly
        d_head_s = max(8, (d_model // heads) // 2 * 2)
        d_model = heads * d_head_s
        if self.rwkv:  # d_model must be a multiple of the rwkv head size
            d_model = max(16, d_model // 16 * 16)
        hd2 = (d_model // heads) // 2
        sec = (hd2 // 4, (hd2 - hd2 // 4) // 2,
               hd2 - hd2 // 4 - (hd2 - hd2 // 4) // 2)
        return dataclasses.replace(
            self, name=self.name + "-smoke", num_layers=nl, d_model=d_model,
            num_heads=heads, num_kv_heads=kv,
            d_head=max(8, (d_model // heads) // 2 * 2),
            d_ff=shrink(self.d_ff, lo=16),
            vocab_size=min(512, self.vocab_size),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            moe=moe, mamba=mamba, rwkv=rwkv, loss_chunk=64, fsdp=False,
            mrope_sections=sec if self.mrope else self.mrope_sections,
            remat=False,  # halves XLA compile time on the 1-core CI box
        )


def eff_d_ff(cfg: ModelConfig) -> int:
    """FFN width after the BinarEye S-knob (width_mult)."""
    return max(8, int(cfg.d_ff * cfg.width_mult))


def eff_d_expert(cfg: ModelConfig) -> int:
    return max(8, int(cfg.moe.d_expert * cfg.width_mult))


def param_count(cfg: ModelConfig) -> int:
    """Approximate parameter count (embeddings + blocks), for roofline's 6ND."""
    d, v = cfg.d_model, cfg.vocab_size
    dh = cfg.head_dim
    n = v * d * (1 if cfg.tie_embeddings else 2) * (cfg.num_codebooks if cfg.num_codebooks > 1 else 1)
    def attn_params():
        return d * dh * (cfg.num_heads + 2 * cfg.num_kv_heads) + cfg.num_heads * dh * d
    def mlp_params(ff):
        return 3 * d * ff
    def moe_params():
        m = cfg.moe
        return (m.num_experts + m.num_shared_experts) * 3 * d * eff_d_expert(cfg) + m.num_experts * d
    def mamba_params():
        mc = cfg.mamba
        di = mc.expand * d
        dtr = mc.dt_rank or -(-d // 16)
        return d * 2 * di + di * mc.d_conv + di * (dtr + 2 * mc.d_state) + dtr * di + di * mc.d_state + di + di * d
    def rwkv_params():
        rc = cfg.rwkv
        tm = 5 * d * d + 2 * d * rc.decay_lora + 10 * d * rc.mix_lora
        cm = 2 * d * eff_d_ff(cfg) + d * d
        return tm + cm
    total = n
    for kind in cfg.prefix + cfg.pattern * cfg.num_pattern_repeats:
        if kind in ("attn", "local", "global"):
            total += attn_params() + mlp_params(eff_d_ff(cfg))
        elif kind == "dense":
            total += attn_params() + mlp_params(eff_d_ff(cfg))
        elif kind == "attn_moe":
            total += attn_params() + moe_params()
        elif kind == "mamba":
            total += mamba_params() + mlp_params(eff_d_ff(cfg))
        elif kind == "mamba_moe":
            total += mamba_params() + moe_params()
        elif kind == "rwkv":
            total += rwkv_params()
        else:
            raise ValueError(kind)
    return int(total)


def active_param_count(cfg: ModelConfig) -> int:
    """Active params per token (MoE: top_k + shared experts only)."""
    if cfg.moe is None:
        return param_count(cfg)
    d = cfg.d_model
    m = cfg.moe
    full_moe = (m.num_experts + m.num_shared_experts) * 3 * d * eff_d_expert(cfg)
    act_moe = (m.top_k + m.num_shared_experts) * 3 * d * eff_d_expert(cfg)
    n_moe_layers = sum(1 for k in cfg.prefix + cfg.pattern * cfg.num_pattern_repeats
                       if k.endswith("_moe"))
    return param_count(cfg) - n_moe_layers * (full_moe - act_moe)

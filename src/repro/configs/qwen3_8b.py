"""Qwen3-8B — qk-norm, GQA [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8, d_head=128,
    d_ff=12288, vocab_size=151936,
    pattern=("attn",), qk_norm=True, rope_theta=1e6,
)

"""Jamba v0.1 52B — Mamba+attention 1:7 interleave, 16-expert MoE every
other layer [arXiv:2403.19887].

Period-8 block (indices 0-7): attention at index 4, Mamba elsewhere;
MoE replaces the MLP at odd indices.  4 repeats = 32 layers.
"""
from repro.configs.base import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, d_head=128,
    d_ff=14336, vocab_size=65536,
    pattern=("mamba", "mamba_moe", "mamba", "mamba_moe",
             "attn", "mamba_moe", "mamba", "mamba_moe"),
    moe=MoEConfig(num_experts=16, top_k=2, d_expert=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    fsdp=True, param_dtype="bfloat16", 
)

"""Assigned input-shape set + ShapeDtypeStruct input specs for the dry-run.

Four shapes per LM architecture (40 cells total):
  train_4k     seq 4096,    global_batch 256  -> train_step
  prefill_32k  seq 32768,   global_batch 32   -> prefill_step
  decode_32k   seq 32768,   global_batch 128  -> serve_step (1 new token)
  long_500k    seq 524288,  global_batch 1    -> serve_step; ONLY for
               sub-quadratic archs (rwkv6, jamba) — see DESIGN.md §4.

``input_specs`` returns weak-type-correct ShapeDtypeStructs (no allocation).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (constant/linear-state sequence mixers)
SUBQUADRATIC = ("rwkv6-3b", "jamba-v0.1-52b")


def cell_supported(cfg, shape_name: str) -> tuple[bool, str]:
    """(supported, reason-if-not) for an (arch x shape) cell."""
    if shape_name == "long_500k" and cfg.name not in SUBQUADRATIC:
        return False, ("full-attention KV cache at 524288 tokens is not a "
                       "sensible deployment (quadratic prefill; see DESIGN.md §4)")
    return True, ""


def _token_struct(cfg, b, s):
    if cfg.num_codebooks > 1:
        return jax.ShapeDtypeStruct((b, s, cfg.num_codebooks), jnp.int32)
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b = shape.global_batch
    if shape.step == "train":
        s = shape.seq_len
        specs = {"tokens": _token_struct(cfg, b, s),
                 "labels": _token_struct(cfg, b, s)}
        if not cfg.embed_inputs:  # VLM stub: precomputed patch embeddings
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.dtype(cfg.dtype))
            specs.pop("tokens")
        if cfg.mrope:
            specs["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
        return specs
    if shape.step == "prefill":
        s = shape.seq_len
        specs = {"tokens": _token_struct(cfg, b, s)}
        if not cfg.embed_inputs:
            specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                   jnp.dtype(cfg.dtype))
            specs.pop("tokens")
        if cfg.mrope:
            specs["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
        return specs
    # decode: one new token against a cache of seq_len
    specs = {"tokens": _token_struct(cfg, b, 1)}
    if not cfg.embed_inputs:
        specs["embeds"] = jax.ShapeDtypeStruct((b, 1, cfg.d_model),
                                               jnp.dtype(cfg.dtype))
        specs.pop("tokens")
    if cfg.mrope:
        specs["positions"] = jax.ShapeDtypeStruct((b, 1, 3), jnp.int32)
    return specs

"""repro: BinarEye (Moons et al., 2018) as a production JAX framework.

Tier A: faithful chip reproduction (ISA, neuron array, energy model).
Tier B: BinaryNet compute + width-scalability as first-class features of a
multi-pod LM training/serving stack (10 assigned architectures).
"""
__version__ = "0.1.0"

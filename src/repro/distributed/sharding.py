"""Logical-axis sharding rules: params, batch, cache -> PartitionSpecs.

Logical axes:
  tp    -> mesh "model"          (tensor parallel: heads / ffn hidden / vocab)
  fsdp  -> ("pod","data")        (ZeRO-3 weight sharding, only if cfg.fsdp)
  dp    -> ("pod","data")        (batch)
  sp    -> mesh "model"          (sequence, in MoE blocks and decode KV)
  ep    -> mesh "model"          (experts)

Rules are matched on the parameter path string (first match wins); stacked
scan leaves under ``blocks/`` automatically get a leading ``None``.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed import context as dctx


def _axes(mesh, cfg):
    dp = tuple(a for a in mesh.axis_names if a != "model")
    tp = "model" if "model" in mesh.axis_names else None
    fsdp = dp if cfg.fsdp else None
    return dp, tp, fsdp


def _divisible(dim: int, axes, mesh) -> bool:
    if axes is None:
        return False
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0


def _maybe(dim, axes, mesh):
    """Use `axes` for this dim only if it divides evenly, else replicate."""
    return axes if _divisible(dim, axes, mesh) else None


def param_rules(cfg, mesh):
    """Ordered (regex, fn(shape) -> PartitionSpec) rules."""
    dp, tp, fsdp = _axes(mesh, cfg)

    def spec(*ax):
        return P(*ax)

    def embed(shape):
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _maybe(shape[-2], tp, mesh), _maybe(shape[-1], fsdp, mesh))

    def head(shape):
        lead = (None,) * (len(shape) - 2)
        return P(*lead, _maybe(shape[-2], fsdp, mesh), _maybe(shape[-1], tp, mesh))

    def col(shape):   # (in, out) -> out on tp  (wq/wk/wv/wi/wg/in_proj...)
        return P(_maybe(shape[0], fsdp, mesh), _maybe(shape[1], tp, mesh))

    def row(shape):   # (in, out) -> in on tp   (wo/out_proj/cm_wv...)
        return P(_maybe(shape[0], tp, mesh), _maybe(shape[1], fsdp, mesh))

    def bias_tp(shape):
        return P(_maybe(shape[0], tp, mesh))

    def expert_col(shape):  # (E, D, F)
        return P(_maybe(shape[0], tp, mesh), _maybe(shape[1], fsdp, mesh), None)

    def expert_row(shape):  # (E, F, D)
        return P(_maybe(shape[0], tp, mesh), None, _maybe(shape[2], fsdp, mesh))

    def repl(shape):
        return P()

    return [
        (r"embed/table$", embed),
        (r"lm_head/w$", head),
        (r"(attn/(wq|wk|wv)|mlp/(wi|wg)|shared/(wi|wg)|rwkv/(wr|wk|wv|wg|cm_wk|cm_wr)|mamba/in_proj)/w$", col),
        (r"(attn/wo|mlp/wo|shared/wo|rwkv/(wo|cm_wv)|mamba/out_proj)/w$", row),
        (r"(attn/(wq|wk|wv)|mlp/(wi|wg)|mamba/in_proj)/b$", bias_tp),
        (r"moe/(wi|wg)$", expert_col),
        (r"moe/wo$", expert_row),
        (r"moe/router$", repl),
        (r"mamba/conv_w$", lambda s: P(None, _maybe(s[1], tp, mesh))),
        (r"mamba/conv_b$", bias_tp),
        (r"mamba/x_proj/w$", lambda s: P(_maybe(s[0], tp, mesh), None)),
        (r"mamba/dt_proj/w$", lambda s: P(None, _maybe(s[1], tp, mesh))),
        (r"mamba/dt_proj/b$", bias_tp),
        (r"mamba/A_log$", lambda s: P(_maybe(s[0], tp, mesh), None)),
        (r"mamba/D$", bias_tp),
        (r"rwkv/mix_w1$", lambda s: P(_maybe(s[0], fsdp, mesh), None)),
        (r".*", repl),
    ]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
    return "/".join(parts)


def param_specs(cfg, mesh, params_shape):
    """PartitionSpec pytree matching a params (shape) pytree."""
    rules = param_rules(cfg, mesh)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        stacked = ps.startswith("blocks/")
        eff_shape = shape[1:] if stacked else shape
        for pat, fn in rules:
            if re.search(pat, ps):
                spec = fn(eff_shape)
                break
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# Batch / cache / activation specs
# ---------------------------------------------------------------------------

def batch_specs(cfg, mesh, batch_shape):
    dp, tp, fsdp = _axes(mesh, cfg)

    def one(path, leaf):
        b = leaf.shape[0]
        lead = _maybe(b, dp, mesh)
        return P(lead, *([None] * (len(leaf.shape) - 1)))

    return jax.tree_util.tree_map_with_path(one, batch_shape)


def cache_specs(cfg, mesh, cache_shape):
    """Decode caches: KV seq over 'model' (split-K decode), states over tp."""
    dp, tp, fsdp = _axes(mesh, cfg)

    def one(path, leaf):
        ps = _path_str(path)
        shape = leaf.shape
        stacked = ps.startswith("blocks/")
        s = shape[1:] if stacked else shape
        if "wkv" in ps:                       # (B,H,hs,hs)
            spec = P(_maybe(s[0], dp, mesh), _maybe(s[1], tp, mesh), None, None)
        elif "shift" in ps:                   # (B,1,d)
            spec = P(_maybe(s[0], dp, mesh), None, None)
        elif len(s) == 4:                     # attn kv (B,L,KH,dh)
            spec = P(_maybe(s[0], dp, mesh), _maybe(s[1], tp, mesh), None, None)
        elif len(s) == 3:                     # mamba states
            if s[2] <= 64:                    # (B, di, ds) ssm state
                spec = P(_maybe(s[0], dp, mesh), _maybe(s[1], tp, mesh), None)
            else:                             # (B, dc-1, di) conv state
                spec = P(_maybe(s[0], dp, mesh), None, _maybe(s[2], tp, mesh))
        else:
            spec = P(*([None] * len(s)))
        if stacked:
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(one, cache_shape)


# ---------------------------------------------------------------------------
# In-model constraint helper (no-op without a mesh)
# ---------------------------------------------------------------------------

def constrain(x, logical: tuple):
    """logical entries: 'dp' | 'tp' | 'sp' | None."""
    mesh = dctx.current_mesh()
    if mesh is None or jax.core.get_aval(x).ndim != len(logical):
        return x
    dp = tuple(a for a in mesh.axis_names if a != "model")
    table = {"dp": dp, "tp": "model", "sp": "model", None: None}
    axes = []
    for dim, l in zip(x.shape, logical):
        ax = table[l]
        axes.append(ax if _divisible(dim, ax, mesh) else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*axes)))


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Chip-tier serving: plan-aware specs for InferencePlan execution
# ---------------------------------------------------------------------------
# The serving data-parallel layout mirrors the chip's LD-once/CONV-many
# schedule, lifted one level: every device holds a full replica of the
# packed deployment artifact (uint32 weight words + int32 thresholds — the
# SRAM contents), and the frame batch is scattered on the batch axis.
# Weights move to a device once; frames stream through.

SERVE_AXIS = "frames"


def serve_mesh(devices=None, axis: str = SERVE_AXIS):
    """1-axis serving mesh over ``devices`` (default: all local devices).

    Degrades gracefully to a 1-device mesh on CPU; under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (or on a real
    multi-chip host) the same call yields an N-way frame-scatter mesh.
    """
    import numpy as np
    devs = list(jax.devices()) if devices is None else list(devices)
    return jax.sharding.Mesh(np.array(devs, dtype=object), (axis,))


def partition_serve_meshes(n: int, devices=None, axis: str = SERVE_AXIS):
    """``n`` serving meshes over disjoint host-major device groups.

    The fleet's replica topology: the flat device list (host-major —
    ``jax.devices()`` orders by process index, then local id) is split
    into ``n`` contiguous groups, one sub-mesh per simulated host, so a
    replica's frames scatter only over its own devices and a host loss
    takes out exactly one group.  Remainder devices go to the leading
    groups (sizes differ by at most one).  With fewer devices than
    replicas the groups wrap round-robin — replicas then *share* devices,
    which only simulation allows, but keeps single-device CPU tests able
    to exercise fleet scheduling.
    """
    if n < 1:
        raise ValueError(f"need >= 1 replica, got {n}")
    devs = list(jax.devices()) if devices is None else list(devices)
    if len(devs) >= n:
        base, rem = divmod(len(devs), n)
        groups, at = [], 0
        for i in range(n):
            size = base + (1 if i < rem else 0)
            groups.append(devs[at:at + size])
            at += size
    else:
        groups = [[devs[i % len(devs)]] for i in range(n)]
    return [serve_mesh(g, axis=axis) for g in groups]


def plan_serve_specs(mesh):
    """(artifact_spec, frames_spec, out_spec) for a sharded InferencePlan.

    The packed artifact is replicated (``P()`` matches every leaf of the
    {conv: [...], fc: [...]} pytree as a spec prefix); frames and the
    (logits, labels) outputs are split on the leading batch axis.
    """
    axis = mesh.axis_names[0]
    return P(), P(axis), P(axis)


def replicate_artifact(mesh, packed):
    """Place one full packed-weight replica on every device of ``mesh``."""
    art_spec, _, _ = plan_serve_specs(mesh)
    s = NamedSharding(mesh, art_spec)
    return jax.tree.map(lambda x: jax.device_put(x, s), packed)


def scatter_frames(mesh, frames):
    """Scatter a frame batch over the serving mesh's batch axis.

    The leading dim must divide the mesh size (the serving scheduler pads
    its static batches to guarantee this).
    """
    _, frame_spec, _ = plan_serve_specs(mesh)
    n = mesh.devices.size
    if frames.shape[0] % n:
        raise ValueError(
            f"frame batch {frames.shape[0]} not divisible by "
            f"{n}-device serving mesh")
    return jax.device_put(frames, NamedSharding(mesh, frame_spec))

"""Fault tolerance for 1000+ node runs.

Layers of defense (all exercised in tests/test_fault.py):

1. **Checkpoint/restart** — AsyncCheckpointer every N steps; the training
   loop resumes from ``latest_step`` after any crash.  Data is step-indexed
   (data/tokens.py) so the resumed run consumes identical batches.
2. **Preemption** — SIGTERM/SIGINT flips a flag; the loop checkpoints at
   the next step boundary and exits cleanly (TPU maintenance events give
   ~30s — one step at our scale).
3. **Straggler mitigation** — StepTimer keeps a rolling step-time
   distribution; steps slower than ``threshold x median`` raise a flag the
   driver uses to (a) log the slow host, (b) trigger the runtime's
   hot-swap path (on Borg/GKE: recreate the slice member).  Inside a
   synchronous SPMD step there is no per-host escape hatch — mitigation is
   detect-and-replace, which matches production practice.
4. **Elastic re-scale** — checkpoints are topology-free (full arrays), so
   restore onto a different mesh just re-shards (checkpoint/ckpt.py); data
   sharding is a pure function of (step, host_id, num_hosts).
5. **Step retry** — transient collective failures raise; ``retry_step``
   re-runs the step function up to k times with deterministic
   exponential backoff between attempts (params are immutable inputs,
   so a retried step is exact; the injectable sleep keeps tests
   instant).  The serving fleet reuses exactly this machinery to bring
   replacement replicas up after a host loss (serving/fleet.py).
"""

from __future__ import annotations

import collections
import signal
import statistics
import time
from typing import Callable, Optional


class PreemptionGuard:
    """SIGTERM/SIGINT -> request a clean checkpoint-and-exit."""

    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:  # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, h in self._prev.items():
            signal.signal(sig, h)


class StepTimer:
    """Rolling step-time stats + straggler detection."""

    def __init__(self, window: int = 50, threshold: float = 3.0):
        self.times = collections.deque(maxlen=window)
        self.threshold = threshold
        self._t0: Optional[float] = None
        self.stragglers = 0

    def __enter__(self):
        self._t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        dt = time.monotonic() - self._t0
        self.slow = False
        if len(self.times) >= 5:
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                self.stragglers += 1
                self.slow = True
        self.times.append(dt)
        return False

    @property
    def median(self) -> Optional[float]:
        return statistics.median(self.times) if self.times else None


def retry_step(fn: Callable, *args, retries: int = 2,
               exceptions=(RuntimeError,), on_retry: Callable = None,
               backoff_s: float = 0.0, backoff_factor: float = 2.0,
               max_backoff_s: float = 30.0,
               sleep: Callable[[float], None] = time.sleep,
               stats: Optional[dict] = None):
    """Re-run a pure step on transient failure (inputs are immutable).

    Failed attempt ``k`` (0-based) waits ``backoff_s * backoff_factor**k``
    seconds (capped at ``max_backoff_s``) before the next try —
    deterministic exponential backoff, so a retry loop never hammers a
    still-failing replica during failover.  ``sleep`` is injectable
    (tests pass a virtual sleep and stay instant).  ``on_retry(attempt,
    delay_s)`` fires before each backoff; ``stats`` (an optional dict)
    surfaces the final count to the caller: ``stats["attempts"]`` is the
    total number of calls made and ``stats["backoff_s"]`` the total
    backoff requested.  The default ``backoff_s=0.0`` keeps the
    pre-backoff immediate-retry behaviour.
    """
    if backoff_s < 0.0 or backoff_factor < 1.0 or max_backoff_s < 0.0:
        raise ValueError(
            f"bad backoff ({backoff_s=}, {backoff_factor=}, "
            f"{max_backoff_s=})")
    total_backoff = 0.0
    for attempt in range(retries + 1):
        try:
            result = fn(*args)
        except exceptions:
            if stats is not None:
                stats["attempts"] = attempt + 1
                stats["backoff_s"] = total_backoff
            if attempt == retries:
                raise
            delay = min(backoff_s * backoff_factor ** attempt, max_backoff_s)
            if on_retry:
                on_retry(attempt, delay)
            if delay > 0.0:
                sleep(delay)
                total_backoff += delay
            continue
        if stats is not None:
            stats["attempts"] = attempt + 1
            stats["backoff_s"] = total_backoff
        return result

"""GPipe-style pipeline parallelism over the ``pod`` mesh axis.

For multi-pod runs the cheapest cross-pod traffic is boundary activations,
not gradient all-reduces — so the ``pod`` axis can act as the pipeline
axis: stage = a contiguous block of layers, microbatches flow through a
``shard_map`` + ``ppermute`` schedule (GPipe: all-forward then all-backward,
bubble = (S-1)/(M+S-1)).

``pipelined`` wraps any per-stage function ``stage_fn(stage_params, x)``:
stage params live sharded P("pod") on their leading stage dim; x is split
into microbatches on the host side of the shard_map.  The returned function
is differentiable (jax traces through ppermute), so it drops straight into
the train step.  Used by the PP dry-run variant (launch/dryrun.py --pp) and
tests/test_pipeline.py.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed import context as dctx


def pipelined(stage_fn: Callable, mesh, num_microbatches: int,
              axis: str = "pod"):
    """Returns fn(stage_params, x) running S stages over the `axis`.

    stage_params: pytree with leading dim = n_stages on every leaf.
    x: (B, ...) global batch; B % num_microbatches == 0.
    """
    n_stages = mesh.shape[axis]
    other = tuple(a for a in mesh.axis_names if a != axis)

    def run(stage_params, x):
        def body(params_local, x_local):
            # params_local: this stage's params (leading dim 1) -> squeeze
            params_local = jax.tree.map(lambda p: p[0], params_local)
            stage = jax.lax.axis_index(axis)
            mb = x_local.reshape((num_microbatches,
                                  x_local.shape[0] // num_microbatches)
                                 + x_local.shape[1:])
            n_ticks = num_microbatches + n_stages - 1
            # the carry becomes pod-varying after ppermute/axis_index; the
            # zero init must be marked pod-varying too (shard_map vma rule)
            buf = dctx.pcast(jnp.zeros_like(mb[0]), (axis,), to="varying")
            outs = dctx.pcast(jnp.zeros_like(mb), (axis,), to="varying")

            def tick(carry, t):
                buf, outs = carry
                # stage 0 injects microbatch t (if any remain)
                inject = jnp.where(t < num_microbatches, t, 0)
                x_in = jnp.where(stage == 0,
                                 mb[inject].astype(buf.dtype), buf)
                y = stage_fn(params_local, x_in)
                # last stage stores result for microbatch t - (S-1)
                out_idx = jnp.clip(t - (n_stages - 1), 0, num_microbatches - 1)
                store = jnp.logical_and(stage == n_stages - 1,
                                        t >= n_stages - 1)
                outs = jax.lax.dynamic_update_index_in_dim(
                    outs, jnp.where(store, y, outs[out_idx]), out_idx, 0)
                # shift boundary activations to the next stage
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                buf = jax.lax.ppermute(y, axis, perm)
                return (buf, outs), None

            (buf, outs), _ = jax.lax.scan(tick, (buf, outs),
                                          jnp.arange(n_ticks))
            # broadcast final outputs from the last stage to all stages so
            # the result is replicated over the pipeline axis
            outs = jax.lax.psum(
                jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
                axis)
            return outs.reshape(x_local.shape)

        in_specs = (jax.tree.map(lambda _: P(axis), stage_params),
                    P(other if other else None))
        return dctx.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=P(other if other else None))(
                                 stage_params, x)

    return run

"""Ambient mesh context.

``shard_map``-based blocks (expert-parallel MoE, pipeline) need the Mesh at
trace time.  The launcher / step-builder installs it here so model code can
stay mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax

_state = threading.local()


def current_mesh() -> Optional[jax.sharding.Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[jax.sharding.Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def data_axes(mesh: jax.sharding.Mesh) -> tuple:
    """All mesh axes that carry the batch (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis_size(mesh: Optional[jax.sharding.Mesh]) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]

"""Ambient mesh context.

``shard_map``-based blocks (expert-parallel MoE, pipeline) need the Mesh at
trace time.  The launcher / step-builder installs it here so model code can
stay mesh-agnostic.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax

_state = threading.local()


def current_mesh() -> Optional[jax.sharding.Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Optional[jax.sharding.Mesh]):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


# ---------------------------------------------------------------------------
# Version portability: shard_map / pcast moved surfaces across JAX releases
# ---------------------------------------------------------------------------

def shard_map(body, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` where it exists, ``jax.experimental.shard_map``
    otherwise.

    The experimental form predates varying-manual-axes (vma) tracking, so
    replication checking is disabled there — the newer surface checks vma
    natively and the bodies used here (ppermute pipeline, all_to_all MoE)
    mark their carries with :func:`pcast` when the runtime supports it.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def pcast(x, axes, to="varying"):
    """``jax.lax.pcast`` on runtimes with vma tracking; identity before it
    existed (older shard_map has no replication typing to satisfy)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to=to)
    return x


def data_axes(mesh: jax.sharding.Mesh) -> tuple:
    """All mesh axes that carry the batch (everything except 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis_size(mesh: Optional[jax.sharding.Mesh]) -> int:
    if mesh is None or "model" not in mesh.axis_names:
        return 1
    return mesh.shape["model"]

"""Synthetic image pipeline for the chip networks (CIFAR-like, 7-bit RGB).

Class-conditional blobs + noise: class identity is recoverable (a trained
BinaryNet separates them), deterministic per (seed, step).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def class_templates(key, num_classes: int, h: int = 32, w: int = 32,
                    channels: int = 3, levels: int = 128):
    """Smooth per-class templates in [0, levels)."""
    freqs = jax.random.normal(key, (num_classes, 4, channels))
    yy = jnp.linspace(0, 3.14159 * 2, h)[:, None, None]
    xx = jnp.linspace(0, 3.14159 * 2, w)[None, :, None]
    t = (jnp.sin(yy * (1 + freqs[:, 0][:, None, None]) )
         + jnp.cos(xx * (1 + freqs[:, 1][:, None, None]))
         + jnp.sin((yy + xx) * freqs[:, 2][:, None, None]))
    t = (t - t.min()) / (t.max() - t.min() + 1e-9)
    return (t * (levels - 1)).astype(jnp.int32)


def batch_for_step(step: int, *, batch: int, num_classes: int = 10,
                   h: int = 32, w: int = 32, channels: int = 3,
                   levels: int = 128, seed: int = 0):
    """Returns (images (B,H,W,C) int32 in [0,levels), labels (B,))."""
    tkey = jax.random.PRNGKey(seed)
    templates = class_templates(tkey, num_classes, h, w, channels, levels)
    key = jax.random.fold_in(jax.random.fold_in(tkey, 1), step)
    k1, k2 = jax.random.split(key)
    labels = jax.random.randint(k1, (batch,), 0, num_classes)
    base = templates[labels]
    noise = jax.random.normal(k2, base.shape) * levels * 0.15
    img = jnp.clip(base + noise.astype(jnp.int32), 0, levels - 1)
    return img, labels

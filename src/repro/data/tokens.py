"""Deterministic synthetic LM token pipeline.

Restart-safety / elasticity contract: the batch for global step ``s`` is a
pure function of ``(seed, s)`` — hosts joining after a preemption or an
elastic re-scale regenerate identical data, and each host slices its own
rows, so no data service or shared filesystem is required.

The stream is a noisy affine Markov chain over the vocab (plus periodic
copy motifs), so models show real learning signal (loss drops well below
uniform) while staying fully offline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def batch_for_step(cfg, step: int, *, global_batch: int, seq_len: int,
                   seed: int = 0, host_id: int = 0, num_hosts: int = 1):
    """Returns {"tokens": (B_host, S), "labels": (B_host, S)} int32."""
    assert global_batch % num_hosts == 0
    b_host = global_batch // num_hosts
    key = jax.random.fold_in(jax.random.fold_in(jax.random.PRNGKey(seed),
                                                step), host_id)
    return _gen(key, cfg, b_host, seq_len)


def _gen(key, cfg, batch: int, seq_len: int):
    v = cfg.vocab_size
    k1, k2, k3 = jax.random.split(key, 3)
    ncb = cfg.num_codebooks
    shape = (batch, seq_len + 1, ncb) if ncb > 1 else (batch, seq_len + 1)

    x0 = jax.random.randint(k1, shape[:1] + shape[2:], 0, v)
    noise = jax.random.bernoulli(k2, 0.1, shape)
    rand = jax.random.randint(k3, shape, 0, v)

    def step(tok, inp):
        nz, rnd = inp
        nxt = (tok * 31 + 7) % v          # learnable affine structure
        nxt = jnp.where(nz, rnd, nxt)     # 10% noise
        return nxt, nxt

    _, seq = jax.lax.scan(
        step, x0, (noise.swapaxes(0, 1), rand.swapaxes(0, 1)))
    seq = seq.swapaxes(0, 1)              # (B, S+1, ...)
    tokens = seq[:, :-1]
    labels = seq[:, 1:]
    return {"tokens": tokens.astype(jnp.int32),
            "labels": labels.astype(jnp.int32)}


def vlm_batch_for_step(cfg, step: int, *, global_batch: int, seq_len: int,
                       seed: int = 0):
    """VLM stub batch: precomputed 'patch embeddings' + M-RoPE positions."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 7), step)
    k1, k2 = jax.random.split(key)
    embeds = jax.random.normal(
        k1, (global_batch, seq_len, cfg.d_model), jnp.dtype(cfg.dtype)) * 0.02
    lab = _gen(k2, cfg, global_batch, seq_len)["labels"]
    # grid-like positions: t fixed per image row block, h/w rasterized
    side = max(1, int(seq_len ** 0.5))
    idx = jnp.arange(seq_len)
    pos = jnp.stack([idx // (side * side), (idx // side) % side, idx % side],
                    axis=-1)
    positions = jnp.broadcast_to(pos[None], (global_batch, seq_len, 3))
    return {"embeds": embeds, "labels": lab,
            "positions": positions.astype(jnp.int32)}

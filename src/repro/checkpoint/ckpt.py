"""Sharding-aware checkpointing: save / restore / elastic re-shard / async.

Format: one .npz of flattened leaves + a JSON manifest (paths, dtypes,
shapes, step).  Restore re-places leaves with ``jax.device_put`` against
the *current* mesh's NamedShardings, so a checkpoint written on a 16x16
mesh restores onto 2x16x16 (or a single CPU device) unchanged — this is
the elastic-scaling path.

``AsyncCheckpointer`` snapshots to host memory synchronously (cheap) and
writes to disk on a background thread, overlapping I/O with the next steps;
``wait()`` joins before the process exits.  Writes are atomic
(tmp + rename) so a preemption mid-write never corrupts the latest good
checkpoint.
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def save(path: str, state: Any, step: Optional[int] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = _flatten(state)
    manifest = {
        "step": int(step if step is not None else 0),
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
    }
    tmp = path + ".tmp.npz"
    np.savez(tmp, **{k: v for k, v in arrays.items()})
    os.replace(tmp, path + ".npz")
    tmpm = path + ".tmp.json"
    with open(tmpm, "w") as f:
        json.dump(manifest, f)
    os.replace(tmpm, path + ".json")


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """Version-portable mesh constructor for the restore-after-fault path.

    A job restarted after a fault rebuilds its mesh on whatever topology
    survived and restores the latest checkpoint onto it.  ``jax.make_mesh``
    grew an ``axis_types`` kwarg (and ``jax.sharding.AxisType``) only in
    newer JAX releases; restore code that reached for those crashed the
    recovery itself on older runtimes.  This helper uses only the Mesh
    constructor every supported version has, so rebuilding the mesh can
    never be the step that kills a restart.
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    n = 1
    for s in axis_shapes:
        n *= int(s)
    if n > len(devices):
        raise ValueError(
            f"mesh {tuple(axis_shapes)} needs {n} devices, "
            f"only {len(devices)} available after restart")
    arr = np.array(devices[:n], dtype=object).reshape(tuple(axis_shapes))
    return jax.sharding.Mesh(arr, tuple(axis_names))


def restore(path: str, state_like: Any, mesh=None, specs=None) -> Any:
    """Restore into the structure of ``state_like``; re-shard onto ``mesh``.

    ``state_like`` may hold arrays or ShapeDtypeStructs.  When mesh+specs
    are given, leaves are placed as NamedSharding(mesh, spec) — elastic
    restore onto any device topology.
    """
    with np.load(path + ".npz") as z:
        arrays = {k: z[k] for k in z.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    spec_flat = None
    if specs is not None:
        spec_flat = [s for _, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0]]
    leaves = []
    for i, (path_k, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(path_k)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != {want_shape}")
        if mesh is not None and spec_flat is not None:
            sharding = jax.sharding.NamedSharding(mesh, spec_flat[i])
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


def latest_step(directory: str, prefix: str = "ckpt_") -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith(prefix) and name.endswith(".json"):
            try:
                steps.append(int(name[len(prefix):-len(".json")]))
            except ValueError:
                pass
    return max(steps) if steps else None


class AsyncCheckpointer:
    """Snapshot-to-host sync, write-to-disk async (one in flight)."""

    def __init__(self, directory: str, prefix: str = "ckpt_", keep: int = 3):
        self.directory = directory
        self.prefix = prefix
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, state: Any, step: int) -> None:
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def _write():
            try:
                path = os.path.join(self.directory, f"{self.prefix}{step}")
                save(path, host_state, step)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(s for s in (latest_step(self.directory, self.prefix),)
                       if s is not None)
        all_steps = []
        for name in os.listdir(self.directory):
            if name.startswith(self.prefix) and name.endswith(".json"):
                try:
                    all_steps.append(int(name[len(self.prefix):-len(".json")]))
                except ValueError:
                    pass
        for s in sorted(all_steps)[:-self.keep]:
            for ext in (".json", ".npz"):
                try:
                    os.remove(os.path.join(self.directory,
                                           f"{self.prefix}{s}{ext}"))
                except OSError:
                    pass

"""Pallas TPU kernel: fused sign + bitpack producer.

Turns an fp feature tile into packed sign words in one VMEM pass, so the
binarize step never round-trips an unpacked +/-1 tensor through HBM.  This
is the producer feeding xnor_matmul / binary_conv2x2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.binarize import PACK_WIDTH


def _binarize_pack_kernel(x_ref, out_ref):
    x = x_ref[...]                                    # (bm, K)
    bits = (x < 0).astype(jnp.uint32)
    bm, k = bits.shape
    bits = bits.reshape(bm, k // PACK_WIDTH, PACK_WIDTH)
    shifts = jnp.arange(PACK_WIDTH, dtype=jnp.uint32)
    out_ref[...] = jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def binarize_pack(x: jax.Array, *, bm: int = 256, interpret: bool = False) -> jax.Array:
    """(M, K) float -> (M, ceil(K/32)) uint32 packed signs (bit=1 means -1)."""
    m, k = x.shape
    kp = (-k) % PACK_WIDTH
    if kp:
        x = jnp.pad(x, ((0, 0), (0, kp)), constant_values=1.0)   # +1 -> bit 0
    bm = min(bm, m)
    mp = (-m) % bm
    if mp:
        x = jnp.pad(x, ((0, mp), (0, 0)), constant_values=1.0)
    gm = x.shape[0] // bm
    kw = x.shape[1] // PACK_WIDTH

    out = pl.pallas_call(
        _binarize_pack_kernel,
        grid=(gm,),
        in_specs=[pl.BlockSpec((bm, x.shape[1]), lambda m_: (m_, 0))],
        out_specs=pl.BlockSpec((bm, kw), lambda m_: (m_, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], kw), jnp.uint32),
        interpret=interpret,
    )(x)
    return out[:m]

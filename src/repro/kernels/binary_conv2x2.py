"""Pallas TPU kernel: the chip's F x C x 2x2 stride-1 binary convolution.

BinarEye's neuron array convolves a full (W x H) feature map with 2x2
binary kernels, holding all weights resident (local flip-flops) while the
2x2 window slides.  The TPU mapping: one grid step owns a tile of F output
channels (= a group of neurons); its packed weights live in VMEM for the
whole spatial sweep, and the *entire* feature map is VMEM-resident too
(chip feature maps are <= 32x32x256b = 32 kB packed -- the "all memory on
chip" property transfers directly to VMEM).

The 2x2 conv is computed as 4 shifted XNOR-popcount contractions -- no
im2col buffer, mirroring the chip's reuse of 2 of the 4 feature bits from
the previous step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def accumulate_tap_popcounts(a, w, h: int, wd: int) -> jax.Array:
    """The 2x2 conv as 4 shifted XNOR-popcount contractions.

    a: (bb, H, W, Cw) uint32 packed maps; w: (bf, 4, Cw) packed taps,
    (dy, dx) row-major.  Returns (bb, H-1, W-1, bf) int32 popcounts of
    disagreeing bits — shared by the unfused and fused conv kernels.
    """
    acc = jnp.zeros((a.shape[0], h - 1, wd - 1, w.shape[0]), jnp.int32)
    for dy in range(2):
        for dx in range(2):
            patch = a[:, dy:dy + h - 1, dx:dx + wd - 1, :]      # (bb,H-1,W-1,Cw)
            tap = w[:, 2 * dy + dx, :]                          # (bf, Cw)
            x = jnp.bitwise_xor(patch[:, :, :, None, :],
                                tap[None, None, None, :, :])
            acc += jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)
    return acc


def _binary_conv2x2_kernel(a_ref, w_ref, out_ref, *, k4: int, h: int, w: int):
    """a_ref: (bb, H, W, Cw) uint32; w_ref: (bf, 4, Cw); out_ref: (bb, H-1, W-1, bf)."""
    acc = accumulate_tap_popcounts(a_ref[...], w_ref[...], h, w)
    out_ref[...] = jnp.int32(k4) - 2 * acc


@functools.partial(jax.jit, static_argnames=("c", "bf", "bb", "interpret"))
def binary_conv2x2(a_words: jax.Array, w_words: jax.Array, *, c: int,
                   bf: int = 64, bb: int = 8,
                   interpret: bool = False) -> jax.Array:
    """Packed 2x2 stride-1 VALID binary conv, batched through the grid.

    a_words: (H, W, Cw) or (B, H, W, Cw) uint32 packed feature map(s).
    w_words: (F, 4, Cw) uint32 packed weights, tap order (dy, dx) row-major.
    c:       true channel count (k per tap); total dot length = 4*c.
    Returns (H-1, W-1, F) / (B, H-1, W-1, F) int32.

    Batch rides the grid in frame tiles of ``bb`` (F tiles outermost), so
    each weight tile is fetched once and stays VMEM-resident while every
    frame in the batch streams past it — no per-image ``vmap`` retracing
    the kernel.
    """
    squeeze = a_words.ndim == 3
    if squeeze:
        a_words = a_words[None]
    b, h, w, kw = a_words.shape
    f, taps, kw2 = w_words.shape
    assert taps == 4 and kw == kw2, (w_words.shape, a_words.shape)

    bf = min(bf, f)
    fp = (-f) % bf
    if fp:
        w_words = jnp.pad(w_words, ((0, fp), (0, 0), (0, 0)))
    gf = w_words.shape[0] // bf

    bb = min(bb, b)
    bp = (-b) % bb
    if bp:
        a_words = jnp.pad(a_words, ((0, bp), (0, 0), (0, 0), (0, 0)))
    gb = a_words.shape[0] // bb

    out = pl.pallas_call(
        functools.partial(_binary_conv2x2_kernel, k4=4 * c, h=h, w=w),
        grid=(gf, gb),
        in_specs=[
            pl.BlockSpec((bb, h, w, kw), lambda f_, b_: (b_, 0, 0, 0)),
            pl.BlockSpec((bf, 4, kw), lambda f_, b_: (f_, 0, 0)),  # stationary
        ],
        out_specs=pl.BlockSpec((bb, h - 1, w - 1, bf),
                               lambda f_, b_: (b_, 0, 0, f_)),
        out_shape=jax.ShapeDtypeStruct(
            (a_words.shape[0], h - 1, w - 1, w_words.shape[0]), jnp.int32),
        interpret=interpret,
    )(a_words, w_words)
    out = out[:b, :, :, :f]
    return out[0] if squeeze else out

"""Pallas TPU kernel: fused conv -> threshold -> pool -> repack, all packed.

BinarEye's defining property is that feature maps never leave the chip:
every layer consumes binary data and produces binary data, with no wide
intermediate ever crossing a memory boundary.  The seed mapping lost that
property on TPU — ``binary_conv2x2`` wrote int32 sums to HBM, the
comparator ran on unpacked +/-1 floats, and the next layer re-packed to
uint32 words.  This kernel restores it: one grid step computes the 2x2
XNOR-popcount convolution for a tile of F output neurons, applies the
folded integer threshold comparator (``tau``/``flip``) on the in-register
sums, optionally performs the chip's streamed 2x2/2 max-pool *in the sign
domain* (max over +/-1 == AND of sign bits, since bit=1 encodes -1), and
writes re-packed uint32 words.  Only packed bits ever touch HBM.

Batch is a grid axis rather than a ``jax.vmap``: the grid is (F tiles,
batch) with F outermost, so a weight tile is fetched to VMEM once and
stays resident while the whole batch streams through it — the chip's
LD-once / CONV-many schedule extended over frames.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.binarize import PACK_WIDTH, pack_bit_lanes
from repro.kernels.binary_conv2x2 import accumulate_tap_popcounts


def conv_block_body(a, w, tau, flip, *, k4: int, h: int, wd: int,
                    pool: bool) -> jax.Array:
    """The fused layer body on in-register values: conv -> threshold ->
    pool -> repack.  Shared by the staged per-layer kernel below and the
    whole-network megakernel (``kernels.megakernel``), so both paths run
    the identical arithmetic and stay bit-exact against each other.

    a:    (bb, H, W, Cw) uint32 packed input maps.
    w:    (bf, 4, Cw)    uint32 packed weight taps, (dy, dx) row-major.
    tau:  (bf,) int32 comparator thresholds; flip: (bf,) int32 direction.
    Returns (bb, Ho, Wo, bf // 32) uint32 packed output words.
    """
    bb = a.shape[0]
    bf = w.shape[0]
    acc = accumulate_tap_popcounts(a, w, h, wd)
    s = jnp.int32(k4) - 2 * acc                                # integer sums

    # folded comparator, in-register: output is +1 iff (s >= tau) XOR flip;
    # under the bit=1 <=> -1 convention the sign bit is the negation of that.
    ge = (s >= tau[None, None, None, :]).astype(jnp.int32)
    bits = (jnp.int32(1) - jnp.bitwise_xor(ge, flip[None, None, None, :])
            ).astype(jnp.uint32)                               # (bb,H-1,W-1,bf)

    if pool:
        # streamed 2x2/2 max-pool in the sign domain: max over +/-1 == any
        # +1 in the window == AND of the (negative-sign) bits.
        ho, wo = (h - 1) // 2, (wd - 1) // 2
        bits = bits[:, :ho * 2, :wo * 2, :].reshape(bb, ho, 2, wo, 2, bf)
        bits = bits[:, :, 0] & bits[:, :, 1]
        bits = bits[:, :, :, 0, :] & bits[:, :, :, 1, :]       # (bb, ho, wo, bf)

    return pack_bit_lanes(bits)


def conv_block_body_grouped(a, w, tau, flip, *, k4: int, h: int, wd: int,
                            pool: bool) -> jax.Array:
    """:func:`conv_block_body` vmapped over a leading sub-array axis.

    The megakernel's composite dispatch stacks members with identical
    IO+conv chains on a group axis G — G concurrent sub-arrays, each with
    its own weights/thresholds, evaluated in one fused batched
    contraction (the chip's side-by-side S-mode recombination; on TPU
    the G axis fills the lanes a single narrow sub-array would leave
    idle).  Bit-exact per group row vs the solo body by construction.

    a:    (G, bb, H, W, Cw) uint32 packed input maps.
    w:    (G, bf, 4, Cw)    uint32 packed weight taps, (dy, dx) row-major.
    tau/flip: (G, bf) int32 comparator thresholds / directions.
    Returns (G, bb, Ho, Wo, bf // 32) uint32 packed output words.
    """
    body = functools.partial(conv_block_body, k4=k4, h=h, wd=wd, pool=pool)
    return jax.vmap(body)(a, w, tau, flip)


def _conv_block_kernel(a_ref, w_ref, tau_ref, flip_ref, out_ref, *,
                       k4: int, h: int, w: int, pool: bool):
    """One (f-tile, frame-tile) grid step.

    a_ref:    (bb, H, W, Cw) uint32 packed input maps (a tile of frames).
    w_ref:    (bf, 4, Cw)    uint32 packed weight taps, (dy, dx) row-major.
    tau_ref:  (1, bf) int32 comparator thresholds; flip_ref: (1, bf) int32.
    out_ref:  (bb, Ho, Wo, bf // 32) uint32 packed output words.
    """
    out_ref[...] = conv_block_body(a_ref[...], w_ref[...], tau_ref[0],
                                   flip_ref[0], k4=k4, h=h, wd=w, pool=pool)


@functools.partial(jax.jit,
                   static_argnames=("c", "pool", "bf", "bb", "interpret"))
def binary_conv2x2_block(a_words: jax.Array, w_words: jax.Array,
                         tau: jax.Array, flip: jax.Array, *, c: int,
                         pool: bool = False, bf: int = 64, bb: int = 8,
                         interpret: bool = False) -> jax.Array:
    """Fused packed conv layer: packed words in, packed words out.

    a_words: (B, H, W, Cw) uint32 packed input feature maps (C channels).
    w_words: (F, 4, Cw) uint32 packed weights, tap order (dy, dx) row-major.
    tau:     (F,) int32 folded integer thresholds (s >= tau fires).
    flip:    (F,) comparator direction (gamma < 0), bool or int.
    c:       true channel count per tap; total dot length = 4*c.
    pool:    apply the streamed 2x2 stride-2 max-pool before repacking.
    bf, bb:  neuron / frame tile sizes.  VMEM at the worst chip shape
             (32x32 map, C=256 -> Cw=8, bb=8, bf=64): packed maps are
             tiny (bb*32 kB), but the dominant live values are the
             int32 accumulator bb*31*31*bf*4B ~ 1.9 MB and the per-tap
             xor/popcount intermediate bb*31*31*bf*Cw*4B ~ 15.7 MB if
             the compiler materializes it unfused — Mosaic normally
             fuses the popcount-reduce so the tap temporary stays
             register-resident, but when tuning for a real TPU treat
             acc (+ one fused tap row) as the budget and shrink bb/bf
             first if VMEM overflows.
    Returns (B, Ho, Wo, F // 32) uint32 — Ho = (H-1)//2 if pool else H-1.
    """
    b, h, w, kw = a_words.shape
    f, taps, kw2 = w_words.shape
    assert taps == 4 and kw == kw2, (w_words.shape, a_words.shape)
    assert f % PACK_WIDTH == 0, (
        f"fused packed output needs F % {PACK_WIDTH} == 0, got F={f}")

    bf = min(bf, f)
    bf = -(-bf // PACK_WIDTH) * PACK_WIDTH     # round up to whole words
    fp = (-f) % bf
    if fp:                                     # pad F to the tile multiple;
        w_words = jnp.pad(w_words, ((0, fp), (0, 0), (0, 0)))
        tau = jnp.pad(tau, (0, fp))            # padded words trimmed below
        flip = jnp.pad(flip, (0, fp))
    tau2 = tau.astype(jnp.int32).reshape(1, -1)
    flip2 = flip.astype(jnp.int32).reshape(1, -1)
    gf = w_words.shape[0] // bf

    bb = min(bb, b)
    bp = (-b) % bb
    if bp:                                     # pad the batch to the frame
        a_words = jnp.pad(a_words, ((0, bp), (0, 0), (0, 0), (0, 0)))
    gb = a_words.shape[0] // bb                # tile; extra frames trimmed

    ho, wo = h - 1, w - 1
    if pool:
        ho, wo = ho // 2, wo // 2
    bfw = bf // PACK_WIDTH

    out = pl.pallas_call(
        functools.partial(_conv_block_kernel, k4=4 * c, h=h, w=w, pool=pool),
        grid=(gf, gb),                          # F outermost: weights stay
        in_specs=[                              # resident across the batch
            pl.BlockSpec((bb, h, w, kw), lambda f_, b_: (b_, 0, 0, 0)),
            pl.BlockSpec((bf, 4, kw), lambda f_, b_: (f_, 0, 0)),
            pl.BlockSpec((1, bf), lambda f_, b_: (0, f_)),
            pl.BlockSpec((1, bf), lambda f_, b_: (0, f_)),
        ],
        out_specs=pl.BlockSpec((bb, ho, wo, bfw),
                               lambda f_, b_: (b_, 0, 0, f_)),
        out_shape=jax.ShapeDtypeStruct(
            (a_words.shape[0], ho, wo, w_words.shape[0] // PACK_WIDTH),
            jnp.uint32),
        interpret=interpret,
    )(a_words, w_words, tau2, flip2)
    return out[:b, :, :, :f // PACK_WIDTH]

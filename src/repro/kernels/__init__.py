"""Pallas TPU kernels for the BinarEye binary-compute hot spots.

Layout (per repo convention):
  <name>.py -- pl.pallas_call + BlockSpec kernel
  ops.py    -- jit'd public wrappers (auto interpret on CPU)
  ref.py    -- pure-jnp oracles the kernels are tested against

Inference kernels keep feature maps in the bit-packed uint32 domain
end-to-end (the chip's all-memory-on-chip property mapped to VMEM):
  binarize_pack        -- fused sign+pack producer (the single IO pack)
  binary_conv2x2       -- packed conv -> int32 sums (training/reference)
  binary_conv2x2_block -- fused conv -> threshold -> pool -> repack;
                          packed words in, packed words out
  xnor_matmul          -- packed FC; ``pack_out=True`` fuses sign+pack
                          for hidden layers so only the final logits
                          are ever unpacked
"""

"""Pallas TPU kernels for the BinarEye binary-compute hot spots.

Layout (per repo convention):
  <name>.py -- pl.pallas_call + BlockSpec kernel
  ops.py    -- jit'd public wrappers (auto interpret on CPU)
  ref.py    -- pure-jnp oracles the kernels are tested against
"""

"""Flash attention (forward) as a Pallas TPU kernel.

This is the structural fix for the dominant memory-roofline term of every
full-attention train/prefill cell (EXPERIMENTS.md §Perf): the S^2-sized
score/probability tensors never leave VMEM, so HBM traffic drops from
O(S^2 * heads) to O(S * d) — q, k, v, o only.  The JAX-level chunked
attention (models/attention.py) is the oracle and the CPU/dry-run path;
this kernel is the TPU deployment path (Pallas cannot compile on the CPU
backend — validated with interpret=True in tests/test_kernels_flash.py).

Layout: grid = (B * KH, num_q_blocks, num_k_blocks), k innermost so the
(m, l, acc) scratch carries across k-steps of one q-block (TPU grid
iteration is sequential).  GQA: the G query heads of one KV head are
folded into the q-block rows.  Causal blocks beyond the diagonal are
skipped via the index map visiting only the lower triangle... kept simple
here: masked out in-kernel (Mosaic still skips fully-masked matmuls'
writes); the block-sparse schedule is the JAX-level chunker's job.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
                      bq: int, bk: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0]                       # (bq*G, d) — row r = (qoff=r//G, g=r%G)
    k = k_ref[0]                       # (bk, d)
    v = v_ref[0]                       # (bk, d)
    g = q.shape[0] // bq
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        rows = (jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // g
                + qi * bq)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) + ki * bk
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_sc[...] = l_sc[...] * alpha + p.sum(axis=1)
    acc_sc[...] = acc_sc[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_sc[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0] = (acc_sc[...] /
                    jnp.maximum(l_sc[...], 1e-37)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True,
                        block_q: int = 256, block_k: int = 256,
                        scale: float | None = None,
                        interpret: bool | None = None):
    """q: (B, S, H, D); k/v: (B, S, KH, D) -> (B, S, H, D).

    The G = H // KH query heads sharing a KV head are folded into the
    q-block rows so one grid step computes a (bq*G, bk) score tile.
    """
    from repro.kernels import ops
    if interpret is None:
        interpret = ops.default_interpret()
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)

    # query rows (qpos, g)-interleaved: row = qpos * G + g, so a block of
    # bq*G rows covers exactly q positions [i*bq, (i+1)*bq) for all G heads
    qr = q.reshape(b, s, kh, g, d).transpose(0, 2, 1, 3, 4)  # (B,KH,S,G,D)
    qr = qr.reshape(b * kh, s * g, d)
    kr = k.transpose(0, 2, 1, 3).reshape(b * kh, s, d)
    vr = v.transpose(0, 2, 1, 3).reshape(b * kh, s, d)

    nq, nk = s // bq, s // bk
    grid = (b * kh, nq, nk)

    out = pl.pallas_call(
        functools.partial(_flash_fwd_kernel, bq=bq, bk=bk, causal=causal,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g * bq, d), lambda h_, i, j: (h_, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h_, i, j: (h_, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h_, i, j: (h_, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g * bq, d), lambda h_, i, j: (h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kh, g * s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq,), jnp.float32),
            pltpu.VMEM((g * bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)

    out = out.reshape(b, kh, s, g, d).transpose(0, 2, 1, 3, 4)
    return out.reshape(b, s, h, d)

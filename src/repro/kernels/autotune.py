"""Persistent tile autotuner: measure-and-cache kernel tile sizes.

The chip has one fixed datapath; the TPU mapping has schedule knobs —
the megakernel's frame-tile ``bb`` and conv f-tile ``ft``, the staged conv
kernel's neuron/frame tiles ``bf``/``bb`` — whose best values depend on
the (program, backend, batch) triple (VMEM headroom vs per-step overhead
trade exactly like ChewBaccaNN's tiling/scheduling match between network
shape and datapath).  This module owns that choice:

* ``tune_mega`` / ``tune_staged_conv`` measure a small candidate grid on
  the live backend and record the winner.
* The cache is a flat JSON file (default ``BENCH_autotune.json`` in the
  CWD, override with ``REPRO_AUTOTUNE_CACHE``) keyed by
  ``kind/program-fingerprint/batch/backend-fingerprint``.  The program
  fingerprint hashes the *assembled instruction words* plus S — two
  programs with identical SRAM geometry share an entry; the backend
  fingerprint pins platform + device kind + host ISA, so a cache tuned on
  one machine class never silently mis-tunes another.
* ``mega_tiles`` / ``composite_tiles`` / ``conv_tiles`` are the read
  side, consulted by ``InferencePlan.forward``/``forward_mega`` and
  ``CompositePlan.forward`` at trace time: explicit arguments win, then
  an exact cache hit, then the nearest-batch entry for the same
  program+backend, and a cold cache falls back to the historical
  defaults — tuning is always a pure perf choice, never a numeric one.
* Entry keys carry a schema version prefix (``v2/...``): when the tuned
  fields or the kernel schedule they describe change shape (e.g. v2
  added per-member-group composite f-tiles and the member-DMA/compute
  overlap), the version bumps and every stale entry silently degrades
  to the cold-cache defaults instead of mis-steering the new kernel —
  a stale ``BENCH_autotune.json`` is never an error, just cold.

The bench job ships the cache next to ``BENCH_kernels.json`` so CI (and
the next session) start warm.
"""

from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Dict, Iterable, Optional

import jax

from repro.core.chip import isa

DEFAULT_CACHE = "BENCH_autotune.json"
CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
SCHEMA = 2          # bump when tuned fields / kernel schedule change shape

# the pre-autotuner defaults, kept as the documented cold-cache behaviour
DEFAULTS = {
    "mega": {"bb": 8, "ft": 0},
    "staged_conv": {"bf": 64, "bb": 8},
}

_cache: Optional[Dict[str, dict]] = None
_cache_file: Optional[str] = None


def cache_path() -> str:
    return os.environ.get(CACHE_ENV, DEFAULT_CACHE)


def backend_fingerprint() -> str:
    """Platform + device kind + host ISA: the machine class a measurement
    is valid for (mirrors the bench baseline's ``host`` fingerprint)."""
    import platform
    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", "unknown").replace(" ", "_")
    return f"{jax.default_backend()}:{kind}:{platform.machine()}"


def program_key(program: isa.Program) -> str:
    """Fingerprint of the assembled program words + S (the SRAM geometry:
    identical instruction streams tune identically)."""
    words = isa.assemble(program)
    return hashlib.sha1(words.tobytes()
                        + bytes([program.s])).hexdigest()[:12]


def composite_key(programs: Iterable[isa.Program]) -> str:
    """Order-sensitive fingerprint of a composite's member programs."""
    joined = "+".join(program_key(p) for p in programs)
    return "comp-" + hashlib.sha1(joined.encode()).hexdigest()[:12]


def _entry_key(kind: str, pkey: str, batch: int) -> str:
    # the vN prefix versions the schema: entries written for an older
    # kernel schedule never match and degrade gracefully to defaults
    return f"v{SCHEMA}/{kind}/{pkey}/b{int(batch)}/{backend_fingerprint()}"


def _load() -> Dict[str, dict]:
    global _cache, _cache_file
    path = cache_path()
    if _cache is None or _cache_file != path:
        try:
            with open(path) as f:
                _cache = json.load(f)
        except (FileNotFoundError, json.JSONDecodeError, OSError):
            _cache = {}
        if not isinstance(_cache, dict):
            # valid JSON but not a cache (e.g. a truncated/foreign file):
            # degrade to cold — the cache may only ever change perf
            _cache = {}
        _cache_file = path
    return _cache


def invalidate() -> None:
    """Drop the in-process cache (tests / after an external refresh)."""
    global _cache, _cache_file
    _cache, _cache_file = None, None


def lookup(kind: str, pkey: str, batch: int) -> Optional[dict]:
    """Exact (kind, program, batch, backend) entry, else the same
    program+backend's nearest-batch entry, else None (cold)."""
    cache = _load()
    hit = cache.get(_entry_key(kind, pkey, batch))
    if hit is not None:
        return hit
    prefix = f"v{SCHEMA}/{kind}/{pkey}/b"
    suffix = f"/{backend_fingerprint()}"
    nearest = None
    for key, entry in cache.items():
        if not (key.startswith(prefix) and key.endswith(suffix)):
            continue
        try:
            b = int(key[len(prefix):len(key) - len(suffix)])
        except ValueError:
            continue
        d = abs(b - batch)
        if nearest is None or d < nearest[0]:
            nearest = (d, entry)
    return nearest[1] if nearest else None


def record(kind: str, pkey: str, batch: int, entry: dict) -> dict:
    """Persist a tuned entry (merged into the JSON cache file)."""
    cache = _load()
    cache[_entry_key(kind, pkey, batch)] = dict(entry)
    path = cache_path()
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=2, sort_keys=True)
    os.replace(tmp, path)
    return cache[_entry_key(kind, pkey, batch)]


# ---------------------------------------------------------------------------
# Read side: tile resolution (explicit args > cache > defaults)
# ---------------------------------------------------------------------------

def _resolve(kind: str, pkey: str, batch: int, **overrides):
    """Shared resolution: each ``None`` override falls through to the
    cache entry, then to ``DEFAULTS[kind]``; explicit values always win.
    Field names come from DEFAULTS[kind] (insertion order)."""
    defaults = DEFAULTS[kind]
    entry = (lookup(kind, pkey, batch) or {}
             if any(v is None for v in overrides.values()) else {})
    return tuple(int(entry.get(f, defaults[f])) if overrides[f] is None
                 else overrides[f] for f in defaults)


def mega_tiles(program: isa.Program, batch: int,
               bb: Optional[int] = None, ft: Optional[int] = None):
    """(bb, ft) for the solo megakernel on ``program`` at ``batch``."""
    return _resolve("mega", program_key(program), batch, bb=bb, ft=ft)


def composite_tiles(programs: Iterable[isa.Program], batch: int,
                    bb: Optional[int] = None, ft=None, *,
                    per_group: bool = False, n_groups: Optional[int] = None):
    """(bb, ft) for a composite dispatch of ``programs`` at ``batch``.

    Default resolution returns the composite's single tuned ``ft``.
    With ``per_group=True`` (and ``n_groups``, the member-group count of
    the composite's spec) a tuned per-group entry (``ftg``) resolves to
    a tuple with one f-tile per group; entries whose group count doesn't
    match (or predate per-group tuning) fall back to the global ``ft``.
    Explicit arguments always win, in either form.
    """
    if ft is not None:
        return (_resolve("mega", composite_key(programs), batch,
                         bb=bb, ft=0)[0], ft)
    pkey = composite_key(programs)
    bb_r, ft_r = _resolve("mega", pkey, batch, bb=bb, ft=ft)
    if per_group:
        entry = lookup("mega", pkey, batch) or {}
        ftg = entry.get("ftg")
        if isinstance(ftg, (list, tuple)) and (
                n_groups is None or len(ftg) == n_groups):
            return bb_r, tuple(int(f) for f in ftg)
    return bb_r, ft_r


def conv_tiles(program: isa.Program, batch: int,
               bf: Optional[int] = None, bb: Optional[int] = None):
    """(bf, bb) for the staged fused conv kernel."""
    return _resolve("staged_conv", program_key(program), batch, bf=bf, bb=bb)


# ---------------------------------------------------------------------------
# Write side: measure-and-cache tuners
# ---------------------------------------------------------------------------

def _time_us(fn, *args, iters: int = 3) -> float:
    """Best-of-iters wall time (us); min is the least noisy estimator on a
    shared host (contention only ever adds time)."""
    jax.block_until_ready(fn(*args))              # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _ft_candidates(f: int, candidates) -> list:
    """Valid f-tile sizes for an F-wide conv stack (0 = untiled), rounded
    the same way the kernel rounds them (whole packed words) so cached
    winners match the measured configurations exactly."""
    from repro.core.binarize import PACK_WIDTH
    out = {0}
    for ft in candidates:
        if ft and ft < f:
            out.add(max(PACK_WIDTH, ft // PACK_WIDTH * PACK_WIDTH))
    return sorted(out)


def tune_mega(plan, image, frames, *, bb_candidates=(2, 4, 8, 16, 32),
              ft_candidates=(0, 16, 32, 64, 128, 256), iters: int = 3,
              interpret: Optional[bool] = None) -> dict:
    """Measure the megakernel candidate grid for ``plan`` on this backend
    and cache the winner under (program, backend, batch).  Returns the
    recorded entry ({"bb", "ft", "us"})."""
    program = plan.program
    batch = frames.shape[0]
    f = isa.ARRAY_CHANNELS // program.s
    best = None
    for bb in sorted({min(b, batch) for b in bb_candidates}):
        for ft in _ft_candidates(f, ft_candidates):
            def fwd(image, frames, _bb=bb, _ft=ft):
                return plan.forward_mega(image, frames, interpret=interpret,
                                         bb=_bb, ft=_ft)
            us = _time_us(jax.jit(fwd), image, frames, iters=iters)
            if best is None or us < best[0]:
                best = (us, bb, ft)
    entry = {"bb": best[1], "ft": best[2], "us": round(best[0], 1)}
    return record("mega", program_key(program), batch, entry)


def tune_composite(cplan, image, frames, *, bb_candidates=(2, 4, 8, 16, 32),
                   ft_candidates=(0, 16, 32, 64, 128), iters: int = 3,
                   per_group: bool = True,
                   interpret: Optional[bool] = None) -> dict:
    """Tune a composite's (bb, ft) and cache under the composite
    fingerprint.

    Phase 1 sweeps one global (bb, ft) grid exactly like ``tune_mega``.
    Phase 2 (``per_group=True``, the default) refines each member
    *group's* f-tile independently around the phase-1 winner — groups of
    different sub-array widths (a 2xS2 group next to two S=4 singletons,
    say) rarely share a best ``ft``.  The entry records both: ``ft`` is
    the global winner (what pre-per-group readers resolve), ``ftg`` the
    per-group tuple (what ``CompositePlan.forward`` resolves).
    """
    from repro.kernels.megakernel import member_groups

    frames = tuple(frames)
    batch = max(f.shape[0] for f in frames)
    fmin = min(isa.ARRAY_CHANNELS // p.s for p in cplan.programs)
    groups = member_groups(cplan.spec)

    def timed(bb, ft):
        def fwd(image, frames, _bb=bb, _ft=ft):
            return cplan.forward(image, frames, interpret=interpret,
                                 bb=_bb, ft=_ft)
        return _time_us(jax.jit(fwd), image, frames, iters=iters)

    best = None
    for bb in sorted({min(b, batch) for b in bb_candidates}):
        for ft in _ft_candidates(fmin, ft_candidates):
            us = timed(bb, ft)
            if best is None or us < best[0]:
                best = (us, bb, ft)
    best_us, bb, ft = best

    ftg = [ft] * len(groups)
    if per_group and len(groups) > 1:
        for gi, group in enumerate(groups):
            # this group's conv width bounds its valid f-tiles
            convs = [st[4] for st in cplan.spec[group[0]]
                     if st[0] == "conv"]
            fg = min(convs) if convs else 0
            for cand in _ft_candidates(fg, ft_candidates) if fg else [0]:
                if cand == ftg[gi]:
                    continue
                trial = tuple(ftg[:gi] + [cand] + ftg[gi + 1:])
                us = timed(bb, trial)
                if us < best_us:
                    best_us, ftg[gi] = us, cand
    entry = {"bb": bb, "ft": ft, "ftg": list(ftg),
             "us": round(best_us, 1)}
    return record("mega", composite_key(cplan.programs), batch, entry)


def tune_staged_conv(plan, packed, frames, *,
                     bf_candidates=(16, 32, 64, 128, 256),
                     bb_candidates=(2, 4, 8, 16, 32), iters: int = 3,
                     interpret: Optional[bool] = None) -> dict:
    """Tune the staged pipeline's fused-conv (bf, bb) tiles for ``plan``
    and cache under (program, backend, batch)."""
    program = plan.program
    batch = frames.shape[0]
    f = isa.ARRAY_CHANNELS // program.s
    best = None
    for bf in sorted({min(c, f) for c in bf_candidates}):
        for bb in sorted({min(c, batch) for c in bb_candidates}):
            def fwd(packed, frames, _bf=bf, _bb=bb):
                return plan.forward(packed, frames, interpret=interpret,
                                    conv_tiles=(_bf, _bb))
            us = _time_us(jax.jit(fwd), packed, frames, iters=iters)
            if best is None or us < best[0]:
                best = (us, bf, bb)
    entry = {"bf": best[1], "bb": best[2], "us": round(best[0], 1)}
    return record("staged_conv", program_key(program), batch, entry)

"""Pallas TPU megakernel: whole networks resident in VMEM, per frame tile.

BinarEye "stores full network models and feature maps and hence requires no
off-chip bandwidth": weights sit in the 259 kB SRAM, feature maps ping-pong
between the west/east 32 kB feature SRAMs, and the only off-chip traffic is
the image in and the label out.  The staged ``InferencePlan`` lost that on
TPU — one ``pallas_call`` per layer means every packed feature map takes an
HBM round trip between stages.  This kernel restores the chip's execution
model in one ``pallas_call``:

* **SRAM image in VMEM.**  All packed conv weight words + int32 comparator
  thresholds + packed FC weights for *every* layer enter as VMEM-resident
  operands (constant index maps: fetched once, resident across the grid) —
  the TPU analogue of the weight SRAM contents.  For the worst chip shape
  (cifar9 at S=1) the conv image is 8 x 256x4x8 words = 262 kB, within 1%
  of the chip's 259 kB weight SRAM.
* **Feature maps stay in VMEM.**  Inter-layer maps are kernel-resident
  values — Mosaic allocates them out of VMEM, the analogue of the chip's
  west/east feature SRAMs — and never touch HBM.
* **Double-buffered frame streaming.**  The grid iterates frame tiles;
  raw frames stay in HBM (``memory_space=ANY``) and are streamed tile by
  tile with manual ``make_async_copy``/wait into a 2-slot VMEM buffer, so
  tile N+1 DMAs in while tile N computes; logits DMA out the same way.
  The IO thermometer encode runs in-kernel on the raw integer pixels, so
  the only HBM traffic of the whole network is frames in, logits out.
* **f-tiled conv.**  Each conv layer's F output neurons are computed in
  chunks of ``ft`` (``ft=0`` = all F in one chunk).  Tiling is a pure
  schedule choice — packed output words concatenate to the identical
  result — but it bounds the dominant live value, the int32 accumulator
  ``bb*(H-1)*(W-1)*ft*4B``, which is the S=1 VMEM-headroom knob.  The
  best ``bb``/``ft`` per (program, backend, batch) comes from the
  persistent autotune cache (``kernels.autotune``); a composite accepts
  one ``ft`` per member *group* (groups of different sub-array widths
  want different f-tiles), as a tuple in ``member_groups`` order.
* **Multi-program composite dispatch (sub-array sharing).**  When several
  resident programs' S-modes tile the 256-channel array exactly (4xS4,
  2xS2, 2xS4+1xS2, ...), their weight images pack side-by-side on the F
  axis into ONE composite SRAM image and their frame streams run through
  ONE ``pallas_call`` per batch — the chip's concurrent sub-array
  recombination, not time-interleaved whole-array dispatches.  Each
  member computes on its own disjoint F range (and its own feature maps);
  members with identical IO+conv chains are additionally *grouped*: their
  maps stack on a leading sub-array axis and one fused conv evaluates all
  of them — the lanes the solo S=4 dispatch leaves idle now carry the
  other sub-arrays.

The per-layer arithmetic is ``binary_conv2x2_block.conv_block_body`` (and
its grouped twin) — the staged path's exact function — so all execution
modes are bit-exact by construction (tested, ``tests/test_megakernel.py``
and ``tests/test_composite.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binarize import (PACK_WIDTH, pack_bit_lanes,
                                 thermometer_pack, xnor_dot_popcount)
from repro.kernels.binary_conv2x2_block import (conv_block_body,
                                                conv_block_body_grouped)

# Member stage spec entries (hashable; built by interpreter):
#   ("io",   h, w, cin, bits, channels)
#   ("conv", h, w, c, f, pool, f_off)      h/w = input map size; f_off =
#                                          the member's row offset on the
#                                          composite image's F axis
#   ("fc",   k, n, final, pack_out, n_off) n_off = row offset on the
#                                          composite FC image's N axis
# A composite spec is a tuple of member specs; the solo megakernel is the
# one-member special case (offsets 0), so both paths share one kernel.


def _solo_member_spec(spec):
    """Lift ``InferencePlan.mega``'s offset-less stage tuples to a
    one-member composite spec (all offsets 0)."""
    return (tuple(st if st[0] == "io" else st + (0,) for st in spec),)


def _f_tiles(f: int, ft: int):
    """Static (offset, length) chunks of the F axis; ft=0 -> one chunk."""
    if not ft or ft >= f:
        return ((0, f),)
    ft = max(PACK_WIDTH, ft // PACK_WIDTH * PACK_WIDTH)
    return tuple((f0, min(ft, f - f0)) for f0 in range(0, f, ft))


def _fc_body(x, wfc, k: int):
    """Packed FC on values: (bb, Kw) x (N, Kw) -> (bb, N) int32 sums."""
    return xnor_dot_popcount(x[:, None, :], wfc[None, :, :], k)


def _run_fc_tail(fm, fw, fc_stages):
    """The FC chain of one member on a VMEM-resident map/row value."""
    x = fm.reshape(fm.shape[0], -1) if fm.ndim == 4 else fm
    for fi, st in enumerate(fc_stages):
        _, k, n, final, _pack_out, n_off = st
        kw = -(-k // PACK_WIDTH)
        s = _fc_body(x, fw[fi, n_off:n_off + n, :kw], k)
        if final:
            return s
        if n % PACK_WIDTH == 0:
            x = pack_bit_lanes((s < 0).astype(jnp.uint32))
        else:                  # odd-width hidden FC: sign, pad, repack
            bits_ = (s < 0).astype(jnp.uint32)
            bits_ = jnp.pad(bits_, ((0, 0), (0, (-n) % PACK_WIDTH)))
            x = pack_bit_lanes(bits_)
    raise AssertionError("member spec must end with a final FC stage")


def _split_stages(stages):
    """(io+conv prefix, fc tail) of a member spec."""
    n = sum(1 for st in stages if st[0] != "fc")
    return stages[:n], stages[n:]


def _run_member(frames, cw, ct, cf, fw, stages, ft):
    """One member's whole-network pipeline on one VMEM frame tile.

    ``frames``: (bb, H, W, Cin) int32 raw pixels; ``cw``/``ct``/``cf``/
    ``fw``: the (composite) SRAM image — the member reads its own F rows
    via the spec's static offsets.  Returns (bb, classes) int32 logits.
    """
    head, tail = _split_stages(stages)
    ci = 0
    fm = None
    for st in head:
        if st[0] == "io":
            _, h, w, cin, bits, channels = st
            fm = thermometer_pack(frames, bits, cin, channels)
        else:
            _, h, w, c, f, pool, f_off = st
            cwp = c // PACK_WIDTH
            chunks = [
                conv_block_body(fm, cw[ci, f_off + f0:f_off + f0 + fl, :, :cwp],
                                ct[ci, f_off + f0:f_off + f0 + fl],
                                cf[ci, f_off + f0:f_off + f0 + fl],
                                k4=4 * c, h=h, wd=w, pool=pool)
                for f0, fl in _f_tiles(f, ft)]
            fm = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks, -1)
            ci += 1
    return _run_fc_tail(fm, fw, tail)


def _run_group(tiles, cw, ct, cf, fw, specs, ft):
    """Members with identical IO+conv chains, run as stacked sub-arrays.

    Their frame tiles stack on a leading sub-array axis and every conv
    evaluates all of them in one fused contraction — side-by-side F-axis
    occupancy instead of one idle-laned sub-array at a time.  FC tails
    (which may differ per member) run per member.  Returns the members'
    logits in ``specs`` order.
    """
    head, _ = _split_stages(specs[0])
    ci = 0
    fmg = None
    for idx, st in enumerate(head):
        if st[0] == "io":
            _, h, w, cin, bits, channels = st
            fmg = thermometer_pack(jnp.stack(tiles), bits, cin, channels)
        else:
            _, h, w, c, f, pool, _ = st
            g = len(specs)
            cwp = c // PACK_WIDTH
            offs = [sp[idx][6] for sp in specs]
            # adjacent members (the common case: pack_programs assigns F
            # offsets in member order) form one contiguous slab — slice
            # + reshape instead of gathering G strided slices per grid
            # step / f-tile
            contiguous = (ft == 0 or ft >= f) and all(
                o == offs[0] + gi * f for gi, o in enumerate(offs))

            def rows(img, f0, fl, width=None):
                if contiguous:
                    slab = (img[ci, offs[0]:offs[0] + g * f, :, :width]
                            if width else img[ci, offs[0]:offs[0] + g * f])
                    return slab.reshape((g, f) + slab.shape[1:])
                if width:
                    return jnp.stack([img[ci, o + f0:o + f0 + fl, :, :width]
                                      for o in offs])
                return jnp.stack([img[ci, o + f0:o + f0 + fl] for o in offs])

            chunks = []
            for f0, fl in _f_tiles(f, ft):
                chunks.append(conv_block_body_grouped(
                    fmg, rows(cw, f0, fl, cwp), rows(ct, f0, fl),
                    rows(cf, f0, fl), k4=4 * c, h=h, wd=w, pool=pool))
            fmg = chunks[0] if len(chunks) == 1 else jnp.concatenate(chunks, -1)
            ci += 1
    return [_run_fc_tail(fmg[g], fw, _split_stages(sp)[1])
            for g, sp in enumerate(specs)]


def _member_groups(spec):
    """Partition member indices into sub-array groups: members whose
    IO+conv chains are shape-identical (F offsets stripped) stack into one
    grouped conv; singletons run the plain member body."""
    classes = {}
    for m, stages in enumerate(spec):
        head, _ = _split_stages(stages)
        key = tuple(st[:6] for st in head)     # strips the conv f_off
        classes.setdefault(key, []).append(m)
    return tuple(tuple(v) for v in classes.values())


def member_groups(spec):
    """Public alias of :func:`_member_groups`: the composite's sub-array
    groups, in the order per-group tile overrides (``ft`` tuples) index."""
    return _member_groups(spec)


def _group_ft(ft, gi: int) -> int:
    """Resolve the f-tile for member group ``gi``: a plain int applies to
    every group, a tuple carries one entry per group."""
    return ft[gi] if isinstance(ft, tuple) else ft


def _run_members(read, cw, ct, cf, fw, spec, ft, wait=None):
    """All members of a composite on their VMEM frame tiles -> logits.

    ``read(m)`` yields member m's frame tile; ``wait(m)`` (when given)
    blocks on member m's input DMA and is called immediately before the
    member's group computes — so member group k+1's copy keeps streaming
    while group k convolves, instead of every member's DMA completing
    before any compute starts.
    """
    logits = [None] * len(spec)
    for gi, group in enumerate(_member_groups(spec)):
        if wait is not None:
            for m in group:
                wait(m)
        gft = _group_ft(ft, gi)
        if len(group) == 1:
            m, = group
            logits[m] = _run_member(read(m), cw, ct, cf, fw, spec[m], gft)
        else:
            outs = _run_group([read(m) for m in group], cw, ct, cf, fw,
                              [spec[m] for m in group], gft)
            for m, lg in zip(group, outs):
                logits[m] = lg
    return logits


def _composite_kernel(*refs, spec, bb: int, n_tiles: int, ft: int):
    """One frame-tile grid step: per-member 2-slot input/output DMA
    pipelining around the fused multi-member compute."""
    nm = len(spec)
    frames_hbm = refs[:nm]
    cw_ref, ct_ref, cf_ref, fw_ref = refs[nm:nm + 4]
    out_hbm = refs[nm + 4:nm + 4 + nm]
    sc = refs[nm + 4 + nm:]
    fbuf, obuf = sc[:nm], sc[nm:2 * nm]
    in_sem, out_sem = sc[2 * nm:3 * nm], sc[3 * nm:4 * nm]

    i = pl.program_id(0)
    slot = jax.lax.rem(i, 2)
    nxt = jax.lax.rem(i + 1, 2)

    def in_copy(p, s, t):
        return pltpu.make_async_copy(
            frames_hbm[p].at[pl.ds(t * bb, bb)], fbuf[p].at[s],
            in_sem[p].at[s])

    def out_copy(p, s, t):
        return pltpu.make_async_copy(
            obuf[p].at[s], out_hbm[p].at[pl.ds(t * bb, bb)], out_sem[p].at[s])

    @pl.when(i == 0)                     # warm-up: every member's tile 0
    def _():
        for p in range(nm):
            in_copy(p, 0, 0).start()

    @pl.when(i + 1 < n_tiles)            # tile N+1 streams while N computes
    def _():
        for p in range(nm):
            in_copy(p, nxt, jnp.minimum(i + 1, n_tiles - 1)).start()

    # input waits are issued per member group, right before that group's
    # compute (_run_members): member group k+1's DMA keeps streaming while
    # group k convolves — the chip's IO-pads-during-CONV overlap, per
    # sub-array — instead of a barrier on every member's copy up front.
    logits = _run_members(lambda p: fbuf[p][slot],
                          cw_ref[...], ct_ref[...], cf_ref[...], fw_ref[...],
                          spec, ft,
                          wait=lambda p: in_copy(p, slot, i).wait())

    if n_tiles > 2:                      # drain the DMA issued 2 tiles ago
        @pl.when(i >= 2)                 # before reusing its slot
        def _():
            for p in range(nm):
                out_copy(p, slot, jnp.maximum(i - 2, 0)).wait()
    for p in range(nm):
        obuf[p][slot] = logits[p]
        out_copy(p, slot, i).start()

    @pl.when(i == n_tiles - 1)           # final tile: drain everything
    def _():
        for p in range(nm):
            out_copy(p, slot, i).wait()
    if n_tiles > 1:
        @pl.when(i == n_tiles - 1)
        def _():
            for p in range(nm):
                out_copy(p, 1 - slot, i - 1).wait()


@functools.partial(jax.jit, static_argnames=("spec", "bb", "ft", "interpret"))
def composite_forward(image, frames, *, spec, bb: int = 8, ft=0,
                      interpret: bool = False):
    """Multi-program packed inference in a single resident ``pallas_call``.

    image:  the composite weight image (``interpreter.pack_programs``) —
            or a member's own image for the one-member (solo) case:
            ``cw`` (Lc, F_total, 4, Cw) uint32 conv words, ``ct``/``cf``
            (Lc, F_total) int32 thresholds/directions, ``fw``
            (Lf, N_total, Kw) uint32 padded FC words.
    frames: tuple of (B_m, H_m, W_m, Cin_m) integer images, one per
            member; ragged B_m are padded to the longest member's batch
            (padding frames compute garbage that is trimmed on return).
    spec:   static tuple of member stage specs (see module header).
    bb:     frame-tile size (the double-buffered streaming granule).
    ft:     conv f-tile size; 0 = all F per chunk.  A tuple carries one
            f-tile per *member group* (``member_groups(spec)`` order) —
            groups with different sub-array widths tune separately.
    Returns a tuple of (B_m, classes_m) int32 logits, one per member.
    """
    assert len(frames) == len(spec), (len(frames), len(spec))
    if isinstance(ft, tuple):
        n_groups = len(_member_groups(spec))
        if len(ft) != n_groups:
            raise ValueError(
                f"per-group ft {ft} carries {len(ft)} entries for "
                f"{n_groups} member groups")
    bs = [f.shape[0] for f in frames]
    bmax = max(bs)
    bb = max(1, min(bb, bmax))
    bpad = -(-bmax // bb) * bb
    n_tiles = bpad // bb

    padded = []
    for f in frames:
        f = f.astype(jnp.int32)
        if f.shape[0] != bpad:
            f = jnp.pad(f, ((0, bpad - f.shape[0]),) + ((0, 0),) * 3)
        padded.append(f)

    ncls = []
    geom = []
    for stages in spec:
        io = stages[0]
        assert io[0] == "io", stages
        geom.append((io[1], io[2], io[3]))
        final = stages[-1]
        assert final[0] == "fc" and final[3], stages
        ncls.append(final[2])

    def resident(arr):                   # whole array, fetched once
        nd = arr.ndim
        return pl.BlockSpec(arr.shape, lambda i, _n=nd: (0,) * _n)

    nm = len(spec)
    outs = pl.pallas_call(
        functools.partial(_composite_kernel, spec=spec, bb=bb,
                          n_tiles=n_tiles, ft=ft),
        grid=(n_tiles,),
        in_specs=(
            [pl.BlockSpec(memory_space=pltpu.ANY)] * nm      # frames: HBM
            + [resident(image["cw"]), resident(image["ct"]),
               resident(image["cf"]), resident(image["fw"])]),
        out_specs=[pl.BlockSpec(memory_space=pltpu.ANY)] * nm,
        out_shape=[jax.ShapeDtypeStruct((bpad, n), jnp.int32) for n in ncls],
        scratch_shapes=(
            [pltpu.VMEM((2, bb, h, w, c), jnp.int32) for h, w, c in geom]
            + [pltpu.VMEM((2, bb, n), jnp.int32) for n in ncls]
            + [pltpu.SemaphoreType.DMA((2,)) for _ in range(2 * nm)]),
        interpret=interpret,
    )(*padded, image["cw"], image["ct"], image["cf"], image["fw"])
    outs = outs if isinstance(outs, (list, tuple)) else [outs]
    return tuple(o[:b] for o, b in zip(outs, bs))


# ---------------------------------------------------------------------------
# In-kernel conditional cascade: detector -> escalation queue -> recognizer
# ---------------------------------------------------------------------------

def _member_ft(ft, spec, m: int):
    """Member ``m``'s conv f-tile: a plain int applies everywhere, a
    tuple carries one entry per member *group* (``member_groups`` order)."""
    if not isinstance(ft, tuple):
        return ft
    for gi, group in enumerate(_member_groups(spec)):
        if m in group:
            return ft[gi]
    raise AssertionError(f"member {m} not in any group of {spec}")


def bounded_drain_loop(cond_fun, chunk_fun, n_chunks: int,
                       check_every: int = 1) -> None:
    """Drain up to ``n_chunks`` work chunks, re-checking the live
    condition every ``check_every`` chunks — the while_loop-with-a-
    limited-cond idiom made jittable: the trip count is static
    (``n_chunks`` bounds the queue), early exit is a *predicated skip*
    rather than a data-dependent trip count, and the condition is
    evaluated once per chunk group instead of once per chunk (the
    ``k``-step re-check that amortizes the cond when chunks are cheap).

    ``cond_fun(g0)`` must return a scalar bool — "is there still work at
    or beyond chunk ``g0``" — and ``chunk_fun(c)`` performs chunk ``c``'s
    effects (ref stores, DMA); both run *inside* a Pallas kernel: the
    group skip lowers to ``pl.when`` and the intra-group sweep to a
    ``lax.fori_loop``, so a drained queue skips whole groups of
    recognizer work at trace-free runtime cost.
    """
    if check_every < 1:
        raise ValueError(f"check_every must be >= 1, got {check_every}")
    for g0 in range(0, n_chunks, check_every):
        n = min(check_every, n_chunks - g0)

        @pl.when(cond_fun(g0))
        def _(g0=g0, n=n):
            jax.lax.fori_loop(0, n,
                              lambda k, c: (chunk_fun(g0 + k), c)[1], 0)


def _cascade_kernel(frames_hbm, ctrl_ref, cw_ref, ct_ref, cf_ref, fw_ref,
                    det_out, rec_out, queue, count,
                    fbuf, gbuf, in_sem, g_sem,
                    *, spec, bb: int, rb: int, bpad: int,
                    check_every: int, positive_class: int, ft):
    """One grid step of the fused detector->recognizer cascade.

    Grid = (n_det_tiles + 1,): every step but the last streams one
    detector frame tile (2-slot double-buffered DMA, exactly the
    composite kernel's pipeline), runs the detector member, writes its
    logits, and *escalates in-kernel* — the integer logit margin
    (positive-class logit minus the best competitor) is compared against
    the ``ctrl`` threshold and winning frame indices are compacted into
    the VMEM escalation ``queue`` (count[0, 0] = queue depth).  The
    final step drains the queue through the recognizer member in chunks
    of ``rb`` via :func:`bounded_drain_loop`: each live chunk gathers
    its frames from HBM by queue index (per-lane dynamic-slice DMA),
    runs the recognizer, and stores logits at the chunk's queue rows
    (compacted layout: recognizer row k answers queue entry k).
    count[0, 1] counts recognizer frame slots actually computed — the
    energy bill's escalated + chunk-padding figure, reported back to the
    host as a scalar output.
    """
    n_det = bpad // bb
    n_chunks = -(-bpad // rb)
    det_spec, rec_spec = spec
    i = pl.program_id(0)
    slot = jax.lax.rem(i, 2)
    nxt = jax.lax.rem(i + 1, 2)

    def in_copy(s, t):
        return pltpu.make_async_copy(frames_hbm.at[pl.ds(t * bb, bb)],
                                     fbuf.at[s], in_sem.at[s])

    @pl.when(i == 0)                     # init + warm-up DMA for tile 0
    def _():
        count[...] = jnp.zeros_like(count)
        queue[...] = jnp.zeros_like(queue)
        rec_out[...] = jnp.zeros_like(rec_out)
        in_copy(0, 0).start()

    @pl.when(i + 1 < n_det)              # tile N+1 streams while N computes
    def _():
        in_copy(nxt, i + 1).start()

    thr = ctrl_ref[0, 0]
    n_real = ctrl_ref[0, 1]

    @pl.when(i < n_det)                  # detector phase: one frame tile
    def _():
        in_copy(slot, i).wait()
        logits = _run_member(fbuf[slot], cw_ref[...], ct_ref[...],
                             cf_ref[...], fw_ref[...], det_spec,
                             _member_ft(ft, spec, 0))
        det_out[pl.ds(i * bb, bb)] = logits
        # escalation mask: integer margin vs the pre-ceiled threshold
        # (m >= ceil(margin) <=> m >= margin for integer m), padding
        # lanes (global index >= n_real) never escalate
        pos = logits[:, positive_class]
        rest = jnp.max(jnp.where(
            jnp.arange(logits.shape[1])[None, :] == positive_class,
            jnp.iinfo(jnp.int32).min, logits), axis=1)
        m = pos - rest
        gidx = i * bb + jnp.arange(bb, dtype=jnp.int32)
        mask = (m >= thr) & (gidx < n_real)
        # order-preserving compaction into the escalation queue: frame
        # p lands at queue row cnt + (# escalated before p in this tile)
        cnt = count[0, 0]
        tgt = jnp.where(mask, cnt + jnp.cumsum(mask) - 1, bpad)
        queue[...] = queue[...].at[tgt, 0].set(gidx, mode="drop")
        count[0, 0] = cnt + jnp.sum(mask)

    @pl.when(i == n_det)                 # recognizer phase: drain the queue
    def _():
        total = count[0, 0]

        def chunk(c):
            # ragged tail clamps into range; the overlapped rows are
            # recomputed idempotently (same queue entries, same logits)
            base = jnp.minimum(c * rb, bpad - rb)
            idxs = queue[pl.ds(base, rb)][:, 0]
            copies = [pltpu.make_async_copy(
                frames_hbm.at[pl.ds(idxs[j], 1)],
                gbuf.at[pl.ds(j, 1)], g_sem.at[j]) for j in range(rb)]
            for cp in copies:            # gather rb frames by queue index
                cp.start()
            for cp in copies:
                cp.wait()
            logits = _run_member(gbuf[...], cw_ref[...], ct_ref[...],
                                 cf_ref[...], fw_ref[...], rec_spec,
                                 _member_ft(ft, spec, 1))
            rec_out[pl.ds(base, rb)] = logits
            count[0, 1] = count[0, 1] + rb   # slots computed = the bill

        bounded_drain_loop(lambda g0: g0 * rb < total, chunk,
                           n_chunks, check_every)


@functools.partial(jax.jit, static_argnames=(
    "spec", "bb", "rb", "ft", "check_every", "positive_class", "interpret"))
def cascade_forward(image, frames: jax.Array, ctrl, *, spec,
                    bb: int = 8, rb: int = 0, ft=0, check_every: int = 1,
                    positive_class: int = 1, interpret: bool = False):
    """Fused two-stage cascade in ONE resident ``pallas_call``.

    image:  the det+rec composite weight image
            (``interpreter.pack_cascade``) — both stages' SRAM contents
            VMEM-resident for the whole dispatch.
    frames: (B, H, W, Cin) integer images — ONE stream; the detector
            sees every frame, the recognizer only the frames the kernel
            itself escalates.
    ctrl:   (1, 2) int32 ``[threshold, n_real]`` — the escalation
            threshold on the integer logit margin (host float margins
            pre-ceiled by ``CascadePlan.margin_ctrl``; dynamic, so
            margin sweeps and ragged batches never retrace) and the
            count of real (non-padding) frames.
    spec:   static 2-member composite spec, detector first.
    bb/ft:  detector frame-tile / conv f-tile sizes (``ft`` may be a
            per-group tuple, ``member_groups`` order).
    rb:     recognizer chunk size (0 = ``bb``): escalated frames drain
            through the recognizer ``rb`` at a time.
    check_every: drain-loop condition re-check period, in chunks
            (:func:`bounded_drain_loop`).

    Returns ``(det_logits (B, Cd), rec_logits (B, Cr), queue (B,),
    counts (2,))`` — all int32.  ``counts[0]`` is the escalated count E;
    ``queue[:E]`` holds the escalated frame indices in ascending order
    and ``rec_logits[k]`` answers frame ``queue[k]`` (compacted layout;
    rows >= E are zeros/garbage).  ``counts[1]`` is the number of
    recognizer frame slots computed (>= E: chunk padding) — the
    recognizer-stage energy bill.
    """
    if len(spec) != 2:
        raise ValueError(f"cascade spec needs exactly 2 members (detector, "
                         f"recognizer), got {len(spec)}")
    det_spec, rec_spec = spec
    io = det_spec[0]
    assert io[0] == "io", det_spec
    h, w, cin = io[1], io[2], io[3]
    ncd, ncr = det_spec[-1][2], rec_spec[-1][2]
    if ncd < 2:
        raise ValueError(f"detector needs >= 2 classes, got {ncd}")
    if not 0 <= positive_class < ncd:
        raise ValueError(f"positive_class {positive_class} out of range for "
                         f"{ncd} detector classes")
    b = frames.shape[0]
    bb = max(1, min(bb, b))
    bpad = -(-b // bb) * bb
    n_det = bpad // bb
    rb = max(1, min(rb if rb else bb, bpad))

    frames = frames.astype(jnp.int32)
    if frames.shape[0] != bpad:
        frames = jnp.pad(frames, ((0, bpad - b),) + ((0, 0),) * 3)
    ctrl = jnp.asarray(ctrl, jnp.int32).reshape(1, 2)

    def resident(arr):                   # whole array, fetched once
        nd = arr.ndim
        return pl.BlockSpec(arr.shape, lambda i, _n=nd: (0,) * _n)

    def vmem_out(shape):                 # VMEM-resident across the grid
        nd = len(shape)
        return pl.BlockSpec(shape, lambda i, _n=nd: (0,) * _n)

    det, rec, qout, cnt = pl.pallas_call(
        functools.partial(_cascade_kernel, spec=spec, bb=bb, rb=rb,
                          bpad=bpad, check_every=check_every,
                          positive_class=positive_class, ft=ft),
        grid=(n_det + 1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),   # frames: HBM
                  resident(ctrl),
                  resident(image["cw"]), resident(image["ct"]),
                  resident(image["cf"]), resident(image["fw"])],
        out_specs=[vmem_out((bpad, ncd)), vmem_out((bpad, ncr)),
                   vmem_out((bpad, 1)), vmem_out((1, 2))],
        out_shape=[jax.ShapeDtypeStruct((bpad, ncd), jnp.int32),
                   jax.ShapeDtypeStruct((bpad, ncr), jnp.int32),
                   jax.ShapeDtypeStruct((bpad, 1), jnp.int32),
                   jax.ShapeDtypeStruct((1, 2), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((2, bb, h, w, cin), jnp.int32),
                        pltpu.VMEM((rb, h, w, cin), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((rb,))],
        interpret=interpret,
    )(frames, ctrl, image["cw"], image["ct"], image["cf"], image["fw"])
    return det[:b], rec[:b], qout[:b, 0], cnt[0]


# ---------------------------------------------------------------------------
# In-kernel frame-delta gating: popcount gate -> change queue -> recompute
# ---------------------------------------------------------------------------

def _delta_kernel(frames_hbm, ctrl_ref, last_ref, llog_ref,
                  cw_ref, ct_ref, cf_ref, fw_ref,
                  log_out, last_out, queue, count, delta_out,
                  fbuf, gbuf, in_sem, g_sem,
                  *, spec, bb: int, rb: int, bpad: int,
                  check_every: int, ft):
    """One grid step of the delta-gated megakernel.

    Grid = (n_tiles + 1,): every step but the last streams one frame
    tile (the cascade kernel's 2-slot double-buffered DMA), thermometer-
    packs it in-kernel, and computes the packed Hamming distance against
    the resident last-frame words (``popcount(cur XOR ref)`` summed per
    lane — the same integer domain the conv kernel works in).  Lanes
    whose delta reaches the ``ctrl`` threshold are *changed*: their
    indices compact into the VMEM ``queue`` (order-preserving, exactly
    the cascade's escalation compaction) and their last-frame words
    advance to the current frame; unchanged lanes keep their reference
    words, so drift never accumulates while a lane coasts.  The final
    step drains the queue through the network in chunks of ``rb``
    (:func:`bounded_drain_loop`), scattering fresh logits into an output
    that was *initialized from the resident last-logits buffer* — skipped
    lanes therefore emit their cached logits and the merged output doubles
    as the next step's last-logits state.  count[0, 0] = changed count,
    count[0, 1] = frame slots actually computed (the energy bill's
    recompute + chunk-padding figure).
    """
    (member,) = spec
    _, h, w, cin, bits, channels = member[0]
    n_tiles = bpad // bb
    n_chunks = -(-bpad // rb)
    i = pl.program_id(0)
    slot = jax.lax.rem(i, 2)
    nxt = jax.lax.rem(i + 1, 2)

    def in_copy(s, t):
        return pltpu.make_async_copy(frames_hbm.at[pl.ds(t * bb, bb)],
                                     fbuf.at[s], in_sem.at[s])

    @pl.when(i == 0)                     # init + warm-up DMA for tile 0
    def _():
        count[...] = jnp.zeros_like(count)
        queue[...] = jnp.zeros_like(queue)
        log_out[...] = llog_ref[...]     # skipped lanes -> cached logits
        in_copy(0, 0).start()

    @pl.when(i + 1 < n_tiles)            # tile N+1 streams while N gates
    def _():
        in_copy(nxt, i + 1).start()

    thr = ctrl_ref[0, 0]
    n_real = ctrl_ref[0, 1]

    @pl.when(i < n_tiles)                # gate phase: one frame tile
    def _():
        in_copy(slot, i).wait()
        cur = thermometer_pack(fbuf[slot], bits, cin, channels)
        ref = last_ref[pl.ds(i * bb, bb)]
        d = jnp.sum(jax.lax.population_count(cur ^ ref).astype(jnp.int32),
                    axis=(1, 2, 3))
        gidx = i * bb + jnp.arange(bb, dtype=jnp.int32)
        live = gidx < n_real
        mask = (d >= thr) & live
        delta_out[pl.ds(i * bb, bb)] = jnp.where(live, d, 0)[:, None]
        # the reference advances ONLY on recompute: a coasting lane's
        # delta stays measured against the frame that produced its
        # cached logits, so sub-threshold drift cannot accumulate
        last_out[pl.ds(i * bb, bb)] = jnp.where(
            mask[:, None, None, None], cur, ref)
        # order-preserving compaction into the change queue (the
        # cascade's escalation idiom)
        cnt = count[0, 0]
        tgt = jnp.where(mask, cnt + jnp.cumsum(mask) - 1, bpad)
        queue[...] = queue[...].at[tgt, 0].set(gidx, mode="drop")
        count[0, 0] = cnt + jnp.sum(mask)

    @pl.when(i == n_tiles)               # recompute phase: drain the queue
    def _():
        total = count[0, 0]

        def chunk(c):
            # ragged tail clamps into range; overlapped rows recompute
            # idempotently (same queue entries, same scatter targets)
            base = jnp.minimum(c * rb, bpad - rb)
            idxs = queue[pl.ds(base, rb)][:, 0]
            copies = [pltpu.make_async_copy(
                frames_hbm.at[pl.ds(idxs[j], 1)],
                gbuf.at[pl.ds(j, 1)], g_sem.at[j]) for j in range(rb)]
            for cp in copies:            # gather rb frames by queue index
                cp.start()
            for cp in copies:
                cp.wait()
            logits = _run_member(gbuf[...], cw_ref[...], ct_ref[...],
                                 cf_ref[...], fw_ref[...], member,
                                 _member_ft(ft, spec, 0))
            for j in range(rb):          # scatter fresh logits by index
                log_out[pl.ds(idxs[j], 1)] = logits[j:j + 1]
            count[0, 1] = count[0, 1] + rb   # slots computed = the bill

        bounded_drain_loop(lambda g0: g0 * rb < total, chunk,
                           n_chunks, check_every)


@functools.partial(jax.jit, static_argnames=(
    "spec", "bb", "rb", "ft", "check_every", "interpret"))
def delta_forward(image, frames: jax.Array, last, llog, ctrl, *, spec,
                  bb: int = 8, rb: int = 0, ft=0, check_every: int = 1,
                  interpret: bool = False):
    """Delta-gated whole-network inference in ONE resident ``pallas_call``.

    image:  the program's weight image (``interpreter.pack_delta`` /
            ``fold_params(..., image=True)``), VMEM-resident throughout.
    frames: (B, H, W, Cin) integer images — batch slot b is *stream* b
            of an always-on deployment; one call advances every stream
            by one time step.
    last:   (B, H, W, channels//32) uint32 — each stream's resident
            last-frame words (the packed thermometer encoding of the
            frame that produced its cached logits).
    llog:   (B, classes) int32 — each stream's cached logits.
    ctrl:   (1, 2) int32 ``[threshold, n_real]`` (build with
            ``DeltaPlan.delta_ctrl``): the change threshold on the packed
            Hamming distance (dynamic — threshold sweeps never retrace)
            and the count of real (non-padding) streams.
    spec:   static 1-member composite spec.
    bb/ft:  frame-tile / conv f-tile sizes.
    rb:     recompute chunk size (0 = ``bb``): changed frames drain
            through the network ``rb`` at a time.
    check_every: drain-loop condition re-check period, in chunks.

    Returns ``(logits (B, C), new_last (B, H, W, Cw), queue (B,),
    counts (2,), deltas (B,))``.  ``logits`` merges fresh logits for
    changed lanes with cached logits for skipped lanes — it is also the
    next call's ``llog``.  ``new_last`` is the next call's ``last``.
    ``counts[0]`` is the changed count K; ``queue[:K]`` holds the changed
    frame indices ascending.  ``counts[1]`` is the number of frame slots
    computed (>= K: chunk padding) — the recompute energy bill.
    ``deltas`` are the per-lane packed Hamming distances (0 for padding).
    """
    if len(spec) != 1:
        raise ValueError(
            f"delta spec needs exactly 1 member, got {len(spec)}")
    (member,) = spec
    io = member[0]
    assert io[0] == "io", member
    h, w, cin, bits, channels = io[1], io[2], io[3], io[4], io[5]
    cpw = channels // PACK_WIDTH
    final = member[-1]
    assert final[0] == "fc" and final[3], member
    ncls = final[2]

    b = frames.shape[0]
    bb = max(1, min(bb, b))
    bpad = -(-b // bb) * bb
    n_tiles = bpad // bb
    rb = max(1, min(rb if rb else bb, bpad))

    if last.shape != (b, h, w, cpw):
        raise ValueError(f"last-frame state must be {(b, h, w, cpw)}, "
                         f"got {last.shape}")
    if llog.shape != (b, ncls):
        raise ValueError(f"last-logits state must be {(b, ncls)}, "
                         f"got {llog.shape}")
    frames = frames.astype(jnp.int32)
    last = jnp.asarray(last, jnp.uint32)
    llog = jnp.asarray(llog, jnp.int32)
    if bpad != b:
        frames = jnp.pad(frames, ((0, bpad - b),) + ((0, 0),) * 3)
        last = jnp.pad(last, ((0, bpad - b),) + ((0, 0),) * 3)
        llog = jnp.pad(llog, ((0, bpad - b), (0, 0)))
    ctrl = jnp.asarray(ctrl, jnp.int32).reshape(1, 2)

    def resident(arr):                   # whole array, fetched once
        nd = arr.ndim
        return pl.BlockSpec(arr.shape, lambda i, _n=nd: (0,) * _n)

    def vmem_out(shape):                 # VMEM-resident across the grid
        nd = len(shape)
        return pl.BlockSpec(shape, lambda i, _n=nd: (0,) * _n)

    logits, new_last, qout, cnt, deltas = pl.pallas_call(
        functools.partial(_delta_kernel, spec=spec, bb=bb, rb=rb,
                          bpad=bpad, check_every=check_every, ft=ft),
        grid=(n_tiles + 1,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),   # frames: HBM
                  resident(ctrl), resident(last), resident(llog),
                  resident(image["cw"]), resident(image["ct"]),
                  resident(image["cf"]), resident(image["fw"])],
        out_specs=[vmem_out((bpad, ncls)), vmem_out((bpad, h, w, cpw)),
                   vmem_out((bpad, 1)), vmem_out((1, 2)),
                   vmem_out((bpad, 1))],
        out_shape=[jax.ShapeDtypeStruct((bpad, ncls), jnp.int32),
                   jax.ShapeDtypeStruct((bpad, h, w, cpw), jnp.uint32),
                   jax.ShapeDtypeStruct((bpad, 1), jnp.int32),
                   jax.ShapeDtypeStruct((1, 2), jnp.int32),
                   jax.ShapeDtypeStruct((bpad, 1), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((2, bb, h, w, cin), jnp.int32),
                        pltpu.VMEM((rb, h, w, cin), jnp.int32),
                        pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((rb,))],
        interpret=interpret,
    )(frames, ctrl, last, llog,
      image["cw"], image["ct"], image["cf"], image["fw"])
    return logits[:b], new_last[:b], qout[:b, 0], cnt[0], deltas[:b, 0]


def megakernel_forward(image, frames: jax.Array, *, spec,
                       bb: int = 8, ft: int = 0,
                       interpret: bool = False) -> jax.Array:
    """Whole-network packed inference for ONE program: the one-member
    composite (see :func:`composite_forward`).

    image:  the weight-image artifact (``interpreter.fold_params(...,
            image=True)``).
    frames: (B, H, W, Cin) integer images.
    spec:   static stage tuple from ``InferencePlan.mega``.
    bb/ft:  frame-tile / conv f-tile sizes (tuned values come from the
            ``kernels.autotune`` cache via the interpreter layer).
    Returns (B, classes) int32 logits.
    """
    return composite_forward(image, (frames,), spec=_solo_member_spec(spec),
                             bb=bb, ft=ft, interpret=interpret)[0]

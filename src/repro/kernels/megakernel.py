"""Pallas TPU megakernel: the whole network resident in VMEM, per frame tile.

BinarEye "stores full network models and feature maps and hence requires no
off-chip bandwidth": weights sit in the 259 kB SRAM, feature maps ping-pong
between the west/east 32 kB feature SRAMs, and the only off-chip traffic is
the image in and the label out.  The staged ``InferencePlan`` lost that on
TPU — one ``pallas_call`` per layer means every packed feature map takes an
HBM round trip between stages.  This kernel restores the chip's execution
model in one ``pallas_call``:

* **SRAM image in VMEM.**  All packed conv weight words + int32 comparator
  thresholds + packed FC weights for *every* layer enter as VMEM-resident
  operands (constant index maps: fetched once, resident across the grid) —
  the TPU analogue of the weight SRAM contents.  For the worst chip shape
  (cifar9 at S=1) the conv image is 8 x 256x4x8 words = 262 kB, within 1%
  of the chip's 259 kB weight SRAM.
* **Feature maps stay in VMEM.**  Inter-layer maps are kernel-resident
  values — Mosaic allocates them out of VMEM, the analogue of the chip's
  west/east feature SRAMs — and never touch HBM.  (An explicit ping-pong
  scratch buffer would model the SRAM pair even more literally, but it
  adds a write+read bounce per layer that is real extra VMEM traffic on
  every backend, so the maps flow as values instead.)
* **Double-buffered frame streaming.**  The grid iterates frame tiles;
  raw frames stay in HBM (``memory_space=ANY``) and are streamed tile by
  tile with manual ``make_async_copy``/wait into a 2-slot VMEM buffer, so
  tile N+1 DMAs in while tile N computes; logits DMA out the same way.
  The IO thermometer encode runs in-kernel on the raw integer pixels, so
  the only HBM traffic of the whole network is frames in, logits out.

The per-layer arithmetic is ``binary_conv2x2_block.conv_block_body`` — the
exact function the staged path runs — so the two paths are bit-exact by
construction (and tested, ``tests/test_megakernel.py``).

VMEM budget: unlike the staged kernel, a conv layer here computes all F
neurons in one step, so the dominant live value is the int32 accumulator
``bb * (H-1) * (W-1) * F * 4B`` (~7.9 MB for cifar9-S1 at bb=8).  On a
real TPU shrink ``bb`` first (bb=2 keeps the worst case under 2 MB); the
weight image + streaming buffers are small (<1 MB total).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binarize import (PACK_WIDTH, pack_bit_lanes,
                                 thermometer_pack, xnor_dot_popcount)
from repro.kernels.binary_conv2x2_block import conv_block_body

# Static stage spec entries (hashable; built by interpreter.InferencePlan):
#   ("io",   h, w, cin, bits, channels)
#   ("conv", h, w, c, f, pool)            h/w = input map size
#   ("fc",   k, n, final, pack_out)


def _fc_body(x, wfc, k: int):
    """Packed FC on values: (bb, Kw) x (N, Kw) -> (bb, N) int32 sums."""
    return xnor_dot_popcount(x[:, None, :], wfc[None, :, :], k)


def _run_stages(frames, cw, ct, cf, fw, spec):
    """The whole-network pipeline on one VMEM-resident frame tile.

    ``frames``: (bb, H, W, Cin) int32 raw pixels (already DMA'd to VMEM);
    ``cw``/``ct``/``cf``: the conv SRAM image; ``fw``: the padded FC
    image.  The feature map flows layer to layer as a VMEM-resident
    value.  Returns (bb, classes) int32 logits.
    """
    ci = fi = 0
    fm = None                      # packed spatial map, (bb, h, w, Cw)
    x = None                       # packed FC row words once spatial ends
    logits = None
    for st in spec:
        if st[0] == "io":
            _, h, w, cin, bits, channels = st
            # the staged path's exact IO arithmetic, run in-kernel
            fm = thermometer_pack(frames, bits, cin, channels)
        elif st[0] == "conv":
            _, h, w, c, f, pool = st
            fm = conv_block_body(fm, cw[ci], ct[ci], cf[ci],
                                 k4=4 * c, h=h, wd=w, pool=pool)
            ci += 1
        else:
            _, k, n, final, pack_out = st
            kw = -(-k // PACK_WIDTH)
            if x is None:          # flatten the last spatial map into rows
                # (bb, h, w, Cw) words flatten directly into packed FC
                # rows: F % 32 == 0 makes word order the channel order.
                x = fm.reshape(fm.shape[0], -1)
            s = _fc_body(x, fw[fi, :n, :kw], k)
            if final:
                logits = s
            elif n % PACK_WIDTH == 0:
                x = pack_bit_lanes((s < 0).astype(jnp.uint32))
            else:                  # odd-width hidden FC: sign, pad, repack
                bits_ = (s < 0).astype(jnp.uint32)
                padn = (-n) % PACK_WIDTH
                bits_ = jnp.pad(bits_, ((0, 0), (0, padn)))
                x = pack_bit_lanes(bits_)
            fi += 1
    return logits


def _mega_kernel(frames_hbm, cw_ref, ct_ref, cf_ref, fw_ref, out_hbm,
                 fbuf, obuf, in_sem, out_sem, *,
                 spec, bb: int, n_tiles: int):
    """One frame-tile grid step with 2-slot input/output DMA pipelining."""
    i = pl.program_id(0)
    slot = jax.lax.rem(i, 2)
    nxt = jax.lax.rem(i + 1, 2)

    def in_copy(s, t):
        return pltpu.make_async_copy(
            frames_hbm.at[pl.ds(t * bb, bb)], fbuf.at[s], in_sem.at[s])

    def out_copy(s, t):
        return pltpu.make_async_copy(
            obuf.at[s], out_hbm.at[pl.ds(t * bb, bb)], out_sem.at[s])

    @pl.when(i == 0)                     # warm-up: tile 0 streams in
    def _():
        in_copy(0, 0).start()

    @pl.when(i + 1 < n_tiles)            # tile N+1 streams while N computes
    def _():
        in_copy(nxt, jnp.minimum(i + 1, n_tiles - 1)).start()

    in_copy(slot, i).wait()
    logits = _run_stages(fbuf[slot], cw_ref[...], ct_ref[...], cf_ref[...],
                         fw_ref[...], spec)

    if n_tiles > 2:                      # drain the DMA issued 2 tiles ago
        @pl.when(i >= 2)                 # before reusing its slot
        def _():
            out_copy(slot, jnp.maximum(i - 2, 0)).wait()
    obuf[slot] = logits
    out_copy(slot, i).start()

    @pl.when(i == n_tiles - 1)           # final tile: drain everything
    def _():
        out_copy(slot, i).wait()
    if n_tiles > 1:
        @pl.when(i == n_tiles - 1)
        def _():
            out_copy(1 - slot, i - 1).wait()


@functools.partial(jax.jit, static_argnames=("spec", "bb", "interpret"))
def megakernel_forward(image, frames: jax.Array, *, spec,
                       bb: int = 8, interpret: bool = False) -> jax.Array:
    """Whole-network packed inference in a single resident ``pallas_call``.

    image:  the weight-image artifact (``interpreter.fold_params(...,
            image=True)``): ``cw`` (n_conv, F, 4, Cw) uint32 conv words,
            ``ct``/``cf`` (n_conv, F) int32 thresholds/directions,
            ``fw`` (n_fc, Nmax, Kwmax) uint32 padded FC words.
    frames: (B, H, W, Cin) integer images.
    spec:   static stage tuple from ``InferencePlan.mega``.
    bb:     frame-tile size (the double-buffered streaming granule).
    Returns (B, classes) int32 logits.
    """
    io = spec[0]
    assert io[0] == "io", spec
    h, w, cin = io[1], io[2], io[3]
    final = spec[-1]
    assert final[0] == "fc" and final[3], spec
    ncls = final[2]

    b = frames.shape[0]
    bb = min(bb, b)
    bp = (-b) % bb
    frames = frames.astype(jnp.int32)
    if bp:                               # ragged final tile: pad, trim below
        frames = jnp.pad(frames, ((0, bp), (0, 0), (0, 0), (0, 0)))
    n_tiles = frames.shape[0] // bb

    def resident(arr):                   # whole array, fetched once
        nd = arr.ndim
        return pl.BlockSpec(arr.shape, lambda i, _n=nd: (0,) * _n)

    out = pl.pallas_call(
        functools.partial(_mega_kernel, spec=spec, bb=bb, n_tiles=n_tiles),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),      # frames stay in HBM
            resident(image["cw"]), resident(image["ct"]),
            resident(image["cf"]), resident(image["fw"]),
        ],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((frames.shape[0], ncls), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((2, bb, h, w, cin), jnp.int32),     # frame tiles
            pltpu.VMEM((2, bb, ncls), jnp.int32),          # logit tiles
            pltpu.SemaphoreType.DMA((2,)),
            pltpu.SemaphoreType.DMA((2,)),
        ],
        interpret=interpret,
    )(frames, image["cw"], image["ct"], image["cf"], image["fw"])
    return out[:b]

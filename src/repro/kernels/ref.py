"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are tested against (allclose over
shape/dtype sweeps, see tests/test_kernels_*.py).  They operate on *unpacked*
+/-1 arrays so the math is transparently the BinaryNet math.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import binarize


def xnor_matmul_ref(a_signs: jnp.ndarray, w_signs: jnp.ndarray) -> jnp.ndarray:
    """Binary matmul oracle.

    a_signs: (M, K) in {-1,+1};  w_signs: (N, K) in {-1,+1}.
    Returns (M, N) int32 = a @ w.T  (exact integer result).
    """
    return jnp.dot(a_signs.astype(jnp.int32), w_signs.astype(jnp.int32).T)


def xnor_matmul_packed_ref(a_words, w_words, k: int) -> jnp.ndarray:
    """Same contract as the kernel: packed uint32 inputs -> int32 (M, N)."""
    return binarize.xnor_dot_popcount(a_words[:, None, :], w_words[None, :, :], k)


def binary_conv2x2_ref(a_signs: jnp.ndarray, w_signs: jnp.ndarray) -> jnp.ndarray:
    """2x2 stride-1 VALID binary conv oracle (the chip's only conv shape).

    a_signs: (H, W, C) in {-1,+1};  w_signs: (F, 2, 2, C) in {-1,+1}.
    Returns (H-1, W-1, F) int32.
    """
    h, w, _ = a_signs.shape
    a = a_signs.astype(jnp.int32)
    wgt = w_signs.astype(jnp.int32)
    out = None
    for dy in range(2):
        for dx in range(2):
            patch = a[dy:h - 1 + dy, dx:w - 1 + dx, :]          # (H-1, W-1, C)
            tap = jnp.einsum("ywc,fc->ywf", patch, wgt[:, dy, dx, :])
            out = tap if out is None else out + tap
    return out


def binarize_pack_ref(x: jnp.ndarray) -> jnp.ndarray:
    """sign+pack oracle: (M, K) float -> (M, ceil(K/32)) uint32."""
    return binarize.pack_signs(binarize.hard_sign(x), axis=-1)

"""Jit'd public wrappers around the Pallas kernels.

On the CPU container the kernels run with ``interpret=True`` (Pallas
executes the kernel body in Python); on a real TPU the same code lowers
through Mosaic.  ``default_interpret()`` picks automatically.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import binarize
from repro.kernels import binarize_pack as _bp
from repro.kernels import binary_conv2x2 as _bc
from repro.kernels import binary_conv2x2_block as _bcb
from repro.kernels import megakernel as _mk
from repro.kernels import xnor_matmul as _xm


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Fused sign+pack for a (..., K) float array -> (..., ceil(K/32)) uint32."""
    if interpret is None:
        interpret = default_interpret()
    lead = x.shape[:-1]
    flat = x.reshape((-1, x.shape[-1]))
    out = _bp.binarize_pack(flat, interpret=interpret)
    return out.reshape(lead + (out.shape[-1],))


def xnor_matmul(a_words: jax.Array, w_words: jax.Array, k: int, *,
                interpret: bool | None = None, **tiles) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    return _xm.xnor_matmul(a_words, w_words, k=k, interpret=interpret, **tiles)


def binary_conv2x2(a_words: jax.Array, w_words: jax.Array, c: int, *,
                   interpret: bool | None = None, **tiles) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    return _bc.binary_conv2x2(a_words, w_words, c=c, interpret=interpret, **tiles)


def binary_conv2x2_block(a_words: jax.Array, w_words: jax.Array,
                         tau: jax.Array, flip: jax.Array, c: int, *,
                         pool: bool = False, interpret: bool | None = None,
                         **tiles) -> jax.Array:
    """Fused packed conv layer: conv -> integer threshold -> pool -> repack.

    (B, H, W, Cw) uint32 in, (B, Ho, Wo, F//32) uint32 out — the
    feature map never leaves the bit-packed domain.
    """
    if interpret is None:
        interpret = default_interpret()
    return _bcb.binary_conv2x2_block(a_words, w_words, tau, flip, c=c,
                                     pool=pool, interpret=interpret, **tiles)


def megakernel_forward(image, frames: jax.Array, *, spec, bb: int = 8,
                       ft: int = 0,
                       interpret: bool | None = None) -> jax.Array:
    """Whole-network VMEM-resident inference: raw frames -> int32 logits.

    One ``pallas_call`` runs every stage of the compiled plan (``spec``
    from ``InferencePlan.mega``) with the full weight image resident in
    VMEM, feature maps in VMEM scratch and frame tiles of ``bb``
    double-buffered through the grid — no HBM traffic between layers.
    ``ft`` f-tiles each conv layer's F axis (0 = all F per chunk).
    """
    if interpret is None:
        interpret = default_interpret()
    return _mk.megakernel_forward(image, frames, spec=spec, bb=bb, ft=ft,
                                  interpret=interpret)


def composite_forward(image, frames, *, spec, bb: int = 8, ft=0,
                      interpret: bool | None = None):
    """Shared-array multi-program inference: one ``pallas_call`` runs
    every member of a composite (programs whose S-modes tile the array
    exactly) on its own frame stream against the composite weight image.

    ``frames`` is a tuple of per-member (B, H, W, Cin) batches; returns a
    tuple of per-member (B, classes) int32 logits.  ``ft`` may be a
    tuple with one f-tile per member group (``member_groups`` order).
    See ``interpreter.pack_programs`` for building ``image``/``spec``.
    """
    if interpret is None:
        interpret = default_interpret()
    return _mk.composite_forward(image, tuple(frames), spec=spec, bb=bb,
                                 ft=ft, interpret=interpret)


def cascade_forward(image, frames, ctrl, *, spec, bb: int = 8, rb: int = 0,
                    ft=0, check_every: int = 1, positive_class: int = 1,
                    interpret: bool | None = None):
    """Fused detector->recognizer cascade in one resident ``pallas_call``:
    the detector screens every frame tile, the escalation mask (integer
    logit margin vs the ``ctrl`` threshold) is computed in-kernel, and
    the recognizer drains only the escalated lanes through the bounded
    drain loop.  Returns (det_logits, rec_logits, queue, counts) — see
    ``megakernel.cascade_forward`` for the compacted layout and
    ``interpreter.pack_cascade`` for building ``image``/``spec``.
    """
    if interpret is None:
        interpret = default_interpret()
    return _mk.cascade_forward(image, frames, ctrl, spec=spec, bb=bb, rb=rb,
                               ft=ft, check_every=check_every,
                               positive_class=positive_class,
                               interpret=interpret)


def delta_forward(image, frames, last, llog, ctrl, *, spec, bb: int = 8,
                  rb: int = 0, ft=0, check_every: int = 1,
                  interpret: bool | None = None):
    """Delta-gated whole-network inference in one resident ``pallas_call``:
    each frame tile is thermometer-packed in-kernel and popcount-XORed
    against the resident last-frame words; lanes whose packed Hamming
    distance reaches the ``ctrl`` threshold compact into the change queue
    and recompute through the bounded drain loop, while skipped lanes
    emit their cached logits.  Returns (logits, new_last, queue, counts,
    deltas) — see ``megakernel.delta_forward`` for the state contract and
    ``interpreter.pack_delta`` for building ``image``/``spec``.
    """
    if interpret is None:
        interpret = default_interpret()
    return _mk.delta_forward(image, frames, last, llog, ctrl, spec=spec,
                             bb=bb, rb=rb, ft=ft, check_every=check_every,
                             interpret=interpret)


def member_groups(spec):
    """A composite spec's sub-array groups (members with shape-identical
    IO+conv chains stack into one fused conv); per-group ``ft`` tuples
    index groups in this order."""
    return _mk.member_groups(spec)


def binary_linear(x: jax.Array, w_signs: jax.Array, *,
                  interpret: bool | None = None) -> jax.Array:
    """End-to-end W1A1 linear for inference: float x, +/-1 weights.

    x: (..., K) float;  w_signs: (N, K) in {-1,+1}.  Returns (..., N) int32
    (the exact binary dot products; caller applies threshold / scale).
    """
    k = x.shape[-1]
    lead = x.shape[:-1]
    a_words = pack(x.reshape((-1, k)), interpret=interpret)
    w_words = binarize.pack_signs(w_signs, axis=-1)
    out = xnor_matmul(a_words, w_words, k, interpret=interpret)
    return out.reshape(lead + (w_signs.shape[0],))

"""Pallas TPU kernel: bitpacked XNOR-popcount binary matmul.

Computes ``out[m, n] = K - 2 * popcount(a[m] ^ w[n])`` over uint32 words —
the BinarEye neuron dot product, vectorized over the TPU VPU (the MXU has no
1-bit mode; packing 32 binary channels per int32 lane gives the 32x density
that the chip gets from its XNOR gates).

Weight-stationarity (the chip's LD-once / CONV-many pattern) is expressed
through the grid order: the N (neuron) index is the *outermost* grid axis and
the weight BlockSpec depends only on it, so a weight tile is fetched to VMEM
once and stays resident while the M (activation positions) axis streams.

VMEM budget per grid step (defaults bm=bn=128, bk=64 words = 2048 channels):
  a tile 128*64*4B = 32 kB, w tile 32 kB, out tile 128*128*4B = 64 kB,
  xor broadcast intermediate bm*bn*bk*4B = 4 MB  -> fits the ~16 MB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.binarize import PACK_WIDTH, pack_bit_lanes


def _xnor_matmul_kernel(a_ref, w_ref, out_ref, *, k: int, nk: int):
    """Grid = (N/bn, M/bm, Kw/bk); accumulate popcounts over the k axis."""
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]                      # (bm, bk) uint32
    w = w_ref[...]                      # (bn, bk) uint32
    x = jnp.bitwise_xor(a[:, None, :], w[None, :, :])     # (bm, bn, bk)
    pc = jax.lax.population_count(x).astype(jnp.int32)
    out_ref[...] += jnp.sum(pc, axis=-1)

    @pl.when(kb == nk - 1)
    def _finalize():
        # dot = K - 2 * popcount(disagreements); padding words are zero on
        # both sides (pack_signs pads with +1 -> bit 0) so they contribute 0.
        out_ref[...] = jnp.int32(k) - 2 * out_ref[...]


def _xnor_matmul_pack_kernel(a_ref, w_ref, out_ref, acc_ref, *, k: int, nk: int):
    """Fused variant: sign the final sums and emit packed uint32 words.

    Accumulation runs in a VMEM scratch (the packed output words have a
    different shape/dtype than the int32 partials); the last k step
    applies ``sign(K - 2*acc)`` and packs 32 neurons per word, so a
    hidden FC layer's activations never exist unpacked outside the
    kernel.  out_ref: (bm, bn // 32) uint32.
    """
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    w = w_ref[...]
    x = jnp.bitwise_xor(a[:, None, :], w[None, :, :])
    acc_ref[...] += jnp.sum(jax.lax.population_count(x).astype(jnp.int32),
                            axis=-1)

    @pl.when(kb == nk - 1)
    def _finalize():
        s = jnp.int32(k) - 2 * acc_ref[...]               # (bm, bn) sums
        bits = (s < 0).astype(jnp.uint32)                 # sign: bit=1 -> -1
        out_ref[...] = pack_bit_lanes(bits)


@functools.partial(jax.jit, static_argnames=("k", "bm", "bn", "bk",
                                             "pack_out", "interpret"))
def xnor_matmul(a_words: jax.Array, w_words: jax.Array, *, k: int,
                bm: int = 128, bn: int = 128, bk: int = 64,
                pack_out: bool = False, interpret: bool = False) -> jax.Array:
    """Packed binary matmul.

    a_words: (M, Kw) uint32 packed activations (+1 -> bit0, -1 -> bit1).
    w_words: (N, Kw) uint32 packed weights.
    k:       true (unpadded) channel count; output = K - 2*popcount(xor).
    pack_out: fuse the sign activation and bit-pack along N inside the
        kernel, returning (M, N // 32) uint32 instead of (M, N) int32 —
        the stay-binary path for hidden FC layers (requires N % 32 == 0).
    Returns (M, N) int32, or (M, N // 32) uint32 when ``pack_out``.

    The M axis doubles as the batch axis (callers flatten (B, K) frames
    into rows), and N is the outermost grid axis, so each weight tile is
    loaded once and serves the entire batch.
    """
    m, kw = a_words.shape
    n, kw2 = w_words.shape
    assert kw == kw2, (kw, kw2)
    if pack_out:
        assert n % PACK_WIDTH == 0, (
            f"pack_out needs N % {PACK_WIDTH} == 0, got N={n}")

    bm = min(bm, m)
    bn = min(bn, n)
    if pack_out:
        bn = -(-bn // PACK_WIDTH) * PACK_WIDTH    # whole words per tile
    bk = min(bk, kw)
    # pad to tile multiples (zero words == +1 signs on both sides: no-op)
    mp, np_, kp = (-m) % bm, (-n) % bn, (-kw) % bk
    if mp or kp:
        a_words = jnp.pad(a_words, ((0, mp), (0, kp)))
    if np_ or kp:
        w_words = jnp.pad(w_words, ((0, np_), (0, kp)))
    gm, gn, gk = a_words.shape[0] // bm, w_words.shape[0] // bn, a_words.shape[1] // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda n_, m_, k_: (m_, k_)),   # activations stream
        pl.BlockSpec((bn, bk), lambda n_, m_, k_: (n_, k_)),   # weights: loop-invariant in m_
    ]
    if pack_out:
        out = pl.pallas_call(
            functools.partial(_xnor_matmul_pack_kernel, k=k, nk=gk),
            grid=(gn, gm, gk),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn // PACK_WIDTH),
                                   lambda n_, m_, k_: (m_, n_)),
            out_shape=jax.ShapeDtypeStruct(
                (a_words.shape[0], w_words.shape[0] // PACK_WIDTH),
                jnp.uint32),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
            interpret=interpret,
        )(a_words, w_words)
        return out[:m, :n // PACK_WIDTH]

    out = pl.pallas_call(
        functools.partial(_xnor_matmul_kernel, k=k, nk=gk),
        grid=(gn, gm, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda n_, m_, k_: (m_, n_)),
        out_shape=jax.ShapeDtypeStruct((a_words.shape[0], w_words.shape[0]), jnp.int32),
        interpret=interpret,
    )(a_words, w_words)
    return out[:m, :n]

"""Persistent warm-start cache: compiled serve executables, keyed like tiles.

BinarEye keeps *everything* resident — weights in SRAM, instructions in
the 16-slot program memory — so a chip powers up serving-ready the
moment its image is loaded.  The TPU mapping's cold start is dominated
by something the chip never pays: tracing + XLA-compiling each resident
program's serve function.  For a single server that cost amortizes; for
a *fleet* it is the failover recovery path — a replacement replica's
cold-start-to-first-served-frame is exactly one trace+compile of every
resident program (tracked in the bench as
``fleet_failover_recovery_ms`` / ``replica_warm_start_speedup``).

This module makes that start warm, following the autotuner's
schema-versioned-key discipline (:mod:`repro.kernels.autotune`):

* **Process tier** — a keyed memo of built (jit'd) serve functions.
  Keys fingerprint the *computation*: program instruction words + S
  (``autotune.program_key``), the serve options that change the traced
  graph (megakernel / donation / interpret / composite member order),
  the mesh's device set, and the backend (platform + device kind + JAX
  version).  Two servers asking for the same key share one function —
  and therefore one set of compiled executables — so a replacement
  replica built after a host loss skips straight past trace+compile.
  The key schema carries a ``v1/`` prefix: when the serve-fn signature
  or kernel schedule changes shape, the version bumps and stale entries
  silently degrade to a cold build (never an error, never a wrong
  executable — a cache hit may only ever change *speed*).
* **Persistent tier** — JAX's own compilation cache, pointed at a
  directory (env ``REPRO_WARM_CACHE``, default ``BENCH_warm_cache``):
  XLA executables are serialized per (computation fingerprint, device
  kind, compiler version) by JAX itself, so a replica in a *new
  process* also comes up hot.  CI uploads the directory as an artifact
  next to ``BENCH_autotune.json``; enabling is best-effort — on a JAX
  build without the config knobs it degrades to the process tier only.

The in-process ledger (:func:`stats`) records hits/misses and the
seconds spent building on misses — the bench derives its warm-start
speedup from wall-clock around real server bring-up, but the ledger is
what tests pin.
"""

from __future__ import annotations

import os
import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax

from repro.core.chip import isa
from repro.kernels import autotune

SCHEMA = 1          # bump when serve-fn signatures / kernel schedule change
CACHE_ENV = "REPRO_WARM_CACHE"
DEFAULT_DIR = "BENCH_warm_cache"

_fns: Dict[str, Any] = {}
_stats = {"hits": 0, "misses": 0, "build_s": 0.0}
_persistent_dir: Optional[str] = None


def backend_fingerprint() -> str:
    """The machine class + compiler an executable is valid for: the
    autotuner's platform/device-kind/host-ISA triple plus the JAX
    version (a jaxlib upgrade invalidates serialized executables)."""
    return f"{autotune.backend_fingerprint()}:jax{jax.__version__}"


def serve_fn_key(programs: Iterable[isa.Program], *,
                 mesh=None, megakernel: bool = False,
                 donate_frames: bool = False,
                 interpret: Optional[bool] = None,
                 kind: str = "serve") -> str:
    """Cache key for a (composite) serve function.

    ``programs`` is the ordered member tuple — one program for a solo
    serve fn, the composite's member order for a shared-array fn (order
    is part of the traced graph, exactly like ``autotune.composite_key``).
    The mesh contributes its device ids: a function traced through
    ``shard_map`` closes over its mesh, so sub-meshes of different
    simulated hosts must never share an entry.
    """
    programs = tuple(programs)
    pkey = (autotune.program_key(programs[0]) if len(programs) == 1
            else autotune.composite_key(programs))
    devs = ("nodev" if mesh is None else
            "d" + "-".join(str(getattr(d, "id", d)) for d in
                           mesh.devices.flatten()))
    opts = f"mk{int(megakernel)}.dn{int(donate_frames)}.it{interpret}"
    return (f"v{SCHEMA}/{kind}/{pkey}/{devs}/{opts}/"
            f"{backend_fingerprint()}")


def lookup_fn(key: str) -> Optional[Any]:
    """Process-tier hit (None = cold).  Ledger counts the outcome."""
    fn = _fns.get(key)
    if fn is None:
        _stats["misses"] += 1
    else:
        _stats["hits"] += 1
    return fn


def record_fn(key: str, fn: Any, build_s: float = 0.0) -> Any:
    _fns[key] = fn
    _stats["build_s"] += build_s
    return fn


def get_or_build(key: str, build: Callable[[], Any]) -> Any:
    """The one-call form: hit returns the cached fn, miss runs ``build``
    (timed into the ledger) and records the result."""
    fn = lookup_fn(key)
    if fn is None:
        t0 = time.perf_counter()
        fn = build()
        record_fn(key, fn, time.perf_counter() - t0)
    return fn


def stats() -> Dict[str, Any]:
    """Ledger snapshot: process-tier hits/misses, seconds spent building
    on misses, entry count, and the persistent dir (None = disabled)."""
    return dict(_stats, entries=len(_fns), persistent_dir=_persistent_dir)


def invalidate() -> None:
    """Drop the process tier and zero the ledger (tests / cold-start
    measurement).  The persistent tier is untouched — on-disk executables
    stay valid; only the in-process memo goes cold."""
    global _fns
    _fns = {}
    _stats.update(hits=0, misses=0, build_s=0.0)


def cache_dir() -> str:
    return os.environ.get(CACHE_ENV, DEFAULT_DIR)


def persistent_dir() -> Optional[str]:
    return _persistent_dir


def enable_persistent(path: Optional[str] = None) -> Optional[str]:
    """Point JAX's compilation cache at ``path`` (default: ``cache_dir()``)
    so XLA executables persist across processes.

    Best-effort: returns the directory on success, None when this JAX
    build lacks the config knobs (the process tier still works).  The
    min-compile-time/entry-size floors are dropped to zero so the small
    CPU-interpret serve functions are cached too — on a real TPU the
    default floors would also admit them.
    """
    global _persistent_dir
    path = path if path is not None else cache_dir()
    try:
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        for knob, val in (("jax_persistent_cache_min_compile_time_secs", 0.0),
                          ("jax_persistent_cache_min_entry_size_bytes", 0)):
            try:
                jax.config.update(knob, val)
            except (AttributeError, ValueError):
                pass        # older JAX: floor stays at its default
    except (AttributeError, ValueError, OSError):
        _persistent_dir = None
        return None
    _persistent_dir = path
    return path

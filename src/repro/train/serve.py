"""Serving: prefill and decode step builders with sharded caches.

* ``prefill_step`` — run the full prompt, return last-position logits + a
  cache padded to ``max_len`` (KV leaves sequence-sharded over the model
  axis: split-K decode layout).
* ``decode_step``  — one token for every sequence in the batch against the
  cache; recurrent archs (mamba/rwkv) carry constant-size states instead.
* ``sample`` — greedy / temperature sampling helper.

Batched requests: the serve driver (launch/serve.py) packs requests into
fixed batch slots; finished slots keep decoding padding into a dead slot
until replaced (standard static-batch serving).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer


_ATTN_KINDS = ("attn", "local", "global", "dense", "attn_moe")


def _pad_cache_to(cfg, cache, max_len: int):
    """Pad prefill KV (B,S,KH,D) leaves (attention blocks only) to max_len."""
    def pad(path, leaf):
        if leaf is None:
            return None
        parts = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        if parts[0] == "prefix":
            kind = cfg.prefix[int(parts[1])]
        else:  # blocks/pos{i}/...
            kind = cfg.pattern[int(str(parts[1])[3:])]
        if kind not in _ATTN_KINDS:
            return leaf
        s_ax = leaf.ndim - 3                       # (R?, B, S, KH, D)
        cur = leaf.shape[s_ax]
        if cur == max_len:
            return leaf
        pad_widths = [(0, 0)] * leaf.ndim
        pad_widths[s_ax] = (0, max_len - cur)
        return jnp.pad(leaf, pad_widths)

    return jax.tree_util.tree_map_with_path(pad, cache)


def build_prefill_step(cfg, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        h, cache, _ = transformer.forward(params, cfg, batch, mode="prefill")
        logits = transformer.lm_logits(params, cfg, h[:, -1:])
        if max_len is not None:
            cache = _pad_cache_to(cfg, cache, max_len)
        return logits, cache
    return prefill_step


def build_decode_step(cfg):
    def decode_step(params, cache, tokens_or_embeds, cache_len):
        """tokens: (B,1)/(B,1,ncb) (or embeds (B,1,D)); cache_len: scalar."""
        if cfg.embed_inputs:
            batch = {"tokens": tokens_or_embeds}
        else:
            batch = {"embeds": tokens_or_embeds}
        if cfg.mrope:
            b = tokens_or_embeds.shape[0]
            pos = jnp.broadcast_to(cache_len[None, None, None]
                                   if hasattr(cache_len, "shape")
                                   else jnp.asarray(cache_len)[None, None, None],
                                   (b, 1, 3)).astype(jnp.int32)
            batch["positions"] = pos
        else:
            b = tokens_or_embeds.shape[0]
            batch["positions"] = jnp.broadcast_to(
                jnp.asarray(cache_len, jnp.int32)[None, None], (b, 1))
        h, cache, _ = transformer.forward(params, cfg, batch, mode="decode",
                                          cache=cache, cache_len=cache_len)
        logits = transformer.lm_logits(params, cfg, h)
        return logits, cache
    return decode_step


def sample(key, logits, temperature: float = 0.0):
    """logits (B,1,V) or (B,1,ncb,V) -> token ids."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)

"""Step builders: train / eval, with chunked cross-entropy and sharding.

The LM head + softmax is the peak-memory site at large vocab (163k for
Kimi): ``chunked_ce`` scans the sequence in ``cfg.loss_chunk`` slices with
rematerialization, bounding logits memory to B x chunk x V while keeping
the same gradients.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed import context as dctx, sharding as shd
from repro.models import transformer
from repro.optim import optimizers as opt


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _ce_chunk(params, cfg, h_chunk, labels_chunk):
    logits = transformer.lm_logits(params, cfg, h_chunk).astype(jnp.float32)
    logits = shd.constrain(
        logits, ("dp",) + (None,) * (logits.ndim - 2) + ("tp",))
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels_chunk[..., None],
                               axis=-1)[..., 0]
    return jnp.sum(lse - gold), labels_chunk.size


def chunked_ce(params, cfg, h, labels):
    """h: (B,S,D); labels: (B,S) or (B,S,ncb). Mean CE over all tokens."""
    b, s, d = h.shape
    c = min(cfg.loss_chunk, s)
    if s % c:
        c = s  # fallback: single chunk
    n = s // c
    hc = h.reshape(b, n, c, d).swapaxes(0, 1)                  # (n,B,c,D)
    lc = labels.reshape((b, n, c) + labels.shape[2:]).swapaxes(0, 1)

    def body(carry, xs):
        hx, lx = xs
        tot, cnt = jax.checkpoint(
            functools.partial(_ce_chunk, params, cfg))(hx, lx)
        return (carry[0] + tot, carry[1] + cnt), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), 0), (hc, lc))
    return tot / jnp.maximum(cnt, 1)


# ---------------------------------------------------------------------------
# Train / eval steps
# ---------------------------------------------------------------------------

def make_loss_fn(cfg):
    def loss_fn(params, batch):
        h, _, aux = transformer.forward(params, cfg, batch, mode="train")
        ce = chunked_ce(params, cfg, h, batch["labels"])
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def build_train_step(cfg, optimizer: opt.Optimizer):
    loss_fn = make_loss_fn(cfg)

    def train_step(state, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], batch)
        new_params, new_opt, gnorm = optimizer.update(
            grads, state["opt_state"], state["params"], state["step"])
        metrics = {"loss": loss, "ce": parts["ce"], "aux": parts["aux"],
                   "grad_norm": gnorm}
        return {"params": new_params, "opt_state": new_opt,
                "step": state["step"] + 1}, metrics

    return train_step


def build_eval_step(cfg):
    loss_fn = make_loss_fn(cfg)

    def eval_step(params, batch):
        loss, parts = loss_fn(params, batch)
        return {"loss": loss, **parts}

    return eval_step


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

def create_state(cfg, key, optimizer: opt.Optimizer):
    params = transformer.init_params(key, cfg)
    return {"params": params, "opt_state": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_shape(cfg, optimizer: opt.Optimizer):
    """abstract state (ShapeDtypeStructs) without allocating anything."""
    return jax.eval_shape(lambda k: create_state(cfg, k, optimizer),
                          jax.random.PRNGKey(0))


def state_specs(cfg, mesh, optimizer: opt.Optimizer):
    """PartitionSpecs for the full train state.

    Optimizer leaves mirror their parameter's spec exactly; adafactor's
    factored vectors inherit the surviving dims' axes ("vr" drops the last
    dim, "vc" drops the second-to-last).
    """
    P = jax.sharding.PartitionSpec
    shapes = state_shape(cfg, optimizer)
    pspecs = shd.param_specs(cfg, mesh, shapes["params"])
    by_path = {shd._path_str(path): spec for path, spec in
               jax.tree_util.tree_flatten_with_path(
                   pspecs, is_leaf=lambda x: isinstance(x, P))[0]}

    def opt_spec(path, leaf):
        parts = shd._path_str(path).split("/")
        tail = None
        if parts and parts[-1] in ("vr", "vc", "v"):
            tail = parts[-1]
        core = parts[1:-1] if tail else parts[1:]   # strip leading m|v dict key
        ref = by_path.get("/".join(core))
        if ref is None and tail is None:
            ref = by_path.get("/".join(parts[1:]))
            tail = None
        if ref is None:
            return P(*([None] * len(leaf.shape)))
        if tail == "vr":
            return P(*ref[:-1])
        if tail == "vc":
            return P(*ref[:-2], ref[-1])
        return ref

    ospecs = jax.tree_util.tree_map_with_path(opt_spec, shapes["opt_state"])
    return {"params": pspecs, "opt_state": ospecs, "step": P()}

"""End-to-end training driver: a *binary* (W1A1, the paper's technique)
language model trained for a few hundred steps, with a simulated
preemption + checkpoint restart in the middle, then greedy decoding
through the serving path.

This is the paper's contribution lifted to the LM tier of the framework:
BitLinear projections (XNOR-popcount semantics, STE-trained) inside a
standard transformer, the BinarEye S-knob exposed as ``width_mult``.

    PYTHONPATH=src python examples/train_binary_lm.py
"""

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import ckpt
from repro.configs.registry import get_config
from repro.data import tokens as dtok
from repro.optim import optimizers as opt
from repro.train import serve, steps

TOTAL_STEPS = 240
CRASH_AT = 120          # simulated preemption
B, S = 8, 64


def make_cfg():
    # smollm family, reduced for CPU, with the paper's technique ON:
    # every FFN/attention projection is a BitLinear (W1A1 + STE).
    return (get_config("smollm-360m", quant="binary").scaled()
            .with_(dtype="float32", param_dtype="float32",
                   quant="binary", loss_chunk=32))


def train(cfg, ckpt_dir, start_step, state=None):
    optimizer = opt.make(cfg.optimizer, opt.cosine_schedule(3e-3, 20, TOTAL_STEPS))
    if state is None:
        state = steps.create_state(cfg, jax.random.PRNGKey(0), optimizer)
        if start_step > 0:  # restart path: restore from latest checkpoint
            state = ckpt.restore(os.path.join(ckpt_dir, f"ckpt_{start_step}"),
                                 state)
            print(f"  restored checkpoint @ step {start_step}")
    train_step = jax.jit(steps.build_train_step(cfg, optimizer), donate_argnums=0)
    writer = ckpt.AsyncCheckpointer(ckpt_dir, keep=2)
    losses = []
    for i in range(start_step, TOTAL_STEPS):
        batch = dtok.batch_for_step(cfg, i, global_batch=B, seq_len=S)
        state, metrics = train_step(state, batch)
        losses.append(float(metrics["loss"]))
        if i % 40 == 0:
            print(f"  step {i:4d}  loss {losses[-1]:.3f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}")
        if (i + 1) % CRASH_AT == 0:
            writer.save(state, i + 1)
        if (i + 1) == CRASH_AT:
            writer.wait()
            print(f"  !! simulated preemption after step {i + 1}")
            return state, losses, True
    writer.wait()
    return state, losses, False


def main():
    cfg = make_cfg()
    ckpt_dir = tempfile.mkdtemp(prefix="binary_lm_")
    print(f"config: {cfg.name} quant={cfg.quant} "
          f"d_model={cfg.d_model} layers={cfg.num_layers}")

    print("\nphase 1: train until preemption")
    _, losses1, crashed = train(cfg, ckpt_dir, 0)
    assert crashed

    print("\nphase 2: fresh process restarts from the checkpoint")
    latest = ckpt.latest_step(ckpt_dir)
    state, losses2, _ = train(cfg, ckpt_dir, latest)
    losses = losses1 + losses2

    first, last = sum(losses[:20]) / 20, sum(losses[-20:]) / 20
    print(f"\nloss: first-20 avg {first:.3f} -> last-20 avg {last:.3f}")
    assert last < first, "training did not reduce the loss"

    print("\nphase 3: greedy decode through the serving path")
    prefill = jax.jit(serve.build_prefill_step(cfg, max_len=S + 16))
    decode = jax.jit(serve.build_decode_step(cfg))
    batch = dtok.batch_for_step(cfg, 0, global_batch=2, seq_len=S)
    prompt = batch["tokens"][:, : S // 2]
    logits, cache = prefill(state["params"],
                            {"tokens": prompt,
                             "positions": jnp.arange(S // 2)[None, :].repeat(2, 0)})
    toks = serve.sample(None, logits)
    out = [toks]
    for t in range(8):
        logits, cache = decode(state["params"], cache, toks,
                               jnp.asarray(S // 2 + t, jnp.int32))
        toks = serve.sample(None, logits)
        out.append(toks)
    gen = jnp.concatenate(out, axis=1)
    print(f"generated token ids: {gen.tolist()}")
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab_size)))
    shutil.rmtree(ckpt_dir, ignore_errors=True)
    print("\nOK: binary LM trained, survived preemption, served.")


if __name__ == "__main__":
    main()

"""End-to-end serving driver: BinarEye as an always-on sliding-window
face detector on QQVGA frames (the paper's Sec. III-B deployment).

A stream of 160x120 frames is scanned with 32x32 windows at stride 16
(the paper's setting); every window batch runs through the deployed
(folded, integer-threshold) detector; per-frame detections come back with
the frame's energy/latency bill from the chip model.

    PYTHONPATH=src python examples/always_on_detector.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core.chip import energy, interpreter, isa, networks
from repro.data import images as dimg
from repro.optim import optimizers as opt

QQVGA_H, QQVGA_W = 120, 160
WIN, STRIDE = 32, 16


def detector_batch(i, batch=32):
    """Half 'face' windows (smooth class template + noise), half background
    windows drawn from the SAME distribution the deployed stream sees."""
    faces, _ = dimg.batch_for_step(i, batch=batch // 2, num_classes=1,
                                   h=WIN, w=WIN)
    key = jax.random.fold_in(jax.random.PRNGKey(3), i)
    bg = jax.random.randint(key, (batch - batch // 2, WIN, WIN, 3), 0, 128)
    images = jnp.concatenate([faces, bg])
    labels = jnp.concatenate([jnp.ones(batch // 2, jnp.int32),
                              jnp.zeros(batch - batch // 2, jnp.int32)])
    return images, labels


def train_detector(program, steps=40):
    """Face/no-face BinaryNet, trained on synthetic 2-class data."""
    key = jax.random.PRNGKey(7)
    params = interpreter.init_params(key, program)
    optimizer = opt.make("adamw", opt.cosine_schedule(2e-3, 20, steps))
    opt_state = optimizer.init(params)

    @jax.jit
    def step(params, opt_state, i, images, labels):
        def loss_fn(p):
            logits, new_p = interpreter.forward_train(p, program, images)
            one_hot = jax.nn.one_hot(labels, 2)
            loss = jnp.mean(jnp.sum(jnp.maximum(
                0.0, 1.0 - (2 * one_hot - 1) * logits * 0.1), axis=-1))
            return loss, new_p
        (loss, new_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, _ = optimizer.update(grads, opt_state, new_p, i)
        return params, opt_state, loss

    for i in range(steps):
        images, labels = detector_batch(i)
        params, opt_state, loss = step(params, opt_state, jnp.asarray(i),
                                       images, labels)
    return params


def windows_of(frame):
    """(H,W,C) -> (N,32,32,C) sliding windows at stride 16."""
    ys = range(0, QQVGA_H - WIN + 1, STRIDE)
    xs = range(0, QQVGA_W - WIN + 1, STRIDE)
    wins = [frame[y:y + WIN, x:x + WIN] for y in ys for x in xs]
    return jnp.stack(wins), [(y, x) for y in ys for x in xs]


def synthetic_frame(step, face_at=None):
    """A QQVGA frame of background noise, optionally with a 'face' pasted."""
    key = jax.random.fold_in(jax.random.PRNGKey(99), step)
    frame = jax.random.randint(key, (QQVGA_H, QQVGA_W, 3), 0, 128)
    if face_at is not None:
        face, _ = dimg.batch_for_step(step, batch=1, num_classes=1,
                                      h=WIN, w=WIN)
        y, x = face_at
        frame = frame.at[y:y + WIN, x:x + WIN].set(face[0])
    return frame


def main():
    # the paper's face-detection operating point: 9-layer net at S=4
    program = networks.face_detector()
    print("training the detector (synthetic face/background data)...")
    params = train_detector(program)
    # deployment: fold BN into integer thresholds and bit-pack the weights
    # (the artifact the chip's SRAMs would hold), then compile the program
    # geometry once into the packed-domain inference plan.
    packed = interpreter.fold_params(params, program, packed=True)
    plan = interpreter.compile_plan(program)
    infer = plan.make_fn()

    # chip-level cost of one frame: 54 windows/frame at stride 16
    r = energy.analyze_net(program)
    n_win = len(range(0, QQVGA_H - WIN + 1, STRIDE)) * \
        len(range(0, QQVGA_W - WIN + 1, STRIDE))
    e_frame = r.i2l_energy_per_inference * n_win
    fps_1mw = 1e-3 / e_frame
    fps_10mw = 10e-3 / e_frame
    print(f"\nchip bill: {n_win} windows/frame x "
          f"{r.i2l_energy_per_inference*1e6:.2f} uJ = "
          f"{e_frame*1e6:.0f} uJ/frame")
    print(f"  -> {fps_1mw:5.1f} fps at 1 mW, {fps_10mw:5.1f} fps at 10 mW "
          "(paper: 1-20 fps @ 1 mW, 15-200 @ 10 mW, task-dependent stride)")

    # stream 8 frames, half with a face planted
    print("\nstreaming QQVGA frames (packed-domain plan, batched windows):")
    hits = 0
    host_s = 0.0
    for t in range(8):
        face_at = (16 + 16 * (t % 3), 32 + 16 * (t % 4)) if t % 2 else None
        frame = synthetic_frame(t, face_at)
        wins, coords = windows_of(frame)
        t0 = time.perf_counter()
        _, pred = infer(packed, wins)
        pred.block_until_ready()
        host_ms = (time.perf_counter() - t0) * 1e3
        if t:                                   # skip the compile frame
            host_s += host_ms * 1e-3
        det = [coords[i] for i in range(n_win) if int(pred[i]) == 1]
        # a window is a true hit if it overlaps the planted face
        hit = face_at is not None and any(
            abs(y - face_at[0]) <= 16 and abs(x - face_at[1]) <= 16
            for (y, x) in det)
        hits += hit or (face_at is None and not det)
        chip_ms = n_win / r.inferences_per_s * 1e3
        print(f"  frame {t}: face@{face_at}  detections={det[:3]}"
              f"{'...' if len(det) > 3 else ''}  "
              f"[chip {chip_ms:.1f} ms, host-sim {host_ms:.0f} ms]")
    host_fps = 7 / host_s
    host_wps = host_fps * n_win
    print(f"\nframe-level agreement: {hits}/8")
    print(f"host-sim throughput: {host_fps:.1f} frames/s "
          f"({host_wps:,.0f} windows/s through the packed plan)")
    print(f"battery: 810 mWh AAA / 1 mW = {810/24:.1f} days always-on at "
          f"{fps_1mw:.1f} fps (paper: 'up to 33 days')")


if __name__ == "__main__":
    main()

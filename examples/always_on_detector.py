"""End-to-end serving example: BinarEye as an always-on sliding-window
face detector on QQVGA frames (the paper's Sec. III-B deployment).

A stream of 160x120 frames is scanned with 32x32 windows at stride 16
(the paper's setting); every window is *submitted to the chip-tier
serving layer* (``repro.serving.ChipServer``): the detector program stays
resident with its packed deployment artifact, windows queue as frame
requests, and the scheduler dispatches static batches through the packed
``InferencePlan``.  Per-frame detections come back with the frame's
energy/latency bill from the chip model, and the run closes with the
server's aggregate serving stats.

    PYTHONPATH=src python examples/always_on_detector.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chip import interpreter, networks
from repro.data import images as dimg
from repro.optim import optimizers as opt
from repro.serving import ChipServer

QQVGA_H, QQVGA_W = 120, 160
WIN, STRIDE = 32, 16


def detector_batch(i, batch=32):
    """Half 'face' windows (smooth class template + noise), half background
    windows drawn from the SAME distribution the deployed stream sees."""
    faces, _ = dimg.batch_for_step(i, batch=batch // 2, num_classes=1,
                                   h=WIN, w=WIN)
    key = jax.random.fold_in(jax.random.PRNGKey(3), i)
    bg = jax.random.randint(key, (batch - batch // 2, WIN, WIN, 3), 0, 128)
    images = jnp.concatenate([faces, bg])
    labels = jnp.concatenate([jnp.ones(batch // 2, jnp.int32),
                              jnp.zeros(batch - batch // 2, jnp.int32)])
    return images, labels


def train_detector(program, steps=40):
    """Face/no-face BinaryNet, trained on synthetic 2-class data."""
    key = jax.random.PRNGKey(7)
    params = interpreter.init_params(key, program)
    optimizer = opt.make("adamw", opt.cosine_schedule(2e-3, 20, steps))
    opt_state = optimizer.init(params)

    @jax.jit
    def step(params, opt_state, i, images, labels):
        def loss_fn(p):
            logits, new_p = interpreter.forward_train(p, program, images)
            one_hot = jax.nn.one_hot(labels, 2)
            loss = jnp.mean(jnp.sum(jnp.maximum(
                0.0, 1.0 - (2 * one_hot - 1) * logits * 0.1), axis=-1))
            return loss, new_p
        (loss, new_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, _ = optimizer.update(grads, opt_state, new_p, i)
        return params, opt_state, loss

    for i in range(steps):
        images, labels = detector_batch(i)
        params, opt_state, loss = step(params, opt_state, jnp.asarray(i),
                                       images, labels)
    return params


def windows_of(frame):
    """(H,W,C) -> (N,32,32,C) sliding windows at stride 16."""
    ys = range(0, QQVGA_H - WIN + 1, STRIDE)
    xs = range(0, QQVGA_W - WIN + 1, STRIDE)
    wins = [frame[y:y + WIN, x:x + WIN] for y in ys for x in xs]
    return jnp.stack(wins), [(y, x) for y in ys for x in xs]


def synthetic_frame(step, face_at=None):
    """A QQVGA frame of background noise, optionally with a 'face' pasted."""
    key = jax.random.fold_in(jax.random.PRNGKey(99), step)
    frame = jax.random.randint(key, (QQVGA_H, QQVGA_W, 3), 0, 128)
    if face_at is not None:
        face, _ = dimg.batch_for_step(step, batch=1, num_classes=1,
                                      h=WIN, w=WIN)
        y, x = face_at
        frame = frame.at[y:y + WIN, x:x + WIN].set(face[0])
    return frame


def main():
    # the paper's face-detection operating point: 9-layer net at S=4
    program = networks.face_detector()
    print("training the detector (synthetic face/background data)...")
    params = train_detector(program)
    # deployment: fold BN into integer thresholds and bit-pack the weights
    # (the artifact the chip's SRAMs would hold), then park the program
    # resident in the chip-tier serving layer — windows arrive as frame
    # requests and dispatch as static batches through the packed plan.
    packed = interpreter.fold_params(params, program, packed=True)
    n_win = len(range(0, QQVGA_H - WIN + 1, STRIDE)) * \
        len(range(0, QQVGA_W - WIN + 1, STRIDE))
    server = ChipServer({"face": program}, {"face": packed}, batch=n_win)

    # chip-level cost of one frame: 54 windows/frame at stride 16
    r = server.stats().chip.reports["face"]
    e_frame = r.i2l_energy_per_inference * n_win
    fps_1mw = 1e-3 / e_frame
    fps_10mw = 10e-3 / e_frame
    print(f"\nchip bill: {n_win} windows/frame x "
          f"{r.i2l_energy_per_inference*1e6:.2f} uJ = "
          f"{e_frame*1e6:.0f} uJ/frame")
    print(f"  -> {fps_1mw:5.1f} fps at 1 mW, {fps_10mw:5.1f} fps at 10 mW "
          "(paper: 1-20 fps @ 1 mW, 15-200 @ 10 mW, task-dependent stride)")

    # stream 8 frames, half with a face planted
    print("\nstreaming QQVGA frames (windows served as frame requests):")
    hits = 0
    compile_wall = 0.0              # frame 0 includes the jit compile
    for t in range(8):
        face_at = (16 + 16 * (t % 3), 32 + 16 * (t % 4)) if t % 2 else None
        frame = synthetic_frame(t, face_at)
        wins, coords = windows_of(frame)
        wall0 = server.stats().host_wall_s
        rids = server.submit_many("face", np.asarray(wins))
        results = {res.rid: res for res in server.drain()}
        host_ms = (server.stats().host_wall_s - wall0) * 1e3
        if t == 0:
            compile_wall = host_ms * 1e-3
        det = [coords[i] for i, rid in enumerate(rids)
               if results[rid].label == 1]
        # a window is a true hit if it overlaps the planted face
        hit = face_at is not None and any(
            abs(y - face_at[0]) <= 16 and abs(x - face_at[1]) <= 16
            for (y, x) in det)
        hits += hit or (face_at is None and not det)
        chip_ms = n_win / r.inferences_per_s * 1e3
        print(f"  frame {t}: face@{face_at}  detections={det[:3]}"
              f"{'...' if len(det) > 3 else ''}  "
              f"[chip {chip_ms:.1f} ms, host-sim {host_ms:.0f} ms]")
    stats = server.stats()
    # steady-state throughput: exclude the compile frame, as the seed did
    steady_s = stats.host_wall_s - compile_wall
    host_fps = 7 / steady_s if steady_s else 0.0
    print(f"\nframe-level agreement: {hits}/8")
    print(f"serving stats: {stats.total_served} windows in "
          f"{stats.dispatches} dispatches, 0 padded slots expected -> "
          f"{stats.padded['face']} padded")
    print(f"host-sim throughput: {host_fps:.1f} frames/s "
          f"({host_fps * n_win:,.0f} windows/s through the server)")
    print(f"chip-model serving bill: {stats.chip.uj_per_frame:.2f} uJ/window,"
          f" {stats.chip.frames_per_s:,.0f} windows/s at Emin")
    print(f"battery: 810 mWh AAA / 1 mW = {810/24:.1f} days always-on at "
          f"{fps_1mw:.1f} fps (paper: 'up to 33 days')")


if __name__ == "__main__":
    main()

"""Quickstart: train a BinaryNet on the BinarEye chip model, fold it for
deployment, and read off the chip-level energy/latency report.

Runs in ~1 minute on CPU:

    PYTHONPATH=src python examples/quickstart.py

Walks through all three levels of the chip's flexibility:
  1. retrainable weights   (STE BinaryNet training -> fold -> deploy)
  2. programmable depth    (the ISA program defines the network)
  3. programmable width    (the S knob trades energy for accuracy)
"""

import jax
import jax.numpy as jnp

from repro.core.chip import energy, interpreter, isa, networks
from repro.data import images as dimg
from repro.optim import optimizers as opt


def main():
    # --- 1. a *small* always-on program (depth = ISA program) --------------
    # cifar9(s=4) is the paper's face-detection operating point; we shrink
    # the input to 16x16 for a CPU-friendly demo with the same structure.
    f = isa.ARRAY_CHANNELS // 4
    program = isa.Program(s=4, instrs=(
        isa.IOInstr(height=16, width=16, in_channels=3, bits=7, channels=f),
        isa.ConvInstr(height=16, width=16, features=f, maxpool=True),  # ->7
        isa.ConvInstr(height=7, width=7, features=f, maxpool=True),    # ->3
        isa.FCInstr(in_features=3 * 3 * f, out_features=10, final=True),
    ))
    isa.validate(program)

    # --- 2. train it (BinaryNet STE semantics, synthetic 10-class data) ----
    key = jax.random.PRNGKey(0)
    params = interpreter.init_params(key, program)
    optimizer = opt.make("adamw", opt.cosine_schedule(2e-3, 20, 300))
    opt_state = optimizer.init(params)

    @jax.jit
    def step(params, opt_state, i, images, labels):
        def loss_fn(p):
            logits, new_p = interpreter.forward_train(p, program, images)
            one_hot = jax.nn.one_hot(labels, 10)
            # hinge-style loss works well for integer BinaryNet logits
            loss = jnp.mean(jnp.sum(jnp.maximum(
                0.0, 1.0 - one_hot * logits + (1 - one_hot) * logits * 0.1),
                axis=-1))
            return loss, new_p
        (loss, new_p), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, _gn = optimizer.update(grads, opt_state, new_p, i)
        return params, opt_state, loss

    for i in range(300):
        images, labels = dimg.batch_for_step(i, batch=64, num_classes=10,
                                             h=16, w=16)
        params, opt_state, loss = step(params, opt_state,
                                       jnp.asarray(i), images, labels)
        if i % 50 == 0:
            print(f"step {i:4d}  loss {float(loss):.3f}")

    # --- 3. fold + deploy (what the chip actually stores/computes) ---------
    folded = interpreter.fold_params(params, program)
    infer = interpreter.make_infer_fn(program)
    images, labels = dimg.batch_for_step(10_000, batch=256, num_classes=10,
                                         h=16, w=16)
    _, pred = infer(folded, images)
    acc = float(jnp.mean(pred == labels))
    print(f"\ndeployed accuracy (folded integer comparator): {acc:.1%}")

    # --- 4. the energy/latency story (the paper's evaluation axis) ---------
    print("\nchip-level report for the paper's S operating points "
          "(9-layer net):")
    for s in (1, 2, 4):
        r = energy.analyze_net(networks.cifar9(s))
        print(f"  S={s}: {r.i2l_energy_per_inference*1e6:6.2f} uJ/frame, "
              f"{r.inferences_per_s:7.0f} inf/s, {r.power_w*1e3:5.2f} mW, "
              f"{r.i2l_tops_per_w:6.1f} I2L TOPS/W")
    print("\n(energy scales ~S^2: the third flexibility level — width)")


if __name__ == "__main__":
    main()

"""Kernel-level microbenchmark: the XNOR-popcount binary path vs the
float path, wall-clock on this host (CPU XLA) plus the analytic TPU
picture.

On TPU the binary path's win is structural: 32 channels/int32 lane give a
32x bandwidth-density gain on the VPU (the MXU has no 1-bit mode), which
is the BinarEye insight mapped to TPU.  On CPU XLA we can still *measure*
the packed-popcount path vs float matmul to show the data-movement win is
real, and we verify allclose against ref.py oracles.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref


def _bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(csv: bool = True):
    key = jax.random.PRNGKey(0)
    M, K, N = 512, 1024, 512
    a = jnp.where(jax.random.bernoulli(key, shape=(M, K)), 1, -1).astype(jnp.int8)
    w = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(1), shape=(K, N)),
                  1, -1).astype(jnp.int8)

    a_f = a.astype(jnp.float32)
    w_f = w.astype(jnp.float32)
    a_words = ops.pack(a)
    w_words = ops.pack(w.T)

    float_mm = jax.jit(lambda x, y: x @ y)
    packed_mm = jax.jit(lambda x, y: ref.xnor_matmul_packed_ref(x, y, K))

    t_float = _bench(float_mm, a_f, w_f)
    t_packed = _bench(packed_mm, a_words, w_words)

    got = packed_mm(a_words, w_words)
    want = a_f @ w_f
    ok = bool(jnp.all(got.astype(jnp.float32) == want))

    print("\n== Kernel microbench: XNOR-popcount vs float matmul "
          f"({M}x{K}x{N}) ==")
    print(f"float f32 matmul : {t_float:9.0f} us")
    print(f"packed xnor path : {t_packed:9.0f} us   "
          f"({t_float/t_packed:.1f}x vs float on CPU XLA)")
    print(f"bitpacked operand bytes: {a_words.nbytes + w_words.nbytes} "
          f"vs float {a_f.nbytes + w_f.nbytes} "
          f"({(a_f.nbytes + w_f.nbytes)/(a_words.nbytes + w_words.nbytes):.0f}x "
          "bandwidth density)")
    print(f"exact match vs float oracle: {ok}")

    # analytic TPU picture (per chip): binary VPU path vs bf16 MXU path
    # VPU: 8x128 lanes x ~940 MHz x (xor+popcount+acc ~ 3 ops on 32 ch) =
    #      ~32 ch/lane -> ~1e13 int ops/s -> ~3.2e14 1b-MAC/s
    # MXU bf16: 197e12/2 = 9.85e13 MAC/s with +-1 as bf16
    vpu_1b_macs = 8 * 128 * 940e6 * 32 / 3
    mxu_bf16_macs = 197e12 / 2
    print(f"TPU analytic: VPU packed-binary ~{vpu_1b_macs:.1e} MAC/s vs "
          f"MXU bf16(+-1) ~{mxu_bf16_macs:.1e} MAC/s -> "
          f"{vpu_1b_macs/mxu_bf16_macs:.1f}x, plus 16x smaller weight "
          "footprint (VMEM-resident models)")
    if csv:
        print(f"CSV,kernel_microbench,{t_packed:.0f},"
              f"speedup_vs_float={t_float/t_packed:.2f};exact={int(ok)}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)

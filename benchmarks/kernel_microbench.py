"""Kernel-level microbenchmark: the packed-domain inference pipeline.

Three measurements, all on this host (CPU XLA; on TPU the same code
lowers through Mosaic):

1. packed XNOR-popcount matmul vs float matmul (the seed's original
   data-movement demonstration, kept as a trend anchor);
2. the fused batched pipeline (``InferencePlan``: single IO pack, fused
   conv->threshold->pool->repack stages, packed hidden FC) vs the seed
   path (per-image ``jax.vmap`` conv kernel + float comparator + repack
   at every layer boundary) on a full benchmark program — this is the
   end-to-end win of keeping feature maps bit-packed;
3. frames/sec of the deployed plan, the serving-throughput headline.

Results are written to ``BENCH_kernels.json`` so CI keeps a perf
trajectory across PRs.  Exit 0 iff both paths are bit-exact vs their
oracles.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.chip import interpreter, networks, neuron_array as na
from repro.kernels import ops, ref
from repro.kernels import binary_conv2x2 as _bc

BENCH_JSON = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")


def _bench(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))              # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _seed_vmap_forward(program, folded, images):
    """The seed inference path, reproduced verbatim as the baseline: a
    per-image vmap of the 3D conv kernel, float comparator, float pool,
    and a pack/unpack round-trip at *every* layer boundary."""
    ci = fi = 0
    x = None
    from repro.core.chip import isa
    for ins in program.instrs:
        if isinstance(ins, isa.IOInstr):
            x = na.thermometer_encode(images, ins.bits, ins.channels)
        elif isinstance(ins, isa.ConvInstr):
            p = folded["conv"][ci]
            c = x.shape[-1]
            f = p["w"].shape[0]
            x_words = binarize.pack_signs(x, axis=-1)
            w_words = binarize.pack_signs(p["w"].reshape(f, 4, c), axis=-1)
            conv = lambda img: _bc.binary_conv2x2(
                img, w_words, c=c, interpret=ops.default_interpret())
            s = jax.vmap(conv)(x_words).astype(jnp.float32)
            x = na.comparator(s, p["tau"], p["flip"])
            if ins.maxpool:
                x = na.maxpool2x2(x)
            ci += 1
        else:
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            p = folded["fc"][fi]
            s = na.fc_packed(x, p["w"])
            x = s if ins.final else binarize.hard_sign(s)
            fi += 1
    return x, jnp.argmax(x, axis=-1)


def _bench_matmul(results):
    key = jax.random.PRNGKey(0)
    M, K, N = 512, 1024, 512
    a = jnp.where(jax.random.bernoulli(key, shape=(M, K)), 1, -1).astype(jnp.int8)
    w = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(1), shape=(K, N)),
                  1, -1).astype(jnp.int8)

    a_f = a.astype(jnp.float32)
    w_f = w.astype(jnp.float32)
    a_words = ops.pack(a)
    w_words = ops.pack(w.T)

    float_mm = jax.jit(lambda x, y: x @ y)
    packed_mm = jax.jit(lambda x, y: ref.xnor_matmul_packed_ref(x, y, K))

    t_float = _bench(float_mm, a_f, w_f)
    t_packed = _bench(packed_mm, a_words, w_words)
    ok = bool(jnp.all(packed_mm(a_words, w_words).astype(jnp.float32)
                      == a_f @ w_f))

    print(f"\n== XNOR-popcount vs float matmul ({M}x{K}x{N}) ==")
    print(f"float f32 matmul : {t_float:9.0f} us")
    print(f"packed xnor path : {t_packed:9.0f} us   "
          f"({t_float/t_packed:.1f}x vs float on CPU XLA)")
    print(f"bitpacked operand bytes: {a_words.nbytes + w_words.nbytes} "
          f"vs float {a_f.nbytes + w_f.nbytes} "
          f"({(a_f.nbytes + w_f.nbytes)/(a_words.nbytes + w_words.nbytes):.0f}x "
          "bandwidth density)")
    print(f"exact match vs float oracle: {ok}")
    results["xnor_matmul_us"] = round(t_packed, 1)
    results["float_matmul_us"] = round(t_float, 1)
    results["matmul_speedup_vs_float"] = round(t_float / t_packed, 2)
    return ok


def _bench_pipeline(results):
    """Fused batched plan vs the seed per-image-vmap path, full program."""
    program = networks.mnist5()
    batch = 8
    key = jax.random.PRNGKey(2)
    params = interpreter.init_params(key, program)
    io = program.instrs[0]
    imgs = jax.random.randint(
        jax.random.PRNGKey(3), (batch, io.height, io.width, io.in_channels),
        0, 2 ** io.bits)
    _, params = interpreter.forward_train(params, program, imgs)
    folded = interpreter.fold_params(params, program)
    packed = interpreter.pack_folded(folded)

    plan = interpreter.compile_plan(program)
    # interpret=None -> per-backend choice: Python interpret on CPU,
    # Mosaic lowering on a real TPU (keeps the perf trajectory honest)
    fused = jax.jit(lambda pk, im: plan.forward(pk, im))
    seed = jax.jit(lambda fl, im: _seed_vmap_forward(program, fl, im))

    t_fused = _bench(fused, packed, imgs, iters=3)
    t_seed = _bench(seed, folded, imgs, iters=3)

    logits_f, labels_f = fused(packed, imgs)
    logits_s, labels_s = seed(folded, imgs)
    ok = bool(jnp.all(logits_f == logits_s) and jnp.all(labels_f == labels_s))
    fps = batch / (t_fused * 1e-6)
    speedup = t_seed / t_fused

    print(f"\n== Packed pipeline ({program.instrs[1].features}-wide mnist5, "
          f"batch={batch}) ==")
    print(f"seed per-image vmap path : {t_seed:9.0f} us/batch "
          "(int32->float->repack at every layer)")
    print(f"fused batched plan       : {t_fused:9.0f} us/batch "
          "(bit-packed end to end)")
    print(f"  -> {speedup:.2f}x, {fps:,.0f} frames/s host-sim throughput")
    print(f"fused plan bit-exact vs seed path: {ok}")
    results["pipeline_seed_vmap_us"] = round(t_seed, 1)
    results["pipeline_fused_us"] = round(t_fused, 1)
    results["pipeline_fused_speedup"] = round(speedup, 2)
    results["pipeline_frames_per_s"] = round(fps, 1)
    results["pipeline_batch"] = batch
    return ok, speedup


def run(csv: bool = True):
    results = {"backend": jax.default_backend()}
    ok_mm = _bench_matmul(results)
    ok_pipe, speedup = _bench_pipeline(results)
    ok = ok_mm and ok_pipe

    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"\nwrote {BENCH_JSON}")
    if csv:
        print(f"CSV,kernel_microbench,{results['pipeline_fused_us']:.0f},"
              f"fused_speedup={speedup:.2f};"
              f"fps={results['pipeline_frames_per_s']:.0f};exact={int(ok)}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)

"""Kernel-level microbenchmark: the packed-domain inference pipeline.

Three measurements, all on this host (CPU XLA; on TPU the same code
lowers through Mosaic):

1. packed XNOR-popcount matmul vs float matmul (the seed's original
   data-movement demonstration, kept as a trend anchor);
2. the fused batched pipeline (``InferencePlan``: single IO pack, fused
   conv->threshold->pool->repack stages, packed hidden FC) vs the seed
   path (per-image ``jax.vmap`` conv kernel + float comparator + repack
   at every layer boundary) on a full benchmark program — this is the
   end-to-end win of keeping feature maps bit-packed;
3. frames/sec of the deployed plan, the serving-throughput headline;
4. frames/sec through the chip-tier serving subsystem (``ChipServer``):
   the same packed plan behind the request queue / static-batch
   scheduler, single-program and with two programs resident (S-mode
   multi-program batching) — and, when more than one device is visible
   (e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=4``), over
   the sharded serving mesh.

Results are written to ``BENCH_kernels.json`` so CI keeps a perf
trajectory across PRs.  Exit 0 iff all paths are bit-exact vs their
oracles.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.chip import interpreter, networks, neuron_array as na
from repro.kernels import ops, ref
from repro.kernels import binary_conv2x2 as _bc

BENCH_JSON = os.environ.get("BENCH_KERNELS_JSON", "BENCH_kernels.json")


def _bench(fn, *args, iters=5):
    jax.block_until_ready(fn(*args))              # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def _seed_vmap_forward(program, folded, images):
    """The seed inference path, reproduced verbatim as the baseline: a
    per-image vmap of the 3D conv kernel, float comparator, float pool,
    and a pack/unpack round-trip at *every* layer boundary."""
    ci = fi = 0
    x = None
    from repro.core.chip import isa
    for ins in program.instrs:
        if isinstance(ins, isa.IOInstr):
            x = na.thermometer_encode(images, ins.bits, ins.channels)
        elif isinstance(ins, isa.ConvInstr):
            p = folded["conv"][ci]
            c = x.shape[-1]
            f = p["w"].shape[0]
            x_words = binarize.pack_signs(x, axis=-1)
            w_words = binarize.pack_signs(p["w"].reshape(f, 4, c), axis=-1)
            conv = lambda img: _bc.binary_conv2x2(
                img, w_words, c=c, interpret=ops.default_interpret())
            s = jax.vmap(conv)(x_words).astype(jnp.float32)
            x = na.comparator(s, p["tau"], p["flip"])
            if ins.maxpool:
                x = na.maxpool2x2(x)
            ci += 1
        else:
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            p = folded["fc"][fi]
            s = na.fc_packed(x, p["w"])
            x = s if ins.final else binarize.hard_sign(s)
            fi += 1
    return x, jnp.argmax(x, axis=-1)


def _bench_matmul(results):
    key = jax.random.PRNGKey(0)
    M, K, N = 512, 1024, 512
    a = jnp.where(jax.random.bernoulli(key, shape=(M, K)), 1, -1).astype(jnp.int8)
    w = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(1), shape=(K, N)),
                  1, -1).astype(jnp.int8)

    a_f = a.astype(jnp.float32)
    w_f = w.astype(jnp.float32)
    a_words = ops.pack(a)
    w_words = ops.pack(w.T)

    float_mm = jax.jit(lambda x, y: x @ y)
    packed_mm = jax.jit(lambda x, y: ref.xnor_matmul_packed_ref(x, y, K))

    t_float = _bench(float_mm, a_f, w_f)
    t_packed = _bench(packed_mm, a_words, w_words)
    ok = bool(jnp.all(packed_mm(a_words, w_words).astype(jnp.float32)
                      == a_f @ w_f))

    print(f"\n== XNOR-popcount vs float matmul ({M}x{K}x{N}) ==")
    print(f"float f32 matmul : {t_float:9.0f} us")
    print(f"packed xnor path : {t_packed:9.0f} us   "
          f"({t_float/t_packed:.1f}x vs float on CPU XLA)")
    print(f"bitpacked operand bytes: {a_words.nbytes + w_words.nbytes} "
          f"vs float {a_f.nbytes + w_f.nbytes} "
          f"({(a_f.nbytes + w_f.nbytes)/(a_words.nbytes + w_words.nbytes):.0f}x "
          "bandwidth density)")
    print(f"exact match vs float oracle: {ok}")
    results["xnor_matmul_us"] = round(t_packed, 1)
    results["float_matmul_us"] = round(t_float, 1)
    results["matmul_speedup_vs_float"] = round(t_float / t_packed, 2)
    return ok


def _bench_pipeline(results):
    """Fused batched plan vs the seed per-image-vmap path, full program."""
    program = networks.mnist5()
    batch = 8
    key = jax.random.PRNGKey(2)
    params = interpreter.init_params(key, program)
    io = program.instrs[0]
    imgs = jax.random.randint(
        jax.random.PRNGKey(3), (batch, io.height, io.width, io.in_channels),
        0, 2 ** io.bits)
    _, params = interpreter.forward_train(params, program, imgs)
    folded = interpreter.fold_params(params, program)
    packed = interpreter.pack_folded(folded)

    plan = interpreter.compile_plan(program)
    # interpret=None -> per-backend choice: Python interpret on CPU,
    # Mosaic lowering on a real TPU (keeps the perf trajectory honest)
    fused = jax.jit(lambda pk, im: plan.forward(pk, im))
    seed = jax.jit(lambda fl, im: _seed_vmap_forward(program, fl, im))

    t_fused = _bench(fused, packed, imgs, iters=3)
    t_seed = _bench(seed, folded, imgs, iters=3)

    logits_f, labels_f = fused(packed, imgs)
    logits_s, labels_s = seed(folded, imgs)
    ok = bool(jnp.all(logits_f == logits_s) and jnp.all(labels_f == labels_s))
    fps = batch / (t_fused * 1e-6)
    speedup = t_seed / t_fused

    print(f"\n== Packed pipeline ({program.instrs[1].features}-wide mnist5, "
          f"batch={batch}) ==")
    print(f"seed per-image vmap path : {t_seed:9.0f} us/batch "
          "(int32->float->repack at every layer)")
    print(f"fused batched plan       : {t_fused:9.0f} us/batch "
          "(bit-packed end to end)")
    print(f"  -> {speedup:.2f}x, {fps:,.0f} frames/s host-sim throughput")
    print(f"fused plan bit-exact vs seed path: {ok}")
    results["pipeline_seed_vmap_us"] = round(t_seed, 1)
    results["pipeline_fused_us"] = round(t_fused, 1)
    results["pipeline_fused_speedup"] = round(speedup, 2)
    results["pipeline_frames_per_s"] = round(fps, 1)
    results["pipeline_batch"] = batch
    return ok, speedup


def _bench_serve(results):
    """Serving-layer throughput: the packed plan behind the scheduler.

    Artifacts and synthetic frame streams come from the serving driver's
    own helpers (``launch.chip_serve``) so the bench measures exactly the
    admission path the driver serves.
    """
    from repro.distributed import sharding
    from repro.launch import chip_serve
    from repro.serving import ChipServer

    batch, n_frames = 8, 32
    progs = {"mnist5": networks.mnist5(),
             "wake": networks.mnist5(classes=2)}
    arts, frames, oracle = {}, {}, {}
    for i, (name, prog) in enumerate(progs.items()):
        arts[name] = chip_serve.build_artifact(prog, seed=10 + i,
                                               warm_bn=True)
        frames[name] = chip_serve.frame_stream(prog, n_frames, seed=20 + i)
        plan = interpreter.compile_plan(prog)
        oracle[name] = np.asarray(
            jax.jit(lambda pk, im, plan=plan: plan.forward(pk, im)[1])(
                arts[name], jnp.asarray(frames[name])))

    def serve(names, label, mesh=None):
        server = ChipServer({n: progs[n] for n in names},
                            {n: arts[n] for n in names},
                            batch=batch, mesh=mesh)
        for n in names:                        # warm the compile caches
            server.submit_many(n, frames[n][:batch])
        server.drain()
        t0 = time.perf_counter()
        for i in range(n_frames):              # interleaved arrival
            for n in names:
                server.submit(n, frames[n][i])
        res = server.drain()
        dt = time.perf_counter() - t0
        per = {n: [] for n in names}
        for r in sorted(res, key=lambda r: r.rid):   # per-program FIFO
            per[r.program].append(r.label)
        ok = all(np.array_equal(np.array(per[n]), oracle[n][:len(per[n])])
                 for n in names)
        fps = len(res) / dt
        print(f"{label:24s}: {fps:10,.0f} frames/s "
              f"({len(res)} frames, {dt*1e3:.0f} ms, bit-exact={ok})")
        return fps, ok

    print(f"\n== Chip-tier serving (batch={batch}, {jax.device_count()} "
          "device(s)) ==")
    fps_1, ok_1 = serve(["mnist5"], "single program")
    fps_m, ok_m = serve(list(progs), "two programs resident")
    results["serve_frames_per_s"] = round(fps_1, 1)
    results["serve_frames_per_s_multi"] = round(fps_m, 1)
    results["serve_batch"] = batch
    ok = ok_1 and ok_m
    if jax.device_count() > 1:
        mesh = sharding.serve_mesh()
        fps_s, ok_s = serve(["mnist5"],
                            f"sharded x{mesh.devices.size}", mesh=mesh)
        results["serve_frames_per_s_sharded"] = round(fps_s, 1)
        results["serve_devices"] = int(mesh.devices.size)
        ok = ok and ok_s
    return ok


def run(csv: bool = True):
    results = {"backend": jax.default_backend()}
    ok_mm = _bench_matmul(results)
    ok_pipe, speedup = _bench_pipeline(results)
    ok_serve = _bench_serve(results)
    ok = ok_mm and ok_pipe and ok_serve

    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"\nwrote {BENCH_JSON}")
    if csv:
        print(f"CSV,kernel_microbench,{results['pipeline_fused_us']:.0f},"
              f"fused_speedup={speedup:.2f};"
              f"fps={results['pipeline_frames_per_s']:.0f};exact={int(ok)}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)

"""Kernel-level microbenchmark: the packed-domain inference pipeline.

Three measurements, all on this host (CPU XLA; on TPU the same code
lowers through Mosaic):

1. packed XNOR-popcount matmul vs float matmul (the seed's original
   data-movement demonstration, kept as a trend anchor);
2. the fused batched pipeline (``InferencePlan``: single IO pack, fused
   conv->threshold->pool->repack stages, packed hidden FC) vs the seed
   path (per-image ``jax.vmap`` conv kernel + float comparator + repack
   at every layer boundary) on a full benchmark program over a streaming
   batch — this is the end-to-end win of keeping feature maps bit-packed
   — plus a per-layer timing breakdown of the staged path;
3. the whole-network **megakernel** (weight image VMEM-resident, feature
   maps in VMEM scratch, frame tiles double-buffered through one
   ``pallas_call``) vs the staged plan, with the HBM bytes each mode
   moves (``energy.hbm_traffic``) — the all-memory-on-chip headline.
   Tile sizes come from the **persistent autotuner**
   (``kernels.autotune``): the bench tunes (bb, ft) / (bf, bb) for its
   programs on this backend, records the winners in the JSON cache
   (``BENCH_autotune.json``, shipped next to the bench baseline and
   uploaded as a CI artifact) and then benches through the cache-resolved
   tiles — exactly the warm path a deployment hits;
4. frames/sec of the deployed plan, the serving-throughput headline;
5. frames/sec through the chip-tier serving subsystem (``ChipServer``):
   the same packed plan behind the request queue / static-batch
   scheduler, single-program, with two programs resident (S-mode
   multi-program batching), with double-buffered submission
   (``prefetch=True``) — and, when more than one device is visible
   (e.g. ``XLA_FLAGS=--xla_force_host_platform_device_count=4``), over
   the sharded serving mesh;
6. **shared-array dispatch**: four S=4 programs resident at once, served
   time-interleaved (solo dispatches at 25% array occupancy) vs through
   ``ChipServer(shared=True)`` composite dispatches (one ``pallas_call``
   per batch runs all four sub-arrays concurrently) — the paired
   speedup, both frames/s figures and the measured ``array_utilization``
   go into the baseline, and the regression guard holds the speedup
   floor at 1.0;
7. the **always-on cascade** (face detector -> owner recognizer): the
   measured chip-model uJ/frame of screening every frame with the 0.92
   uJ S=4 detector and escalating only logit-margin positives to the
   14.4 uJ S=1 recognizer, vs running the recognizer on every frame —
   ``cascade_savings_vs_recognizer`` is floored at 1.0 by the
   regression guard (the cascade must never cost more than the big net
   alone), plus the cascade's host-side frames/s;
8. the **operating-point controller**: a cifar9 family served under a
   tightened energy budget — ``controller_downshift_ratio`` records the
   fraction of dispatches the controller moved below the top operating
   point (0 would mean the budget knob does nothing);
9. **continuous batching**: a seeded Poisson arrival trace replayed in
   real time against the static policy and the SLO-aware continuous
   policy — p50/p95/p99 input-to-label latency, padding ratio, and
   uJ/frame for both, with ``serve_p99_speedup_vs_static`` and
   ``serve_energy_ratio_vs_static`` floored at 1.0 (continuous must win
   both on the streaming workload) and the per-frame latency traces
   written to ``benchmarks/out/BENCH_latency_trace.json``;
10. **temporal delta gating**: the same seeded video trace (static
    backgrounds + moving patches, committed seed) replayed through the
    delta-gated pipeline at threshold 1 (skip bit-identical frames)
    and at ``-inf`` (gate off = recompute everything) — paired rounds
    give ``temporal_speedup_vs_full`` (floored at 1.0) and the
    chip-model ``temporal_uj_per_frame`` must undercut the ungated
    bill at perfect label agreement.

Results go to ``benchmarks/out/BENCH_fresh.json`` (override with
``BENCH_KERNELS_JSON``; the committed baseline refresh below writes to
the repo root, everything else stays out of the tree);
``benchmarks/check_regression.py`` compares a fresh run against the
*committed* baseline ``BENCH_kernels.json`` and fails CI when the
frames/s keys regress more than 10% (ratio floors on any host; absolute
frames/s when the host class matches).  To refresh the baseline after an
intentional perf change::

    BENCH_KERNELS_JSON=BENCH_kernels.json \
        PYTHONPATH=src python benchmarks/kernel_microbench.py

Results are written to ``BENCH_kernels.json`` so CI keeps a perf
trajectory across PRs.  Exit 0 iff all paths are bit-exact vs their
oracles.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import binarize
from repro.core.chip import energy, interpreter, networks, neuron_array as na
from repro.kernels import autotune, ops, ref
from repro.kernels import binary_conv2x2 as _bc

# default to a fresh-run file under the (gitignored) scratch directory:
# the committed BENCH_kernels.json baseline is only overwritten on an
# explicit BENCH_KERNELS_JSON=BENCH_kernels.json
BENCH_JSON = os.environ.get("BENCH_KERNELS_JSON",
                            os.path.join("benchmarks", "out",
                                         "BENCH_fresh.json"))


def _bench(fn, *args, iters=5):
    """Best-of-iters wall time (us): the min is the least noisy estimator
    on a shared host — contention only ever adds time."""
    jax.block_until_ready(fn(*args))              # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6  # us


def _seed_vmap_forward(program, folded, images):
    """The seed inference path, reproduced verbatim as the baseline: a
    per-image vmap of the 3D conv kernel, float comparator, float pool,
    and a pack/unpack round-trip at *every* layer boundary."""
    ci = fi = 0
    x = None
    from repro.core.chip import isa
    for ins in program.instrs:
        if isinstance(ins, isa.IOInstr):
            x = na.thermometer_encode(images, ins.bits, ins.channels)
        elif isinstance(ins, isa.ConvInstr):
            p = folded["conv"][ci]
            c = x.shape[-1]
            f = p["w"].shape[0]
            x_words = binarize.pack_signs(x, axis=-1)
            w_words = binarize.pack_signs(p["w"].reshape(f, 4, c), axis=-1)
            conv = lambda img: _bc.binary_conv2x2(
                img, w_words, c=c, interpret=ops.default_interpret())
            s = jax.vmap(conv)(x_words).astype(jnp.float32)
            x = na.comparator(s, p["tau"], p["flip"])
            if ins.maxpool:
                x = na.maxpool2x2(x)
            ci += 1
        else:
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            p = folded["fc"][fi]
            s = na.fc_packed(x, p["w"])
            x = s if ins.final else binarize.hard_sign(s)
            fi += 1
    return x, jnp.argmax(x, axis=-1)


def _bench_matmul(results):
    key = jax.random.PRNGKey(0)
    M, K, N = 512, 1024, 512
    a = jnp.where(jax.random.bernoulli(key, shape=(M, K)), 1, -1).astype(jnp.int8)
    w = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(1), shape=(K, N)),
                  1, -1).astype(jnp.int8)

    a_f = a.astype(jnp.float32)
    w_f = w.astype(jnp.float32)
    a_words = ops.pack(a)
    w_words = ops.pack(w.T)

    float_mm = jax.jit(lambda x, y: x @ y)
    packed_mm = jax.jit(lambda x, y: ref.xnor_matmul_packed_ref(x, y, K))

    t_float = _bench(float_mm, a_f, w_f)
    t_packed = _bench(packed_mm, a_words, w_words)
    ok = bool(jnp.all(packed_mm(a_words, w_words).astype(jnp.float32)
                      == a_f @ w_f))

    print(f"\n== XNOR-popcount vs float matmul ({M}x{K}x{N}) ==")
    print(f"float f32 matmul : {t_float:9.0f} us")
    print(f"packed xnor path : {t_packed:9.0f} us   "
          f"({t_float/t_packed:.1f}x vs float on CPU XLA)")
    print(f"bitpacked operand bytes: {a_words.nbytes + w_words.nbytes} "
          f"vs float {a_f.nbytes + w_f.nbytes} "
          f"({(a_f.nbytes + w_f.nbytes)/(a_words.nbytes + w_words.nbytes):.0f}x "
          "bandwidth density)")
    print(f"exact match vs float oracle: {ok}")
    results["xnor_matmul_us"] = round(t_packed, 1)
    results["float_matmul_us"] = round(t_float, 1)
    results["matmul_speedup_vs_float"] = round(t_float / t_packed, 2)
    return ok


def _bench_staged_layers(plan, packed, imgs, results):
    """Per-layer timing breakdown of the staged path: where do the µs go
    (and which layer boundaries the megakernel fuses away)."""
    x = imgs
    ci = fi = 0
    rows = []
    for st in plan.stages:
        if isinstance(st, interpreter._IOStage):
            fn = jax.jit(lambda a, st=st: na.thermometer_encode_packed(
                a, st.bits, st.channels))
            name = "IO encode"
        elif isinstance(st, interpreter._ConvStage):
            p = packed["conv"][ci]
            fn = jax.jit(lambda a, p=p, st=st: ops.binary_conv2x2_block(
                a, p["w_words"], p["tau"], p["flip"], st.c, pool=st.pool))
            name = f"conv{ci}" + ("+pool" if st.pool else "")
            ci += 1
        else:
            if x.ndim == 4:
                x = x.reshape(x.shape[0], -1)
            p = packed["fc"][fi]
            fn = jax.jit(lambda a, p=p, st=st: ops.xnor_matmul(
                a, p["w_words"], st.in_features, pack_out=st.pack_out))
            name = f"fc{fi}" + (" (final)" if st.final else "")
            fi += 1
        rows.append((name, _bench(fn, x, iters=3)))
        out = fn(x)
        if rows[-1][0].startswith("fc") and not st.final and not st.pack_out:
            out = binarize.pack_signs(
                binarize.hard_sign(out.astype(jnp.float32)), axis=-1)
        x = out
    print("staged per-layer breakdown:")
    for name, t in rows:
        print(f"  {name:12s}: {t:8.0f} us")
    results["staged_layer_us"] = {name: round(t, 1) for name, t in rows}


def _bench_pipeline(results):
    """Fused staged plan vs the seed per-image-vmap path, full program
    over a streaming batch, with the staged per-layer breakdown."""
    program = networks.mnist5()
    batch = 64
    key = jax.random.PRNGKey(2)
    params = interpreter.init_params(key, program)
    io = program.instrs[0]
    imgs = jax.random.randint(
        jax.random.PRNGKey(3), (batch, io.height, io.width, io.in_channels),
        0, 2 ** io.bits)
    _, params = interpreter.forward_train(params, program, imgs[:8])
    folded = interpreter.fold_params(params, program)
    packed = interpreter.pack_folded(folded)

    plan = interpreter.compile_plan(program)
    # tune the staged conv tiles for this (program, backend, batch) and
    # bench through the cache so the trajectory tracks the warm path
    tuned = autotune.tune_staged_conv(plan, packed, imgs,
                                      bf_candidates=(32, 64),
                                      bb_candidates=(8, 16), iters=2)
    print(f"autotuned staged conv tiles: bf={tuned['bf']} bb={tuned['bb']}")
    results["staged_conv_tuned_bf"] = tuned["bf"]
    results["staged_conv_tuned_bb"] = tuned["bb"]
    # interpret=None -> per-backend choice: Python interpret on CPU,
    # Mosaic lowering on a real TPU (keeps the perf trajectory honest)
    fused = jax.jit(lambda pk, im: plan.forward(pk, im))
    seed = jax.jit(lambda fl, im: _seed_vmap_forward(program, fl, im))

    # paired alternation (see _bench_megakernel): each back-to-back pair
    # sees the same host load, so the median of per-pair ratios is a
    # load-robust speedup; the us fields report best-of-reps.
    jax.block_until_ready(fused(packed, imgs))
    jax.block_until_ready(seed(folded, imgs))
    t_fused = t_seed = float("inf")
    ratios = []
    for _ in range(7):
        t0 = time.perf_counter()
        jax.block_until_ready(seed(folded, imgs))
        ts = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        jax.block_until_ready(fused(packed, imgs))
        tf = (time.perf_counter() - t0) * 1e6
        t_seed, t_fused = min(t_seed, ts), min(t_fused, tf)
        ratios.append(ts / tf)

    logits_f, labels_f = fused(packed, imgs)
    logits_s, labels_s = seed(folded, imgs)
    ok = bool(jnp.all(logits_f == logits_s) and jnp.all(labels_f == labels_s))
    fps = batch / (t_fused * 1e-6)
    speedup = sorted(ratios)[len(ratios) // 2]

    print(f"\n== Packed pipeline ({program.instrs[1].features}-wide mnist5, "
          f"batch={batch}) ==")
    print(f"seed per-image vmap path : {t_seed:9.0f} us/batch "
          "(int32->float->repack at every layer)")
    print(f"fused batched plan       : {t_fused:9.0f} us/batch "
          "(bit-packed end to end)")
    print(f"  -> {speedup:.2f}x, {fps:,.0f} frames/s host-sim throughput")
    print(f"fused plan bit-exact vs seed path: {ok}")
    _bench_staged_layers(plan, packed, imgs, results)
    results["pipeline_seed_vmap_us"] = round(t_seed, 1)
    results["pipeline_fused_us"] = round(t_fused, 1)
    results["pipeline_fused_speedup"] = round(speedup, 2)
    results["pipeline_frames_per_s"] = round(fps, 1)
    results["pipeline_batch"] = batch
    return ok, speedup


def _bench_megakernel(results):
    """Whole-network megakernel vs the staged plan on the paper's always-on
    benchmark net (cifar9 at the S=4 minimum-energy point): 8 conv layers
    whose inter-layer feature maps the staged path round-trips through HBM
    and the megakernel keeps in VMEM scratch."""
    program = networks.cifar9(4)
    batch, bb = 32, 16
    key = jax.random.PRNGKey(4)
    params = interpreter.init_params(key, program)
    io = program.instrs[0]
    imgs = jax.random.randint(
        jax.random.PRNGKey(5), (batch, io.height, io.width, io.in_channels),
        0, 2 ** io.bits)
    _, params = interpreter.forward_train(params, program, imgs[:4])
    packed = interpreter.fold_params(params, program, packed=True)
    image = interpreter.build_weight_image(packed, program)
    plan = interpreter.compile_plan(program)
    # tune (bb, ft) for this (program, backend, batch); the mega fn below
    # resolves its tiles from the cache (bb=None/ft=None), i.e. the bench
    # measures the autotuned f-tiled kernel a warm deployment runs
    tuned = autotune.tune_mega(plan, image, imgs,
                               bb_candidates=(4, 8, bb),
                               ft_candidates=(0, 32), iters=2)
    print(f"autotuned megakernel tiles: bb={tuned['bb']} ft={tuned['ft']}")
    bb = tuned["bb"]
    staged = jax.jit(lambda pk, im: plan.forward(pk, im))
    mega = jax.jit(lambda ig, im: plan.forward_mega(ig, im))

    # alternate the contenders rep by rep: each back-to-back pair sees the
    # same host load, so the *median of per-pair ratios* is a far less
    # noisy speedup estimator on a shared CPU than comparing two
    # independent minima (per-pair ratios scatter with load spikes, the
    # median cancels them); the us fields still report best-of-reps.
    jax.block_until_ready(staged(packed, imgs))
    jax.block_until_ready(mega(image, imgs))
    t_staged = t_mega = float("inf")
    ratios = []
    for _ in range(15):
        t0 = time.perf_counter()
        jax.block_until_ready(staged(packed, imgs))
        ts = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        jax.block_until_ready(mega(image, imgs))
        tm = (time.perf_counter() - t0) * 1e6
        t_staged, t_mega = min(t_staged, ts), min(t_mega, tm)
        ratios.append(ts / tm)

    logits_st, labels_st = staged(packed, imgs)
    logits_mg, labels_mg = mega(image, imgs)
    ok = bool(jnp.all(logits_mg == logits_st)
              and jnp.all(labels_mg == labels_st))
    speedup = sorted(ratios)[len(ratios) // 2]
    fps = batch / (t_mega * 1e-6)
    traffic = energy.hbm_traffic(program, batch=batch)

    print(f"\n== Megakernel (cifar9 S=4, 9 layers, batch={batch}, "
          f"bb={bb}) ==")
    print(f"staged plan (per-layer calls): {t_staged:9.0f} us/batch")
    print(f"resident megakernel          : {t_mega:9.0f} us/batch "
          f"({speedup:.2f}x, {fps:,.0f} frames/s)")
    print(f"HBM bytes/batch: staged {traffic.staged_bytes/1e6:.2f} MB -> "
          f"megakernel {traffic.mega_bytes/1e6:.2f} MB "
          f"({traffic.reduction:.1f}x less off-chip traffic; "
          f"{traffic.weight_image_bytes/1024:.0f} kB weight image resident)")
    print(f"megakernel bit-exact vs staged plan: {ok}")
    results["megakernel_us"] = round(t_mega, 1)
    results["megakernel_staged_us"] = round(t_staged, 1)
    results["megakernel_bb"] = bb
    results["megakernel_ft"] = tuned["ft"]
    results["megakernel_batch"] = batch
    results["megakernel_program"] = "cifar9_s4"
    results["megakernel_speedup_vs_staged"] = round(speedup, 2)
    results["megakernel_frames_per_s"] = round(fps, 1)
    results["hbm_staged_bytes_per_batch"] = traffic.staged_bytes
    results["hbm_megakernel_bytes_per_batch"] = traffic.mega_bytes
    results["hbm_traffic_reduction"] = round(traffic.reduction, 2)
    return ok


def _bench_serve(results):
    """Serving-layer throughput: the packed plan behind the scheduler.

    Artifacts and synthetic frame streams come from the serving driver's
    own helpers (``launch.chip_serve``) so the bench measures exactly the
    admission path the driver serves.
    """
    from repro.distributed import sharding
    from repro.launch import chip_serve
    from repro.serving import ChipServer

    batch, n_frames = 8, 32
    progs = {"mnist5": networks.mnist5(),
             "wake": networks.mnist5(classes=2)}
    arts, frames, oracle = {}, {}, {}
    for i, (name, prog) in enumerate(progs.items()):
        arts[name] = chip_serve.build_artifact(prog, seed=10 + i,
                                               warm_bn=True)
        frames[name] = chip_serve.frame_stream(prog, n_frames, seed=20 + i)
        plan = interpreter.compile_plan(prog)
        oracle[name] = np.asarray(
            jax.jit(lambda pk, im, plan=plan: plan.forward(pk, im)[1])(
                arts[name], jnp.asarray(frames[name])))

    def serve(names, label, mesh=None, prefetch=False):
        server = ChipServer({n: progs[n] for n in names},
                            {n: arts[n] for n in names},
                            batch=batch, mesh=mesh, prefetch=prefetch)
        for n in names:                        # warm the compile caches
            server.submit_many(n, frames[n][:batch])
        server.drain()
        dt = float("inf")
        for _round in range(3):                # best-of-3 timed drains
            t0 = time.perf_counter()
            for i in range(n_frames):          # interleaved arrival
                for n in names:
                    server.submit(n, frames[n][i])
            res = server.drain()
            dt = min(dt, time.perf_counter() - t0)
        per = {n: [] for n in names}
        for r in sorted(res, key=lambda r: r.rid):   # per-program FIFO
            per[r.program].append(r.label)
        ok = all(np.array_equal(np.array(per[n]), oracle[n][:len(per[n])])
                 for n in names)
        fps = len(res) / dt
        print(f"{label:24s}: {fps:10,.0f} frames/s "
              f"({len(res)} frames, {dt*1e3:.0f} ms, bit-exact={ok})")
        return fps, ok

    print(f"\n== Chip-tier serving (batch={batch}, {jax.device_count()} "
          "device(s)) ==")
    fps_1, ok_1 = serve(["mnist5"], "single program")
    fps_m, ok_m = serve(list(progs), "two programs resident")
    fps_p, ok_p = serve(["mnist5"], "single program, prefetch",
                        prefetch=True)
    results["serve_frames_per_s"] = round(fps_1, 1)
    results["serve_frames_per_s_multi"] = round(fps_m, 1)
    results["serve_frames_per_s_prefetch"] = round(fps_p, 1)
    results["serve_batch"] = batch
    ok = ok_1 and ok_m and ok_p
    if jax.device_count() > 1:
        mesh = sharding.serve_mesh()
        fps_s, ok_s = serve(["mnist5"],
                            f"sharded x{mesh.devices.size}", mesh=mesh)
        results["serve_frames_per_s_sharded"] = round(fps_s, 1)
        results["serve_devices"] = int(mesh.devices.size)
        ok = ok and ok_s
    return ok


def _bench_continuous_serve(results):
    """Continuous batching vs static dispatch on one committed Poisson
    trace: frames replayed at their seeded arrival offsets against both
    policies, same seed, same host.  The arrival rate is calibrated from
    the measured full-batch dispatch time (rate = 0.4 / T_batch, so
    arrivals are much slower than a full-batch service and the static
    policy's pad is pure waste), and the SLO from the same measurement,
    making the bench regime host-independent.  The continuous server
    runs with a small headroom so its window target stays pinned at 1 in
    this regime (nominal target 0.2 frames — the EWMA estimate would
    have to read 5x the true rate before the window ever holds a frame):
    the comparison is then *structural* — per-frame service time T1 vs
    the static policy's always-T_batch — rather than riding the replay
    loop's millisecond scheduling jitter.  Continuous batching must
    deliver a lower p99 input-to-label latency at equal-or-better uJ/f
    and a strictly lower padding ratio —
    ``serve_p99_speedup_vs_static`` and ``serve_energy_ratio_vs_static``
    are >= 1.0 floors in ``check_regression.py``.  The per-frame latency
    traces go to ``BENCH_latency_trace.json`` (CI uploads them next to
    the bench JSON)."""
    from repro.launch import chip_serve
    from repro.serving import (ChipServer, ContinuousPolicy, poisson_trace,
                               replay)

    batch, n_frames, seed = 16, 64, 123
    prog = networks.mnist5()
    art = chip_serve.build_artifact(prog, seed=30, warm_bn=True)
    bank = chip_serve.frame_stream(prog, batch, seed=31)
    plan = interpreter.compile_plan(prog)
    seq = np.stack([bank[i % batch] for i in range(n_frames)])
    oracle = np.asarray(jax.jit(
        lambda pk, im: plan.forward(pk, im)[1])(art, jnp.asarray(seq)))

    def make_server(policy, slo_ms=50.0):
        if policy == "continuous":
            policy = ContinuousPolicy(slo_ms=slo_ms, headroom=0.25,
                                      deadline_frac=0.25)
        server = ChipServer({"m": prog}, {"m": art}, batch=batch,
                            policy=policy, slo_ms=slo_ms)
        # warm every bucket size the continuous ladder can dispatch
        # (1, 2, 4, 8, 16) so no timed frame pays a jit compile; warm
        # frames go in unstamped (t_submit=0) and the ledger is wiped
        # after, so compile stalls never reach the latency percentiles
        sz = 1
        while sz <= batch:
            for f in bank[:sz]:
                server.submit("m", f, t_submit=0.0)
            server.drain()
            sz *= 2
        server.reset_stats()
        return server

    # calibrate: T_batch = one warm full-batch dispatch on this host
    server = make_server("static")
    t_full = float("inf")
    for _ in range(5):
        server.submit_many("m", bank)
        t0 = time.perf_counter()
        server.drain()
        t_full = min(t_full, time.perf_counter() - t0)
    rate = 0.4 / t_full                  # arrivals far slower than service
    slo_ms = max(2.0, 2 * t_full * 1e3)
    trace = poisson_trace(["m"], rate=rate, n=n_frames, seed=seed)

    runs = {p: dict(server=make_server(p, slo_ms=slo_ms), ok=True)
            for p in ("static", "continuous")}
    # paired best-of-5: each round replays the SAME trace through both
    # policies back to back, and each policy keeps its lowest-p99 round
    # — host contention only ever adds latency, so the min is the
    # least-noisy tail estimator (same idiom as the paired us benches)
    for _round in range(5):
        for policy, r in runs.items():
            server = r["server"]
            server.reset_stats()
            res = replay(server, trace, {"m": bank})
            stats = server.stats()
            labels = [x.label for x in sorted(res, key=lambda x: x.rid)]
            r["ok"] = r["ok"] and np.array_equal(np.array(labels), oracle)
            if "stats" not in r or stats.p99_ms < r["stats"].p99_ms:
                r["stats"], r["trace"] = stats, server.latency_trace()
    for policy, r in runs.items():
        stats = r["stats"]
        print(f"{policy:12s}: p50 {stats.p50_ms:7.2f} / p99 "
              f"{stats.p99_ms:7.2f} ms, padding {stats.padding_ratio:.3f}, "
              f"{stats.chip.uj_per_frame:.2f} uJ/f, "
              f"{stats.dispatches} dispatches, bit-exact={r['ok']}")

    st, ct = runs["static"]["stats"], runs["continuous"]["stats"]
    ok = runs["static"]["ok"] and runs["continuous"]["ok"]
    p99_speedup = st.p99_ms / ct.p99_ms if ct.p99_ms else 0.0
    uj_ratio = (st.chip.uj_per_frame / ct.chip.uj_per_frame
                if ct.chip.uj_per_frame else 0.0)

    print(f"\n== Continuous batching (poisson trace, {n_frames} frames at "
          f"{rate:,.0f} f/s, SLO {slo_ms:.1f} ms, seed {seed}) ==")
    print(f"p99 input-to-label : {st.p99_ms:.2f} -> {ct.p99_ms:.2f} ms "
          f"({p99_speedup:.2f}x)")
    print(f"padding ratio      : {st.padding_ratio:.3f} -> "
          f"{ct.padding_ratio:.3f}")
    print(f"uJ/frame           : {st.chip.uj_per_frame:.2f} -> "
          f"{ct.chip.uj_per_frame:.2f} ({uj_ratio:.2f}x)")
    results["serve_p50_ms"] = round(ct.p50_ms, 3)
    results["serve_p95_ms"] = round(ct.p95_ms, 3)
    results["serve_p99_ms"] = round(ct.p99_ms, 3)
    results["serve_p50_ms_static"] = round(st.p50_ms, 3)
    results["serve_p99_ms_static"] = round(st.p99_ms, 3)
    results["serve_padding_ratio_continuous"] = round(ct.padding_ratio, 4)
    results["serve_padding_ratio_static"] = round(st.padding_ratio, 4)
    results["serve_uj_per_frame_continuous"] = round(ct.chip.uj_per_frame, 3)
    results["serve_uj_per_frame_static"] = round(st.chip.uj_per_frame, 3)
    results["serve_frames_per_s_continuous"] = round(ct.host_frames_per_s, 1)
    results["serve_p99_speedup_vs_static"] = round(p99_speedup, 2)
    results["serve_energy_ratio_vs_static"] = round(uj_ratio, 2)
    results["serve_traffic_kind"] = trace.kind
    results["serve_traffic_seed"] = seed
    results["serve_traffic_rate"] = round(rate, 1)
    results["serve_slo_ms"] = round(slo_ms, 2)

    trace_json = os.environ.get("BENCH_LATENCY_JSON",
                                os.path.join("benchmarks", "out",
                                             "BENCH_latency_trace.json"))
    os.makedirs(os.path.dirname(trace_json) or ".", exist_ok=True)
    with open(trace_json, "w") as f:
        json.dump({"meta": dict(kind=trace.kind, seed=seed,
                                rate=round(rate, 1), n=n_frames,
                                slo_ms=round(slo_ms, 2)),
                   "static": runs["static"]["trace"],
                   "continuous": runs["continuous"]["trace"]}, f, indent=2)
    print(f"wrote per-frame latency traces to {trace_json}")
    return ok


def _bench_shared_serve(results):
    """Shared-array dispatch: four S=4 programs resident at once, served
    time-interleaved (each solo dispatch occupies one 64-channel
    sub-array, 25% of the array) vs through ``ChipServer(shared=True)``
    (one composite ``pallas_call`` per batch runs all four sub-arrays
    concurrently).  Paired alternation gives a load-robust speedup; the
    regression guard floors it at 1.0."""
    from repro.launch import chip_serve
    from repro.serving import ChipServer

    batch, n_frames = 8, 16
    progs = {"mnist5": networks.mnist5(),
             "wake": networks.mnist5(classes=2),
             "tri": networks.mnist5(classes=3),
             "five": networks.mnist5(classes=5)}
    arts, frames, oracle = {}, {}, {}
    for i, (name, prog) in enumerate(progs.items()):
        arts[name] = chip_serve.build_artifact(prog, seed=40 + i,
                                               warm_bn=True)
        frames[name] = chip_serve.frame_stream(prog, n_frames, seed=60 + i)
        plan = interpreter.compile_plan(prog)
        oracle[name] = np.asarray(
            jax.jit(lambda pk, im, plan=plan: plan.forward(pk, im)[1])(
                arts[name], jnp.asarray(frames[name])))

    # tune the quad composite's (bb, ft) under its own fingerprint — the
    # shared server resolves them from the cache at dispatch time
    cplan, cimage = interpreter.pack_programs(progs, arts)
    tuned = autotune.tune_composite(
        cplan, cimage, tuple(jnp.asarray(frames[n][:batch]) for n in progs),
        bb_candidates=(4, 8), ft_candidates=(0, 32), iters=2)
    print(f"autotuned composite tiles: bb={tuned['bb']} ft={tuned['ft']}")
    results["shared_composite_tuned_bb"] = tuned["bb"]
    results["shared_composite_tuned_ft"] = tuned["ft"]

    def make_server(shared):
        server = ChipServer(progs, arts, batch=batch, shared=shared)
        for n in progs:                        # warm the compile caches
            server.submit_many(n, frames[n][:batch])
        server.drain()
        return server

    def timed_drain(server):
        t0 = time.perf_counter()
        for i in range(n_frames):              # interleaved arrival
            for n in progs:
                server.submit(n, frames[n][i])
        res = server.drain()
        dt = time.perf_counter() - t0
        per = {n: [] for n in progs}
        for r in sorted(res, key=lambda r: r.rid):
            per[r.program].append(r.label)
        ok = all(np.array_equal(np.array(per[n]), oracle[n][:n_frames])
                 for n in progs)
        return len(res) / dt, dt, ok

    solo, shared = make_server(False), make_server(True)
    fps_solo = fps_shared = 0.0
    ok = True
    ratios = []
    for _round in range(3):                    # paired rounds, same load
        f_a, dt_a, ok_a = timed_drain(solo)
        f_b, dt_b, ok_b = timed_drain(shared)
        fps_solo, fps_shared = max(fps_solo, f_a), max(fps_shared, f_b)
        ratios.append(dt_a / dt_b)
        ok = ok and ok_a and ok_b
    speedup = sorted(ratios)[len(ratios) // 2]
    util_solo = solo.stats().array_utilization
    util_shared = shared.stats().array_utilization

    print(f"\n== Shared-array dispatch (4 x S=4 resident, batch={batch}) ==")
    print(f"solo interleaved dispatch : {fps_solo:10,.0f} frames/s "
          f"(array utilization {util_solo:.2f})")
    print(f"shared composite dispatch : {fps_shared:10,.0f} frames/s "
          f"(array utilization {util_shared:.2f}, {speedup:.2f}x)")
    print(f"shared dispatch bit-exact vs solo oracle: {ok}")
    results["serve_frames_per_s_solo4"] = round(fps_solo, 1)
    results["serve_frames_per_s_shared"] = round(fps_shared, 1)
    results["serve_shared_speedup_vs_solo"] = round(speedup, 2)
    # array_utilization is the shared-dispatch path's occupancy (the CI
    # headline); _solo4 is the time-interleaved control at 1/S
    results["array_utilization"] = round(util_shared, 3)
    results["array_utilization_solo4"] = round(util_solo, 3)
    results["serve_shared_programs"] = len(progs)
    return ok


def _bench_cascade(results):
    """The paper's always-on hierarchy as a measured serving path: the
    S=4 face detector (0.92 uJ/f analogue) screens every frame, only
    logit-margin positives escalate to the S=1 owner recognizer (14.4
    uJ/f analogue).  The measured uJ/frame must stay strictly below
    running the recognizer on every frame at identical escalated labels
    — ``cascade_savings_vs_recognizer`` is a >= 1.0 floor in
    ``check_regression.py``."""
    from repro.launch import chip_serve
    from repro.serving import CascadePipeline, ChipServer

    batch, n_frames = 4, 12
    det, rec = networks.face_detector(), networks.owner_detector()
    progs = {"det": det, "rec": rec}
    arts = {n: chip_serve.build_artifact(p, seed=70 + i, warm_bn=True)
            for i, (n, p) in enumerate(progs.items())}
    frames = chip_serve.frame_stream(det, n_frames, seed=90)
    rec_plan = interpreter.compile_plan(rec)
    rec_oracle = np.asarray(jax.jit(
        lambda pk, im: rec_plan.forward(pk, im)[1])(
            arts["rec"], jnp.asarray(frames)))
    # calibrate the escalation threshold instead of eyeballing it: the
    # detector's own offline positive calls stand in for a labelled
    # held-out split (an untrained detector has no ground truth), and
    # calibrate_margin picks the *cheapest* margin whose escalations
    # still capture 95% of those positives — the margin becomes a
    # recall contract rather than the old median-margin heuristic
    from repro.serving import calibrate_margin
    det_plan = interpreter.compile_plan(det)
    det_logits = np.asarray(jax.jit(
        lambda pk, im: det_plan.forward(pk, im)[0])(
            arts["det"], jnp.asarray(frames)))
    margin = calibrate_margin(frames, det_logits.argmax(axis=1) == 1,
                              0.95, detector=det, artifact=arts["det"])

    def run_once():
        server = ChipServer(progs, arts, batch=batch)
        casc = CascadePipeline(server, "det", "rec", positive_class=1,
                               margin=margin)
        t0 = time.perf_counter()
        casc.submit_many(frames)
        out = casc.drain()
        dt = time.perf_counter() - t0
        return casc, out, dt

    run_once()                                 # warm the compile caches
    casc, out, dt = run_once()
    rep = casc.report()
    # escalated labels must be bit-exact vs the recognizer run offline
    # on those same frames
    ok = all(int(rec_oracle[c.rid]) == c.label
             for c in out if c.escalated)
    fps = len(out) / dt

    print(f"\n== Always-on cascade (face_detector -> owner_detector, "
          f"batch={batch}) ==")
    print(f"escalation rate    : {rep.escalation_rate:.2f} "
          f"({rep.escalated}/{rep.frames} frames)")
    print(f"cascade bill       : {rep.uj_per_frame:.2f} uJ/frame "
          f"(det {rep.detector_uj:.2f} + rate x rec {rep.recognizer_uj:.2f})")
    print(f"recognizer-on-all  : {rep.uj_per_frame_recognizer_only:.2f} "
          f"uJ/frame -> {rep.savings:.2f}x saved")
    print(f"host throughput    : {fps:,.0f} frames/s; escalated labels "
          f"bit-exact vs offline recognizer: {ok}")
    results["cascade_uj_per_frame"] = round(rep.uj_per_frame, 3)
    results["cascade_recognizer_only_uj_per_frame"] = round(
        rep.uj_per_frame_recognizer_only, 3)
    results["cascade_savings_vs_recognizer"] = round(rep.savings, 3)
    results["cascade_escalation_rate"] = round(rep.escalation_rate, 3)
    results["serve_frames_per_s_cascade"] = round(fps, 1)
    return ok


def _bench_cascade_fused(results):
    """In-kernel fused cascade vs the host-side cascade on the SAME
    replayed stream: one composite dispatch per detector batch (the
    escalation mask and the recognizer drain both live inside the
    kernel) against the host path's separate detector dispatches,
    result routing and deferred recognizer batches.  Paired alternation
    (see _bench_megakernel): each back-to-back pair sees the same host
    load, so the median of per-pair ratios is the speedup estimator —
    ``cascade_fused_speedup_vs_host`` is a >= 1.0 floor in
    ``check_regression.py``.  Labels must be bit-exact between the two
    paths (and vs the offline recognizer) on every run."""
    from repro.launch import chip_serve
    from repro.serving import CascadePipeline, ChipServer, calibrate_margin

    batch, n_frames = 4, 12
    det, rec = networks.face_detector(), networks.owner_detector()
    progs = {"det": det, "rec": rec}
    arts = {n: chip_serve.build_artifact(p, seed=70 + i, warm_bn=True)
            for i, (n, p) in enumerate(progs.items())}
    frames = chip_serve.frame_stream(det, n_frames, seed=123)
    rec_plan = interpreter.compile_plan(rec)
    rec_oracle = np.asarray(jax.jit(
        lambda pk, im: rec_plan.forward(pk, im)[1])(
            arts["rec"], jnp.asarray(frames)))
    det_plan = interpreter.compile_plan(det)
    det_logits = np.asarray(jax.jit(
        lambda pk, im: det_plan.forward(pk, im)[0])(
            arts["det"], jnp.asarray(frames)))
    margin = calibrate_margin(frames, det_logits.argmax(axis=1) == 1,
                              0.95, detector=det, artifact=arts["det"])

    def run(fused):
        server = ChipServer(progs, arts, batch=batch)
        casc = CascadePipeline(server, "det", "rec", margin=margin,
                               fused=fused)
        t0 = time.perf_counter()
        casc.submit_many(frames)
        out = sorted(casc.drain(), key=lambda c: c.rid)
        dt = time.perf_counter() - t0
        rep = casc.report()
        server.close()
        return out, dt, rep

    run(False)                                 # warm both compile caches
    run(True)
    t_host = t_fused = float("inf")
    ratios = []
    ok = True
    for _ in range(5):
        out_h, th, rep_h = run(False)
        out_f, tf, rep_f = run(True)
        t_host, t_fused = min(t_host, th), min(t_fused, tf)
        ratios.append(th / tf)
        ok = ok and all(
            (h.rid, h.label, h.escalated) == (f.rid, f.label, f.escalated)
            for h, f in zip(out_h, out_f))
        ok = ok and all(int(rec_oracle[c.rid]) == c.label
                        for c in out_f if c.escalated)
    speedup = sorted(ratios)[len(ratios) // 2]
    fps = n_frames / t_fused

    print(f"\n== Fused in-kernel cascade (same pair, one dispatch per "
          f"detector batch, batch={batch}) ==")
    print(f"host cascade       : {t_host * 1e3:8.1f} ms/stream")
    print(f"fused cascade      : {t_fused * 1e3:8.1f} ms/stream "
          f"({speedup:.2f}x, {fps:,.0f} frames/s)")
    print(f"fused bill         : {rep_f.uj_per_frame:.2f} uJ/frame "
          f"(host {rep_h.uj_per_frame:.2f}; escalation rate "
          f"{rep_f.escalation_rate:.2f})")
    print(f"fused labels bit-exact vs host + offline recognizer: {ok}")
    results["cascade_fused_speedup_vs_host"] = round(speedup, 2)
    results["cascade_fused_uj_per_frame"] = round(rep_f.uj_per_frame, 3)
    results["cascade_fused_ms_per_stream"] = round(t_fused * 1e3, 2)
    results["serve_frames_per_s_cascade_fused"] = round(fps, 1)
    return ok


def _bench_controller(results):
    """The operating-point controller under a tightened energy budget:
    a cifar9 family (full-depth S=4 + depth-truncated S=4) served with
    the budget pinned halfway between the two variants' steady-state
    powers, so the controller must visibly downshift —
    ``controller_downshift_ratio`` lands strictly between 0 and 1."""
    from repro.launch import chip_serve
    from repro.serving import ChipServer

    batch, n_frames = 4, 24
    fam = {"cifar9_s4": networks.cifar9(4),
           "cifar9_s4t": networks.cifar9_truncated()}
    arts = {n: chip_serve.build_artifact(p, seed=80 + i, warm_bn=True)
            for i, (n, p) in enumerate(fam.items())}
    pts = energy.operating_points(fam, networks.ACCURACY)
    powers = {p.name: p.power_uj_s for p in pts}
    budget = (max(powers.values()) + min(powers.values())) / 2

    def serve(budget_uj_s):
        server = ChipServer(fam, arts, batch=batch,
                            families={"cifar10": tuple(fam)},
                            budget_uj_s=budget_uj_s)
        server.submit_many("cifar10",
                           chip_serve.frame_stream(fam["cifar9_s4"],
                                                   n_frames, seed=95))
        server.drain()
        return server.stats()

    serve(None)                                # warm the compile caches
    stats = serve(budget)
    ok = 0.0 < stats.downshift_ratio < 1.0
    print(f"\n== Operating-point controller (cifar9_s4 <-> cifar9_s4t, "
          f"budget {budget:,.0f} uJ/s) ==")
    print(f"operating points   : " + " > ".join(
        f"{p.name}[{p.uj_per_frame:.2f}uJ/f, {powers[p.name]:,.0f}uJ/s]"
        for p in pts))
    print(f"variant dispatches : {stats.variant_dispatches} "
          f"(downshift ratio {stats.downshift_ratio:.2f}, "
          f"array utilization {stats.array_utilization:.2f})")
    print(f"energy billed      : {stats.energy_uj:,.0f} uJ under the "
          f"budget; mixes both points: {ok}")
    results["controller_downshift_ratio"] = round(stats.downshift_ratio, 3)
    results["controller_array_utilization"] = round(
        stats.array_utilization, 3)
    results["controller_budget_uj_s"] = round(budget, 1)
    return ok


def _bench_fleet(results):
    """Fleet failover + warm start on the wall clock.

    Two tracked numbers: ``replica_warm_start_speedup`` — bring-up time
    (construct a server AND serve its first batch, i.e. cold-start-to-
    first-served-frame) of a cold warm-start cache vs a hot one (floor
    >= 1.0 in ``check_regression.py``); and ``fleet_failover_recovery_ms``
    — kill-to-first-served-frame of the replacement replica a 2-host
    fleet spawns after a mid-stream host loss (lower-is-better latency
    key).  Zero frame loss and bit-exact labels vs the offline oracle
    are the pass condition."""
    from repro.kernels import cache as warmcache
    from repro.launch import chip_serve
    from repro.serving import ChipServer, FaultInjector, ServeFleet

    batch, n_frames = 4, 32
    prog = networks.mnist5()
    art = chip_serve.build_artifact(prog, seed=30, warm_bn=True)
    frames = chip_serve.frame_stream(prog, n_frames, seed=40)
    plan = interpreter.compile_plan(prog)
    oracle = np.asarray(jax.jit(
        lambda pk, im: plan.forward(pk, im)[1])(art, jnp.asarray(frames)))
    warm_dir = warmcache.enable_persistent()   # CI uploads the directory

    def bring_up():
        t0 = time.perf_counter()
        server = ChipServer({"mnist5": prog}, {"mnist5": art}, batch=batch)
        server.submit_many("mnist5", frames[:batch])
        server.drain()
        return time.perf_counter() - t0

    warmcache.invalidate()                     # measure a true cold start
    t_cold = bring_up()
    t_warm = min(bring_up() for _ in range(3))
    speedup = t_cold / t_warm

    # -- failover: kill host0 mid-stream, replacement must serve -----------
    inj = FaultInjector("host0", after_served=batch)
    fleet = ServeFleet({"mnist5": prog}, {"mnist5": art},
                       replicas=2, batch=batch, injector=inj, replace=True)
    res = []
    for i in range(0, n_frames, batch):        # interleave admit/serve so
        for f in frames[i:i + batch]:          # the kill lands mid-stream
            fleet.submit("mnist5", f)          # and the replacement gets
        res.extend(fleet.step())               # fresh traffic
    res.extend(fleet.drain())
    st = fleet.stats()
    got = {r.rid: r.label for r in res}
    ok = (len(got) == n_frames
          and all(got[i] == int(oracle[i]) for i in range(n_frames))
          and st.billed == st.total_served + sum(st.padded.values())
          and st.failed_replicas == ("host0",)
          and fleet.recovery_ms is not None)
    recovery_ms = fleet.recovery_ms if fleet.recovery_ms is not None else -1.0

    print(f"\n== Serve fleet (2 hosts, batch={batch}, kill host0 "
          f"after {batch} frames) ==")
    print(f"bring-up           : cold {t_cold*1e3:.0f} ms, warm "
          f"{t_warm*1e3:.0f} ms -> {speedup:.2f}x warm-start speedup")
    print(f"failover           : recovery {recovery_ms:.1f} ms, "
          f"{st.migrated_frames} migrated (+{st.refired_frames} refired), "
          f"{len(got)}/{n_frames} served, bit-exact={ok}")
    print(f"fleet bill         : {st.chip.uj_per_frame:.3f} uJ/frame, "
          f"billed {st.billed} == served {st.total_served} + padded "
          f"{sum(st.padded.values())}")
    results["fleet_failover_recovery_ms"] = round(recovery_ms, 2)
    results["replica_warm_start_speedup"] = round(speedup, 2)
    results["fleet_replicas"] = 2
    results["fleet_migrated_frames"] = st.migrated_frames
    results["fleet_refired_frames"] = st.refired_frames
    results["fleet_uj_per_frame"] = round(st.chip.uj_per_frame, 3)
    results["warm_cache_dir"] = warm_dir
    return ok


def _bench_temporal(results):
    """Delta-gated always-on video vs full recompute on the SAME
    committed seeded trace: a static-background + moving-patch scene
    (``video_trace``, seed pinned below) replayed twice through the
    identical delta kernel — once at threshold 1 (skip bit-identical
    packed frames) and once at ``-inf`` (gate off, every lane
    recomputes).  Paired alternation (see _bench_megakernel) makes the
    median per-pair ratio the speedup estimator —
    ``temporal_speedup_vs_full`` is a >= 1.0 floor in
    ``check_regression.py``, and the chip-model ``temporal_uj_per_frame``
    must undercut the ungated bill.  Both paths run the same kernel, so
    labels must be bit-exact vs each other AND the offline oracle."""
    from repro.launch import chip_serve
    from repro.serving import ChipServer, TemporalPipeline, video_trace

    batch, n_steps = 8, 16
    prog = networks.mnist5()
    art = chip_serve.build_artifact(prog, seed=77, warm_bn=True)
    io = prog.instrs[0]
    trace = video_trace((io.height, io.width, io.in_channels), n_steps,
                        streams=batch, seed=77, change_rate=0.25,
                        levels=2 ** io.bits)
    n_frames = len(trace) * trace.streams
    plan = interpreter.compile_plan(prog)
    flat = trace.frames.reshape((-1,) + trace.frames.shape[2:])
    oracle = np.asarray(jax.jit(
        lambda pk, im: plan.forward(pk, im)[1])(
            interpreter.ensure_packed(art), jnp.asarray(flat)))

    def run(threshold):
        server = ChipServer({"mnist5": prog}, {"mnist5": art}, batch=batch)
        pipe = TemporalPipeline(server, "mnist5", threshold=threshold,
                                rb=2)
        t0 = time.perf_counter()
        for t in range(len(trace)):            # time-major: one dispatch
            for s in range(trace.streams):     # per camera tick
                pipe.submit(trace.frames[t, s])
        out = sorted(pipe.drain(), key=lambda r: r.rid)
        dt = time.perf_counter() - t0
        rep = pipe.report()
        skip = pipe.skip_ratio
        server.close()
        return out, dt, rep, skip

    run(float("-inf"))                         # warm the compile caches
    run(1.0)                                   # (same kernel either way)
    t_full = t_gated = float("inf")
    ratios = []
    ok = True
    out_g = []
    rep_g = rep_f = None
    skip = 0.0
    for _ in range(5):
        out_f, tf, rep_f, _ = run(float("-inf"))
        out_g, tg, rep_g, skip = run(1.0)
        t_full, t_gated = min(t_full, tf), min(t_gated, tg)
        ratios.append(tf / tg)
        ok = ok and [r.label for r in out_g] == [r.label for r in out_f]
    speedup = sorted(ratios)[len(ratios) // 2]
    fps = n_frames / t_gated
    agree = float(np.mean([r.label == int(oracle[r.rid])
                           for r in out_g]))
    ok = (ok and agree == 1.0 and skip > 0.0
          and rep_g.uj_per_frame < rep_g.uj_per_frame_ungated)

    print(f"\n== Temporal delta gating (mnist5 always-on video, "
          f"{trace.streams} streams x {n_steps} steps, threshold 1) ==")
    print(f"full recompute     : {t_full * 1e3:8.1f} ms/stream "
          f"({rep_f.uj_per_frame:.2f} uJ/frame)")
    print(f"delta gated        : {t_gated * 1e3:8.1f} ms/stream "
          f"({speedup:.2f}x, {fps:,.0f} frames/s)")
    print(f"gated bill         : {rep_g.uj_per_frame:.2f} uJ/frame vs "
          f"{rep_g.uj_per_frame_ungated:.2f} ungated "
          f"(skip ratio {skip:.2f}, {rep_g.savings:.2f}x saved)")
    print(f"labels bit-exact vs full path + offline oracle: {ok}")
    results["temporal_skip_ratio"] = round(skip, 3)
    results["temporal_speedup_vs_full"] = round(speedup, 2)
    results["temporal_uj_per_frame"] = round(rep_g.uj_per_frame, 3)
    results["temporal_uj_per_frame_ungated"] = round(
        rep_g.uj_per_frame_ungated, 3)
    results["temporal_label_agreement"] = round(agree, 3)
    results["temporal_ms_per_stream"] = round(t_gated * 1e3, 2)
    results["serve_frames_per_s_temporal"] = round(fps, 1)
    return ok


def run(csv: bool = True):
    import platform
    results = {"backend": jax.default_backend(),
               # absolute frames/s are only comparable on the same machine
               # class; the regression guard checks this fingerprint and
               # downgrades absolute-key mismatches to warnings when the
               # host changed (ratio floors always apply).
               "host": f"{platform.machine()}-{os.cpu_count()}cpu"}
    ok_mm = _bench_matmul(results)
    ok_pipe, speedup = _bench_pipeline(results)
    ok_mega = _bench_megakernel(results)
    ok_serve = _bench_serve(results)
    ok_cont = _bench_continuous_serve(results)
    ok_shared = _bench_shared_serve(results)
    ok_cascade = _bench_cascade(results)
    ok_fused_casc = _bench_cascade_fused(results)
    ok_ctrl = _bench_controller(results)
    ok_fleet = _bench_fleet(results)
    ok_temporal = _bench_temporal(results)
    ok = (ok_mm and ok_pipe and ok_mega and ok_serve and ok_cont
          and ok_shared and ok_cascade and ok_fused_casc and ok_ctrl
          and ok_fleet and ok_temporal)
    results["autotune_cache"] = autotune.cache_path()

    os.makedirs(os.path.dirname(BENCH_JSON) or ".", exist_ok=True)
    with open(BENCH_JSON, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)
    print(f"\nwrote {BENCH_JSON}")
    if csv:
        print(f"CSV,kernel_microbench,{results['pipeline_fused_us']:.0f},"
              f"fused_speedup={speedup:.2f};"
              f"fps={results['pipeline_frames_per_s']:.0f};exact={int(ok)}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)

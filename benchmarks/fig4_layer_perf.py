"""Fig. 4 reproduction: per-layer core GOPS and TOPS/W of the 9-layer
always-on benchmark network.

Paper anchors (Sec. III-A):
  * layer 1: 500M binary ops, up to 230 TOPS/W core efficiency,
    352 GOPS at 48 MHz
  * core efficiency drops with smaller WxH maps (relative LD time grows)
  * FC layers: ~1.5 TOPS/W
"""

from __future__ import annotations

import time

from repro.core.chip import energy, networks


def run(csv: bool = True):
    t0 = time.perf_counter()
    p = networks.cifar9(s=1)
    layers = energy.analyze_program(p)
    us = (time.perf_counter() - t0) * 1e6

    rows = []
    print("\n== Fig. 4: per-layer core performance (9-layer net, S=1) ==")
    print(f"{'layer':34s} {'Mops':>9s} {'GOPS@6M':>9s} {'GOPS@48M':>9s} "
          f"{'TOPS/W':>8s} {'LD%':>6s}")
    for l in layers:
        ld_pct = 100.0 * l.ld_cycles / l.cycles if l.cycles else 0.0
        print(f"{l.name:34s} {l.ops/1e6:9.1f} {l.gops(6e6):9.1f} "
              f"{l.gops(48e6):9.1f} {l.tops_per_w():8.1f} {ld_pct:6.1f}")
        rows.append((l.name, l.ops, l.gops(48e6), l.tops_per_w()))

    conv = [l for l in layers if l.kind == "cnn"]
    fc = [l for l in layers if l.kind == "fc"]
    l1 = conv[0]
    checks = [
        ("layer1 ops ~500M", l1.ops, 500e6, 0.05),
        ("layer1 core eff ~230 TOPS/W", l1.tops_per_w(), 230.0, 0.05),
        ("layer1 GOPS@6MHz ~352 (paper Fig. 4)", l1.gops(6e6), 352.0, 0.10),
        ("peak GOPS@48MHz ~2800 (Table 1)", l1.gops(48e6), 2800.0, 0.10),
        ("FC eff ~1.5 TOPS/W", fc[0].tops_per_w(), 1.5, 0.05),
        ("eff drops with depth", conv[0].tops_per_w() - conv[-1].tops_per_w(),
         None, None),
    ]
    print("\nanchor checks vs paper:")
    ok = True
    for name, got, want, tol in checks:
        if want is None:
            good = got > 0
            print(f"  [{'OK' if good else 'FAIL'}] {name}: {got:.2f}")
        else:
            err = abs(got - want) / want
            good = err <= tol
            print(f"  [{'OK' if good else 'FAIL'}] {name}: {got:.1f} "
                  f"(paper {want}, err {err:.1%})")
        ok &= good
    if csv:
        print(f"CSV,fig4_layer_perf,{us:.0f},"
              f"l1_tops_w={l1.tops_per_w():.1f};l1_gops48={l1.gops(48e6):.0f};"
              f"anchors_ok={int(ok)}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)

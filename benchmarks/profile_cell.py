"""Hillclimb profiler: attribute roofline costs to model operations.

Reads a gzipped optimized-HLO dump (``dryrun.py --dump-hlo``), walks the
module with while-loop trip-count scaling (same engine as
launch/hlo_cost.py) and prints the top contributors to each roofline
term, grouped by the JAX ``op_name`` metadata path — i.e. it answers
"which *model layer op* owns the dominant term".

    PYTHONPATH=src python -m benchmarks.profile_cell \
        benchmarks/results/hlo_<cell>.txt.gz [--top 25] [--term bytes]
"""

from __future__ import annotations

import argparse
import gzip
import re
from collections import defaultdict

from repro.launch import hlo_cost as hc

_OPNAME_RE = re.compile(r'op_name="([^"]+)"')


def _group_key(instr: hc.Instr) -> str:
    m = _OPNAME_RE.search(instr.attrs)
    if not m:
        return f"<{instr.op}>"
    name = m.group(1)
    # strip jit wrapper + uniquifying indices: keep the semantic tail
    name = re.sub(r"\[[^\]]*\]", "", name)
    parts = [p for p in name.split("/") if p and not p.startswith("jit(")]
    return "/".join(parts[-4:]) if parts else name


def profile(text: str, n_chips: int):
    comps, entry = hc.parse_module(text)
    memo = {}
    flops = defaultdict(float)
    byts = defaultdict(float)
    coll = defaultdict(float)

    def walk(comp: hc.Computation, scale: float):
        for i in comp.instrs:
            if i.op == "while":
                trips = hc._trip_count(i, comps)
                mb = re.search(r"body=%([\w\.\-]+)", i.attrs)
                mc = re.search(r"condition=%([\w\.\-]+)", i.attrs)
                for sub, t in ((mb, trips), (mc, trips)):
                    if sub and sub.group(1) in comps:
                        walk(comps[sub.group(1)], scale * t)
                continue
            if i.op in ("call", "async-start", "conditional"):
                for b in hc._called(i):
                    if b in comps:
                        walk(comps[b], scale)
                continue
            c = hc._instr_cost(comp, i, comps, memo, n_chips)
            key = _group_key(i)
            flops[key] += c.flops * scale
            byts[key] += c.bytes * scale
            coll[key] += c.coll_bytes * scale

    walk(comps[entry], 1.0)
    return flops, byts, coll


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("hlo_gz")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--chips", type=int, default=256)
    args = ap.parse_args()

    with gzip.open(args.hlo_gz, "rt") as f:
        text = f.read()
    flops, byts, coll = profile(text, args.chips)

    for title, table, unit, div in [
            ("FLOPS (per chip)", flops, "GF", 1e9),
            ("BYTES accessed (per chip)", byts, "GB", 1e9),
            ("COLLECTIVE wire bytes (per chip)", coll, "GB", 1e9)]:
        total = sum(table.values())
        print(f"\n== {title}: total {total/div:.2f} {unit} ==")
        for k, v in sorted(table.items(), key=lambda kv: -kv[1])[:args.top]:
            if v <= 0:
                break
            print(f"  {v/div:10.3f} {unit}  {v/total:6.1%}  {k}")


if __name__ == "__main__":
    main()

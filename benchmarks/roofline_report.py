"""Render the roofline table (EXPERIMENTS.md SS Roofline) from the dry-run
cell JSONs in benchmarks/results/.

Per (arch x shape x mesh): the three terms in seconds, the dominant one,
MODEL_FLOPS/HLO_FLOPS (useful-compute ratio) and the roofline fraction
(useful flops / what the dominant term allows).
"""

from __future__ import annotations

import glob
import json
import os
import time

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load_cells(pattern: str = "dryrun_*.json", results_dir: str = RESULTS,
               baselines_only: bool = True):
    """Baseline cells by default; perf-variant cells carry a _<tag> suffix
    after the mesh name and are reported in EXPERIMENTS.md §Perf."""
    import re
    cells = []
    for path in sorted(glob.glob(os.path.join(results_dir, pattern))):
        if baselines_only and not re.search(r"__(pod|multipod)\.json$", path):
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _advice(c) -> str:
    dom = c.get("bottleneck", "")
    if dom == "memory":
        return "fuse attn/softmax (flash), bf16 intermediates, remat policy"
    if dom == "collective":
        return "reshard: fewer TP collectives / bigger DP; overlap a2a"
    return "larger per-chip tiles; reduce remat recompute"


def render(cells, md: bool = False):
    rows = []
    hdr = (f"{'arch':18s} {'shape':12s} {'mesh':9s} {'stat':7s} "
           f"{'t_comp':>9s} {'t_mem':>9s} {'t_coll':>9s} {'dom':10s} "
           f"{'useful':>7s} {'roofline':>8s}")
    sep = "-" * len(hdr)
    out = [hdr, sep]
    for c in cells:
        if c["status"] != "OK":
            out.append(f"{c['arch']:18s} {c['shape']:12s} {c['mesh']:9s} "
                       f"{c['status']:7s} {c.get('reason', c.get('error',''))[:60]}")
            continue
        useful = min(c["useful_flops_ratio"], 99.0)
        out.append(
            f"{c['arch']:18s} {c['shape']:12s} {c['mesh']:9s} {'OK':7s} "
            f"{c['t_compute']:9.4f} {c['t_memory']:9.4f} "
            f"{c['t_collective']:9.4f} {c['bottleneck']:10s} "
            f"{useful:7.2%} {c['roofline_fraction']:8.2%}")
        rows.append(c)
    return "\n".join(out), rows


def run(csv: bool = True):
    t0 = time.perf_counter()
    cells = load_cells()
    if not cells:
        print("no dry-run cells found; run: python -m repro.launch.dryrun")
        return False
    text, rows = render(cells)
    print("\n== Roofline table (from dry-run compiled artifacts) ==")
    print(text)
    ok_cells = [c for c in cells if c["status"] == "OK"]
    fails = [c for c in cells if c["status"] == "FAIL"]
    if ok_cells:
        worst = min(ok_cells, key=lambda c: c["roofline_fraction"])
        collbound = [c for c in ok_cells if c["bottleneck"] == "collective"]
        print(f"\n{len(ok_cells)} OK, "
              f"{sum(c['status'] == 'SKIPPED' for c in cells)} skipped, "
              f"{len(fails)} failed")
        print(f"worst roofline fraction: {worst['arch']}/{worst['shape']}/"
              f"{worst['mesh']} = {worst['roofline_fraction']:.2%} "
              f"(dom {worst['bottleneck']}; fix: {_advice(worst)})")
        print(f"collective-bound cells: "
              f"{[(c['arch'], c['shape'], c['mesh']) for c in collbound][:6]}")
    us = (time.perf_counter() - t0) * 1e6
    if csv:
        print(f"CSV,roofline_report,{us:.0f},"
              f"cells_ok={len(ok_cells)};cells_fail={len(fails)}")
    return not fails


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)

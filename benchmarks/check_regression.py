"""Bench regression guard: fresh microbench vs the committed baseline.

Usage (CI runs exactly this, see .github/workflows/ci.yml)::

    PYTHONPATH=src python benchmarks/kernel_microbench.py
    PYTHONPATH=src python benchmarks/check_regression.py \
        --baseline BENCH_kernels.json \
        --fresh benchmarks/out/BENCH_fresh.json

Two kinds of checks:

* **throughput keys** (``pipeline_frames_per_s``, ``serve_frames_per_s``)
  fail the job when the fresh run is more than ``--tolerance`` (default
  10%) below the committed baseline — the perf-trajectory contract: a PR
  that slows the packed pipeline or the serving path must either fix the
  regression or consciously refresh the baseline with the fresh numbers
  (and say why in the PR).  Absolute frames/s only compare within one
  machine class, so when the recorded ``host`` fingerprint (or backend)
  differs from the baseline these checks downgrade to warnings.
* **latency keys** (``serve_p99_ms``, ``serve_p99_ms_static``) are the
  mirror image: lower is better, so they fail when the fresh run is
  more than the tolerance *above* the baseline (host-gated the same
  way).
* **invariant keys** — machine-independent ratios that must never dip
  below 1: the megakernel must beat the staged plan
  (``megakernel_speedup_vs_staged``), the fused plan must beat the seed
  path (``pipeline_fused_speedup``), shared-array composite dispatch
  must beat time-interleaved solo dispatch
  (``serve_shared_speedup_vs_solo``), the always-on cascade must cost
  at most the recognizer alone (``cascade_savings_vs_recognizer``), and
  continuous batching must beat static dispatch on the committed
  Poisson trace in both p99 latency
  (``serve_p99_speedup_vs_static``) and uJ/frame
  (``serve_energy_ratio_vs_static``), and the delta-gated video path
  must serve the committed scene no slower than full recompute
  (``temporal_speedup_vs_full``).  These hold on any host, so they
  are hard floors rather than tolerance bands.  Cross-key checks ride
  along: ``serve_padding_ratio_continuous`` must stay strictly below
  ``serve_padding_ratio_static``, and the gated
  ``temporal_uj_per_frame`` strictly below
  ``temporal_uj_per_frame_ungated``, within the fresh run.

Keys present on only ONE side (a metric newly added by this PR, or one
the baseline carries but the fresh run no longer emits) are reported as
warnings, never failures — new metrics land in one PR, and the baseline
refresh that records them is the same ``BENCH_KERNELS_JSON=
BENCH_kernels.json`` run as any intentional perf change.  A key present
in *both* files is always enforced.  (Conscious trade-off: a refactor
that silently stops *emitting* a guarded key only warns — the warning
text calls out "in baseline, not in fresh run" precisely so a reviewer
reading the CI log catches a dropped metric.)

Exit 0 iff every check passes.
"""

from __future__ import annotations

import argparse
import json
import sys

THROUGHPUT_KEYS = ("pipeline_frames_per_s", "serve_frames_per_s",
                   "serve_frames_per_s_multi", "serve_frames_per_s_shared",
                   "serve_frames_per_s_cascade",
                   "serve_frames_per_s_cascade_fused",
                   "serve_frames_per_s_continuous",
                   "serve_frames_per_s_temporal")
# latency keys: LOWER is better — fail when the fresh run is more than
# the tolerance ABOVE the committed baseline (host-gated like the
# absolute frames/s keys)
LATENCY_KEYS = ("serve_p99_ms", "serve_p99_ms_static",
                "fleet_failover_recovery_ms")
INVARIANT_FLOORS = {
    "megakernel_speedup_vs_staged": 1.0,
    "pipeline_fused_speedup": 1.0,
    "serve_shared_speedup_vs_solo": 1.0,
    # the cascade's measured uJ/frame must stay at or below running the
    # recognizer (the big net) on every frame — the whole point of the
    # detector stage; holds on any host (pure energy-model ratio)
    "cascade_savings_vs_recognizer": 1.0,
    # continuous batching must beat static dispatch on the committed
    # Poisson trace: lower p99 input-to-label latency AND equal-or-
    # better uJ/frame — both are same-run paired ratios, so they hold
    # on any host
    "serve_p99_speedup_vs_static": 1.0,
    "serve_energy_ratio_vs_static": 1.0,
    # a replacement replica built through the warm-start cache must come
    # up no slower than the cold build it replaces — a same-run paired
    # ratio, so it holds on any host
    "replica_warm_start_speedup": 1.0,
    # the fused in-kernel cascade (one composite dispatch per detector
    # batch, escalation mask + recognizer drain inside the kernel) must
    # serve the same stream no slower than the host-side cascade — a
    # same-run paired ratio, so it holds on any host
    "cascade_fused_speedup_vs_host": 1.0,
    # skipping unchanged frames must never be slower than recomputing
    # them: gated vs gate-off replay of the same committed video trace
    # through the same kernel — a same-run paired ratio, any host
    "temporal_speedup_vs_full": 1.0,
}
# cross-key invariants: (lhs, rhs) pairs where fresh[lhs] must stay
# strictly below fresh[rhs] — the continuous admission window must burn
# fewer padding slots than the static pad (host-independent)
CROSS_KEY_BELOW = (
    ("serve_padding_ratio_continuous", "serve_padding_ratio_static"),
    # billing skipped frames at delta-compute-only cost must undercut
    # the ungated bill on the committed trace (pure energy-model ratio)
    ("temporal_uj_per_frame", "temporal_uj_per_frame_ungated"),
)


def check(baseline: dict, fresh: dict, tolerance: float) -> list:
    """Returns a list of failure strings (empty == pass)."""
    failures = []
    same_host = (baseline.get("host") is not None
                 and baseline.get("host") == fresh.get("host")
                 and baseline.get("backend") == fresh.get("backend"))
    if not same_host:
        print(f"  host changed ({baseline.get('host')} -> "
              f"{fresh.get('host')}): absolute frames/s checks downgraded "
              "to warnings, ratio floors still enforced")
    for key in THROUGHPUT_KEYS:
        if key not in fresh:
            level = ("warning (in baseline, not in fresh run)"
                     if key in baseline else "warning (not measured)")
            print(f"  {key}: missing from the fresh run — {level}")
            continue
        if key not in baseline:
            print(f"  {key}: no baseline yet ({fresh[key]:.1f} fresh) — "
                  "warning only (refresh BENCH_kernels.json to track it)")
            continue
        base, new = float(baseline[key]), float(fresh[key])
        ratio = new / base if base else 1.0
        bad = ratio < 1.0 - tolerance
        verdict = ("ok" if not bad
                   else "REGRESSION" if same_host else "warning (new host)")
        print(f"  {key}: {base:,.1f} -> {new:,.1f}  ({ratio:.2f}x)  {verdict}")
        if bad and same_host:
            failures.append(
                f"{key} regressed {(1 - ratio) * 100:.0f}% "
                f"(> {tolerance * 100:.0f}% tolerance): "
                f"{base:,.1f} -> {new:,.1f}")
    for key in LATENCY_KEYS:
        if key not in fresh:
            level = ("warning (in baseline, not in fresh run)"
                     if key in baseline else "warning (not measured)")
            print(f"  {key}: missing from the fresh run — {level}")
            continue
        if key not in baseline:
            print(f"  {key}: no baseline yet ({fresh[key]:.2f} ms fresh) — "
                  "warning only (refresh BENCH_kernels.json to track it)")
            continue
        base, new = float(baseline[key]), float(fresh[key])
        ratio = new / base if base else 1.0
        bad = ratio > 1.0 + tolerance
        verdict = ("ok" if not bad
                   else "REGRESSION" if same_host else "warning (new host)")
        print(f"  {key}: {base:.2f} -> {new:.2f} ms  ({ratio:.2f}x)  "
              f"{verdict}")
        if bad and same_host:
            failures.append(
                f"{key} regressed {(ratio - 1) * 100:.0f}% "
                f"(> {tolerance * 100:.0f}% tolerance): "
                f"{base:.2f} -> {new:.2f} ms")
    for key, floor in INVARIANT_FLOORS.items():
        if key not in fresh:
            level = ("warning (in baseline, not in fresh run)"
                     if key in baseline else "warning (not measured)")
            print(f"  {key}: missing from the fresh run — {level}")
            continue
        val = float(fresh[key])
        verdict = "ok" if val >= floor else "BELOW FLOOR"
        print(f"  {key}: {val:.2f} (floor {floor:.2f})  {verdict}")
        if val < floor:
            failures.append(f"{key} = {val:.2f} fell below the {floor:.2f} "
                            "floor")
    for lhs, rhs in CROSS_KEY_BELOW:
        if lhs not in fresh or rhs not in fresh:
            print(f"  {lhs} < {rhs}: not measured — warning only")
            continue
        lo, hi = float(fresh[lhs]), float(fresh[rhs])
        verdict = "ok" if lo < hi else "VIOLATED"
        print(f"  {lhs} ({lo:.4f}) < {rhs} ({hi:.4f})  {verdict}")
        if lo >= hi:
            failures.append(f"{lhs} = {lo:.4f} is not below {rhs} = {hi:.4f}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="BENCH_kernels.json",
                    help="committed perf baseline (the repo's trajectory)")
    ap.add_argument("--fresh", required=True,
                    help="BENCH json written by a fresh microbench run")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional throughput drop (default 0.10)")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except FileNotFoundError:
        print(f"no baseline at {args.baseline} — enforcing only the ratio "
              "floors (commit a BENCH_kernels.json to start the trajectory)")
        baseline = {}
    with open(args.fresh) as f:
        fresh = json.load(f)
    if baseline.get("backend") != fresh.get("backend"):
        print(f"note: backend changed "
              f"({baseline.get('backend')} -> {fresh.get('backend')}); "
              "throughput comparison is indicative only")

    print(f"bench regression check (tolerance {args.tolerance * 100:.0f}%):")
    failures = check(baseline, fresh, args.tolerance)
    if failures:
        print("\nFAIL:")
        for f_ in failures:
            print(f"  - {f_}")
        print("(an intentional perf change must refresh BENCH_kernels.json "
              "with the fresh numbers)")
        return 1
    print("all bench checks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

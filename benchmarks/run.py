"""Benchmark harness: one entry per paper table/figure + system reports.

  fig4_layer_perf    Fig. 4  per-layer core GOPS / TOPS/W
  fig5_i2l           Fig. 5  I2L energy/throughput/power vs S
  table1_comparison  Table 1 cross-chip comparison + advantage ratios
  kernel_microbench  packed XNOR-popcount vs float path (+ allclose)
  roofline_report    40-cell dry-run roofline table (needs dryrun JSONs)

Each prints human tables plus a ``CSV,name,us_per_call,derived`` line.
Exit code 0 iff every anchor check passes.
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (fig4_layer_perf, fig5_i2l, kernel_microbench,
                            roofline_report, table1_comparison)
    results = {}
    for name, mod in [("fig4_layer_perf", fig4_layer_perf),
                      ("fig5_i2l", fig5_i2l),
                      ("table1_comparison", table1_comparison),
                      ("kernel_microbench", kernel_microbench),
                      ("roofline_report", roofline_report)]:
        try:
            results[name] = bool(mod.run())
        except Exception:  # noqa: BLE001 — report, keep going
            import traceback
            traceback.print_exc()
            results[name] = False
    print("\n== benchmark summary ==")
    for name, ok in results.items():
        print(f"  [{'OK' if ok else 'FAIL'}] {name}")
    sys.exit(0 if all(results.values()) else 1)


if __name__ == "__main__":
    main()

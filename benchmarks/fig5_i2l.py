"""Fig. 5 reproduction: IO-to-Label (I2L) system performance vs S.

Paper anchors (Sec. III-B / Fig. 5):
  * S=1: 14.4 uJ/f I2L at ~150 inf/s (CIFAR-10 86%, owner 98.2%)
  * S=2: 3.47 uJ/f (7 face angles)
  * S=4: 0.92 uJ/f at up to 1700 inf/s (face detection 94.5% precision)
  * P @ Emin: 2.2 / 1.8 / 1.6 mW for S=1/2/4
  * ops/net: 2G / 0.5G / 0.12G for S=1/2/4
  * I2L efficiency up to 145 TOPS/W
"""

from __future__ import annotations

import time

from repro.core.chip import energy, networks

PAPER = {  # S -> (i2l uJ/f, ops/net, P mW, inf/s)
    1: (14.4, 2.0e9, 2.2, 150.0),
    2: (3.47, 0.5e9, 1.8, 500.0),
    4: (0.92, 0.125e9, 1.6, 1700.0),
}


def run(csv: bool = True):
    t0 = time.perf_counter()
    reports = {s: energy.analyze_net(networks.cifar9(s)) for s in (1, 2, 4)}
    us = (time.perf_counter() - t0) * 1e6

    print("\n== Fig. 5: I2L energy / throughput / power vs S (9-layer net) ==")
    print(f"{'S':>2s} {'ops/net':>9s} {'core uJ/f':>10s} {'I2L uJ/f':>9s} "
          f"{'inf/s':>7s} {'P mW':>6s} {'core T/W':>9s} {'I2L T/W':>8s}")
    ok = True
    for s, r in reports.items():
        print(f"{s:2d} {r.ops_per_inference/1e9:8.2f}G "
              f"{r.core_energy_per_inference*1e6:10.2f} "
              f"{r.i2l_energy_per_inference*1e6:9.2f} "
              f"{r.inferences_per_s:7.0f} {r.power_w*1e3:6.2f} "
              f"{r.core_tops_per_w:9.1f} {r.i2l_tops_per_w:8.1f}")
    print("\nanchor checks vs paper (10% band unless noted):")
    for s, (uj, ops, p_mw, infs) in PAPER.items():
        r = reports[s]
        checks = [
            (f"S={s} I2L uJ/f", r.i2l_energy_per_inference * 1e6, uj, 0.10),
            (f"S={s} ops/net", r.ops_per_inference, ops, 0.10),
            (f"S={s} P @Emin [mW]", r.power_w * 1e3, p_mw, 0.25),
        ]
        for name, got, want, tol in checks:
            err = abs(got - want) / want
            good = err <= tol
            ok &= good
            print(f"  [{'OK' if good else 'FAIL'}] {name}: {got:.3g} "
                  f"(paper {want:.3g}, err {err:.1%})")
    # throughput scaling: papers says S=4 reaches up to 1700 inf/s
    s4 = reports[4].inferences_per_s
    good = s4 >= 1500
    ok &= good
    print(f"  [{'OK' if good else 'FAIL'}] S=4 inf/s >= 1500: {s4:.0f} "
          f"(paper 'up to 1700')")
    i2l_eff = max(r.i2l_tops_per_w for r in reports.values())
    good = 95 <= i2l_eff <= 160
    ok &= good
    print(f"  [{'OK' if good else 'FAIL'}] peak I2L eff in 95-145 band: "
          f"{i2l_eff:.0f} TOPS/W")
    if csv:
        print(f"CSV,fig5_i2l,{us:.0f},"
              f"s1_uj={reports[1].i2l_energy_per_inference*1e6:.2f};"
              f"s4_uj={reports[4].i2l_energy_per_inference*1e6:.2f};"
              f"anchors_ok={int(ok)}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)

"""Table 1 reproduction: the BinarEye column vs published competitors.

Our analytical chip model regenerates every BinarEye number in Table 1
(energies, inf/s, EDP, power per benchmark); competitor numbers are the
published constants, giving the same advantage ratios the paper claims:
70x vs YodaNN (CIFAR-10 w/ IO), 11.4x vs TrueNorth, 1.33x vs BRein
(MNIST), 3.3x vs Envision / 12x vs the Haar ASIC (face detection).
"""

from __future__ import annotations

import time

from repro.core.chip import energy, networks

# published competitor anchors: benchmark -> (chip, E/inf uJ, note)
COMPETITORS = {
    "CIFAR-10": [("YodaNN(+IO)", 1000.0, "91.7%"),
                 ("TrueNorth", 164.0, "83.4%")],
    "MNIST": [("BRein", 0.28, "90.1%")],
    "Face Detection": [("Envision", 3.0, "94%"), ("Haar-ASIC", 11.8, ">95%")],
}

PAPER_BINAREYE = {  # benchmark -> (S, core uJ/f, I2L uJ/f)
    "MNIST": (4, 0.20, 0.21),
    "CIFAR-10": (1, 13.82, 14.4),
    "Face Detection": (4, 0.89, 0.92),
    "Owner Detection": (1, 13.82, 14.4),
    "7 Face Angles": (2, 3.4, 3.47),
}


def _net_for(bench: str):
    return {
        "MNIST": networks.mnist5,
        "CIFAR-10": lambda: networks.cifar9(1),
        "Face Detection": networks.face_detector,
        "Owner Detection": networks.owner_detector,
        "7 Face Angles": networks.face_angles,
    }[bench]()


def run(csv: bool = True):
    t0 = time.perf_counter()
    ok = True
    print("\n== Table 1: comparison on the paper's benchmarks ==")
    print(f"{'benchmark':16s} {'S':>2s} {'core uJ/f':>10s} {'I2L uJ/f':>9s} "
          f"{'paper I2L':>9s} {'err':>6s} {'inf/s':>7s} {'P mW':>6s}")
    ratios = {}
    for bench, (s, core_uj, i2l_uj) in PAPER_BINAREYE.items():
        r = energy.analyze_net(_net_for(bench))
        got_core = r.core_energy_per_inference * 1e6
        got_i2l = r.i2l_energy_per_inference * 1e6
        err = abs(got_i2l - i2l_uj) / i2l_uj
        good = err <= 0.10
        ok &= good
        print(f"{bench:16s} {s:2d} {got_core:10.2f} {got_i2l:9.2f} "
              f"{i2l_uj:9.2f} {err:6.1%} {r.inferences_per_s:7.0f} "
              f"{r.power_w*1e3:6.2f}" + ("" if good else "  <-- FAIL"))
        for chip, e_uj, note in COMPETITORS.get(bench, []):
            ratios[(bench, chip)] = e_uj / got_i2l
    print("\nadvantage ratios (competitor E / BinarEye I2L E):")
    claims = {("CIFAR-10", "YodaNN(+IO)"): 70.0,
              ("CIFAR-10", "TrueNorth"): 11.4,
              ("MNIST", "BRein"): 1.33,
              ("Face Detection", "Envision"): 3.3,
              ("Face Detection", "Haar-ASIC"): 12.0}
    for key, ratio in ratios.items():
        want = claims.get(key)
        if want is None:
            print(f"  {key[0]:16s} vs {key[1]:12s}: {ratio:6.1f}x")
            continue
        err = abs(ratio - want) / want
        good = err <= 0.15
        ok &= good
        print(f"  [{'OK' if good else 'FAIL'}] {key[0]:16s} vs {key[1]:12s}: "
              f"{ratio:6.1f}x (paper {want}x, err {err:.0%})")
    # EDP rows (uJ*s) — S=1 published at fmax latency, S=2/4 at Emin
    r1 = energy.analyze_net(networks.cifar9(1))
    r2 = energy.analyze_net(networks.cifar9(2))
    r4 = energy.analyze_net(networks.cifar9(4))
    print("\nEDP @ Emin-energy [uJ*s]:")
    for name, got, want in [("S=1 (fmax latency)", r1.edp_ujs_at(energy.F_MAX), 1e-2),
                            ("S=2", r2.edp_ujs, 7e-3),
                            ("S=4", r4.edp_ujs, 5e-4)]:
        err = abs(got - want) / want
        good = err <= 0.35
        ok &= good
        print(f"  [{'OK' if good else 'FAIL'}] {name}: {got:.2e} "
              f"(paper {want:.0e}, err {err:.0%})")
    us = (time.perf_counter() - t0) * 1e6
    if csv:
        print(f"CSV,table1_comparison,{us:.0f},anchors_ok={int(ok)}")
    return ok


if __name__ == "__main__":
    raise SystemExit(0 if run() else 1)

"""Shared test config: graceful fallback when ``hypothesis`` is absent.

The container this repo is developed in has no network access, so the
real hypothesis package may be missing.  Rather than skipping the five
property-test modules wholesale (losing their parametrized cases too),
we install a minimal deterministic stand-in that supports exactly the
subset these tests use: ``@given`` with ``st.integers`` /
``st.sampled_from`` / ``st.booleans`` / ``st.lists`` strategies and
``@settings(max_examples=..., deadline=...)``.  Each property test then runs against a fixed
pseudo-random sample of examples (seeded per test name, so failures
reproduce).  With the real package installed (see requirements-dev.txt)
this file is a no-op.
"""

from __future__ import annotations

import random
import sys
import types


def _install_hypothesis_stub() -> None:
    DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def sample(self, rng):
            return self._draw(rng)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def booleans():
        return _Strategy(lambda rng: bool(rng.randrange(2)))

    def lists(elements, min_size=0, max_size=10):
        return _Strategy(
            lambda rng: [elements.sample(rng)
                         for _ in range(rng.randint(min_size, max_size))])

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", DEFAULT_MAX_EXAMPLES)
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            wrapper._hypothesis_stub = True
            return wrapper
        return deco

    def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.__stub__ = True
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans
    st_mod.lists = lists
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:  # pragma: no cover - exercised implicitly at collection time
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()


import pytest  # noqa: E402  (after the stub so plugins see it installed)


def pytest_collection_modifyitems(config, items):
    """Auto-mark every hypothesis property test ``slow``.

    The property sweeps are the biggest wall-clock offenders in the suite
    (20+ examples x jit each); marking them centrally keeps the fast
    tier (``-m "not slow"``) under control without scattering marks
    across files.  Works for both the real package
    (``is_hypothesis_test``) and the offline stub (``_hypothesis_stub``).
    """
    for item in items:
        fn = getattr(item, "obj", None)
        if fn is not None and (getattr(fn, "is_hypothesis_test", False)
                               or getattr(fn, "_hypothesis_stub", False)):
            item.add_marker(pytest.mark.slow)

"""Serving correctness: prefill + K decode steps == teacher-forced forward.

Covers KV caches (incl. sliding window + softcaps), Mamba conv/ssm states,
RWKV shift/wkv states and MusicGen codebooks.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import transformer
from repro.train import serve

ARCHS = ["smollm-360m", "gemma2-2b", "jamba-v0.1-52b", "rwkv6-3b",
         "musicgen-medium", "olmoe-1b-7b", "qwen3-8b"]


@pytest.mark.parametrize(
    "arch", [pytest.param(a, marks=pytest.mark.slow)
             if a == "jamba-v0.1-52b" else a for a in ARCHS])
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch).scaled().with_(dtype="float32",
                                          param_dtype="float32")
    if arch == "gemma2-2b":
        cfg = cfg.with_(sliding_window=8)  # exercise windowing inside 24 toks
    key = jax.random.PRNGKey(0)
    params = transformer.init_params(key, cfg)
    B, T, K = 2, 24, 4
    shape = (B, T, cfg.num_codebooks) if cfg.num_codebooks > 1 else (B, T)
    toks = jax.random.randint(key, shape, 0, cfg.vocab_size)

    h, _, _ = transformer.forward(params, cfg, {"tokens": toks}, mode="train")
    want = transformer.lm_logits(params, cfg, h)[:, T - K - 1:T]

    pf = serve.build_prefill_step(cfg, max_len=T + 4)
    dc = serve.build_decode_step(cfg)
    logits, cache = pf(params, {"tokens": toks[:, :T - K]})
    outs = [logits]
    for i in range(K):
        lg, cache = dc(params, cache, toks[:, T - K + i][:, None],
                       jnp.int32(T - K + i))
        outs.append(lg)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_sampling_shapes_and_determinism():
    cfg = get_config("smollm-360m").scaled().with_(dtype="float32",
                                                   param_dtype="float32")
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 1, cfg.vocab_size))
    greedy = serve.sample(jax.random.PRNGKey(1), logits, temperature=0.0)
    assert greedy.shape == (3, 1)
    np.testing.assert_array_equal(np.asarray(greedy),
                                  np.asarray(jnp.argmax(logits, -1)))
    s1 = serve.sample(jax.random.PRNGKey(2), logits, temperature=1.0)
    s2 = serve.sample(jax.random.PRNGKey(2), logits, temperature=1.0)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))

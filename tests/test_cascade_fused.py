"""Fused in-kernel cascade: bit-exactness vs the host escalation rule.

The PR's acceptance property: ONE composite dispatch runs the detector
over every frame tile, computes the escalation mask (positive-class
logit margin vs threshold) *inside* the kernel, and drains the
recognizer over escalated lanes only through bounded-iteration control
flow — and the answers are bit-identical to the host-side cascade (and
to the offline recognizer oracle on every escalated frame) for every
margin, batch raggedness, drain schedule and REGISTRY det/rec pair.
"""

import functools
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.chip import interpreter, isa, networks
from repro.kernels import cache as warmcache
from repro.serving import CascadePipeline, ChipServer, margins_of
from test_fold_pack_property import _random_bn_params, random_program


def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


def _artifact(program, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    return interpreter.fold_params(params, program, packed=True)


def _offline(program, packed, frames):
    plan = interpreter.compile_plan(program)
    logits, labels = plan.forward(packed, np.asarray(frames), interpret=True)
    return np.asarray(logits), np.asarray(labels)


# margins covering both extremes, a fractional value (exercises the
# ceil in margin_ctrl), zero and interior thresholds
MARGINS = (float("-inf"), -3.5, 0.0, 1.0, 7.0, float("inf"))


@pytest.fixture(scope="module")
def fused_setup():
    det = networks.mnist5(classes=2)
    rec = networks.mnist5(classes=5)
    progs = {"det": det, "rec": rec}
    arts = {"det": _artifact(det, seed=1), "rec": _artifact(rec, seed=2)}
    frames = _frames(det, 7, seed=3)
    plan, image = interpreter.pack_cascade(
        progs, arts, detector="det", recognizer="rec")
    dl, dlab = _offline(det, arts["det"], frames)
    rl, rlab = _offline(rec, arts["rec"], frames)
    return (det, rec, progs, arts, frames, plan, image,
            (dl, dlab), (rl, rlab))


def _check_fused(plan, image, frames, dl, rl, margin, **kw):
    """One fused dispatch vs the host escalation rule + offline oracles:
    det logits exact, queue == the host mask's indices (ascending),
    counts[0] == the mask popcount, rec rows == the offline recognizer
    on exactly the escalated frames."""
    ctrl = plan.margin_ctrl(margin, len(frames))
    d, dlb, r, rlb, q, cnt = plan.forward_fused(
        image, jnp.asarray(frames), ctrl, interpret=True, **kw)
    d, r, q, cnt = (np.asarray(d), np.asarray(r), np.asarray(q),
                    np.asarray(cnt))
    host_mask = margins_of(dl, plan.positive_class) >= margin
    exp_q = np.nonzero(host_mask)[0]
    np.testing.assert_array_equal(d, dl)
    np.testing.assert_array_equal(np.asarray(dlb), np.argmax(dl, axis=1))
    assert int(cnt[0]) == len(exp_q)
    assert int(cnt[1]) >= int(cnt[0])      # drain chunks may pad, never drop
    np.testing.assert_array_equal(q[:len(exp_q)], exp_q)
    np.testing.assert_array_equal(r[:len(exp_q)], rl[exp_q])
    np.testing.assert_array_equal(np.asarray(rlb)[:len(exp_q)],
                                  np.argmax(rl[exp_q], axis=1))


def test_forward_fused_bit_exact_vs_oracles(fused_setup):
    """Plan-level fused dispatch vs the offline stage oracles at every
    margin, on a ragged batch with ragged drain chunks."""
    det, rec, progs, arts, frames, plan, image, (dl, _), (rl, _) = fused_setup
    for margin in MARGINS:
        _check_fused(plan, image, frames, dl, rl, margin,
                     bb=3, rb=2, check_every=2)


def test_fused_schedule_invariance(fused_setup):
    """bb/rb/check_every are pure schedule knobs: every setting yields
    the identical escalation queue and logits."""
    det, rec, progs, arts, frames, plan, image, (dl, _), (rl, _) = fused_setup
    for bb, rb, ce in ((1, 1, 1), (4, 4, 3), (2, 1, 5), (7, 3, 2)):
        _check_fused(plan, image, frames, dl, rl, 0.0,
                     bb=bb, rb=rb, check_every=ce)


def test_fused_padding_never_escalates(fused_setup):
    """The batch-pad lanes (gidx >= n_real) are masked out of the
    escalation even at margin=-inf, where every *real* frame escalates."""
    det, rec, progs, arts, frames, plan, image, (dl, _), (rl, _) = fused_setup
    five = frames[:5]                    # bb=4 -> bpad=8, 3 pad lanes
    ctrl = plan.margin_ctrl(float("-inf"), 5)
    *_, q, cnt = plan.forward_fused(image, jnp.asarray(five), ctrl,
                                    interpret=True, bb=4, rb=2)
    assert int(np.asarray(cnt)[0]) == 5
    np.testing.assert_array_equal(np.asarray(q)[:5], np.arange(5))


def test_margin_ctrl_bit_exactness():
    """The int32 fold of the host float rule: for integer margins m,
    m >= margin  <=>  m >= ceil(margin); +/-inf map to unreachable
    sentinels; NaN is rejected."""
    mc = interpreter.CascadePlan.margin_ctrl
    assert int(mc(0.0, 3)[0, 0]) == 0
    assert int(mc(0.2, 3)[0, 0]) == 1
    assert int(mc(-0.2, 3)[0, 0]) == 0
    assert int(mc(float("-inf"), 3)[0, 0]) == -(2 ** 31)
    assert int(mc(float("inf"), 3)[0, 0]) == 2 ** 31 - 1
    assert int(mc(1e300, 3)[0, 0]) == 2 ** 31 - 1      # finite clamp
    assert int(mc(0.0, 9)[0, 1]) == 9                  # n_real rides along
    with pytest.raises(ValueError, match="NaN"):
        mc(float("nan"), 3)
    # the equivalence itself, on a grid spanning both signs
    for m in range(-5, 6):
        for margin in np.linspace(-5.5, 5.5, 45):
            thr = int(mc(float(margin), 1)[0, 0])
            assert (m >= margin) == (m >= thr), (m, margin)


def test_fused_pipeline_matches_host_for_every_margin(fused_setup):
    """The serving path: CascadePipeline(fused=True) finalizes the same
    labels, escalation flags, margins and logits as the host cascade at
    every margin — and the padding-free energy bills agree."""
    det, rec, progs, arts, frames, *_ = fused_setup
    for margin in MARGINS:
        runs = {}
        for fused in (False, True):
            server = ChipServer(progs, arts, batch=2, interpret=True)
            casc = CascadePipeline(server, "det", "rec", margin=margin,
                                   fused=fused)
            casc.submit_many(frames)
            res = sorted(casc.drain(), key=lambda c: c.rid)
            assert len(res) == len(frames)
            runs[fused] = (res, casc.report(include_padding=False),
                           casc.escalated)
            server.close()
        host, fusedr = runs[False][0], runs[True][0]
        for h, f in zip(host, fusedr):
            assert (h.rid, h.label, h.escalated, h.detector_label) == \
                   (f.rid, f.label, f.escalated, f.detector_label), margin
            assert h.detector_margin == pytest.approx(f.detector_margin)
            np.testing.assert_array_equal(h.logits, f.logits)
        assert runs[False][2] == runs[True][2]
        assert runs[False][1].uj_per_frame == pytest.approx(
            runs[True][1].uj_per_frame)


def test_fused_pipeline_margin_extremes(fused_setup):
    """-inf escalates everything (labels == recognizer offline), +inf
    nothing (labels == detector offline) — through the fused path."""
    det, rec, progs, arts, frames, _, _, (_, dlab), (_, rlab) = fused_setup
    for margin, oracle, want_esc in ((float("-inf"), rlab, True),
                                     (float("inf"), dlab, False)):
        server = ChipServer(progs, arts, batch=2, interpret=True)
        casc = CascadePipeline(server, "det", "rec", margin=margin,
                               fused=True)
        casc.submit_many(frames)
        res = sorted(casc.drain(), key=lambda c: c.rid)
        assert all(c.escalated == want_esc for c in res)
        np.testing.assert_array_equal(
            np.array([c.label for c in res]), oracle)
        assert casc.fused_dispatches == 4          # 7 frames / batch 2
        server.close()


def test_fused_billing_invariant_and_kernel_slots(fused_setup):
    """Fused dispatches keep the server's launch-ledger invariant
    (billed == served + padded over every lane) and bill the recognizer
    on the kernel-reported slot count: escalated frames plus the drain
    chunks' padding, never less than the escalations."""
    det, rec, progs, arts, frames, *_ = fused_setup
    server = ChipServer(progs, arts, batch=2, interpret=True)
    casc = CascadePipeline(server, "det", "rec", margin=0.0, fused=True)
    casc.submit_many(frames)
    casc.drain()
    stats = server.stats()
    assert server._billed == (sum(stats.served.values())
                              + sum(stats.padded.values()))
    assert stats.served["det"] == len(frames)
    assert stats.served["rec"] == casc.escalated
    assert stats.padded["rec"] >= 0
    rep = casc.report()
    assert rep.frames == len(frames)
    assert rep.escalated == casc.escalated
    server.close()


def test_fused_warm_cache_and_positive_class_key(fused_setup):
    """The fused dispatch routes through the warm-start cache: a second
    pipeline over the same pair warm-starts (cache hit), while a
    different positive_class compiles its own fn (the escalation mask is
    traced against the class index)."""
    det, rec, progs, arts, frames, *_ = fused_setup
    servers = [ChipServer(progs, arts, batch=2, interpret=True)
               for _ in range(3)]
    try:
        warmcache.invalidate()
        CascadePipeline(servers[0], "det", "rec", fused=True)
        s0 = warmcache.stats()
        assert s0["misses"] >= 1
        CascadePipeline(servers[1], "det", "rec", fused=True)
        s1 = warmcache.stats()
        assert s1["hits"] == s0["hits"] + 1          # warm-started
        assert s1["misses"] == s0["misses"]
        CascadePipeline(servers[2], "det", "rec", fused=True,
                        positive_class=0)
        s2 = warmcache.stats()
        assert s2["misses"] == s1["misses"] + 1      # new trace
    finally:
        for s in servers:
            s.close()
        warmcache.invalidate()


def test_fused_positive_class_zero_bit_exact(fused_setup):
    """positive_class=0 flips which logit is 'positive': the fused mask
    still matches the host rule exactly."""
    det, rec, progs, arts, frames, plan0, image0, (dl, _), (rl, _) = \
        fused_setup
    plan, image = interpreter.pack_cascade(
        progs, arts, detector="det", recognizer="rec", positive_class=0)
    _check_fused(plan, image, frames, dl, rl, 0.0, bb=3, rb=2)


def test_pack_cascade_guards():
    det = networks.mnist5(classes=2)
    rec = networks.mnist5(classes=5)
    wide = networks.cifar9(4, classes=2)
    arts = {"det": _artifact(det, 1), "rec": _artifact(rec, 2),
            "wide": _artifact(wide, 3)}
    progs = {"det": det, "rec": rec, "wide": wide}
    with pytest.raises(isa.ProgramError, match="distinct"):
        interpreter.pack_cascade(progs, arts, detector="det",
                                 recognizer="det")
    with pytest.raises(KeyError, match="missing"):
        interpreter.pack_cascade(progs, arts, detector="det",
                                 recognizer="ghost")
    with pytest.raises(isa.ProgramError, match="geometry"):
        interpreter.pack_cascade(progs, arts, detector="det",
                                 recognizer="wide")
    with pytest.raises(isa.ProgramError, match="positive_class"):
        interpreter.pack_cascade(progs, arts, detector="det",
                                 recognizer="rec", positive_class=2)


def test_pack_programs_exact_tiling_gate():
    """pack_programs still rejects non-tiling multi-program packs by
    default; the cascade's exact_tiling=False escape hatch admits
    sequential-phase pairs whose S-modes oversubscribe the array."""
    det = networks.face_detector()                   # S=4 -> 64 channels
    rec = networks.REGISTRY["cifar9_s1"]()           # S=1 -> 256 channels
    progs = {"det": det, "rec": rec}
    arts = {"det": _artifact(det, 1), "rec": _artifact(rec, 2)}
    with pytest.raises(isa.ProgramError, match="tile"):
        interpreter.pack_programs(progs, arts)
    cplan, _ = interpreter.pack_programs(progs, arts, exact_tiling=False)
    assert len(cplan.programs) == 2


def test_fused_serve_fn_rejects_multi_device_mesh(fused_setup):
    """The in-kernel escalation queue is batch-global, so the fused
    dispatch refuses to shard over a multi-device mesh."""
    plan = fused_setup[5]
    fake_mesh = types.SimpleNamespace(devices=np.zeros((2,)))
    with pytest.raises(ValueError, match="multi-device"):
        plan.make_serve_fn(mesh=fake_mesh)


# ---------------------------------------------------------------------------
# Property: fused mask == host margin rule over random programs
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(s_det=st.sampled_from([2, 4]),
       s_rec=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 10 ** 6),
       margin_kind=st.sampled_from(
           ["neginf", "posinf", "zero", "median", "frac"]),
       n=st.integers(1, 6),
       bb=st.integers(1, 3))
def test_fused_mask_matches_host_property(s_det, s_rec, seed, margin_kind,
                                          n, bb):
    """Over random valid programs x margins x ragged batches: the fused
    kernel's escalation queue is exactly the host `margin >= thr` rule's
    index set, and every escalated lane carries the offline recognizer's
    logits.  Same seed -> same IO geometry for any S (the generator
    draws frame geometry before the S-dependent layers), so every
    (s_det, s_rec) pair is cascade-compatible."""
    det = random_program(s_det, seed)
    rec = random_program(s_rec, seed)
    arts = {
        "det": interpreter.fold_params(
            _random_bn_params(det, seed + 10), det, packed=True),
        "rec": interpreter.fold_params(
            _random_bn_params(rec, seed + 20), rec, packed=True),
    }
    ncd = det.instrs[-1].out_features
    pc = seed % ncd
    plan, image = interpreter.pack_cascade(
        {"det": det, "rec": rec}, arts, detector="det", recognizer="rec",
        positive_class=pc)
    frames = _frames(det, n, seed=seed + 30)
    dl, _ = _offline(det, arts["det"], frames)
    rl, _ = _offline(rec, arts["rec"], frames)
    margins = margins_of(dl, pc)
    margin = {"neginf": float("-inf"), "posinf": float("inf"),
              "zero": 0.0, "median": float(np.median(margins)),
              "frac": float(np.median(margins)) - 0.5}[margin_kind]
    _check_fused(plan, image, frames, dl, rl, margin,
                 bb=bb, rb=2, check_every=2)


# ---------------------------------------------------------------------------
# Every REGISTRY det/rec pair (acceptance criterion)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _reg_prog(name):
    return networks.REGISTRY[name]()


@functools.lru_cache(maxsize=None)
def _reg_art(name):
    return _artifact(_reg_prog(name), seed=hash(name) % 1000)


@functools.lru_cache(maxsize=None)
def _reg_offline(name):
    prog = _reg_prog(name)
    return _offline(prog, _reg_art(name), _frames(prog, 4, seed=11))


def _registry_pairs():
    names = sorted(networks.REGISTRY)
    geom = {}
    for n in names:
        io = _reg_prog(n).instrs[0]
        geom[n] = (io.height, io.width, io.in_channels, io.bits)
    return [(a, b) for a in names for b in names
            if a != b and geom[a] == geom[b]
            and _reg_prog(a).instrs[-1].out_features >= 2]


@pytest.mark.slow
@pytest.mark.parametrize("det_name,rec_name", _registry_pairs())
def test_fused_registry_pairs(det_name, rec_name):
    """Acceptance: the fused cascade is bit-exact vs the host cascade
    and the offline recognizer oracle for every geometry-compatible
    ordered REGISTRY pair — including the oversubscribed S=4 -> S=1
    paper pair (sequential phases need no exact tiling)."""
    det, rec = _reg_prog(det_name), _reg_prog(rec_name)
    arts = {det_name: _reg_art(det_name), rec_name: _reg_art(rec_name)}
    plan, image = interpreter.pack_cascade(
        {det_name: det, rec_name: rec}, arts,
        detector=det_name, recognizer=rec_name)
    frames = _frames(det, 4, seed=11)
    dl, _ = _reg_offline(det_name)
    rl, _ = _reg_offline(rec_name)
    # a margin that splits the batch when possible: the median margin
    margin = float(np.median(margins_of(dl)))
    _check_fused(plan, image, frames, dl, rl, margin, bb=4, rb=2)

"""Fused binary_conv2x2_block kernel vs the float reference chain.

The oracle is the unfused float path the chip model trains against:
conv sums -> folded comparator -> (optional) 2x2/2 max-pool -> pack.
The fused kernel must reproduce its packed output words bit-exactly for
every array width mode S in {1, 2, 4}, odd and even map sizes, and
pool/no-pool — plus the xnor_matmul pack_out fused sign+pack.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binarize
from repro.core.chip import neuron_array as na
from repro.kernels import ref
from repro.kernels.binary_conv2x2 import binary_conv2x2
from repro.kernels.binary_conv2x2_block import binary_conv2x2_block
from repro.kernels.xnor_matmul import xnor_matmul


def _rand_signs(rng, shape):
    return rng.choice(np.array([-1.0, 1.0], np.float32), size=shape)


def _pack_weights(w_signs):
    f, _, _, c = w_signs.shape
    return binarize.pack_signs(jnp.asarray(w_signs).reshape(f, 4, c), axis=-1)


def _oracle_words(a, wgt, tau, flip, pool):
    """Float reference chain, batched: packed words of the layer output."""
    s = jnp.stack([ref.binary_conv2x2_ref(jnp.asarray(a[i]), jnp.asarray(wgt))
                   for i in range(a.shape[0])]).astype(jnp.float32)
    act = binarize.threshold_activation(s, jnp.asarray(tau), jnp.asarray(flip))
    if pool:
        act = na.maxpool2x2(act)
    return binarize.pack_signs(act, axis=-1)


def _run_case(rng, b, h, w, c, f, pool, **tiles):
    a = _rand_signs(rng, (b, h, w, c))
    wgt = _rand_signs(rng, (f, 2, 2, c))
    tau = (rng.normal(size=f) * 3).astype(np.float32)
    flip = rng.integers(0, 2, f).astype(bool)
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    got = binary_conv2x2_block(
        a_words, _pack_weights(wgt),
        binarize.threshold_to_int(jnp.asarray(tau)), jnp.asarray(flip),
        c=c, pool=pool, interpret=True, **tiles)
    want = _oracle_words(a, wgt, tau, flip, pool)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# the chip's three array width modes: F = C = 256/S, S in {1, 2, 4}
MODE_CASES = [
    (2, 8, 8, 64, 64),       # S=4
    (2, 9, 7, 128, 128),     # S=2, odd/non-square map
    (1, 6, 6, 256, 256),     # S=1, full array
    (3, 5, 8, 40, 64),       # C not a multiple of 32 (packed padding)
    (2, 32, 32, 64, 64),     # full-size chip map
]


@pytest.mark.parametrize("pool", [False, True])
@pytest.mark.parametrize("b,h,w,c,f", MODE_CASES)
def test_fused_block_matches_float_reference(b, h, w, c, f, pool):
    rng = np.random.default_rng(h * 1000 + w * 100 + c + f + pool)
    _run_case(rng, b, h, w, c, f, pool)


@pytest.mark.parametrize("bf", [32, 64, 128])
def test_fused_block_f_tile_invariance(bf):
    rng = np.random.default_rng(5)
    _run_case(rng, 2, 10, 10, 64, 128, True, bf=bf)


@settings(max_examples=10, deadline=None)
@given(h=st.integers(3, 12), w=st.integers(3, 12), c=st.integers(1, 70),
       pool=st.sampled_from([False, True]), seed=st.integers(0, 2**31 - 1))
def test_fused_block_property_random(h, w, c, pool, seed):
    rng = np.random.default_rng(seed)
    _run_case(rng, 2, h, w, c, 32, pool, bf=32)


def test_fused_block_integer_threshold_edges():
    """Exactly-integer and extreme taus: ceil quantization can't disagree
    with the float comparator on integer sums."""
    rng = np.random.default_rng(9)
    b, h, w, c, f = 2, 6, 6, 32, 32
    a = _rand_signs(rng, (b, h, w, c))
    wgt = _rand_signs(rng, (f, 2, 2, c))
    # sums live in [-4c, 4c]; cover ties (integer tau), just-off-integer
    # taus, and never/always-fire extremes
    tau = np.array([0.0, 1.0, -1.0, 0.5, -0.5, 2.0 ** 20, -2.0 ** 20, 3.999]
                   * (f // 8), np.float32)
    flip = (np.arange(f) % 2).astype(bool)
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    got = binary_conv2x2_block(
        a_words, _pack_weights(wgt),
        binarize.threshold_to_int(jnp.asarray(tau)), jnp.asarray(flip),
        c=c, pool=False, interpret=True)
    want = _oracle_words(a, wgt, tau, flip, False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_conv_matches_per_image():
    """Batched-grid binary_conv2x2 == the same kernel run per image."""
    rng = np.random.default_rng(3)
    b, h, w, c, f = 4, 7, 9, 48, 24
    a = _rand_signs(rng, (b, h, w, c))
    wgt = _rand_signs(rng, (f, 2, 2, c))
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    w_words = _pack_weights(wgt)
    got = binary_conv2x2(a_words, w_words, c=c, bf=16, interpret=True)
    for i in range(b):
        want = binary_conv2x2(a_words[i], w_words, c=c, bf=16, interpret=True)
        np.testing.assert_array_equal(np.asarray(got[i]), np.asarray(want))


# ---------------------------------------------------------------------------
# xnor_matmul pack_out: fused sign+pack for hidden FC layers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n,bk", [(3, 64, 32, 64), (5, 300, 64, 2),
                                      (17, 2048, 128, 8), (1, 33, 96, 1)])
def test_xnor_pack_out_matches_oracle(m, k, n, bk):
    """Multi-k-block accumulation in scratch + fused sign+pack."""
    rng = np.random.default_rng(m * 7 + k + n)
    a = _rand_signs(rng, (m, k))
    wgt = _rand_signs(rng, (n, k))
    aw = binarize.pack_signs(jnp.asarray(a), axis=-1)
    ww = binarize.pack_signs(jnp.asarray(wgt), axis=-1)
    got = xnor_matmul(aw, ww, k=k, bk=bk, pack_out=True, interpret=True)
    s = ref.xnor_matmul_ref(jnp.asarray(a), jnp.asarray(wgt))
    want = binarize.pack_signs(binarize.hard_sign(s.astype(jnp.float32)),
                               axis=-1)
    assert got.shape == (m, n // 32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_xnor_pack_out_rejects_partial_words():
    rng = np.random.default_rng(1)
    aw = binarize.pack_signs(jnp.asarray(_rand_signs(rng, (2, 32))), axis=-1)
    ww = binarize.pack_signs(jnp.asarray(_rand_signs(rng, (33, 32))), axis=-1)
    with pytest.raises(AssertionError, match="pack_out"):
        xnor_matmul(aw, ww, k=32, pack_out=True, interpret=True)

"""InferencePlan: full-program packed pipeline vs the float reference.

The acceptance property of the packed-domain refactor: for *every*
benchmark program in ``networks.REGISTRY`` the compiled plan — single
pack at the IO encoding, fused packed conv stages, fused packed hidden
FCs, int32 logits at the final FC — agrees bit-exactly with the float
+/-1 reference interpreter, and no unpack/repack happens between layers.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarize
from repro.core.chip import interpreter, isa, networks, neuron_array as na


def _images(program, b=2, seed=0):
    io = program.instrs[0]
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (b, io.height, io.width, io.in_channels),
                              0, 2 ** io.bits)


def _trained_folded(program, seed=0):
    """Folded params with realistic (nonzero) BN state."""
    key = jax.random.PRNGKey(seed)
    params = interpreter.init_params(key, program)
    _, params = interpreter.forward_train(params, program,
                                          _images(program, b=4, seed=1))
    return interpreter.fold_params(params, program)


def test_thermometer_encode_packed_bit_exact():
    img = jax.random.randint(jax.random.PRNGKey(0), (2, 6, 7, 3), 0, 128)
    want = binarize.pack_signs(na.thermometer_encode(img, 7, 64), axis=-1)
    got = na.thermometer_encode_packed(img, 7, 64)
    assert got.dtype == jnp.uint32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("name", sorted(networks.REGISTRY))
def test_plan_bit_exact_on_every_registry_program(name):
    program = networks.REGISTRY[name]()
    folded = _trained_folded(program)
    packed = interpreter.pack_folded(folded)
    imgs = _images(program, b=2, seed=7)

    logits_ref, labels_ref = interpreter.forward_infer(folded, program, imgs,
                                                       use_kernels=False)
    plan = interpreter.compile_plan(program)
    logits_pk, labels_pk = plan.forward(packed, imgs, interpret=True)

    np.testing.assert_array_equal(np.asarray(logits_ref),
                                  np.asarray(logits_pk))
    np.testing.assert_array_equal(np.asarray(labels_ref),
                                  np.asarray(labels_pk))


def test_forward_infer_kernels_routes_through_plan():
    """use_kernels=True accepts both float-folded and packed artifacts."""
    program = networks.mnist5()
    folded = _trained_folded(program, seed=3)
    imgs = _images(program, b=3, seed=11)
    ref_out = interpreter.forward_infer(folded, program, imgs,
                                        use_kernels=False)
    for art in (folded, interpreter.pack_folded(folded)):
        got = interpreter.forward_infer(art, program, imgs,
                                        use_kernels=True, interpret=True)
        np.testing.assert_array_equal(np.asarray(ref_out[0]),
                                      np.asarray(got[0]))
        np.testing.assert_array_equal(np.asarray(ref_out[1]),
                                      np.asarray(got[1]))


def test_no_unpack_or_repack_inside_plan_forward(monkeypatch):
    """The packed pipeline never leaves the bit domain: with the artifact
    packed up front, a pack_signs/unpack_signs call during the forward is
    a bug (single pack at IO, single int32 readout at the final FC)."""
    program = networks.mnist5()        # exercises conv AND hidden-FC stages
    folded = _trained_folded(program, seed=5)
    packed = interpreter.pack_folded(folded)
    plan = interpreter.compile_plan(program)
    imgs = _images(program, b=2, seed=2)

    def boom(*a, **k):
        raise AssertionError("float-domain (re)pack inside packed plan")

    monkeypatch.setattr(binarize, "pack_signs", boom)
    monkeypatch.setattr(binarize, "unpack_signs", boom)
    logits, labels = plan.forward(packed, imgs, interpret=True)
    assert logits.shape[0] == 2 and labels.shape == (2,)


def test_packed_artifact_layout():
    """fold_params(packed=True) emits the documented deployment layout."""
    program = networks.mnist5()
    key = jax.random.PRNGKey(0)
    params = interpreter.init_params(key, program)
    packed = interpreter.fold_params(params, program, packed=True)

    geoms = [g for g in isa.layer_geometry(program)
             if isinstance(g[0], isa.ConvInstr)]
    assert len(packed["conv"]) == len(geoms)
    for p, (ins, _h, _w, c, *_r) in zip(packed["conv"], geoms):
        cw = -(-c // binarize.PACK_WIDTH)
        assert p["w_words"].shape == (ins.features, 4, cw)
        assert p["w_words"].dtype == jnp.uint32
        assert p["tau"].shape == (ins.features,) and p["tau"].dtype == jnp.int32
        assert p["flip"].shape == (ins.features,)
    fcs = program.fc_instrs
    for p, ins in zip(packed["fc"], fcs):
        kw = -(-ins.in_features // binarize.PACK_WIDTH)
        assert p["w_words"].shape == (ins.out_features, kw)
        assert p["w_words"].dtype == jnp.uint32


def test_plan_is_cached_and_static():
    program = networks.cifar9(4)
    plan1 = interpreter.compile_plan(program)
    plan2 = interpreter.compile_plan(networks.cifar9(4))
    assert plan1 is plan2                       # geometry resolved once
    convs = [s for s in plan1.stages
             if isinstance(s, interpreter._ConvStage)]
    assert len(convs) == 8
    assert [s.pool for s in convs] == [False, False, False, True,
                                       False, True, False, True]
    fc = plan1.stages[-1]
    assert fc.final and not fc.pack_out         # logits stay int32


def test_plan_make_fn_jits():
    program = networks.mnist5()
    folded = _trained_folded(program, seed=9)
    packed = interpreter.pack_folded(folded)
    plan = interpreter.compile_plan(program)
    fn = plan.make_fn(interpret=True)
    logits, labels = fn(packed, _images(program, b=2, seed=4))
    assert logits.shape == (2, 10) and labels.shape == (2,)

"""Cascaded always-on pipelines: routing, bit-exactness, energy billing.

The acceptance property: the cascade's final labels are bit-exact vs the
stage that produced them — every escalated frame's label equals the
recognizer's offline forward on that exact frame, every non-escalated
frame's label equals the detector's — and the energy bill composes
``det + rate * rec`` so the cascade beats recognizing every frame
whenever the escalation rate is below ``1 - det/rec``.
"""

import jax
import numpy as np
import pytest

from repro.core.chip import energy, interpreter, networks
from repro.serving import CascadePipeline, ChipServer


def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


def _artifact(program, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    return interpreter.fold_params(params, program, packed=True)


def _offline(program, packed, frames):
    plan = interpreter.compile_plan(program)
    logits, labels = plan.forward(packed, np.asarray(frames), interpret=True)
    return np.asarray(logits), np.asarray(labels)


@pytest.fixture(scope="module")
def cascade_setup():
    """A cheap 2-class detector and a 5-class recognizer sharing the
    mnist5 frame geometry (the fast stand-in for the paper's
    face-detect -> owner-recognition pair; the bench runs the real
    cifar9 S=4 -> S=1 pair)."""
    det = networks.mnist5(classes=2)
    rec = networks.mnist5(classes=5)
    arts = {"det": _artifact(det, seed=1), "rec": _artifact(rec, seed=2)}
    frames = _frames(det, 7, seed=3)
    det_logits, det_labels = _offline(det, arts["det"], frames)
    rec_logits, rec_labels = _offline(rec, arts["rec"], frames)
    return (det, rec, arts, frames,
            (det_logits, det_labels), (rec_logits, rec_labels))


def _server(det, rec, arts, **kw):
    return ChipServer({"det": det, "rec": rec}, arts, batch=2,
                      interpret=True, **kw)


def test_cascade_labels_bit_exact_per_stage(cascade_setup):
    """Escalated frames carry the recognizer's offline label, everything
    else the detector's — and the escalation decision itself matches the
    offline logit-margin rule frame by frame."""
    det, rec, arts, frames, (dl, dlab), (rl, rlab) = cascade_setup
    margins = dl[:, 1] - dl[:, 0]
    casc = CascadePipeline(_server(det, rec, arts), "det", "rec",
                           positive_class=1, margin=0.0)
    rids = casc.submit_many(frames)
    assert rids == list(range(len(frames)))
    results = sorted(casc.drain(), key=lambda c: c.rid)
    assert len(results) == len(frames)
    for i, c in enumerate(results):
        want_escalate = bool(margins[i] >= 0.0)
        assert c.escalated == want_escalate, i
        assert c.detector_label == dlab[i]
        assert c.detector_margin == pytest.approx(margins[i])
        if c.escalated:
            assert c.label == rlab[i]
            np.testing.assert_array_equal(c.logits, rl[i])
        else:
            assert c.label == dlab[i]
            np.testing.assert_array_equal(c.logits, dl[i])
    assert casc.escalated == sum(1 for c in results if c.escalated)


def test_cascade_margin_extremes(cascade_setup):
    """margin=-inf escalates every frame (labels == recognizer offline,
    the 'recognizer on every frame it escalates' oracle); margin=+inf
    escalates none (labels == detector offline)."""
    det, rec, arts, frames, (_, dlab), (_, rlab) = cascade_setup
    casc = CascadePipeline(_server(det, rec, arts), "det", "rec",
                           margin=float("-inf"))
    casc.submit_many(frames)
    res = sorted(casc.drain(), key=lambda c: c.rid)
    assert all(c.escalated for c in res)
    np.testing.assert_array_equal(np.array([c.label for c in res]), rlab)

    casc = CascadePipeline(_server(det, rec, arts), "det", "rec",
                           margin=float("inf"))
    casc.submit_many(frames)
    res = sorted(casc.drain(), key=lambda c: c.rid)
    assert not any(c.escalated for c in res)
    np.testing.assert_array_equal(np.array([c.label for c in res]), dlab)


def test_cascade_with_prefetch_and_step_interleaving(cascade_setup):
    """The cascade composes with the depth-k submission pipeline and
    incremental step()/submit() interleaving: same final label set."""
    det, rec, arts, frames, _, _ = cascade_setup
    runs = {}
    for prefetch in (0, 2):
        casc = CascadePipeline(_server(det, rec, arts, prefetch=prefetch),
                               "det", "rec")
        got = []
        for f in frames:
            casc.submit(f)
            got.extend(casc.step())
        got.extend(casc.drain())
        casc.server.close()
        runs[prefetch] = sorted((c.rid, c.label, c.escalated) for c in got)
    assert runs[0] == runs[2]
    assert len(runs[0]) == len(frames)


def test_cascade_report_math(cascade_setup):
    """The bill composes det + rate*rec (+ padding) and the savings
    ratio is measured against recognizing every frame."""
    det, rec, arts, frames, _, _ = cascade_setup
    server = _server(det, rec, arts)
    casc = CascadePipeline(server, "det", "rec", margin=float("-inf"))
    casc.submit_many(frames)
    casc.drain()
    stats = server.stats()
    rep = casc.report()
    det_uj = energy.analyze_net(det).i2l_energy_per_inference * 1e6
    rec_uj = energy.analyze_net(rec).i2l_energy_per_inference * 1e6
    want = ((len(frames) + stats.padded["det"]) * det_uj
            + (len(frames) + stats.padded["rec"]) * rec_uj) / len(frames)
    assert rep.uj_per_frame == pytest.approx(want)
    assert rep.uj_per_frame_recognizer_only == pytest.approx(rec_uj)
    assert rep.escalation_rate == 1.0
    assert rep.savings == pytest.approx(rec_uj / want)
    # ignoring padding: the pure det + rate*rec composition
    rep_np = casc.report(include_padding=False)
    assert rep_np.uj_per_frame == pytest.approx(det_uj + rec_uj)


def test_cascade_billing_ragged_drain(cascade_setup):
    """Launch-ledger billing across a ragged drain: the trailing partial
    recognizer batch's padding is billed exactly once, the server-wide
    invariant ``billed == served + padded`` holds, and the escalation
    rate's denominator is the frames served (not the padded slots)."""
    det, rec, arts, frames, (dl, _), _ = cascade_setup
    margins = dl[:, 1] - dl[:, 0]
    # a margin escalating an ODD count (batch=2 -> ragged remainder)
    margin = float(np.sort(margins)[-3])       # top-3 escalate, 3 = 2 + 1
    server = _server(det, rec, arts)
    casc = CascadePipeline(server, "det", "rec", margin=margin)
    casc.submit_many(frames)
    casc.drain()
    stats = server.stats()
    assert server._billed == (sum(stats.served.values())
                              + sum(stats.padded.values()))
    assert stats.served["det"] == 7 and stats.padded["det"] == 1
    assert stats.served["rec"] == 3 and stats.padded["rec"] == 1
    rep = casc.report()
    det_uj = energy.analyze_net(det).i2l_energy_per_inference * 1e6
    rec_uj = energy.analyze_net(rec).i2l_energy_per_inference * 1e6
    assert rep.frames == 7 and rep.escalated == 3
    assert rep.escalation_rate == pytest.approx(3 / 7)
    assert rep.uj_per_frame == pytest.approx(
        (8 * det_uj + 4 * rec_uj) / 7)


def test_cascade_report_midstream_never_bills_queued(cascade_setup):
    """A mid-stream report bills only what hit the array: frames still
    queued on the detector — or escalations deferred awaiting a full
    recognizer batch — are absent from the bill until dispatched."""
    det, rec, arts, frames, _, _ = cascade_setup
    server = _server(det, rec, arts)
    casc = CascadePipeline(server, "det", "rec", margin=float("-inf"))
    casc.submit_many(frames[:5])
    casc.step()                               # one det dispatch of 2
    rep = casc.report()
    assert rep.frames == 2                    # 3 still queued
    # both frames escalated but the recognizer batch is still deferred
    assert casc.escalated == 2 and rep.escalated == 0
    casc.drain()
    assert casc.report().frames == 5
    assert casc.report().escalated == 5
    server.close()


def test_cascade_report_paper_pair_beats_recognizer_only():
    """The paper's pair (0.92 uJ/f S=4 detector -> 14.4 uJ/f S=1
    recognizer): at any escalation rate below 1 - det/rec the cascade
    bill is strictly below running the recognizer on every frame."""
    det, rec = networks.face_detector(), networks.owner_detector()
    rep = energy.cascade_report(det, rec, frames=100, escalated=20)
    # the calibrated model lands within its documented ~7% validation
    # band of the paper's published points
    assert rep.detector_uj == pytest.approx(0.92, rel=0.07)
    assert rep.recognizer_uj == pytest.approx(14.4, rel=0.07)
    assert rep.uj_per_frame < rep.uj_per_frame_recognizer_only
    assert rep.savings > 1.0
    # break-even boundary: rate just under 1 - det/rec still saves
    rate = 1 - rep.detector_uj / rep.recognizer_uj
    almost = energy.cascade_report(det, rec, frames=1000,
                                   escalated=int(rate * 1000) - 1)
    assert almost.savings > 1.0
    with pytest.raises(ValueError, match="exceeds"):
        energy.cascade_report(det, rec, frames=5, escalated=6)


def test_cascade_coexists_with_other_server_lanes(cascade_setup):
    """The cascade shares its server with unrelated resident lanes:
    their results pass through to ``other_results`` instead of crashing
    or corrupting cascade state."""
    det, rec, arts, frames, _, _ = cascade_setup
    other = networks.mnist5(classes=7)
    server = ChipServer(
        {"det": det, "rec": rec, "other": other},
        {**arts, "other": _artifact(other, seed=9)}, batch=2,
        interpret=True)
    other_frames = _frames(other, 3, seed=8)
    oracle = _offline(other, _artifact(other, seed=9), other_frames)[1]
    casc = CascadePipeline(server, "det", "rec")
    casc.submit_many(frames)
    other_rids = server.submit_many("other", other_frames)
    results = casc.drain()
    assert len(results) == len(frames)
    got = {r.rid: r.label for r in casc.other_results}
    assert sorted(got) == other_rids
    np.testing.assert_array_equal(
        np.array([got[r] for r in other_rids]), oracle)


def test_cascade_rejects_family_stage(cascade_setup):
    """Family lanes can't be cascade stages (the energy bill is per
    stage program, and the controller may swap variants)."""
    det, rec, arts, frames, _, _ = cascade_setup
    rec2 = networks.mnist5(classes=5)
    server = ChipServer(
        {"det": det, "rec": rec, "rec2": rec2},
        {**arts, "rec2": _artifact(rec2, seed=6)}, batch=2,
        interpret=True, policy="operating-point",
        families={"fam": ("rec", "rec2")})
    with pytest.raises(ValueError, match="family"):
        CascadePipeline(server, "det", "fam")


def test_cascade_guards(cascade_setup):
    det, rec, arts, frames, _, _ = cascade_setup
    server = _server(det, rec, arts)
    with pytest.raises(KeyError, match="not resident"):
        CascadePipeline(server, "det", "ghost")
    with pytest.raises(ValueError, match="distinct"):
        CascadePipeline(server, "det", "det")
    cifar = networks.cifar9(4, classes=2)
    mixed = ChipServer({"det": det, "wide": cifar},
                       {"det": arts["det"], "wide": _artifact(cifar)},
                       batch=2, interpret=True)
    with pytest.raises(ValueError, match="geometry"):
        CascadePipeline(mixed, "det", "wide")

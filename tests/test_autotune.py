"""Persistent tile autotuner: cache semantics + resolution precedence.

The autotuner must never change numerics (tiles are a pure schedule
choice — covered by the megakernel/composite equivalence suites); these
tests lock down the cache behaviour itself: fingerprinting, exact and
nearest-batch lookup, cold-cache defaults, explicit-argument precedence,
and that a tuned entry actually steers ``forward_mega``.
"""

import json

import jax
import numpy as np
import pytest

from repro.core.chip import interpreter, networks
from repro.kernels import autotune


@pytest.fixture
def tmp_cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    autotune.invalidate()
    yield path
    autotune.invalidate()


def _setup(program, batch=6, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    io = program.instrs[0]
    imgs = jax.random.randint(
        jax.random.PRNGKey(seed + 1),
        (batch, io.height, io.width, io.in_channels), 0, 2 ** io.bits)
    return (interpreter.compile_plan(program),
            interpreter.fold_params(params, program, packed=True),
            interpreter.fold_params(params, program, image=True), imgs)


def test_cold_cache_falls_back_to_defaults(tmp_cache):
    program = networks.mnist5()
    assert autotune.mega_tiles(program, 8) == (
        autotune.DEFAULTS["mega"]["bb"], autotune.DEFAULTS["mega"]["ft"])
    assert autotune.conv_tiles(program, 8) == (
        autotune.DEFAULTS["staged_conv"]["bf"],
        autotune.DEFAULTS["staged_conv"]["bb"])


def test_explicit_arguments_beat_the_cache(tmp_cache):
    program = networks.mnist5()
    autotune.record("mega", autotune.program_key(program), 8,
                    {"bb": 2, "ft": 32})
    assert autotune.mega_tiles(program, 8) == (2, 32)
    assert autotune.mega_tiles(program, 8, bb=16) == (16, 32)
    assert autotune.mega_tiles(program, 8, bb=16, ft=0) == (16, 0)


def test_lookup_exact_then_nearest_batch(tmp_cache):
    program = networks.mnist5()
    pkey = autotune.program_key(program)
    autotune.record("mega", pkey, 8, {"bb": 8, "ft": 0})
    autotune.record("mega", pkey, 64, {"bb": 16, "ft": 32})
    assert autotune.mega_tiles(program, 8) == (8, 0)       # exact
    assert autotune.mega_tiles(program, 64) == (16, 32)    # exact
    assert autotune.mega_tiles(program, 48) == (16, 32)    # nearest (64)
    assert autotune.mega_tiles(program, 9) == (8, 0)       # nearest (8)


def test_program_and_backend_fingerprints_partition_entries(tmp_cache):
    a, b = networks.mnist5(), networks.mnist5(classes=2)
    assert autotune.program_key(a) != autotune.program_key(b)
    assert autotune.program_key(a) == autotune.program_key(networks.mnist5())
    autotune.record("mega", autotune.program_key(a), 8, {"bb": 2, "ft": 32})
    # a different program never sees another program's entry
    assert autotune.mega_tiles(b, 8) == (
        autotune.DEFAULTS["mega"]["bb"], autotune.DEFAULTS["mega"]["ft"])
    # entries are keyed under the live backend fingerprint
    raw = json.loads(tmp_cache.read_text())
    assert all(k.endswith(autotune.backend_fingerprint()) for k in raw)
    # composite fingerprints are order-sensitive and distinct from solo
    ck = autotune.composite_key([a, b])
    assert ck != autotune.composite_key([b, a])
    assert ck.startswith("comp-")


def test_cache_persists_across_process_reload(tmp_cache):
    program = networks.mnist5()
    autotune.record("staged_conv", autotune.program_key(program), 8,
                    {"bf": 32, "bb": 4})
    autotune.invalidate()                      # simulate a fresh process
    assert autotune.conv_tiles(program, 8) == (32, 4)


def test_tune_mega_records_and_forward_consumes(tmp_cache):
    """tune_mega measures candidates, persists the winner, and a
    subsequent forward_mega with default tiles resolves through it —
    bit-exact vs any explicit tiling."""
    program = networks.mnist5()
    plan, packed, image, imgs = _setup(program)
    entry = autotune.tune_mega(plan, image, imgs, bb_candidates=(2, 4),
                               ft_candidates=(0, 32), iters=1,
                               interpret=True)
    assert set(entry) == {"bb", "ft", "us"}
    assert autotune.mega_tiles(program, imgs.shape[0]) == (
        entry["bb"], entry["ft"])
    ref = np.asarray(plan.forward(packed, imgs, interpret=True)[0])
    got = np.asarray(plan.forward_mega(image, imgs, interpret=True)[0])
    np.testing.assert_array_equal(got, ref)


def test_tune_staged_conv_records(tmp_cache):
    program = networks.mnist5()
    plan, packed, _image, imgs = _setup(program, seed=3)
    entry = autotune.tune_staged_conv(plan, packed, imgs,
                                      bf_candidates=(32, 64),
                                      bb_candidates=(4,), iters=1,
                                      interpret=True)
    assert entry["bf"] in (32, 64) and entry["bb"] == 4
    assert autotune.conv_tiles(program, imgs.shape[0]) == (
        entry["bf"], entry["bb"])
    # staged forward with tuned tiles stays bit-exact vs kernel defaults
    ref = np.asarray(plan.forward(packed, imgs, interpret=True,
                                  conv_tiles=(64, 8))[0])
    got = np.asarray(plan.forward(packed, imgs, interpret=True)[0])
    np.testing.assert_array_equal(got, ref)


def test_tune_composite_records_and_forward_consumes(tmp_cache):
    """tune_composite caches under the composite fingerprint (not any
    member's) and CompositePlan.forward resolves through it — bit-exact
    vs explicit tiles."""
    progs = {"a": networks.mnist5(), "b": networks.mnist5(classes=2),
             "c": networks.mnist5(classes=3), "d": networks.mnist5(classes=5)}
    arts, frames = {}, []
    for i, (n, p) in enumerate(progs.items()):
        params = interpreter.init_params(jax.random.PRNGKey(i), p)
        arts[n] = interpreter.fold_params(params, p, packed=True)
        io = p.instrs[0]
        frames.append(jax.random.randint(
            jax.random.PRNGKey(50 + i),
            (4, io.height, io.width, io.in_channels), 0, 2 ** io.bits))
    cplan, cimage = interpreter.pack_programs(progs, arts)
    entry = autotune.tune_composite(cplan, cimage, tuple(frames),
                                    bb_candidates=(2,), ft_candidates=(0, 32),
                                    iters=1, interpret=True)
    assert autotune.composite_tiles(cplan.programs, 4) == (
        entry["bb"], entry["ft"])
    # members' solo fingerprints stay cold — the entry is composite-keyed
    assert autotune.mega_tiles(progs["a"], 4) == (
        autotune.DEFAULTS["mega"]["bb"], autotune.DEFAULTS["mega"]["ft"])
    ref = cplan.forward(cimage, tuple(frames), interpret=True, bb=8, ft=0)
    got = cplan.forward(cimage, tuple(frames), interpret=True)  # via cache
    for r, g in zip(ref[0], got[0]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_corrupt_cache_file_degrades_to_cold(tmp_cache):
    """A broken cache file may never change behaviour — invalid JSON and
    valid-but-non-dict JSON both degrade to the cold-cache defaults."""
    defaults = (autotune.DEFAULTS["mega"]["bb"],
                autotune.DEFAULTS["mega"]["ft"])
    program = networks.mnist5()
    for text in ("{not json", "[]", '"a string"', "3"):
        tmp_cache.write_text(text)
        autotune.invalidate()
        assert autotune.mega_tiles(program, 8) == defaults, text

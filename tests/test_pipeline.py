"""GPipe pipeline (distributed/pipeline.py): the ppermute microbatch
schedule must equal sequential stage application, and be differentiable.

Subprocess with 8 fake devices (4-stage pipe x 2-way data)."""

import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.distributed.pipeline import pipelined

    S, M, B, D = 4, 8, 16, 32
    mesh = jax.make_mesh((S, 2), ("pod", "data"))

    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    key = jax.random.PRNGKey(0)
    params = {
        "w": jax.random.normal(key, (S, D, D)) * 0.5,
        "b": jnp.zeros((S, D)),
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (B, D))

    # sequential oracle
    y_ref = x
    for i in range(S):
        y_ref = stage_fn(jax.tree.map(lambda p: p[i], params), y_ref)

    run = pipelined(stage_fn, mesh, num_microbatches=M)
    y = jax.jit(run)(params, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)

    # differentiable end to end (GPipe all-fwd/all-bwd via jax AD)
    def loss(params, x):
        return jnp.sum(run(params, x) ** 2)
    g = jax.jit(jax.grad(loss))(params, x)
    gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
    assert gn > 0 and np.isfinite(gn), gn

    # compiles on the multi-pod production mesh shape too (2 pods x 2 x 2)
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    run3 = pipelined(stage_fn, mesh3, num_microbatches=4)
    params2 = {"w": params["w"][:2], "b": params["b"][:2]}
    lowered = jax.jit(run3).lower(params2, x)
    lowered.compile()
    print("PIPELINE_OK")
""")


def test_pipeline_matches_sequential_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"

"""Delta-gated always-on video: kernel gate vs host popcount reference.

Covers the full temporal stack:

* the in-kernel skip mask (change queue + counts + per-lane deltas)
  equals the host popcount rule over random programs x thresholds x
  ragged batches x tile schedules (hypothesis property);
* at threshold 0 / -inf the gated path is bit-exact vs the plain
  megakernel — fast subset here, every REGISTRY program under
  ``@pytest.mark.slow``;
* skipped lanes emit exactly the label they last served, and state
  reset (scene change) forces a full recompute;
* ``TemporalPipeline`` billing, reporting, activity-coupled
  downshifting, and threshold calibration;
* ``video_trace`` determinism and its pixel-exact changed mask.
"""

import functools
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binarize
from repro.core.chip import energy, interpreter, networks
from repro.serving import ChipServer
from repro.serving import temporal as tmp
from repro.serving.traffic import video_trace

from test_fold_pack_property import _random_bn_params, random_program


def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


def _artifact(program, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    return interpreter.fold_params(params, program, packed=True)


def _pack(program, frames):
    io = program.instrs[0]
    return np.asarray(binarize.thermometer_pack(
        jnp.asarray(frames, jnp.int32), io.bits, io.in_channels,
        io.channels))


def _host_deltas(packed, last):
    """Per-lane packed Hamming distance — the gate's host reference."""
    x = np.ascontiguousarray(np.bitwise_xor(packed, np.asarray(last)))
    return np.unpackbits(
        x.view(np.uint8).reshape(len(x), -1), axis=1).sum(axis=1)


# thresholds covering both sentinels, zero (= plain megakernel), a
# fractional value (exercises the ceil in delta_ctrl) and interior ones
THRESHOLDS = (float("-inf"), 0.0, 1.0, 2.5, 64.0, float("inf"))


@pytest.fixture(scope="module")
def delta_setup():
    prog = networks.mnist5()
    art = _artifact(prog, seed=1)
    dplan, image = interpreter.pack_delta(prog, art, name="mnist5")
    frames = _frames(prog, 5, seed=3)
    plan = interpreter.compile_plan(prog)
    ml, mlab = plan.forward_mega(image, frames, interpret=True)
    return prog, art, dplan, image, frames, np.asarray(ml), np.asarray(mlab)


def _gated(dplan, image, frames, last, llog, thr, n_real, **kw):
    ctrl = interpreter.DeltaPlan.delta_ctrl(thr, n_real)
    out = dplan.forward_delta(image, jnp.asarray(frames, jnp.int32),
                              last, llog, ctrl, interpret=True, **kw)
    return [np.asarray(o) for o in out]


# ---------------------------------------------------------------------------
# Bit-exactness vs the plain megakernel (threshold 0 / cold -inf)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("thr", [float("-inf"), 0.0])
@pytest.mark.parametrize("bb,rb", [(2, 2), (5, 1), (3, 4)])
def test_threshold_zero_matches_megakernel(delta_setup, thr, bb, rb):
    """With the gate open (cold -inf, or 0 against warm state) every
    live lane recomputes and logits/labels equal the plain megakernel
    bit for bit, for every tile schedule."""
    prog, _, dplan, image, frames, ml, mlab = delta_setup
    last, llog = dplan.init_state(len(frames))
    lg, lb, nl, nllog, queue, counts, _ = _gated(
        dplan, image, frames, last, llog, thr, len(frames), bb=bb, rb=rb)
    assert np.array_equal(lg, ml)
    assert np.array_equal(lb, mlab)
    assert counts[0] == len(frames)
    assert list(queue[:counts[0]]) == list(range(len(frames)))
    # warmed state: the packed current frames + the fresh logits
    assert np.array_equal(nl, _pack(prog, frames))
    assert np.array_equal(nllog.astype(np.float32), ml)


def test_skipped_lanes_serve_cached_labels(delta_setup):
    """Dispatch 2 re-sends the same frames at threshold 1: everything
    skips and the served labels are exactly dispatch 1's; perturbing one
    frame recomputes only that lane."""
    prog, _, dplan, image, frames, ml, mlab = delta_setup
    n = len(frames)
    last, llog = dplan.init_state(n)
    _, _, last, llog, _, _, _ = [
        jnp.asarray(o) for o in _gated(dplan, image, frames, last, llog,
                                       float("-inf"), n, bb=2, rb=2)]
    # identical frames: all deltas 0, nothing recomputes, cache serves
    lg, lb, nl, nllog, queue, counts, deltas = _gated(
        dplan, image, frames, last, llog, 1.0, n, bb=2, rb=2)
    assert counts[0] == 0 and np.all(deltas == 0)
    assert np.array_equal(lg, ml) and np.array_equal(lb, mlab)
    # one changed frame: exactly that lane recomputes, fresh answer
    # merges over the cache
    bumped = frames.copy()
    bumped[2] = (bumped[2] + 1) % (2 ** prog.instrs[0].bits)
    lg2, lb2, _, _, queue2, counts2, deltas2 = _gated(
        dplan, image, bumped, jnp.asarray(nl), jnp.asarray(nllog),
        1.0, n, bb=2, rb=2)
    assert counts2[0] == 1 and queue2[0] == 2 and deltas2[2] > 0
    plan = interpreter.compile_plan(prog)
    ml2, _ = plan.forward_mega(image, bumped, interpret=True)
    expect = ml.copy()
    expect[2] = np.asarray(ml2)[2]
    assert np.array_equal(lg2, expect)


def test_ragged_batch_masks_padding_lanes(delta_setup):
    """Padding lanes (index >= n_real) never enter the change queue even
    at -inf, and their cached state passes through untouched."""
    _, _, dplan, image, frames, ml, _ = delta_setup
    n = len(frames)
    last, llog = dplan.init_state(n)
    _, _, _, _, queue, counts, _ = _gated(
        dplan, image, frames, last, llog, float("-inf"), 3, bb=2, rb=2)
    assert counts[0] == 3
    assert list(queue[:3]) == [0, 1, 2]


# ---------------------------------------------------------------------------
# delta_ctrl folding
# ---------------------------------------------------------------------------

def test_delta_ctrl_folding():
    c = lambda t: int(interpreter.DeltaPlan.delta_ctrl(t, 7)[0, 0])
    assert c(float("-inf")) == -(2 ** 31)
    assert c(float("inf")) == 2 ** 31 - 1
    assert c(0.0) == 0
    assert c(2.5) == 3          # ceil: d >= 2.5 <=> d >= 3 for integer d
    assert c(-3.5) == -3
    assert int(interpreter.DeltaPlan.delta_ctrl(1.0, 7)[0, 1]) == 7
    with pytest.raises(ValueError):
        interpreter.DeltaPlan.delta_ctrl(float("nan"), 7)


def test_serve_fn_rejects_multi_device_mesh(delta_setup):
    _, _, dplan, *_ = delta_setup
    mesh = types.SimpleNamespace(devices=np.zeros((2,)))
    with pytest.raises(ValueError, match="does not shard"):
        dplan.make_serve_fn(mesh=mesh)


# ---------------------------------------------------------------------------
# The gate property: kernel skip mask == host popcount rule
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(s=st.sampled_from([1, 2, 4]), seed=st.integers(0, 10 ** 6),
       thr_i=st.integers(0, len(THRESHOLDS) - 1),
       n_real_off=st.integers(0, 3),
       bb=st.integers(1, 5), rb=st.integers(1, 5))
def test_gate_matches_host_popcount(s, seed, thr_i, n_real_off, bb, rb):
    """Over random programs, thresholds, ragged batches and tile
    schedules: the kernel's change queue, counts, per-lane deltas, state
    advance and merged logits all equal the host popcount-gate rule."""
    prog = random_program(s, seed)
    params = _random_bn_params(prog, seed + 1)
    art = interpreter.fold_params(params, prog, packed=True)
    dplan, image = interpreter.pack_delta(prog, art)
    n = 5
    n_real = n - n_real_off
    thr = THRESHOLDS[thr_i]
    frames = _frames(prog, n, seed=seed + 2)
    # warm, *distinct* state: packed codes of different frames + integer
    # logits, so interior thresholds split the batch nontrivially
    prev = _frames(prog, n, seed=seed + 3)
    last = jnp.asarray(_pack(prog, prev))
    llog = jnp.asarray(
        jax.random.randint(jax.random.PRNGKey(seed + 4),
                           (n, dplan.classes), -50, 50), jnp.int32)
    lg, lb, nl, nllog, queue, counts, deltas = _gated(
        dplan, image, frames, last, llog, thr, n_real, bb=bb, rb=rb)
    packed = _pack(prog, frames)
    d_host = _host_deltas(packed, last)
    thr_int = int(interpreter.DeltaPlan.delta_ctrl(thr, n_real)[0, 0])
    live = np.arange(n) < n_real
    mask = (d_host >= thr_int) & live
    assert np.array_equal(deltas, np.where(live, d_host, 0))
    assert counts[0] == mask.sum()
    assert list(queue[:counts[0]]) == list(np.flatnonzero(mask))
    assert counts[1] >= counts[0]          # drain-chunk padding only adds
    # reference advances only where the gate fired
    assert np.array_equal(nl, np.where(mask[:, None, None, None],
                                       packed, np.asarray(last)))
    plan = interpreter.compile_plan(prog)
    ml, _ = plan.forward_mega(image, frames, interpret=True)
    expect = np.where(mask[:, None], np.asarray(ml),
                      np.asarray(llog, np.float32))
    assert np.array_equal(lg, expect)
    assert np.array_equal(lb, expect.argmax(-1))


# ---------------------------------------------------------------------------
# Every REGISTRY program (acceptance criterion)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _reg_prog(name):
    return networks.REGISTRY[name]()


@pytest.mark.slow
@pytest.mark.parametrize("name", sorted(networks.REGISTRY))
def test_registry_threshold_zero_bit_exact(name):
    """Acceptance: at threshold 0 the gated path equals the plain
    megakernel bit for bit on every REGISTRY program."""
    prog = _reg_prog(name)
    art = _artifact(prog, seed=hash(name) % 1000)
    dplan, image = interpreter.pack_delta(prog, art, name=name)
    frames = _frames(prog, 4, seed=11)
    prev = _frames(prog, 4, seed=12)
    last = jnp.asarray(_pack(prog, prev))
    llog = jnp.zeros((4, dplan.classes), jnp.int32)
    lg, lb, *_ = _gated(dplan, image, frames, last, llog, 0.0, 4,
                        bb=2, rb=2)
    plan = interpreter.compile_plan(prog)
    ml, mlab = plan.forward_mega(image, frames, interpret=True)
    assert np.array_equal(lg, np.asarray(ml))
    assert np.array_equal(lb, np.asarray(mlab))


# ---------------------------------------------------------------------------
# TemporalPipeline: serving, billing, reset, calibration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def pipe_setup():
    prog = networks.mnist5()
    art = _artifact(prog, seed=1)
    io = prog.instrs[0]
    trace = video_trace((io.height, io.width, io.in_channels), 6,
                        streams=4, seed=3, change_rate=0.3,
                        levels=2 ** io.bits)
    plan = interpreter.compile_plan(prog)
    flat = trace.frames.reshape((-1,) + trace.frames.shape[2:])
    _, oracle = plan.forward(interpreter.ensure_packed(art), flat,
                             interpret=True)
    return prog, art, trace, np.asarray(oracle)


def _serve(prog, art, trace, **kw):
    srv = ChipServer({"m": prog}, {"m": art}, batch=trace.streams,
                     interpret=True)
    pipe = tmp.TemporalPipeline(srv, "m", rb=1, **kw)
    for t in range(len(trace)):
        for s in range(trace.streams):
            pipe.submit(trace.frames[t, s])
    return srv, pipe, pipe.drain()


def test_pipeline_agreement_and_billing(pipe_setup):
    """At threshold 1 (skip only bit-identical packed frames) the gated
    labels equal ungated inference exactly; the server ledger bills only
    computed slots and stays consistent; the temporal report accounts
    every frame."""
    prog, art, trace, oracle = pipe_setup
    srv, pipe, res = _serve(prog, art, trace, threshold=1.0)
    got = np.array([r.label for r in sorted(res, key=lambda r: r.rid)])
    assert np.array_equal(got, oracle)
    n_frames = len(trace) * trace.streams
    assert pipe.frames == n_frames
    assert pipe.computed + pipe.skipped == n_frames
    # the trace's pixel-exact changed mask lower-bounds nothing — it IS
    # the compute set at threshold 1 (identical pixels <=> identical
    # packed codes <=> delta 0)
    assert pipe.computed == int(trace.changed.sum())
    stats = srv.stats()     # serve_report asserts billed == served+padded
    assert stats.served["m"] == pipe.computed
    rep = pipe.report()
    assert rep.frames == n_frames
    assert rep.skipped == pipe.skipped
    assert rep.skip_ratio == pytest.approx(pipe.skip_ratio)
    assert rep.uj_per_frame < rep.uj_per_frame_ungated
    assert rep.savings == pytest.approx(
        rep.uj_per_frame_ungated / rep.uj_per_frame)
    # per-result metadata is consistent
    assert sum(r.computed for r in res) == pipe.computed
    assert all(r.delta == 0 for r in res if not r.computed)


def test_pipeline_gate_off_matches_ungated(pipe_setup):
    """-inf threshold recomputes every frame: zero skips, served labels
    ungated, report degenerates to the ungated bill plus delta toll."""
    prog, art, trace, oracle = pipe_setup
    _, pipe, res = _serve(prog, art, trace, threshold=float("-inf"))
    got = np.array([r.label for r in sorted(res, key=lambda r: r.rid)])
    assert np.array_equal(got, oracle)
    assert pipe.skipped == 0
    assert pipe.report().skip_ratio == 0.0


def test_pipeline_reset_recomputes(pipe_setup):
    """reset() drops the resident state: the next dispatch recomputes
    every stream even when frames did not change."""
    prog, art, trace, _ = pipe_setup
    srv = ChipServer({"m": prog}, {"m": art}, batch=trace.streams,
                     interpret=True)
    pipe = tmp.TemporalPipeline(srv, "m", threshold=1.0, rb=1)
    frame0 = trace.frames[0]
    for s in range(trace.streams):
        pipe.submit(frame0[s])
    pipe.drain()
    for s in range(trace.streams):      # identical frames: all skip
        pipe.submit(frame0[s])
    res = pipe.drain()
    assert not any(r.computed for r in res)
    pipe.reset()
    for s in range(trace.streams):      # still identical, but state is gone
        pipe.submit(frame0[s])
    res = pipe.drain()
    assert all(r.computed for r in res)
    assert pipe.activity == 1.0


def test_pipeline_calibrate_adopts_threshold(pipe_setup):
    prog, art, trace, _ = pipe_setup
    srv = ChipServer({"m": prog}, {"m": art}, batch=trace.streams,
                     interpret=True)
    pipe = tmp.TemporalPipeline(srv, "m", rb=1)
    thr = pipe.calibrate(trace.frames, target_agreement=1.0)
    assert thr == pipe.threshold >= 1.0


def test_pipeline_validation():
    prog = networks.mnist5()
    art = _artifact(prog)
    srv = ChipServer({"m": prog}, {"m": art}, batch=2, interpret=True)
    with pytest.raises(KeyError):
        tmp.TemporalPipeline(srv, "nope")
    with pytest.raises(ValueError):
        tmp.TemporalPipeline(srv, "m", threshold=float("nan"))
    with pytest.raises(ValueError):
        tmp.TemporalPipeline(srv, "m", activity_alpha=0.0)


def test_family_lane_needs_operating_point_policy():
    fam = {n: _reg_prog(n) for n in networks.FAMILIES["cifar10"]}
    arts = {n: _artifact(p, seed=5) for n, p in fam.items()}
    srv = ChipServer(fam, arts, batch=2, interpret=True,
                     families={"cifar10": tuple(fam)}, policy="continuous")
    with pytest.raises(ValueError, match="OperatingPointPolicy"):
        tmp.TemporalPipeline(srv, "cifar10")


def test_activity_downshifts_quiet_scene():
    """A quiet activity signal downshifts the operating point one step
    below what budget and backlog alone would pick."""
    fam = {n: _reg_prog(n) for n in networks.FAMILIES["cifar10"]}
    arts = {n: _artifact(p, seed=5) for n, p in fam.items()}
    srv = ChipServer(fam, arts, batch=2, interpret=True,
                     families={"cifar10": tuple(fam)},
                     policy="operating-point")
    pol = srv.policy
    order = pol.variant_order("cifar10")
    busy = pol._choose("cifar10", 0, 2, 0.0, 0.0)
    assert busy == order[0]
    pol.set_activity("cifar10", 0.1)        # below activity_low
    quiet = pol._choose("cifar10", 0, 2, 0.0, 0.0)
    assert quiet == order[1]
    pol.set_activity("cifar10", 0.9)        # active again: back to the top
    assert pol._choose("cifar10", 0, 2, 0.0, 0.0) == order[0]
    with pytest.raises(KeyError):
        pol.set_activity("nope", 0.5)
    with pytest.raises(ValueError):
        pol.set_activity("cifar10", 1.5)


# ---------------------------------------------------------------------------
# energy.temporal_report
# ---------------------------------------------------------------------------

def test_temporal_report_arithmetic():
    prog = networks.mnist5()
    rep = energy.temporal_report(prog, frames=100, computed=25,
                                 computed_padded=5)
    full = energy.analyze_net(prog, energy.F_EMIN)
    full_uj = full.i2l_energy_per_inference * 1e6
    assert rep.skipped == 75 and rep.skip_ratio == pytest.approx(0.75)
    assert rep.full_uj == pytest.approx(full_uj)
    assert rep.delta_uj < full_uj           # the toll must undercut full
    assert rep.uj_per_frame == pytest.approx(
        rep.delta_uj + 30 * full_uj / 100)
    assert rep.uj_per_frame < rep.uj_per_frame_ungated == pytest.approx(
        full_uj)
    assert rep.savings == pytest.approx(
        rep.uj_per_frame_ungated / rep.uj_per_frame)
    with pytest.raises(ValueError):
        energy.temporal_report(prog, frames=10, computed=11)
    with pytest.raises(ValueError):
        energy.temporal_report(prog, frames=10, computed=5,
                               computed_padded=-1)


# ---------------------------------------------------------------------------
# video_trace content generation
# ---------------------------------------------------------------------------

def test_video_trace_deterministic_and_changed_mask():
    a = video_trace((8, 8, 1), 10, streams=3, seed=7, change_rate=0.4,
                    scene_change_every=4, levels=16)
    b = video_trace((8, 8, 1), 10, streams=3, seed=7, change_rate=0.4,
                    scene_change_every=4, levels=16)
    assert np.array_equal(a.frames, b.frames)
    assert np.array_equal(a.changed, b.changed)
    assert a.frames.shape == (10, 3, 8, 8, 1)
    assert a.frames.min() >= 0 and a.frames.max() < 16
    # the changed mask is pixel-exact ground truth
    for t in range(1, 10):
        for s in range(3):
            assert a.changed[t, s] == (
                not np.array_equal(a.frames[t, s], a.frames[t - 1, s]))
    assert a.changed[0].all()               # first frames always "change"
    assert 0.0 < a.change_ratio < 1.0
    c = video_trace((8, 8, 1), 10, streams=3, seed=8, change_rate=0.0)
    assert not c.changed[1:].any()          # static scene stays static


# ---------------------------------------------------------------------------
# Threshold calibration
# ---------------------------------------------------------------------------

def test_simulate_gate_reference_rule():
    """The host simulator's reference advances only on recompute."""
    packed = np.zeros((4, 1, 1, 1, 1), np.uint32)
    packed[1] = 3        # 2 bits away from frame 0
    packed[2] = 3        # identical to frame 1
    packed[3] = 0        # back to frame 0's code, 2 bits from frame 2
    rec, ref = tmp.simulate_gate(packed, 2.0)
    assert rec[:, 0].tolist() == [True, True, False, True]
    assert ref[:, 0].tolist() == [0, 1, 1, 3]


def test_calibrate_threshold_meets_agreement(pipe_setup):
    prog, art, trace, oracle = pipe_setup
    thr = tmp.calibrate_delta_threshold(trace.frames, 0.95, program=prog,
                                        artifact=art, interpret=True)
    _, packed = tmp._packed_streams(trace.frames, prog)
    _, ref = tmp.simulate_gate(packed, thr)
    o = oracle.reshape(len(trace), trace.streams)
    emitted = o[ref, np.arange(trace.streams)[None, :]]
    assert (emitted == o).mean() >= 0.95
    # a perfect target still terminates (threshold 1 is always exact)
    thr1 = tmp.calibrate_delta_threshold(trace.frames, 1.0, program=prog,
                                         artifact=art, interpret=True)
    assert thr1 >= 1.0
    with pytest.raises(ValueError):
        tmp.calibrate_delta_threshold(trace.frames, 0.0, program=prog,
                                      artifact=art)


def test_threshold_for_skip(pipe_setup):
    prog, _, trace, _ = pipe_setup
    thr = tmp.threshold_for_skip(trace.frames, 0.3, program=prog)
    _, packed = tmp._packed_streams(trace.frames, prog)
    rec, _ = tmp.simulate_gate(packed, thr)
    assert 1.0 - rec.mean() >= 0.3
    with pytest.raises(ValueError, match="unreachable"):
        # cold frames always compute: skip ratio can't hit 0.99 in 6 steps
        tmp.threshold_for_skip(trace.frames, 0.99, program=prog)
    with pytest.raises(ValueError):
        tmp.threshold_for_skip(trace.frames, 1.0, program=prog)

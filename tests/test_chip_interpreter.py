"""Interpreter equivalences: train-mode eval == folded inference == Pallas path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.chip import interpreter, isa, networks, neuron_array as na


def _small_program(s=4):
    """A reduced program of the cifar9 family (small maps, full ISA checks)."""
    f = isa.ARRAY_CHANNELS // s
    instrs = (
        isa.IOInstr(height=8, width=8, in_channels=3, bits=7, channels=f),
        isa.ConvInstr(height=8, width=8, features=f, maxpool=True),   # ->3
        isa.ConvInstr(height=3, width=3, features=f),                 # ->2
        isa.FCInstr(in_features=2 * 2 * f, out_features=10, final=True),
    )
    p = isa.Program(s=s, instrs=instrs)
    isa.validate(p)
    return p


def _images(key, h=8, w=8, b=2, c=3, levels=128):
    return jax.random.randint(key, (b, h, w, c), 0, levels)


def test_thermometer_encode_monotone():
    """More intense pixels turn on >= as many +1 planes (monotone code)."""
    img = jnp.arange(128)[None, :, None, None]  # (1, 128, 1, 1) values 0..127
    enc = na.thermometer_encode(img, bits=7, channels=64)
    ones = (enc > 0).sum(axis=-1)[0, :, 0]
    assert bool(jnp.all(jnp.diff(ones) >= 0))
    assert int(ones[0]) < int(ones[-1])


def test_train_forward_shapes_and_finite():
    p = _small_program()
    key = jax.random.PRNGKey(0)
    params = interpreter.init_params(key, p)
    logits, new_params = interpreter.forward_train(params, p, _images(key))
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # BN stats moved
    assert not np.allclose(np.asarray(new_params["conv"][0]["mean"]), 0.0)


def test_train_grads_flow_to_all_weights():
    p = _small_program()
    key = jax.random.PRNGKey(1)
    params = interpreter.init_params(key, p)
    imgs = _images(key)

    def loss(params):
        logits, _ = interpreter.forward_train(params, p, imgs)
        return jnp.mean(logits ** 2)

    g = jax.grad(loss)(params)
    for gc in g["conv"]:
        assert float(jnp.abs(gc["w"]).max()) > 0.0
    for gf in g["fc"]:
        assert float(jnp.abs(gf["w"]).max()) > 0.0


@pytest.mark.parametrize("s", [1, 2, 4])
def test_eval_equals_folded_inference(s):
    """sign(BN(conv)) path == integer-threshold comparator path."""
    p = _small_program(s)
    key = jax.random.PRNGKey(2 + s)
    params = interpreter.init_params(key, p)
    # give BN stats a realistic nonzero state
    _, params = interpreter.forward_train(params, p, _images(key, b=4))
    imgs = _images(jax.random.PRNGKey(7), b=3)

    logits_train, _ = interpreter.forward_train(params, p, imgs, train=False)
    folded = interpreter.fold_params(params, p)
    logits_inf, labels = interpreter.forward_infer(folded, p, imgs)
    np.testing.assert_array_equal(np.asarray(logits_train), np.asarray(logits_inf))
    assert labels.shape == (3,)


def test_folded_inference_matches_pallas_kernels():
    p = _small_program(4)
    key = jax.random.PRNGKey(3)
    params = interpreter.init_params(key, p)
    _, params = interpreter.forward_train(params, p, _images(key, b=4))
    folded = interpreter.fold_params(params, p)
    imgs = _images(jax.random.PRNGKey(11), b=2)

    logits_ref, labels_ref = interpreter.forward_infer(folded, p, imgs,
                                                       use_kernels=False)
    logits_krn, labels_krn = interpreter.forward_infer(folded, p, imgs,
                                                       use_kernels=True,
                                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(logits_ref), np.asarray(logits_krn))
    np.testing.assert_array_equal(np.asarray(labels_ref), np.asarray(labels_krn))


def test_infer_fn_jits():
    p = _small_program(4)
    key = jax.random.PRNGKey(4)
    params = interpreter.init_params(key, p)
    folded = interpreter.fold_params(params, p)
    fn = interpreter.make_infer_fn(p)
    logits, labels = fn(folded, _images(key))
    assert logits.shape == (2, 10) and labels.shape == (2,)


def test_maxpool_is_binary_or():
    x = jnp.array([[[[-1.], [-1.]], [[-1.], [1.]]],
                   [[[-1.], [-1.]], [[-1.], [-1.]]]])  # (2,2,2,1)
    out = na.maxpool2x2(x)
    np.testing.assert_array_equal(np.asarray(out).ravel(), [1.0, -1.0])

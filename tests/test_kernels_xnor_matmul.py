"""xnor_matmul Pallas kernel vs pure-jnp oracle: shape/dtype sweeps + properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binarize
from repro.kernels import ops, ref
from repro.kernels.xnor_matmul import xnor_matmul


def _rand_signs(rng, shape):
    return rng.choice(np.array([-1.0, 1.0], np.float32), size=shape)


SHAPES = [
    (1, 1, 32),       # minimal
    (3, 5, 32),       # sub-tile M/N
    (16, 64, 64),     # K spans 2 words
    (128, 128, 256),  # exactly one default tile
    (130, 129, 2048), # padding on every axis, multi-word K
    (256, 64, 100),   # K not a multiple of 32 (padded packing)
]


@pytest.mark.parametrize("m,n,k", SHAPES)
def test_matches_oracle(m, n, k):
    rng = np.random.default_rng(seed=m * 7919 + n * 31 + k)
    a = _rand_signs(rng, (m, k))
    w = _rand_signs(rng, (n, k))
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    w_words = binarize.pack_signs(jnp.asarray(w), axis=-1)
    got = xnor_matmul(a_words, w_words, k=k, interpret=True)
    want = ref.xnor_matmul_ref(jnp.asarray(a), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 1), (32, 16, 2), (128, 128, 64)])
def test_tile_shape_invariance(bm, bn, bk):
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(0)
    m, n, k = 96, 80, 96
    a = _rand_signs(rng, (m, k))
    w = _rand_signs(rng, (n, k))
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    w_words = binarize.pack_signs(jnp.asarray(w), axis=-1)
    got = xnor_matmul(a_words, w_words, k=k, bm=bm, bn=bn, bk=bk, interpret=True)
    want = ref.xnor_matmul_ref(jnp.asarray(a), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    kw=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_random_shapes(m, n, kw, seed):
    k = kw * 32 - (seed % 7)  # exercise non-multiple-of-32 K too
    k = max(k, 1)
    rng = np.random.default_rng(seed)
    a = _rand_signs(rng, (m, k))
    w = _rand_signs(rng, (n, k))
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    w_words = binarize.pack_signs(jnp.asarray(w), axis=-1)
    got = xnor_matmul(a_words, w_words, k=k, bm=16, bn=16, bk=2, interpret=True)
    want = ref.xnor_matmul_ref(jnp.asarray(a), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_output_parity_property():
    """dot of +/-1 vectors of length k always has parity of k (mod 2)."""
    rng = np.random.default_rng(3)
    k = 37
    a = _rand_signs(rng, (9, k))
    w = _rand_signs(rng, (11, k))
    got = np.asarray(xnor_matmul(
        binarize.pack_signs(jnp.asarray(a)), binarize.pack_signs(jnp.asarray(w)),
        k=k, interpret=True))
    assert np.all((got - k) % 2 == 0)
    assert got.min() >= -k and got.max() <= k


def test_binary_linear_end_to_end():
    rng = np.random.default_rng(5)
    x = rng.normal(size=(4, 7, 200)).astype(np.float32)
    w = _rand_signs(rng, (30, 200))
    got = ops.binary_linear(jnp.asarray(x), jnp.asarray(w), interpret=True)
    want = ref.xnor_matmul_ref(binarize.hard_sign(jnp.asarray(x)).reshape(-1, 200),
                               jnp.asarray(w)).reshape(4, 7, 30)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

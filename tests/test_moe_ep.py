"""Expert-parallel MoE (shard_map + all_to_all) vs the dense oracle.

Runs in a SUBPROCESS with 8 fake devices (the parent pytest process must
keep seeing 1 device — jax locks device count at first init).

With capacity_factor high enough that nothing drops, the EP path must
match the dense path to float tolerance; fp8 dispatch must match within
e4m3 quantization error.
"""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models import moe

    cfg = ModelConfig(
        name="moe-test", family="moe", num_layers=1, d_model=32,
        num_heads=2, num_kv_heads=1, d_ff=64, vocab_size=128,
        pattern=("attn_moe",),
        moe=MoEConfig(num_experts=8, top_k=2, d_expert=16,
                      capacity_factor=8.0, impl="ep"),
        dtype="float32", param_dtype="float32")

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params = moe.init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)

    from repro.distributed import context as dctx
    y_dense, aux_d = moe.apply_dense(params, cfg, x)
    with dctx.mesh_context(mesh):
        y_ep, aux_e = moe.apply_ep(params, cfg, x, mesh)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense),
                               rtol=2e-4, atol=2e-4)

    cfg8 = cfg.with_(moe=dataclasses.replace(cfg.moe, dispatch_fp8=True))
    with dctx.mesh_context(mesh):
        y_f8, _ = moe.apply_ep(params, cfg8, x, mesh)
    err = np.abs(np.asarray(y_f8) - np.asarray(y_dense))
    scale = np.abs(np.asarray(y_dense)).mean() + 1e-6
    assert err.mean() / scale < 0.1, (err.mean(), scale)
    print("MOE_EP_OK")
""")


def test_ep_matches_dense_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "MOE_EP_OK" in r.stdout, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"

"""Whole-network megakernel: bit-exactness + weight image + streaming.

The acceptance property of the all-memory-on-chip lowering: for every
benchmark program the single resident ``pallas_call``
(``InferencePlan.forward_mega`` — weight image VMEM-resident, feature
maps in VMEM scratch, frame tiles double-buffered through the grid)
agrees *bit-exactly* with both the staged packed pipeline and the float
+/-1 reference interpreter — for any frame-tile size ``bb``, including
ragged final tiles and random valid ISA programs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binarize
from repro.core.chip import energy, interpreter, isa, networks
from tests.test_fold_pack_property import _random_bn_params, random_program


def _images(program, b=2, seed=0):
    io = program.instrs[0]
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (b, io.height, io.width, io.in_channels),
                              0, 2 ** io.bits)


def _trained(program, seed=0):
    key = jax.random.PRNGKey(seed)
    params = interpreter.init_params(key, program)
    _, params = interpreter.forward_train(params, program,
                                          _images(program, b=4, seed=1))
    return params


# The S=1/S=2 nets are interpret-mode heavyweights; keep the fast tier on
# the S=4 family and sweep the full registry in the slow job.
_SLOW = {"cifar9_s1", "cifar9_s2", "face_angles", "owner_detector"}


@pytest.mark.parametrize(
    "name", [pytest.param(n, marks=pytest.mark.slow) if n in _SLOW
             else n for n in sorted(networks.REGISTRY)])
def test_megakernel_bit_exact_on_every_registry_program(name):
    """megakernel == staged plan == float oracle, logits and labels."""
    program = networks.REGISTRY[name]()
    params = _trained(program)
    folded = interpreter.fold_params(params, program)
    packed = interpreter.fold_params(params, program, packed=True)
    image = interpreter.fold_params(params, program, image=True)
    imgs = _images(program, b=3, seed=7)           # 3 % bb=2 -> ragged tile

    logits_ref, labels_ref = interpreter.forward_infer(
        folded, program, imgs, use_kernels=False)
    plan = interpreter.compile_plan(program)
    logits_st, labels_st = plan.forward(packed, imgs, interpret=True)
    logits_mg, labels_mg = plan.forward_mega(image, imgs, interpret=True,
                                             bb=2)

    np.testing.assert_array_equal(np.asarray(logits_mg),
                                  np.asarray(logits_st))
    np.testing.assert_array_equal(np.asarray(logits_mg),
                                  np.asarray(logits_ref))
    np.testing.assert_array_equal(np.asarray(labels_mg),
                                  np.asarray(labels_ref))


def test_megakernel_frame_tile_sizes_and_ragged_tiles():
    """Any bb (dividing or ragged, larger than the batch, bb=1) and any
    conv f-tile ft (untiled, dividing, non-dividing, unaligned, larger
    than F): identical logits — tiling is a pure streaming schedule, not
    a numeric choice."""
    program = networks.mnist5()
    params = _trained(program, seed=3)
    packed = interpreter.fold_params(params, program, packed=True)
    image = interpreter.fold_params(params, program, image=True)
    plan = interpreter.compile_plan(program)
    imgs = _images(program, b=7, seed=11)
    ref = np.asarray(plan.forward(packed, imgs, interpret=True)[0])
    for bb in (1, 2, 3, 7, 16):
        got = np.asarray(plan.forward_mega(image, imgs, interpret=True,
                                           bb=bb, ft=0)[0])
        np.testing.assert_array_equal(got, ref, err_msg=f"bb={bb}")
    for ft in (0, 7, 32, 33, 48, 64, 1000):    # F=64 at S=4
        got = np.asarray(plan.forward_mega(image, imgs, interpret=True,
                                           bb=3, ft=ft)[0])
        np.testing.assert_array_equal(got, ref, err_msg=f"ft={ft}")


def test_weight_image_layout():
    """fold_params(image=True) emits the documented VMEM-resident stack,
    and its total size matches energy.hbm_traffic's weight_image bill."""
    program = networks.mnist5()
    params = _trained(program, seed=5)
    packed = interpreter.fold_params(params, program, packed=True)
    image = interpreter.fold_params(params, program, image=True)

    n_conv = len(program.conv_instrs)
    f = isa.ARRAY_CHANNELS // program.s
    cw = f // binarize.PACK_WIDTH
    assert image["cw"].shape == (n_conv, f, 4, cw)
    assert image["cw"].dtype == jnp.uint32
    assert image["ct"].shape == (n_conv, f) and image["ct"].dtype == jnp.int32
    assert image["cf"].shape == (n_conv, f)
    fcs = program.fc_instrs
    n_max = max(i.out_features for i in fcs)
    kw_max = max(-(-i.in_features // binarize.PACK_WIDTH) for i in fcs)
    assert image["fw"].shape == (len(fcs), n_max, kw_max)
    # the stacked words are the per-layer words, zero-padded
    for i, p in enumerate(packed["conv"]):
        np.testing.assert_array_equal(np.asarray(image["cw"][i]),
                                      np.asarray(p["w_words"]))
        np.testing.assert_array_equal(np.asarray(image["ct"][i]),
                                      np.asarray(p["tau"]))
    for i, p in enumerate(packed["fc"]):
        n, kw_ = p["w_words"].shape
        np.testing.assert_array_equal(np.asarray(image["fw"][i, :n, :kw_]),
                                      np.asarray(p["w_words"]))
    traffic = energy.hbm_traffic(program)
    unpadded = (image["cw"].nbytes + image["ct"].nbytes + image["cf"].nbytes
                + sum(p["w_words"].nbytes for p in packed["fc"]))
    assert traffic.weight_image_bytes == unpadded


def test_ensure_image_admits_every_artifact_form():
    program = networks.mnist5()
    params = _trained(program, seed=9)
    folded = interpreter.fold_params(params, program)
    packed = interpreter.pack_folded(folded)
    image = interpreter.fold_params(params, program, image=True)
    for art in (folded, packed, image):
        got = interpreter.ensure_image(art, program)
        for k in ("cw", "ct", "cf", "fw"):
            np.testing.assert_array_equal(np.asarray(got[k]),
                                          np.asarray(image[k]))
    with pytest.raises(TypeError, match="weight-image"):
        interpreter.ensure_packed(image)           # no un-stacking seam


def test_megakernel_zero_interlayer_hbm_claim():
    """The traffic model agrees with the kernel's structure: megakernel
    bytes = frames + weight image + logits, independent of depth."""
    program = networks.cifar9(4)
    t = energy.hbm_traffic(program, batch=16)
    io = program.instrs[0]
    frames = 16 * io.height * io.width * io.in_channels * 4
    logits = 16 * program.instrs[-1].out_features * 4
    assert t.mega_bytes == frames + t.weight_image_bytes + logits
    assert t.staged_bytes > 5 * t.mega_bytes       # the eliminated traffic


@settings(max_examples=12, deadline=None)
@given(s=st.sampled_from([2, 4]), bb=st.sampled_from([1, 2, 3, 4, 8]),
       b=st.integers(1, 9), seed=st.integers(0, 2 ** 16))
def test_megakernel_matches_staged_on_random_programs(s, bb, b, seed):
    """Property: random valid ISA program x random BN state x random batch
    x random frame-tile size -> megakernel == staged plan, bit-exact.
    Covers conv-only tails, hidden FCs (packed and odd-width), ragged
    final tiles and bb > batch."""
    program = random_program(s, seed)
    params = _random_bn_params(program, seed)
    packed = interpreter.fold_params(params, program, packed=True)
    image = interpreter.fold_params(params, program, image=True)
    plan = interpreter.compile_plan(program)
    imgs = _images(program, b=b, seed=seed)

    logits_st, labels_st = plan.forward(packed, imgs, interpret=True)
    logits_mg, labels_mg = plan.forward_mega(image, imgs, interpret=True,
                                             bb=bb)
    np.testing.assert_array_equal(np.asarray(logits_mg),
                                  np.asarray(logits_st))
    np.testing.assert_array_equal(np.asarray(labels_mg),
                                  np.asarray(labels_st))


def test_megakernel_serve_fn_and_sharding(monkeypatch):
    """make_serve_fn(megakernel=True) matches the staged serve fn on the
    same frames — through the mesh path whatever jax.device_count() is."""
    from repro.distributed import sharding
    program = networks.mnist5()
    params = _trained(program, seed=13)
    packed = interpreter.fold_params(params, program, packed=True)
    image = interpreter.fold_params(params, program, image=True)
    plan = interpreter.compile_plan(program)
    mesh = sharding.serve_mesh()
    batch = 2 * mesh.devices.size
    imgs = _images(program, b=batch, seed=17)

    ref = plan.make_serve_fn(interpret=True)(packed, imgs)
    for kw in (dict(), dict(mesh=mesh)):
        got = plan.make_serve_fn(interpret=True, megakernel=True,
                                 bb=2, **kw)(
            sharding.replicate_artifact(mesh, image) if kw else image,
            sharding.scatter_frames(mesh, imgs) if kw else imgs)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(ref[0]))
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(ref[1]))

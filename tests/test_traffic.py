"""Traffic generation + replay: determinism, serialization, end-to-end.

Locks down the measurement side of continuous batching:

1. **Determinism** — every generator is a pure function of its seed and
   parameters: same inputs, bit-identical trace, on any host.
2. **Statistics** — realised mean rates land near the requested rate
   (the traces are the bench's committed workload; a generator drifting
   off its nominal rate would silently change the regression regime).
3. **Serialization** — save/load round-trips exactly (CI re-derives the
   committed bench trace from parameters; the JSON form is the escape
   hatch for external traces).
4. **Replay** — a VirtualClock replay through a real ChipServer is
   deterministic, serves every frame bit-exactly, stamps t_submit with
   the *due* time, and produces latency percentiles + a per-frame trace
   in ServeStats.
"""

import json

import jax
import numpy as np
import pytest

from repro.core.chip import interpreter, networks
from repro.serving import (ChipServer, VirtualClock, bursty_trace,
                           diurnal_trace, load_trace, make_trace,
                           poisson_trace, replay, save_trace)
from repro.serving.traffic import ArrivalTrace, TRAFFIC_KINDS


def _make(kind, **kw):
    args = dict(lanes=["a", "b"], rate=100.0, n=64, seed=7)
    args.update(kw)
    return make_trace(kind, args.pop("lanes"), args.pop("rate"),
                      args.pop("n"), **args)


# ---------------------------------------------------------------------------
# 1. Determinism + basic shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", TRAFFIC_KINDS)
def test_trace_deterministic_per_seed(kind):
    a, b = _make(kind), _make(kind)
    np.testing.assert_array_equal(a.t, b.t)
    assert a.lane == b.lane
    c = _make(kind, seed=8)
    assert not np.array_equal(a.t, c.t)           # the seed matters


@pytest.mark.parametrize("kind", TRAFFIC_KINDS)
def test_trace_shape_and_ordering(kind):
    tr = _make(kind)
    assert len(tr) == 64 and len(tr.lane) == 64
    assert tr.t[0] == 0.0                         # origin at first arrival
    assert np.all(np.diff(tr.t) >= 0)             # sorted
    assert set(tr.lane) <= {"a", "b"}
    assert tr.kind == kind and tr.meta["rate"] == 100.0


@pytest.mark.parametrize("kind", TRAFFIC_KINDS)
def test_trace_mean_rate_near_nominal(kind):
    """512 arrivals at nominal 100/s: the realised mean rate stays within
    a loose statistical band (the diurnal envelope thins below nominal,
    and the MMPP's per-arrival state flips weight time toward the calm
    state, so both run below the raw Poisson rate)."""
    tr = _make(kind, n=512)
    lo = 60.0 if kind == "poisson" else 30.0
    assert lo < tr.mean_rate < 160.0, tr.mean_rate


def test_lane_weights_bias_the_spread():
    tr = poisson_trace(["hot", "cold"], 100.0, 512, seed=3,
                       weights=[0.9, 0.1])
    hot = sum(1 for l in tr.lane if l == "hot")
    assert hot > 400                              # ~460 expected


def test_generator_validation():
    with pytest.raises(ValueError, match="rate"):
        poisson_trace(["a"], 0.0, 4)
    with pytest.raises(ValueError, match="n must"):
        poisson_trace(["a"], 10.0, 0)
    with pytest.raises(ValueError, match="lane"):
        poisson_trace([], 10.0, 4)
    with pytest.raises(ValueError, match="weights"):
        poisson_trace(["a", "b"], 10.0, 4, weights=[1.0])
    with pytest.raises(ValueError, match="burst_factor"):
        bursty_trace(["a"], 10.0, 4, burst_factor=0.5)
    with pytest.raises(ValueError, match="transition"):
        bursty_trace(["a"], 10.0, 4, p_enter=0.0)
    with pytest.raises(ValueError, match="depth"):
        diurnal_trace(["a"], 10.0, 4, depth=1.0)
    with pytest.raises(ValueError, match="unknown traffic kind"):
        make_trace("sawtooth", ["a"], 10.0, 4)
    with pytest.raises(ValueError, match="sorted"):
        ArrivalTrace(kind="poisson", seed=0,
                     t=np.array([1.0, 0.5]), lane=("a", "a"))
    with pytest.raises(ValueError, match="lane tags"):
        ArrivalTrace(kind="poisson", seed=0,
                     t=np.array([0.0, 0.5]), lane=("a",))


# ---------------------------------------------------------------------------
# 2. Serialization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", TRAFFIC_KINDS)
def test_save_load_roundtrip(kind, tmp_path):
    tr = _make(kind)
    p = str(tmp_path / "trace.json")
    save_trace(tr, p)
    back = load_trace(p)
    np.testing.assert_array_equal(back.t, tr.t)
    assert back.lane == tr.lane
    assert back.kind == tr.kind and back.seed == tr.seed
    assert back.meta == tr.meta
    with open(p) as f:                            # plain JSON, no pickles
        assert set(json.load(f)) == {"kind", "seed", "t", "lane", "meta"}


def test_saved_trace_regenerates_from_meta(tmp_path):
    """The committed-parameters contract: a loaded trace's meta is enough
    to regenerate the identical arrival sequence."""
    tr = bursty_trace(["a", "b"], 200.0, 48, seed=11, burst_factor=4.0)
    p = str(tmp_path / "t.json")
    save_trace(tr, p)
    back = load_trace(p)
    m = dict(back.meta)
    regen = make_trace(back.kind, m.pop("lanes"), m.pop("rate"),
                       m.pop("n"), seed=back.seed,
                       **{k: v for k, v in m.items() if v is not None
                          and k != "weights"})
    np.testing.assert_array_equal(regen.t, back.t)
    assert regen.lane == back.lane


# ---------------------------------------------------------------------------
# 3. Replay end-to-end (VirtualClock: deterministic, no wall-clock waits)
# ---------------------------------------------------------------------------

def _artifact(program, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    return interpreter.fold_params(params, program, packed=True)


def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


@pytest.fixture(scope="module")
def replay_setup():
    program = networks.mnist5()
    packed = _artifact(program, seed=2)
    frames = _frames(program, 6, seed=9)
    plan = interpreter.compile_plan(program)
    _, labels = plan.forward(packed, frames, interpret=True)
    return program, packed, frames, np.asarray(labels)


def test_virtual_clock_replay_end_to_end(replay_setup):
    """A Poisson trace replayed under a VirtualClock: every arrival is
    served exactly once, labels bit-exact vs offline, t_submit stamped
    with the due time, and ServeStats carries percentiles + the
    per-frame latency trace."""
    program, packed, frames, labels = replay_setup
    tr = poisson_trace(["m"], rate=200.0, n=12, seed=5)
    vc = VirtualClock(start=1.0)
    server = ChipServer({"m": program}, {"m": packed}, batch=4,
                        interpret=True, policy="continuous",
                        slo_ms=20.0, clock=vc)
    results = replay(server, tr, {"m": frames}, clock=vc, sleep=vc.sleep)

    assert len(results) == len(tr)
    assert [r.rid for r in results] == sorted(r.rid for r in results)
    for i, r in enumerate(results):
        assert r.label == labels[i % len(frames)]
        assert r.t_submit == pytest.approx(1.0 + float(tr.t[i]))
        assert r.t_done >= r.t_submit
    stats = server.stats()
    assert stats.served == {"m": len(tr)}
    assert stats.p50_ms <= stats.p95_ms <= stats.p99_ms
    assert 0.0 <= stats.padding_ratio < 1.0
    trace = server.latency_trace()
    assert len(trace) == len(tr)
    assert all(t["latency_ms"] >= 0.0 for t in trace)


def test_replay_is_deterministic_under_virtual_clock(replay_setup):
    """Same trace + fresh VirtualClock twice: identical dispatch
    structure and identical latency trace — the bench's paired
    comparison rests on this."""
    program, packed, frames, _ = replay_setup
    tr = bursty_trace(["m"], rate=300.0, n=10, seed=21)

    def run():
        vc = VirtualClock(start=1.0)
        server = ChipServer({"m": program}, {"m": packed}, batch=4,
                            interpret=True, policy="continuous",
                            slo_ms=10.0, clock=vc)
        replay(server, tr, {"m": frames}, clock=vc, sleep=vc.sleep)
        return server.latency_trace(), server.stats()

    ta, sa = run()
    tb, sb = run()
    assert ta == tb
    assert sa.dispatches == sb.dispatches
    assert sa.p99_ms == sb.p99_ms


def test_replay_speed_compresses_time(replay_setup):
    """speed=k divides every inter-arrival gap: the virtual clock
    advances ~k times less for the same trace."""
    program, packed, frames, _ = replay_setup
    tr = poisson_trace(["m"], rate=50.0, n=8, seed=4)
    spans = []
    for speed in (1.0, 4.0):
        vc = VirtualClock(start=0.0)
        server = ChipServer({"m": program}, {"m": packed}, batch=4,
                            interpret=True, policy="continuous",
                            slo_ms=100.0, clock=vc)
        replay(server, tr, {"m": frames}, speed=speed,
               clock=vc, sleep=vc.sleep)
        spans.append(vc())
    assert spans[1] < spans[0]
    with pytest.raises(ValueError, match="speed"):
        replay(None, tr, {}, speed=0.0)

"""Policy layer: mechanism/policy split, operating points, controller.

Locks down the serving refactor four ways:

1. **Back-compat** — the pre-split import surface keeps working
   (``repro.serving.scheduler`` shim), and the split package exposes the
   mechanism/policy seam (`FrameQueue` primitives, `DispatchPolicy`).
2. **Fairness property** — under ANY dispatch policy (static, static
   shared-array, operating-point with/without budget and co-dispatch) a
   lane that is backlogged before a dispatch is served within the next
   ``n_lanes`` dispatches, every request exactly once, per-lane FIFO —
   the round-robin contract survives the policy indirection.
3. **Budget property** — the operating-point controller never exceeds a
   feasible energy budget (>= the cheapest variant's steady-state power)
   by more than one dispatch's energy, and pins to the floor variant
   when the budget is infeasible.
4. **End-to-end** — family serving through ``ChipServer`` returns labels
   bit-exact vs the *chosen variant's* offline forward, downshifts under
   a tight budget, and co-dispatches on-the-fly composites bit-exactly.
"""

import random

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.chip import energy, interpreter, networks
from repro.serving import (ChipServer, ContinuousPolicy, DispatchPolicy,
                           FrameQueue, FrameRequest, OperatingPointPolicy,
                           PolicyContext, StaticPolicy, VirtualClock,
                           plan_shared_groups)


def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


def _artifact(program, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    return interpreter.fold_params(params, program, packed=True)


def _offline(program, packed, frames):
    plan = interpreter.compile_plan(program)
    logits, labels = plan.forward(packed, np.asarray(frames),
                                  interpret=True)
    return np.asarray(logits), np.asarray(labels)


# ---------------------------------------------------------------------------
# 1. Back-compat: the pre-split import surface
# ---------------------------------------------------------------------------

def test_scheduler_shim_keeps_presplit_imports():
    """The acceptance contract: every pre-split name still imports from
    repro.serving.scheduler (and matches the package's objects)."""
    from repro.serving.scheduler import (ChipServer as C, FrameQueue as Q,
                                         FrameRequest as R, FrameResult as F,
                                         ServeStats as S,
                                         plan_shared_groups as g)
    import repro.serving as pkg
    assert C is pkg.ChipServer and Q is pkg.FrameQueue
    assert R is pkg.FrameRequest and F is pkg.FrameResult
    assert S is pkg.ServeStats and g is pkg.plan_shared_groups


def test_queue_primitives_compose_to_next_batch():
    """The policy-facing primitives (rr_lanes/first_backlogged/take/
    advance_past) reproduce next_batch exactly."""
    a, b = FrameQueue(["x", "y", "z"]), FrameQueue(["x", "y", "z"])
    for rid, lane in enumerate(["y", "z", "y", "x"]):
        for q in (a, b):
            q.submit(FrameRequest(rid=rid, program=lane, frame=None))
    while True:
        got = a.next_batch(2)
        lane = b.first_backlogged()
        if got is None:
            assert lane is None
            break
        b.advance_past(lane)
        taken = b.take(lane, 2)
        assert got[0] == lane
        assert [r.rid for r in got[1]] == [r.rid for r in taken]


# ---------------------------------------------------------------------------
# 2. Operating points + family compilation
# ---------------------------------------------------------------------------

def test_operating_points_pareto_front():
    """The cifar10 family forms a clean front: accuracy and energy both
    strictly decrease walking most-accurate-first, and a dominated point
    (more energy, less accuracy) is filtered out."""
    progs = networks.family_programs("cifar10")
    pts = energy.operating_points(progs, networks.ACCURACY)
    assert [p.name for p in pts] == list(networks.FAMILIES["cifar10"])
    for hi, lo in zip(pts, pts[1:]):
        assert hi.accuracy > lo.accuracy
        assert hi.uj_per_frame > lo.uj_per_frame
    # truncated depth really is the cheapest point (below the S=4 floor)
    assert pts[-1].name == "cifar9_s4t"

    # declare the full-depth S=4 net LESS accurate than the truncated one:
    # full-depth is now dominated (more energy, less accuracy) -> dropped
    acc = dict(networks.ACCURACY)
    acc["cifar9_s4"], acc["cifar9_s4t"] = acc["cifar9_s4t"], acc["cifar9_s4"]
    pts = energy.operating_points(progs, acc)
    assert "cifar9_s4" not in [p.name for p in pts]


def test_operating_points_ops_proxy_without_accuracy():
    """Without declared accuracies the ops-count proxy orders width/depth
    variants the way Fig. 5 does (wider + deeper = more accurate)."""
    progs = networks.family_programs("cifar10")
    pts = energy.operating_points(progs)
    assert [p.name for p in pts] == list(networks.FAMILIES["cifar10"])


def test_compile_family_validates_geometry_and_classes():
    ok = interpreter.compile_family(networks.family_programs("cifar10"))
    assert set(ok) == set(networks.FAMILIES["cifar10"])
    with pytest.raises(Exception, match="IO geometry"):
        interpreter.compile_family({"a": networks.cifar9(4),
                                    "b": networks.mnist5()})
    with pytest.raises(Exception, match="class count"):
        interpreter.compile_family({"a": networks.cifar9(4),
                                    "b": networks.cifar9(4, classes=2)})


def test_truncated_cifar9_is_a_valid_cheaper_program():
    full, trunc = networks.cifar9(4), networks.cifar9_truncated()
    assert len(trunc.conv_instrs) == len(full.conv_instrs) - 1
    e_full = energy.analyze_net(full).i2l_energy_per_inference
    e_trunc = energy.analyze_net(trunc).i2l_energy_per_inference
    assert e_trunc < e_full


# ---------------------------------------------------------------------------
# 3. Policy properties (pure Python, no device work)
# ---------------------------------------------------------------------------

def _family_context(batch):
    """A 3-lane context: one 2-variant family, one 2-variant family with a
    different energy spread, one plain single-variant lane."""
    programs = {
        "cifar9_s4": networks.cifar9(4),
        "cifar9_s4t": networks.cifar9_truncated(),
        "owner_detector": networks.owner_detector(),
        "face_detector": networks.face_detector(),
        "mnist5": networks.mnist5(),
    }
    variants = {"cifar10": ("cifar9_s4", "cifar9_s4t"),
                "face": ("owner_detector", "face_detector"),
                "mnist5": ("mnist5",)}
    return PolicyContext(
        batch=batch,
        lanes=tuple(variants),
        variants=variants,
        programs=programs,
        reports={n: energy.analyze_net(p) for n, p in programs.items()},
        groups={})


def _static_context(batch):
    """Four S=4 lanes forming one shared-array group + a solo S=1 lane."""
    programs = {"a": networks.mnist5(), "b": networks.mnist5(classes=2),
                "c": networks.mnist5(classes=3),
                "owner": networks.cifar9(1, classes=2)}
    groups = {}
    for members in plan_shared_groups(programs):
        for m in members:
            groups[m] = members
    return PolicyContext(
        batch=batch, lanes=tuple(programs),
        variants={n: (n,) for n in programs},
        programs=programs,
        reports={n: energy.analyze_net(p) for n, p in programs.items()},
        groups=groups)


def _make_policy(kind, batch):
    if kind == "static":
        ctx = _static_context(batch)
        pol = StaticPolicy()
    elif kind == "opp":
        ctx = _family_context(batch)
        pol = OperatingPointPolicy()
    elif kind == "opp-budget":
        ctx = _family_context(batch)
        # feasible but tight: the floor mix is always affordable
        floor = min(r.power_w for r in ctx.reports.values()) * 1e6
        pol = OperatingPointPolicy(budget_uj_s=floor * 1.2, shared=True)
    elif kind == "continuous":
        # the fairness suite submits unstamped requests, so the window
        # never holds (no deadline to wait on) — what's under test is the
        # variable-size dispatch path through the shared-group mechanism
        ctx = _static_context(batch)
        pol = ContinuousPolicy(inner=StaticPolicy())
    elif kind == "continuous-opp":
        # the full composition: continuous window over the operating-
        # point controller under a feasible energy budget
        ctx = _family_context(batch)
        floor = min(r.power_w for r in ctx.reports.values()) * 1e6
        pol = ContinuousPolicy(
            inner=OperatingPointPolicy(budget_uj_s=floor * 1.2, shared=True))
    else:
        ctx = _family_context(batch)
        pol = OperatingPointPolicy(shared=True, backlog_high=2 * batch)
    pol.bind(ctx)
    return pol, ctx


@settings(max_examples=12, deadline=None)
@given(kind=st.sampled_from(["static", "opp", "opp-budget", "opp-shared",
                             "continuous", "continuous-opp"]),
       n_reqs=st.integers(4, 40), batch=st.integers(1, 4),
       seed=st.integers(0, 2 ** 16))
def test_no_lane_starves_under_any_policy(kind, n_reqs, batch, seed):
    """Property: whatever the policy (static, shared groups, controller
    with budget / backlog downshift / composite riders), a lane that is
    backlogged before a dispatch is served within the next n_lanes
    dispatches, every request exactly once, per-lane FIFO."""
    pol, ctx = _make_policy(kind, batch)
    rng = random.Random(seed)
    queue = FrameQueue(ctx.lanes)
    rid, to_submit = 0, n_reqs
    trace = []
    while to_submit or queue.pending():
        if to_submit and (rng.random() < 0.6 or not queue.pending()):
            lane = rng.choice(list(ctx.lanes))
            queue.submit(FrameRequest(rid=rid, program=lane, frame=None))
            rid += 1
            to_submit -= 1
        else:
            before = {l: queue.pending(l) for l in ctx.lanes}
            d = pol.select(queue)
            assert d is not None
            trace.append((d, before))
    assert pol.select(queue) is None              # drained

    served = [(ld.lane, r.rid) for d, _ in trace for ld in d.lanes
              for r in ld.requests]
    assert sorted(r for _, r in served) == list(range(rid))   # exactly once
    per_lane = {}
    for lane, r in served:
        per_lane.setdefault(lane, []).append(r)
    for lane, rids in per_lane.items():
        assert rids == sorted(rids)               # per-lane FIFO
    # no starvation: a backlogged lane is served within n_lanes dispatches
    n_lanes = len(ctx.lanes)
    for i, (_, before) in enumerate(trace):
        window = trace[i:i + n_lanes]
        if len(window) < n_lanes:
            continue
        served_in_window = {ld.lane for d, _ in window for ld in d.lanes
                            if ld.requests}
        for lane, pending in before.items():
            if pending > 0:
                assert lane in served_in_window, (
                    f"{kind}: lane {lane} starved at dispatch {i}")
    # every dispatched variant belongs to its lane, and every request to
    # its dispatch's lane
    for d, _ in trace:
        for ld in d.lanes:
            assert ld.variant in ctx.variants[ld.lane]
            assert all(r.program == ld.lane for r in ld.requests)


@settings(max_examples=12, deadline=None)
@given(n_reqs=st.integers(4, 40), batch=st.integers(1, 4),
       budget_scale_pct=st.integers(100, 300), shared=st.booleans(),
       seed=st.integers(0, 2 ** 16))
def test_controller_never_exceeds_feasible_budget(n_reqs, batch,
                                                  budget_scale_pct, shared,
                                                  seed):
    """Property: for any feasible budget (>= the cheapest variant's
    steady-state power) the controller's committed energy never exceeds
    budget * committed chip time by more than one dispatch's energy —
    checked after every dispatch, for any submission interleaving."""
    ctx = _family_context(batch)
    floor = min(r.power_w for r in ctx.reports.values()) * 1e6
    budget = floor * budget_scale_pct / 100.0
    pol = OperatingPointPolicy(budget_uj_s=budget, shared=shared)
    pol.bind(ctx)
    max_e = max(batch * r.i2l_energy_per_inference * 1e6
                for r in ctx.reports.values())
    rng = random.Random(seed)
    queue = FrameQueue(ctx.lanes)
    rid, to_submit = 0, n_reqs
    while to_submit or queue.pending():
        if to_submit and (rng.random() < 0.6 or not queue.pending()):
            queue.submit(FrameRequest(rid=rid,
                                      program=rng.choice(list(ctx.lanes)),
                                      frame=None))
            rid += 1
            to_submit -= 1
        else:
            assert pol.select(queue) is not None
            assert (pol.spent_uj
                    <= budget * pol.chip_time_s + max_e + 1e-9), (
                f"budget {budget:.0f} exceeded: {pol.spent_uj:.0f} uJ in "
                f"{pol.chip_time_s:.3f}s")


def test_controller_pins_to_floor_when_budget_infeasible():
    """A budget below the cheapest variant's power can't be met — the
    always-on pipeline serves at the floor operating point instead of
    stalling (the chip's 0.92 uJ/f floor)."""
    ctx = _family_context(batch=2)
    pol = OperatingPointPolicy(budget_uj_s=1e-6)
    pol.bind(ctx)
    queue = FrameQueue(ctx.lanes)
    for rid in range(6):
        queue.submit(FrameRequest(rid=rid, program="cifar10", frame=None))
    while True:
        d = pol.select(queue)
        if d is None:
            break
        assert all(ld.variant == "cifar9_s4t" for ld in d.lanes)


def test_controller_downshifts_under_backlog():
    """Backlog above backlog_high downshifts one step even with no
    budget: the lane catches up at a cheaper, faster operating point."""
    ctx = _family_context(batch=2)
    pol = OperatingPointPolicy(backlog_high=4)
    pol.bind(ctx)
    queue = FrameQueue(ctx.lanes)
    for rid in range(6):                          # 6 >= backlog_high=4
        queue.submit(FrameRequest(rid=rid, program="cifar10", frame=None))
    d = pol.select(queue)
    assert d.lanes[0].variant == "cifar9_s4t"     # downshifted
    queue.take("cifar10", 10)                     # clear the backlog
    queue.submit(FrameRequest(rid=99, program="cifar10", frame=None))
    d = pol.select(queue)
    assert d.lanes[0].variant == "cifar9_s4"      # back to the top point


def test_controller_composites_exact_tilings_only():
    """With shared=True the controller co-dispatches backlogged lanes
    only when the chosen variants tile the array exactly; a downshifted
    family (S=4) plus an S=1 family can't tile -> solo."""
    ctx = _family_context(batch=2)
    pol = OperatingPointPolicy(shared=True, budget_uj_s=1e-6)  # all floors
    pol.bind(ctx)
    queue = FrameQueue(ctx.lanes)
    # floors: cifar10->cifar9_s4t (S=4), face->face_detector (S=4),
    # mnist5 (S=4): only 3 backlogged S=4 lanes -> 0.75 occupancy, no
    # exact tiling -> solo dispatch of the head lane only
    for lane in ("cifar10", "face", "mnist5"):
        queue.submit(FrameRequest(rid=0, program=lane, frame=None))
    d = pol.select(queue)
    assert len(d.lanes) == 1


# ---------------------------------------------------------------------------
# 3b. Continuous batching: window, deadline, buckets, composition
# ---------------------------------------------------------------------------

def _clocked_context(batch, clock, quantum=1):
    import dataclasses as _dc
    return _dc.replace(_static_context(batch), clock=clock, quantum=quantum)


def test_continuous_holds_below_target_until_deadline():
    """Stamped frames arriving fast enough to promise a fuller window are
    HELD (select -> None) until the oldest frame has waited deadline_frac
    of the SLO — then the dispatcher launches early and small."""
    vc = VirtualClock(start=10.0)
    ctx = _clocked_context(batch=4, clock=vc)
    pol = ContinuousPolicy(slo_ms=100.0, headroom=0.5, deadline_frac=0.5)
    pol.bind(ctx)
    queue = FrameQueue(ctx.lanes)
    # establish a high EWMA rate (~1000/s): target = ceil(1000*0.1*0.5)
    # clamps to batch=4, so 2 pending < target -> hold
    for rid in range(8):
        vc.advance(0.001)
        queue.submit(FrameRequest(rid=rid, program="a", frame=None,
                                  t_submit=vc()))
    queue.take("a", 6)                    # leave 2 pending, head freshly old
    assert queue.pending("a") == 2
    assert pol.select(queue) is None      # window open: below target, fresh
    vc.advance(0.040)                     # well under the 50 ms deadline
    assert pol.select(queue) is None
    vc.advance(0.020)                     # past deadline_frac * slo
    d = pol.select(queue)
    assert d is not None
    assert sum(len(ld.requests) for ld in d.lanes) == 2
    assert d.batch == 2                   # early and small, not the pad-4


def test_continuous_flush_dispatches_immediately():
    """Drain mode disables the window entirely: a flushing policy never
    holds frames, whatever the rate/deadline state says."""
    vc = VirtualClock(start=5.0)
    ctx = _clocked_context(batch=4, clock=vc)
    pol = ContinuousPolicy(slo_ms=1e6)    # deadline effectively never
    pol.bind(ctx)
    queue = FrameQueue(ctx.lanes)
    for rid in range(2):
        vc.advance(0.001)
        queue.submit(FrameRequest(rid=rid, program="a", frame=None,
                                  t_submit=vc()))
    assert pol.select(queue) is None      # held: huge SLO, tiny backlog
    pol.set_flush(True)
    d = pol.select(queue)
    assert d is not None and sum(len(ld.requests) for ld in d.lanes) == 2
    assert pol.inner.flush                # flush propagates to the inner
    pol.set_flush(False)
    assert not pol.inner.flush


def test_continuous_bucket_ladder_quantises_to_device_multiples():
    """Dispatch sizes land on the {q, 2q, 4q, ..., batch} ladder so every
    launch shards evenly over the serve mesh and the jit cache stays at
    log2(batch) shapes."""
    vc = VirtualClock()
    ctx = _clocked_context(batch=16, clock=vc, quantum=4)
    pol = ContinuousPolicy()
    pol.bind(ctx)
    assert pol._ladder == (4, 8, 16)
    queue = FrameQueue(ctx.lanes)
    for rid in range(5):                  # 5 unstamped -> dispatch now
        queue.submit(FrameRequest(rid=rid, program="owner", frame=None))
    d = pol.select(queue)
    assert sum(len(ld.requests) for ld in d.lanes) == 5
    assert d.batch == 8                   # 5 rounds up to the next bucket


def test_continuous_target_scales_with_rate():
    """The window target tracks the EWMA arrival rate: ceil(rate * slo *
    headroom), clamped to [min_batch, batch]."""
    pol = ContinuousPolicy(slo_ms=50.0, headroom=0.5, min_batch=1)
    pol.bind(_static_context(batch=8))
    assert pol._target(0.0) == 1          # no rate yet: launch singles
    assert pol._target(100.0) == 3        # ceil(100 * 0.05 * 0.5)
    assert pol._target(10_000.0) == 8     # clamped to the lane batch


def test_continuous_rejects_bad_parameters():
    for bad in (dict(slo_ms=0.0), dict(slo_ms=-1.0), dict(min_batch=0),
                dict(headroom=0.0), dict(headroom=1.5),
                dict(deadline_frac=-0.1), dict(deadline_frac=1.1)):
        with pytest.raises(ValueError):
            ContinuousPolicy(**bad)


@settings(max_examples=12, deadline=None)
@given(n_reqs=st.integers(4, 40), batch=st.integers(1, 4),
       budget_scale_pct=st.integers(100, 300), shared=st.booleans(),
       seed=st.integers(0, 2 ** 16))
def test_composed_controller_respects_budget_across_window_sizes(
        n_reqs, batch, budget_scale_pct, shared, seed):
    """The budget property survives composition: with the continuous
    layer picking variable dispatch sizes, the inner controller's
    committed energy still never exceeds budget * chip time by more than
    one dispatch's energy (sizes are <= batch, so the same slack bound
    applies)."""
    ctx = _family_context(batch)
    floor = min(r.power_w for r in ctx.reports.values()) * 1e6
    budget = floor * budget_scale_pct / 100.0
    inner = OperatingPointPolicy(budget_uj_s=budget, shared=shared)
    pol = ContinuousPolicy(inner=inner)
    pol.bind(ctx)
    max_e = max(batch * r.i2l_energy_per_inference * 1e6
                for r in ctx.reports.values())
    rng = random.Random(seed)
    queue = FrameQueue(ctx.lanes)
    rid, to_submit = 0, n_reqs
    while to_submit or queue.pending():
        if to_submit and (rng.random() < 0.6 or not queue.pending()):
            queue.submit(FrameRequest(rid=rid,
                                      program=rng.choice(list(ctx.lanes)),
                                      frame=None))
            rid += 1
            to_submit -= 1
        else:
            assert pol.select(queue) is not None
            assert (inner.spent_uj
                    <= budget * inner.chip_time_s + max_e + 1e-9), (
                f"budget {budget:.0f} exceeded through the continuous "
                f"layer: {inner.spent_uj:.0f} uJ in {inner.chip_time_s:.3f}s")


def test_continuous_shares_accounting_with_inner():
    """variant_dispatches is ONE dict: the inner policy counts, the outer
    reports — downshift_ratio and ServeStats see the same totals."""
    ctx = _family_context(batch=2)
    pol = ContinuousPolicy(inner=OperatingPointPolicy(budget_uj_s=1e-6))
    pol.bind(ctx)
    assert pol.variant_dispatches is pol.inner.variant_dispatches
    queue = FrameQueue(ctx.lanes)
    for rid in range(4):
        queue.submit(FrameRequest(rid=rid, program="cifar10", frame=None))
    while pol.select(queue) is not None:
        pass
    assert pol.variant_dispatches["cifar9_s4t"] > 0   # floor-pinned
    assert pol.downshift_ratio() == 1.0               # read through outer


# ---------------------------------------------------------------------------
# 4. End-to-end: family serving through ChipServer
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def cifar_family_setup():
    progs = {"cifar9_s4": networks.cifar9(4),
             "cifar9_s4t": networks.cifar9_truncated()}
    arts = {n: _artifact(p, seed=i) for i, (n, p) in enumerate(progs.items())}
    frames = _frames(progs["cifar9_s4"], 6, seed=5)
    oracle = {n: _offline(progs[n], arts[n], frames) for n in progs}
    return progs, arts, frames, oracle


def test_family_serving_bit_exact_per_chosen_variant(cifar_family_setup):
    """Controller-served results carry the variant that ran them, and
    every label/logit row is bit-exact vs that variant's offline forward
    on the same frame — for an unconstrained and a floor-pinned run."""
    progs, arts, frames, oracle = cifar_family_setup
    for budget, want in ((None, {"cifar9_s4"}), (1e-6, {"cifar9_s4t"})):
        server = ChipServer(progs, arts, batch=2, interpret=True,
                            families={"cifar10": tuple(progs)},
                            budget_uj_s=budget)
        rids = server.submit_many("cifar10", frames)
        results = server.drain()
        assert [r.rid for r in results] == rids
        assert {r.variant for r in results} == want
        assert all(r.program == "cifar10" for r in results)
        for i, r in enumerate(results):
            np.testing.assert_array_equal(r.logits, oracle[r.variant][0][i])
            assert r.label == oracle[r.variant][1][i]
        stats = server.stats()
        assert stats.policy == "operating-point"
        assert stats.served == {"cifar10": len(frames)}
        assert stats.downshift_ratio == (0.0 if budget is None else 1.0)
        # utilization reflects the chosen width (both variants are S=4)
        assert stats.array_utilization == pytest.approx(0.25)


def test_family_serving_mixed_budget_downshifts(cifar_family_setup):
    """A budget between the two variants' powers serves a mix: both
    variants dispatch, the average power stays under budget, every
    result still bit-exact vs its chosen variant."""
    progs, arts, frames, oracle = cifar_family_setup
    reps = {n: energy.analyze_net(p) for n, p in progs.items()}
    powers = sorted(r.power_w * 1e6 for r in reps.values())
    budget = (powers[0] + powers[1]) / 2
    server = ChipServer(progs, arts, batch=1, interpret=True,
                        families={"cifar10": tuple(progs)},
                        budget_uj_s=budget)
    server.submit_many("cifar10", frames)
    results = server.drain()
    for i, r in enumerate(results):
        assert r.label == oracle[r.variant][1][i]
    stats = server.stats()
    assert 0.0 < stats.downshift_ratio < 1.0
    assert set(v for v, n in stats.variant_dispatches.items() if n) == \
        set(progs)
    # the committed average power respects the budget (one-dispatch slack)
    pol = server.policy
    max_e = max(1 * r.i2l_energy_per_inference * 1e6 for r in reps.values())
    assert pol.spent_uj <= budget * pol.chip_time_s + max_e


def test_controller_shared_composites_bit_exact():
    """Four single-variant S=4 family lanes under the shared controller
    co-dispatch as ONE on-the-fly composite — bit-exact vs offline, with
    full array utilization."""
    progs = {"a": networks.mnist5(), "b": networks.mnist5(classes=2),
             "c": networks.mnist5(classes=3), "d": networks.mnist5(classes=5)}
    arts = {n: _artifact(p, seed=10 + i)
            for i, (n, p) in enumerate(progs.items())}
    frames = {n: _frames(p, 2, seed=20 + i)
              for i, (n, p) in enumerate(progs.items())}
    oracle = {n: _offline(progs[n], arts[n], frames[n])[1] for n in progs}
    server = ChipServer(progs, arts, batch=2, interpret=True, shared=True,
                        policy="operating-point",
                        families={f"fam_{n}": (n,) for n in progs})
    for n in progs:
        server.submit_many(f"fam_{n}", frames[n])
    results = server.drain()
    stats = server.stats()
    assert stats.shared_dispatches == 1 and stats.dispatches == 1
    assert stats.array_utilization == pytest.approx(1.0)
    for n in progs:
        got = [r.label for r in sorted(results, key=lambda r: r.rid)
               if r.variant == n]
        np.testing.assert_array_equal(np.array(got), oracle[n], err_msg=n)


def test_policy_rebinding_resets_committed_state():
    """Reusing a policy instance on a fresh server must not carry the
    previous server's committed energy/time (or a stale backlog
    threshold) into budget decisions."""
    pol = OperatingPointPolicy(budget_uj_s=1e12)
    pol.bind(_family_context(batch=2))
    queue = FrameQueue(pol.ctx.lanes)
    queue.submit(FrameRequest(rid=0, program="cifar10", frame=None))
    pol.select(queue)
    assert pol.spent_uj > 0
    pol.bind(_family_context(batch=4))
    assert pol.spent_uj == 0.0 and pol.chip_time_s == 0.0
    assert pol._backlog_high == 16                 # 4 * new batch


def test_operating_points_partial_anchors_use_consistent_proxy():
    """A partially-anchored family must not mix real accuracies with the
    raw ops proxy in one sort — the whole family falls back to the
    proxy scale."""
    progs = {"s4": networks.cifar9(4), "s4t": networks.cifar9_truncated()}
    pts = energy.operating_points(progs, {"s4": 0.785})   # s4t unanchored
    assert [p.name for p in pts] == ["s4", "s4t"]
    assert pts[0].accuracy > 1.0                   # proxy scale throughout


def test_custom_policy_instance_is_accepted():
    """A user-supplied DispatchPolicy drives dispatch; ServeStats reports
    its name and per-variant dispatch counts."""

    class CheapestFirst(DispatchPolicy):
        name = "cheapest-first"

        def select(self, queue):
            inner = StaticPolicy()
            inner.ctx = self.ctx
            inner.variant_dispatches = self.variant_dispatches
            return inner.select(queue)

    program = networks.mnist5()
    server = ChipServer({"m": program}, {"m": _artifact(program)},
                        batch=2, interpret=True, policy=CheapestFirst())
    server.submit_many("m", _frames(program, 3))
    assert len(server.drain()) == 3
    stats = server.stats()
    assert stats.policy == "cheapest-first"
    assert stats.variant_dispatches["m"] == 2


def test_server_guards_families():
    progs = {"cifar9_s4": networks.cifar9(4),
             "cifar9_s4t": networks.cifar9_truncated()}
    arts = {n: _artifact(p) for n, p in progs.items()}
    with pytest.raises(ValueError, match="collides"):
        ChipServer(progs, arts, interpret=True,
                   families={"cifar9_s4": ("cifar9_s4t",)})
    with pytest.raises(ValueError, match="not resident"):
        ChipServer(progs, arts, interpret=True,
                   families={"f": ("ghost",)})
    with pytest.raises(ValueError, match="belongs to families"):
        ChipServer(progs, arts, interpret=True,
                   families={"f": ("cifar9_s4",), "g": ("cifar9_s4",)})
    with pytest.raises(ValueError, match="policy"):
        ChipServer(progs, arts, interpret=True, policy="static",
                   families={"f": tuple(progs)})
    with pytest.raises(ValueError, match="unknown policy"):
        ChipServer(progs, arts, interpret=True, policy="zigzag")

"""Checkpoint/restore (+async, elastic), preemption, stragglers, retry."""

import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs.registry import get_config
from repro.data import tokens as dtok
from repro.distributed import fault
from repro.optim import optimizers as opt
from repro.train import steps


def _state(cfg, seed=0):
    optimizer = opt.make("adamw", lambda s: 1e-3)
    return steps.create_state(cfg, jax.random.PRNGKey(seed), optimizer), optimizer


def test_save_restore_roundtrip(tmp_path):
    cfg = get_config("smollm-360m").scaled().with_(dtype="float32",
                                                   param_dtype="float32")
    state, _ = _state(cfg)
    path = os.path.join(tmp_path, "ckpt_1")
    ckpt.save(path, state, step=1)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = ckpt.restore(path, like)
    for (p1, a), (p2, b) in zip(
            jax.tree_util.tree_flatten_with_path(state)[0],
            jax.tree_util.tree_flatten_with_path(restored)[0]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_detects_shape_mismatch(tmp_path):
    cfg = get_config("smollm-360m").scaled()
    state, _ = _state(cfg)
    path = os.path.join(tmp_path, "c")
    ckpt.save(path, {"x": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(path, {"x": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_training_resumes_identically(tmp_path):
    """crash/restart: resumed run == uninterrupted run (bitwise params)."""
    cfg = get_config("smollm-360m").scaled().with_(
        dtype="float32", param_dtype="float32", loss_chunk=32)
    optimizer = opt.make("adamw", lambda s: 1e-3)
    train_step = jax.jit(steps.build_train_step(cfg, optimizer))

    def batch(s):
        return dtok.batch_for_step(cfg, s, global_batch=4, seq_len=32)

    # uninterrupted 6 steps
    s1 = steps.create_state(cfg, jax.random.PRNGKey(0), optimizer)
    for i in range(6):
        s1, _ = train_step(s1, batch(i))

    # interrupted at 3, checkpointed, restored, resumed (data is step-pure)
    s2 = steps.create_state(cfg, jax.random.PRNGKey(0), optimizer)
    for i in range(3):
        s2, _ = train_step(s2, batch(i))
    path = os.path.join(tmp_path, "ckpt_3")
    ckpt.save(path, s2, step=3)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), s2)
    s2r = ckpt.restore(path, like)
    for i in range(3, 6):
        s2r, _ = train_step(s2r, batch(i))

    a = jax.tree.leaves(s1["params"])
    b = jax.tree.leaves(s2r["params"])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_async_checkpointer(tmp_path):
    cfg = get_config("smollm-360m").scaled()
    state, _ = _state(cfg)
    ac = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for step in (1, 2, 3):
        ac.save(state, step)
    ac.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert len(files) == 2  # GC keeps last 2


def test_elastic_restore_onto_mesh(tmp_path):
    """restore with mesh+specs places leaves as NamedSharding (1-dev mesh)."""
    cfg = get_config("smollm-360m").scaled().with_(dtype="float32",
                                                   param_dtype="float32")
    state, optimizer = _state(cfg)
    path = os.path.join(tmp_path, "ckpt_e")
    ckpt.save(path, state, step=0)
    mesh = ckpt.make_mesh((1, 1), ("data", "model"))
    specs = steps.state_specs(cfg, mesh, optimizer)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = ckpt.restore(path, like, mesh=mesh, specs=specs)
    leaf = jax.tree.leaves(restored["params"])[0]
    assert isinstance(leaf.sharding, jax.sharding.NamedSharding)


def test_restore_after_fault_rebuilds_mesh(tmp_path):
    """Regression for the exact seed failure: the restart path built its
    mesh via a JAX API surface (``jax.make_mesh(..., axis_types=...)`` /
    ``jax.sharding.AxisType``) that this runtime doesn't have, so recovery
    died *in the mesh constructor* before touching the checkpoint.  The
    restore-after-fault path must (a) rebuild a mesh with only
    version-stable APIs, (b) restore the latest checkpoint onto it
    bitwise, (c) refuse meshes larger than the surviving device set."""
    cfg = get_config("smollm-360m").scaled().with_(dtype="float32",
                                                   param_dtype="float32")
    state, optimizer = _state(cfg)
    ac = ckpt.AsyncCheckpointer(str(tmp_path))
    ac.save(state, step=7)
    ac.wait()

    # simulated fault -> restart: rediscover latest step, rebuild the mesh
    # on the surviving topology, restore onto it.
    g = fault.PreemptionGuard(install=False)
    g._handler(15, None)
    assert g.requested
    step = ckpt.latest_step(str(tmp_path))
    assert step == 7
    mesh = ckpt.make_mesh((1, 1), ("data", "model"))
    specs = steps.state_specs(cfg, mesh, optimizer)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = ckpt.restore(os.path.join(tmp_path, f"ckpt_{step}"), like,
                            mesh=mesh, specs=specs)
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    leaf = jax.tree.leaves(restored["params"])[0]
    assert isinstance(leaf.sharding, jax.sharding.NamedSharding)

    # a mesh wider than the surviving devices must fail loudly, not hang
    with pytest.raises(ValueError, match="devices"):
        ckpt.make_mesh((max(2, jax.device_count() + 1), 1),
                       ("data", "model"))


# ---------------------------------------------------------------------------
# Fault-tolerance utilities
# ---------------------------------------------------------------------------

def test_step_timer_detects_straggler():
    t = fault.StepTimer(window=20, threshold=2.5)
    for _ in range(8):
        with t:
            time.sleep(0.005)
    assert t.stragglers == 0
    with t:
        time.sleep(0.1)
    assert t.stragglers == 1 and t.slow


def test_retry_step_recovers():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient collective failure")
        return x + 1

    assert fault.retry_step(flaky, 41, retries=3) == 42
    assert calls["n"] == 3


def test_retry_step_gives_up():
    def always(x):
        raise RuntimeError("hard failure")

    with pytest.raises(RuntimeError):
        fault.retry_step(always, 0, retries=2)


def test_retry_step_exponential_backoff():
    """Regression pin for the no-backoff bug: failed attempt k waits
    backoff_s * factor**k (capped), through the injected sleep, and the
    attempt count surfaces via the stats out-dict."""
    slept, calls, stats = [], {"n": 0}, {}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 4:
            raise RuntimeError("transient")
        return x

    retried = []
    out = fault.retry_step(flaky, 7, retries=5, backoff_s=0.1,
                           backoff_factor=2.0, max_backoff_s=0.25,
                           sleep=slept.append, stats=stats,
                           on_retry=lambda a, d: retried.append((a, d)))
    assert out == 7
    assert slept == pytest.approx([0.1, 0.2, 0.25])   # capped at max
    assert retried == [(0, 0.1), (1, pytest.approx(0.2)), (2, 0.25)]
    assert stats["attempts"] == 4
    assert stats["backoff_s"] == pytest.approx(0.55)


def test_retry_step_default_is_immediate():
    """backoff_s=0.0 (the default) keeps the old immediate-retry path:
    the injected sleep is never called."""
    slept, calls = [], {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 2:
            raise RuntimeError("transient")
        return x

    assert fault.retry_step(flaky, 1, retries=2, sleep=slept.append) == 1
    assert slept == []


def test_retry_step_rejects_bad_backoff():
    with pytest.raises(ValueError):
        fault.retry_step(lambda: 0, backoff_s=-1.0)
    with pytest.raises(ValueError):
        fault.retry_step(lambda: 0, backoff_factor=0.5)


def test_preemption_guard_flag():
    g = fault.PreemptionGuard(install=False)
    assert not g.requested
    g._handler(15, None)
    assert g.requested

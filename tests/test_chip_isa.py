"""ISA constraint checks + assemble/disassemble round-trip."""

import numpy as np
import pytest

from repro.core.chip import isa, networks


def test_benchmark_nets_validate():
    for name, build in networks.REGISTRY.items():
        isa.validate(build())


def test_cifar9_matches_paper_footprints():
    """The published SRAM sizes pin the 9-layer topology."""
    p = networks.cifar9(1)
    geoms = isa.layer_geometry(p)
    conv_bits = sum(i.features * c * 4 for (i, _, _, c, *_r) in geoms
                    if isinstance(i, isa.ConvInstr))
    # 8 x 256x256x2x2 bits = 262 kB vs 259 kB weight SRAM (within 1.2%)
    assert conv_bits == 8 * 256 * 256 * 4
    assert conv_bits <= isa.WEIGHT_SRAM_BITS
    # feature maps fit the 32 kB per-side activation SRAM exactly
    assert 32 * 32 * 256 == isa.FEATURE_SRAM_BITS
    # program fits the 16-slot instruction memory
    assert len(p.instrs) == 10


@pytest.mark.parametrize("s", [1, 2, 4])
def test_assemble_roundtrip(s):
    p = networks.cifar9(s)
    words = isa.assemble(p)
    assert words.shape == (isa.MAX_INSTRUCTIONS,)
    assert words.dtype == np.uint32
    q = isa.disassemble(words, s=s)
    assert q == p


def test_rejects_bad_s():
    with pytest.raises(isa.ProgramError):
        isa.validate(isa.Program(s=3, instrs=networks.cifar9(1).instrs))


def test_rejects_too_many_instructions():
    base = networks.cifar9(4)
    pad = tuple(isa.FCInstr(in_features=64, out_features=64)
                for _ in range(12))
    bad = isa.Program(s=4, instrs=base.instrs[:-1] + pad
                      + (isa.FCInstr(64, 10, final=True),))
    with pytest.raises(isa.ProgramError, match="16 instructions"):
        isa.validate(bad)


def test_rejects_wrong_width_for_mode():
    instrs = (isa.IOInstr(height=8, width=8, channels=256),
              isa.ConvInstr(height=8, width=8, features=128),
              isa.FCInstr(in_features=7 * 7 * 128, out_features=10, final=True))
    with pytest.raises(isa.ProgramError, match="256/S"):
        isa.validate(isa.Program(s=1, instrs=instrs))


def test_rejects_too_many_classes():
    p = networks.cifar9(4)
    bad = isa.Program(s=4, instrs=p.instrs[:-1]
                      + (isa.FCInstr(in_features=256, out_features=11, final=True),))
    with pytest.raises(isa.ProgramError, match="classes"):
        isa.validate(bad)


def test_rejects_oversized_input():
    instrs = (isa.IOInstr(height=40, width=40, channels=256),)
    with pytest.raises(isa.ProgramError):
        isa.validate(isa.Program(s=1, instrs=instrs))


def test_rejects_shape_chain_mismatch():
    instrs = (isa.IOInstr(height=16, width=16, channels=256),
              isa.ConvInstr(height=14, width=14, features=256),
              isa.FCInstr(in_features=13 * 13 * 256, out_features=10, final=True))
    with pytest.raises(isa.ProgramError, match="pipeline provides"):
        isa.validate(isa.Program(s=1, instrs=instrs))


def test_rejects_fc_sram_overflow():
    instrs = (isa.IOInstr(height=8, width=8, channels=256),
              isa.ConvInstr(height=8, width=8, features=256),
              isa.FCInstr(in_features=7 * 7 * 256, out_features=8, final=False),
              isa.FCInstr(in_features=8, out_features=8, final=True))
    with pytest.raises(isa.ProgramError, match="FC SRAM"):
        isa.validate(isa.Program(s=1, instrs=instrs))

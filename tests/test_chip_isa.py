"""ISA constraint checks + assemble/disassemble round-trip."""

import numpy as np
import pytest

from repro.core.chip import isa, networks


def test_benchmark_nets_validate():
    for name, build in networks.REGISTRY.items():
        isa.validate(build())


def test_cifar9_matches_paper_footprints():
    """The published SRAM sizes pin the 9-layer topology."""
    p = networks.cifar9(1)
    geoms = isa.layer_geometry(p)
    conv_bits = sum(i.features * c * 4 for (i, _, _, c, *_r) in geoms
                    if isinstance(i, isa.ConvInstr))
    # 8 x 256x256x2x2 bits = 262 kB vs 259 kB weight SRAM (within 1.2%)
    assert conv_bits == 8 * 256 * 256 * 4
    assert conv_bits <= isa.WEIGHT_SRAM_BITS
    # feature maps fit the 32 kB per-side activation SRAM exactly
    assert 32 * 32 * 256 == isa.FEATURE_SRAM_BITS
    # program fits the 16-slot instruction memory
    assert len(p.instrs) == 10


@pytest.mark.parametrize("s", [1, 2, 4])
def test_assemble_roundtrip(s):
    p = networks.cifar9(s)
    words = isa.assemble(p)
    assert words.shape == (isa.MAX_INSTRUCTIONS,)
    assert words.dtype == np.uint32
    q = isa.disassemble(words, s=s)
    assert q == p


@pytest.mark.parametrize("name", sorted(networks.REGISTRY))
def test_assemble_roundtrip_every_registry_program(name):
    """Program memory round-trips every benchmark net exactly — in
    particular mnist5, whose 64-wide hidden FC overflowed the original
    4-bit out_features field."""
    p = networks.REGISTRY[name]()
    q = isa.disassemble(isa.assemble(p), s=p.s)
    assert q == p
    # the re-decoded program must still satisfy every hardware constraint
    isa.validate(q)


@pytest.mark.parametrize("out", [1, 15, 16, 64, 256, isa._FC_OUT_MAX])
def test_fc_out_field_width(out):
    """The widened FC out field holds every width the array can produce
    (up to the full 256-channel hidden layer) without corruption."""
    word = np.uint32(isa._OP_FC | 64 << 14 | out << 2 | 1 << 25)
    ins = isa.disassemble(np.array([word], np.uint32), s=4).instrs[0]
    assert isinstance(ins, isa.FCInstr)
    assert ins.out_features == out
    assert ins.in_features == 64 and ins.final


def test_fc_field_range_checks_fire():
    """assemble range-checks the FC fields before packing the word."""
    ok = (isa.IOInstr(height=5, width=5, channels=64),
          isa.ConvInstr(height=5, width=5, features=64),
          isa.FCInstr(in_features=4 * 4 * 64, out_features=10, final=True))
    isa.assemble(isa.Program(s=4, instrs=ok))  # sanity: encodable
    with pytest.raises(isa.ProgramError, match="out_features"):
        isa._encode_instr(isa.FCInstr(in_features=64,
                                      out_features=isa._FC_OUT_MAX + 1,
                                      final=True))
    with pytest.raises(isa.ProgramError, match="in_features"):
        isa._encode_instr(isa.FCInstr(in_features=isa._FC_IN_MAX + 1,
                                      out_features=10, final=True))


def test_rejects_bad_s():
    with pytest.raises(isa.ProgramError):
        isa.validate(isa.Program(s=3, instrs=networks.cifar9(1).instrs))


def test_rejects_too_many_instructions():
    base = networks.cifar9(4)
    pad = tuple(isa.FCInstr(in_features=64, out_features=64)
                for _ in range(12))
    bad = isa.Program(s=4, instrs=base.instrs[:-1] + pad
                      + (isa.FCInstr(64, 10, final=True),))
    with pytest.raises(isa.ProgramError, match="16 instructions"):
        isa.validate(bad)


def test_rejects_wrong_width_for_mode():
    instrs = (isa.IOInstr(height=8, width=8, channels=256),
              isa.ConvInstr(height=8, width=8, features=128),
              isa.FCInstr(in_features=7 * 7 * 128, out_features=10, final=True))
    with pytest.raises(isa.ProgramError, match="256/S"):
        isa.validate(isa.Program(s=1, instrs=instrs))


def test_rejects_too_many_classes():
    p = networks.cifar9(4)
    bad = isa.Program(s=4, instrs=p.instrs[:-1]
                      + (isa.FCInstr(in_features=256, out_features=11, final=True),))
    with pytest.raises(isa.ProgramError, match="classes"):
        isa.validate(bad)


def test_rejects_oversized_input():
    instrs = (isa.IOInstr(height=40, width=40, channels=256),)
    with pytest.raises(isa.ProgramError):
        isa.validate(isa.Program(s=1, instrs=instrs))


def test_rejects_shape_chain_mismatch():
    instrs = (isa.IOInstr(height=16, width=16, channels=256),
              isa.ConvInstr(height=14, width=14, features=256),
              isa.FCInstr(in_features=13 * 13 * 256, out_features=10, final=True))
    with pytest.raises(isa.ProgramError, match="pipeline provides"):
        isa.validate(isa.Program(s=1, instrs=instrs))


def test_rejects_fc_sram_overflow():
    instrs = (isa.IOInstr(height=8, width=8, channels=256),
              isa.ConvInstr(height=8, width=8, features=256),
              isa.FCInstr(in_features=7 * 7 * 256, out_features=8, final=False),
              isa.FCInstr(in_features=8, out_features=8, final=True))
    with pytest.raises(isa.ProgramError, match="FC SRAM"):
        isa.validate(isa.Program(s=1, instrs=instrs))

"""Training substrate: learning, optimizers, data determinism, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data import tokens as dtok
from repro.optim import grad_compress, optimizers as opt
from repro.train import steps


def _run(cfg, optimizer, n_steps, seed=0):
    state = steps.create_state(cfg, jax.random.PRNGKey(seed), optimizer)
    train_step = jax.jit(steps.build_train_step(cfg, optimizer))
    losses = []
    for s in range(n_steps):
        batch = dtok.batch_for_step(cfg, s, global_batch=8, seq_len=64)
        state, m = train_step(state, batch)
        losses.append(float(m["loss"]))
    return losses, state


@pytest.mark.slow
def test_lm_training_learns():
    cfg = get_config("smollm-360m").scaled().with_(
        dtype="float32", param_dtype="float32", loss_chunk=32)
    optimizer = opt.make("adamw", opt.cosine_schedule(3e-3, 10, 200))
    losses, _ = _run(cfg, optimizer, 40)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] - 0.8, (losses[0], losses[-1])


@pytest.mark.slow
def test_binary_lm_training_learns():
    """BinaryNet (the paper's technique) trains via STE at LM scale."""
    cfg = get_config("smollm-360m").scaled().with_(
        dtype="float32", param_dtype="float32", loss_chunk=32, quant="binary")
    optimizer = opt.make("adamw", opt.cosine_schedule(3e-3, 10, 200))
    losses, _ = _run(cfg, optimizer, 40)
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_loss_chunking_invariance():
    """chunked CE must not depend on the chunk size."""
    cfg = get_config("smollm-360m").scaled().with_(
        dtype="float32", param_dtype="float32")
    key = jax.random.PRNGKey(0)
    from repro.models import transformer
    params = transformer.init_params(key, cfg)
    batch = dtok.batch_for_step(cfg, 0, global_batch=4, seq_len=64)
    losses = []
    for chunk in (16, 32, 64):
        c = cfg.with_(loss_chunk=chunk)
        loss, _ = steps.make_loss_fn(c)(params, batch)
        losses.append(float(loss))
    np.testing.assert_allclose(losses, losses[0], rtol=1e-5)


# ---------------------------------------------------------------------------
# Optimizers
# ---------------------------------------------------------------------------

def _quad_losses(optimizer, n=60):
    """Minimize ||Wx - y||^2; return loss trace."""
    key = jax.random.PRNGKey(0)
    target = jax.random.normal(key, (16, 16))
    params = {"w": jnp.zeros((16, 16)), "b": jnp.zeros((16,))}
    state = optimizer.init(params)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
    y = x @ target.T

    def loss_fn(p):
        return jnp.mean((x @ p["w"].T + p["b"] - y) ** 2)

    losses = []
    step = jnp.zeros((), jnp.int32)
    for i in range(n):
        l, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = optimizer.update(g, state, params, step)
        step = step + 1
        losses.append(float(l))
    return losses


@pytest.mark.parametrize("name,kw", [
    ("adamw", dict(weight_decay=0.0)),
    ("adafactor", dict(min_dim_size_to_factor=8)),
    ("sgdm", dict()),
])
def test_optimizer_converges(name, kw):
    optimizer = opt.make(name, lambda s: 3e-2, **kw)
    losses = _quad_losses(optimizer)
    assert losses[-1] < losses[0] * 0.05, (name, losses[0], losses[-1])


def test_adafactor_state_is_factored():
    optimizer = opt.make("adafactor", lambda s: 1e-3)
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4,))}
    st = optimizer.init(params)
    assert set(st["v"]["big"]) == {"vr", "vc"}
    assert st["v"]["big"]["vr"].shape == (256,)
    assert st["v"]["big"]["vc"].shape == (512,)
    assert set(st["v"]["small"]) == {"v"}


def test_cosine_schedule_shape():
    lr = opt.cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) < 0.2
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 0.11
    assert float(lr(jnp.int32(99))) < 0.2


def test_global_norm_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 20.0) < 1e-4
    assert abs(float(opt.global_norm(clipped)) - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# Data determinism
# ---------------------------------------------------------------------------

def test_data_is_deterministic_per_step():
    cfg = get_config("smollm-360m").scaled()
    a = dtok.batch_for_step(cfg, 7, global_batch=4, seq_len=32)
    b = dtok.batch_for_step(cfg, 7, global_batch=4, seq_len=32)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = dtok.batch_for_step(cfg, 8, global_batch=4, seq_len=32)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_data_labels_are_shifted_stream():
    cfg = get_config("smollm-360m").scaled()
    b = dtok.batch_for_step(cfg, 0, global_batch=2, seq_len=16)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


def test_data_host_sharding_disjoint():
    cfg = get_config("smollm-360m").scaled()
    h0 = dtok.batch_for_step(cfg, 3, global_batch=8, seq_len=16,
                             host_id=0, num_hosts=2)
    h1 = dtok.batch_for_step(cfg, 3, global_batch=8, seq_len=16,
                             host_id=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not np.array_equal(np.asarray(h0["tokens"]),
                              np.asarray(h1["tokens"]))


# ---------------------------------------------------------------------------
# Gradient compression
# ---------------------------------------------------------------------------

def test_compress_roundtrip_error_feedback():
    g = jax.random.normal(jax.random.PRNGKey(0), (128,))
    err = jnp.zeros_like(g)
    q, scale, err1 = grad_compress.compress(g, err)
    deq = grad_compress.decompress(q, scale)
    # int8: coarse but unbiased-ish; residual captured exactly
    np.testing.assert_allclose(np.asarray(deq + err1), np.asarray(g),
                               rtol=1e-6, atol=1e-6)
    assert float(jnp.abs(err1).max()) <= float(scale) * 0.5 + 1e-9


def test_error_feedback_converges():
    """Accumulated dequantized updates approach the true gradient sum."""
    key = jax.random.PRNGKey(1)
    true_g = jax.random.normal(key, (64,)) * 0.01
    err = jnp.zeros_like(true_g)
    acc = jnp.zeros_like(true_g)
    for _ in range(50):
        q, scale, err = grad_compress.compress(true_g, err)
        acc = acc + grad_compress.decompress(q, scale)
    np.testing.assert_allclose(np.asarray(acc / 50), np.asarray(true_g),
                               rtol=0.02, atol=1e-5)

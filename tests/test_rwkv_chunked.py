"""Chunked GLA-style WKV (perf path) must match the per-token scan oracle.

The chunked form is the §Perf optimization for the rwkv6 train/prefill
cells (state HBM round-trips /chunk, intra-chunk work on the MXU); it must
be numerically equivalent on realistic decay ranges, including carried
state across calls and the bonus-u diagonal term.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import rwkv6


def _scan_oracle(rh, kh, vh, wh, u, S0):
    def step(S, inp):
        rt, kt, vt, wt = inp
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", rt, S + u[None, :, :, None] * kv)
        S = wt[..., :, None] * S + kv
        return S, y
    S, ys = jax.lax.scan(step, S0, (rh.transpose(1, 0, 2, 3),
                                    kh.transpose(1, 0, 2, 3),
                                    vh.transpose(1, 0, 2, 3),
                                    wh.transpose(1, 0, 2, 3)))
    return S, ys.transpose(1, 0, 2, 3)


def _rand_inputs(key, b, s, h, hs, w_lo=0.6):
    ks = jax.random.split(key, 5)
    rh = jax.random.normal(ks[0], (b, s, h, hs), jnp.float32)
    kh = jax.random.normal(ks[1], (b, s, h, hs), jnp.float32)
    vh = jax.random.normal(ks[2], (b, s, h, hs), jnp.float32)
    wh = jax.random.uniform(ks[3], (b, s, h, hs), jnp.float32, w_lo, 0.9999)
    u = jax.random.normal(ks[4], (h, hs), jnp.float32) * 0.3
    return rh, kh, vh, wh, u


@pytest.mark.parametrize("chunk", [4, 16, 32])
def test_chunked_matches_scan(chunk):
    b, s, h, hs = 2, 64, 3, 8
    rh, kh, vh, wh, u = _rand_inputs(jax.random.PRNGKey(0), b, s, h, hs)
    S0 = jax.random.normal(jax.random.PRNGKey(9), (b, h, hs, hs)) * 0.1
    S_ref, y_ref = _scan_oracle(rh, kh, vh, wh, u, S0)
    S_c, y_c = rwkv6._wkv_chunked(rh, kh, vh, wh, u, S0, chunk)
    np.testing.assert_allclose(y_c, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S_c, S_ref, rtol=2e-4, atol=2e-4)


def test_chunked_strong_decay_clamp_benign():
    """Aggressive decay (channels past e^-20 within a chunk) must match the
    scan: the chunked path forms the pairwise exponent la_{c-1} - la_s
    directly (always <= 0), so there is no overflow and no clamp — the seed's
    la clamp at -20 made these channels wrong by ~0.1."""
    b, s, h, hs = 1, 32, 2, 4
    rh, kh, vh, wh, u = _rand_inputs(jax.random.PRNGKey(1), b, s, h, hs,
                                     w_lo=0.05)   # aggressive decay
    S0 = jnp.zeros((b, h, hs, hs))
    S_ref, y_ref = _scan_oracle(rh, kh, vh, wh, u, S0)
    S_c, y_c = rwkv6._wkv_chunked(rh, kh, vh, wh, u, S0, 16)
    np.testing.assert_allclose(y_c, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S_c, S_ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=15, deadline=None)
@given(chunk=st.sampled_from([4, 8, 16]),
       nchunks=st.integers(1, 4),
       seed=st.integers(0, 2**16))
def test_chunked_matches_scan_property(chunk, nchunks, seed):
    b, h, hs = 1, 2, 4
    s = chunk * nchunks
    rh, kh, vh, wh, u = _rand_inputs(jax.random.PRNGKey(seed), b, s, h, hs)
    S0 = jnp.zeros((b, h, hs, hs))
    S_ref, y_ref = _scan_oracle(rh, kh, vh, wh, u, S0)
    S_c, y_c = rwkv6._wkv_chunked(rh, kh, vh, wh, u, S0, chunk)
    np.testing.assert_allclose(y_c, y_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(S_c, S_ref, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("sub", [4, 8, 16, 32, 7])
def test_sub_chunked_matches_scan(sub):
    """FLA-style sub-chunking (cross-sub-chunk decay as rebased matmuls,
    exact pairwise einsum only inside a sub-chunk) must match the scan on
    any divisor — and fall back to the exact form on a non-divisor (7)."""
    b, s, h, hs = 2, 64, 3, 8
    chunk = 32
    rh, kh, vh, wh, u = _rand_inputs(jax.random.PRNGKey(2), b, s, h, hs)
    S0 = jax.random.normal(jax.random.PRNGKey(8), (b, h, hs, hs)) * 0.1
    S_ref, y_ref = _scan_oracle(rh, kh, vh, wh, u, S0)
    S_c, y_c = rwkv6._wkv_chunked(rh, kh, vh, wh, u, S0, chunk, sub_chunk=sub)
    np.testing.assert_allclose(y_c, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(S_c, S_ref, rtol=2e-4, atol=2e-4)


def test_sub_chunked_strong_decay_no_overflow():
    """Channels decaying past e^-88 *within one chunk* — the regime where
    the naive factored matmul form produces inf/NaN.  The rebased
    sub-chunk factors are all <= 1, so the result stays finite and
    matches the scan (this is the case that forced the seed's clamp)."""
    b, s, h, hs = 1, 64, 2, 4
    rh, kh, vh, wh, u = _rand_inputs(jax.random.PRNGKey(3), b, s, h, hs,
                                     w_lo=0.01)    # e^-4.6 per step
    S0 = jnp.zeros((b, h, hs, hs))
    S_ref, y_ref = _scan_oracle(rh, kh, vh, wh, u, S0)
    for sub in (4, 16):
        S_c, y_c = rwkv6._wkv_chunked(rh, kh, vh, wh, u, S0, 64,
                                      sub_chunk=sub)
        assert np.all(np.isfinite(np.asarray(y_c)))
        np.testing.assert_allclose(y_c, y_ref, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(S_c, S_ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=12, deadline=None)
@given(chunk=st.sampled_from([8, 16, 32]),
       sub=st.sampled_from([2, 4, 8, 16]),
       nchunks=st.integers(1, 3),
       seed=st.integers(0, 2 ** 16))
def test_sub_chunked_matches_scan_property(chunk, sub, nchunks, seed):
    b, h, hs = 1, 2, 4
    s = chunk * nchunks
    rh, kh, vh, wh, u = _rand_inputs(jax.random.PRNGKey(seed), b, s, h, hs,
                                     w_lo=0.2)
    S0 = jnp.zeros((b, h, hs, hs))
    S_ref, y_ref = _scan_oracle(rh, kh, vh, wh, u, S0)
    S_c, y_c = rwkv6._wkv_chunked(rh, kh, vh, wh, u, S0, chunk,
                                  sub_chunk=sub)
    np.testing.assert_allclose(y_c, y_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(S_c, S_ref, rtol=3e-4, atol=3e-4)


def test_time_mix_chunk_flag_end_to_end():
    """time_mix(chunk=16) == time_mix(scan) through the full block path."""
    import dataclasses
    from repro.configs.registry import get_config
    cfg = get_config("rwkv6-3b").scaled().with_(dtype="float32",
                                                param_dtype="float32")
    cfg_c = cfg.with_(rwkv=dataclasses.replace(cfg.rwkv, chunk=16))
    key = jax.random.PRNGKey(3)
    p = rwkv6.init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, cfg.d_model))
    y_ref, st_ref = rwkv6.time_mix(p, cfg, x)
    y_c, st_c = rwkv6.time_mix(p, cfg_c, x)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_c[1]), np.asarray(st_ref[1]),
                               rtol=2e-4, atol=2e-4)

"""Chunked (flash-style) attention vs naive oracle + causality properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import attention


def naive_attention(q, k, v, *, causal=True, window=None, softcap=None):
    b, s, h, d = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    sc = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k).astype(jnp.float32) / jnp.sqrt(d)
    if softcap is not None:
        sc = softcap * jnp.tanh(sc / softcap)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    sc = jnp.where(mask[None, :, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d)


def _qkv(key, b, s, h, kh, d):
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (b, s, h, d)),
            jax.random.normal(k2, (b, s, kh, d)),
            jax.random.normal(k3, (b, s, kh, d)))


CASES = [
    dict(b=2, s=17, h=4, kh=4, d=8, window=None, softcap=None, cq=8, ck=8),
    dict(b=1, s=64, h=8, kh=2, d=16, window=None, softcap=None, cq=16, ck=16),
    dict(b=2, s=40, h=4, kh=1, d=8, window=16, softcap=None, cq=8, ck=8),
    dict(b=1, s=33, h=2, kh=2, d=8, window=None, softcap=10.0, cq=16, ck=8),
    dict(b=2, s=24, h=6, kh=3, d=8, window=8, softcap=20.0, cq=8, ck=4),
]


@pytest.mark.parametrize("case", CASES)
def test_chunked_matches_naive(case):
    q, k, v = _qkv(jax.random.PRNGKey(0), case["b"], case["s"], case["h"],
                   case["kh"], case["d"])
    got = attention.chunked_attention(q, k, v, causal=True,
                                      window=case["window"],
                                      softcap=case["softcap"],
                                      chunk_q=case["cq"], chunk_k=case["ck"])
    want = naive_attention(q, k, v, causal=True, window=case["window"],
                           softcap=case["softcap"])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(2, 48), cq=st.sampled_from([4, 8, 16]),
       ck=st.sampled_from([4, 8, 16]), seed=st.integers(0, 10**6))
def test_chunk_size_invariance(s, cq, ck, seed):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, s, 2, 1, 8)
    a = attention.chunked_attention(q, k, v, chunk_q=cq, chunk_k=ck)
    b = attention.chunked_attention(q, k, v, chunk_q=s, chunk_k=s)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_decode_matches_last_position():
    """decode_attention on a cache == last row of full chunked attention."""
    b, s, h, kh, d = 2, 20, 4, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(3), b, s, h, kh, d)
    full = attention.chunked_attention(q, k, v, chunk_q=8, chunk_k=8)
    L = 32
    kc = jnp.zeros((b, L, kh, d)).at[:, :s].set(k)
    vc = jnp.zeros((b, L, kh, d)).at[:, :s].set(v)
    dec = attention.decode_attention(q[:, -1:], kc, vc, cache_len=s)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_decode_sliding_window():
    b, s, h, kh, d = 1, 30, 2, 1, 8
    q, k, v = _qkv(jax.random.PRNGKey(4), b, s, h, kh, d)
    w = 8
    full = attention.chunked_attention(q, k, v, window=w, chunk_q=8, chunk_k=8)
    kc = jnp.zeros((b, 32, kh, d)).at[:, :s].set(k)
    vc = jnp.zeros((b, 32, kh, d)).at[:, :s].set(v)
    dec = attention.decode_attention(q[:, -1:], kc, vc, cache_len=s, window=w)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-5, atol=2e-5)


def test_causality_property():
    """Perturbing future K/V never changes earlier outputs."""
    b, s, h, kh, d = 1, 16, 2, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(5), b, s, h, kh, d)
    out1 = attention.chunked_attention(q, k, v, chunk_q=4, chunk_k=4)
    k2 = k.at[:, 10:].set(jax.random.normal(jax.random.PRNGKey(9),
                                            k[:, 10:].shape))
    v2 = v.at[:, 10:].set(-v[:, 10:])
    out2 = attention.chunked_attention(q, k2, v2, chunk_q=4, chunk_k=4)
    np.testing.assert_allclose(np.asarray(out1[:, :10]),
                               np.asarray(out2[:, :10]), rtol=1e-6, atol=1e-6)
    assert float(jnp.abs(out1[:, 10:] - out2[:, 10:]).max()) > 1e-3


def test_probs_bf16_close_to_f32():
    """perf knob (§Perf): bf16 probs must match f32 within bf16 tolerance."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.attention import chunked_attention
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, KH, D = 2, 256, 4, 2, 16
    q = jax.random.normal(k1, (B, S, H, D), jnp.float32)
    k = jax.random.normal(k2, (B, S, KH, D), jnp.float32)
    v = jax.random.normal(k3, (B, S, KH, D), jnp.float32)
    ref = chunked_attention(q, k, v, chunk_q=64, chunk_k=64)
    got = chunked_attention(q, k, v, chunk_q=64, chunk_k=64, probs_bf16=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)

"""Unit + property tests for the binarization core."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import binarize


def test_ste_sign_forward():
    x = jnp.array([-2.0, -0.0, 0.0, 0.5, 3.0])
    out = binarize.ste_sign(x)
    np.testing.assert_array_equal(np.asarray(out), [-1, 1, 1, 1, 1])


def test_ste_sign_gradient_is_clipped_identity():
    g = jax.grad(lambda x: jnp.sum(binarize.ste_sign(x) * jnp.arange(1.0, 5.0)))(
        jnp.array([-2.0, -0.5, 0.5, 2.0]))
    # |x|>1 -> 0 grad; |x|<=1 -> passthrough of upstream (1..4)
    np.testing.assert_array_equal(np.asarray(g), [0.0, 2.0, 3.0, 0.0])


@settings(max_examples=30, deadline=None)
@given(k=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
def test_pack_unpack_roundtrip(k, seed):
    rng = np.random.default_rng(seed)
    x = rng.choice([-1.0, 1.0], size=(3, k)).astype(np.float32)
    words = binarize.pack_signs(jnp.asarray(x), axis=-1)
    assert words.shape == (3, (k + 31) // 32)
    back = binarize.unpack_signs(words, k, axis=-1)
    np.testing.assert_array_equal(np.asarray(back), x)


@settings(max_examples=30, deadline=None)
@given(k=st.integers(1, 256), seed=st.integers(0, 2**31 - 1))
def test_xnor_dot_equals_integer_dot(k, seed):
    rng = np.random.default_rng(seed)
    a = rng.choice([-1, 1], size=(k,)).astype(np.float32)
    w = rng.choice([-1, 1], size=(k,)).astype(np.float32)
    aw = binarize.pack_signs(jnp.asarray(a))
    ww = binarize.pack_signs(jnp.asarray(w))
    got = binarize.xnor_dot_popcount(aw, ww, k)
    assert int(got) == int(np.dot(a, w))


def test_pack_axis_argument():
    rng = np.random.default_rng(1)
    x = rng.choice([-1.0, 1.0], size=(64, 5)).astype(np.float32)
    words = binarize.pack_signs(jnp.asarray(x), axis=0)
    assert words.shape == (2, 5)
    back = binarize.unpack_signs(words, 64, axis=0)
    np.testing.assert_array_equal(np.asarray(back), x)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_bn_threshold_fold_equivalence(seed):
    """sign(BN(s)) == threshold comparator for integer popcount sums."""
    rng = np.random.default_rng(seed)
    n = 16
    s = rng.integers(-256, 257, size=(n,)).astype(np.float32)
    gamma = rng.normal(size=(n,)).astype(np.float32)
    gamma = np.where(np.abs(gamma) < 0.05, 0.05, gamma)  # avoid ~0 gamma
    beta = rng.normal(size=(n,)).astype(np.float32)
    mean = rng.normal(size=(n,)).astype(np.float32) * 10
    var = rng.uniform(0.5, 4.0, size=(n,)).astype(np.float32)

    bn = gamma * (s - mean) / np.sqrt(var + 1e-5) + beta
    want = np.where(bn >= 0, 1.0, -1.0)

    tau, flip = binarize.fold_bn_to_threshold(
        jnp.asarray(gamma), jnp.asarray(beta), jnp.asarray(mean), jnp.asarray(var))
    got = binarize.threshold_activation(jnp.asarray(s), tau, flip)
    # exact equality can differ only when bn == 0 exactly; tolerate none here
    mism = np.asarray(got) != want
    assert mism.sum() == 0 or np.all(np.abs(bn[mism]) < 1e-4)

"""fold_params(packed=True) round-trips, program-memory included.

Property suite (hypothesis) over *random valid* ISA programs: the packed
deployment artifact — uint32 weight words + int32 comparator thresholds,
the chip's SRAM contents — must decode back bit-exact to the float-domain
folded form it was packed from, and the program words themselves must
survive assemble -> disassemble.  Exercises the PR-1 ISA widenings on
their edges: the 10-bit FC ``out_features`` field (hidden layers wider
than the old 4-bit field), and the IO word's ``in_channels``/``bits``
fields at their encodable maxima.
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binarize
from repro.core.chip import interpreter, isa


# ---------------------------------------------------------------------------
# Random valid program generator
# ---------------------------------------------------------------------------

def random_program(s: int, seed: int) -> isa.Program:
    """A random program satisfying every hardware constraint: random IO
    precision/colors (up to the field edges), 1-4 conv layers with random
    pooling, optional hidden FCs sized within the 5 kB FC SRAM."""
    rng = random.Random(seed)
    f = isa.ARRAY_CHANNELS // s
    bits = rng.choice([1, 4, 7, 8, 15])            # 15 = _IO_BITS_MAX edge
    cin = rng.choice([1, 2, 3, 7])                 # 7 = _IO_INCH_MAX edge
    size = rng.choice([6, 8, 10, 12, 14])
    instrs = [isa.IOInstr(height=size, width=size, in_channels=cin,
                          bits=bits, channels=f)]

    weight_bits = 0
    n_conv = rng.randint(1, 4)
    for _ in range(n_conv):
        if size < 2 or weight_bits + f * f * 4 > isa.WEIGHT_SRAM_BITS:
            break
        # pool only while a next conv could still fit a 2x2 window
        pool = rng.random() < 0.5 and (size - 1) // 2 >= 2
        instrs.append(isa.ConvInstr(height=size, width=size, features=f,
                                    maxpool=pool))
        weight_bits += f * f * 4
        size = (size - 1) // 2 if pool else size - 1

    fc_budget = isa.FC_SRAM_BITS
    # keep pooling until a 2-class final FC fits the 5 kB FC SRAM *and*
    # the FC fan-in fits the 11-bit in_features instruction field
    while (size >= 2
           and (size * size * f * 2 > fc_budget
                or size * size * f > isa._FC_IN_MAX)
           and weight_bits + f * f * 4 <= isa.WEIGHT_SRAM_BITS):
        pool = (size - 1) // 2 >= 1 and size - 1 >= 2
        instrs.append(isa.ConvInstr(height=size, width=size, features=f,
                                    maxpool=pool))
        weight_bits += f * f * 4
        size = (size - 1) // 2 if pool else size - 1
    in_feat = size * size * f
    classes = rng.randint(2, isa.MAX_CLASSES)
    # optional hidden FCs — including widths past the old 4-bit field
    for width in rng.sample([f, 64, 256, 512], k=rng.randint(0, 2)):
        if in_feat * width + width * classes > fc_budget:
            continue
        instrs.append(isa.FCInstr(in_features=in_feat, out_features=width))
        fc_budget -= in_feat * width
        in_feat = width
    if in_feat * classes > fc_budget:              # shrink to fit
        classes = max(2, fc_budget // in_feat)
    instrs.append(isa.FCInstr(in_features=in_feat, out_features=classes,
                              final=True))
    p = isa.Program(s=s, instrs=tuple(instrs))
    isa.validate(p)                                # generator soundness
    return p


def _random_bn_params(program: isa.Program, seed: int):
    """init_params + randomized BN stats so tau/flip are nontrivial (both
    comparator directions, non-integer thresholds)."""
    key = jax.random.PRNGKey(seed)
    params = interpreter.init_params(key, program)
    for i, p in enumerate(params["conv"]):
        k = jax.random.fold_in(key, 1000 + i)
        ks = jax.random.split(k, 4)
        n = p["gamma"].shape
        gamma = jax.random.normal(ks[0], n)
        gamma = jnp.where(jnp.abs(gamma) < 0.05, 0.05, gamma)  # both signs
        p["gamma"] = gamma
        p["beta"] = jax.random.normal(ks[1], n)
        p["mean"] = jax.random.normal(ks[2], n) * 3.0
        p["var"] = jnp.abs(jax.random.normal(ks[3], n)) + 0.1
    return params


# ---------------------------------------------------------------------------
# The round-trip property
# ---------------------------------------------------------------------------

def _assert_roundtrip(program: isa.Program, seed: int):
    params = _random_bn_params(program, seed)
    folded = interpreter.fold_params(params, program)
    packed = interpreter.fold_params(params, program, packed=True)

    convs = [g for g in isa.layer_geometry(program)
             if isinstance(g[0], isa.ConvInstr)]
    assert len(packed["conv"]) == len(convs)
    for p, fp, (ins, _h, _w, c, *_r) in zip(packed["conv"], folded["conv"],
                                            convs):
        # weight words -> +/-1 taps, bit-exact vs the folded float form
        w_back = binarize.unpack_signs(p["w_words"], c, axis=-1)
        np.testing.assert_array_equal(
            np.asarray(w_back), np.asarray(fp["w"].reshape(ins.features, 4, c)))
        # integer comparator threshold: ceil of the folded float tau
        np.testing.assert_array_equal(
            np.asarray(p["tau"]),
            np.asarray(binarize.threshold_to_int(fp["tau"])))
        assert p["tau"].dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(p["flip"]),
                                      np.asarray(fp["flip"]).astype(np.int32))

    assert len(packed["fc"]) == len(program.fc_instrs)
    for p, fp, ins in zip(packed["fc"], folded["fc"], program.fc_instrs):
        w_back = binarize.unpack_signs(p["w_words"], ins.in_features, axis=-1)
        np.testing.assert_array_equal(np.asarray(w_back), np.asarray(fp["w"]))
        assert p["w_words"].shape == (
            ins.out_features, -(-ins.in_features // binarize.PACK_WIDTH))

    # program memory round-trip (the packed artifact is only deployable
    # together with its instruction words)
    back = isa.disassemble(isa.assemble(program), s=program.s)
    assert back == program


@settings(max_examples=20, deadline=None)
@given(s=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2 ** 16))
def test_fold_pack_roundtrip_property(s, seed):
    program = random_program(s, seed)
    _assert_roundtrip(program, seed)


def test_fold_pack_roundtrip_field_edges():
    """Deterministic edge program: IO bits/in_channels at their encodable
    maxima (15 / 7) and a 256-wide hidden FC — the exact fields PR 1
    widened (the old 4-bit FC field corrupted anything above 15, the old
    IO word dropped in_channels and truncated 8-bit inputs)."""
    f = 64                                         # s=4
    program = isa.Program(s=4, instrs=(
        isa.IOInstr(height=6, width=6, in_channels=7, bits=15, channels=f),
        isa.ConvInstr(height=6, width=6, features=f, maxpool=True),
        isa.ConvInstr(height=2, width=2, features=f, maxpool=False),
        isa.FCInstr(in_features=f, out_features=256),
        isa.FCInstr(in_features=256, out_features=10, final=True),
    ))
    isa.validate(program)
    back = isa.disassemble(isa.assemble(program), s=4)
    assert back.instrs[0].bits == 15 and back.instrs[0].in_channels == 7
    assert back.instrs[3].out_features == 256      # > old 4-bit max
    _assert_roundtrip(program, seed=99)


def test_fold_pack_rejects_unencodable_fields():
    """Past-the-edge values must fail loudly at assemble time, not wrap."""
    f = 64
    base = [isa.IOInstr(height=6, width=6, in_channels=3, bits=7, channels=f),
            isa.ConvInstr(height=6, width=6, features=f, maxpool=True),
            isa.FCInstr(in_features=2 * 2 * f, out_features=10, final=True)]
    bad_io = isa.Program(s=4, instrs=tuple(
        [isa.IOInstr(height=6, width=6, in_channels=3, bits=16, channels=f)]
        + base[1:]))
    with pytest.raises(isa.ProgramError, match="bits"):
        isa.assemble(bad_io)
    with pytest.raises(isa.ProgramError, match="out_features"):
        isa._encode_instr(isa.FCInstr(in_features=64, out_features=1024))


@settings(max_examples=10, deadline=None)
@given(s=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2 ** 16))
def test_random_program_plan_compiles(s, seed):
    """Every generated program also compiles to an InferencePlan (its
    geometry is fully resolvable) — guards the generator itself and the
    plan builder's stage coverage."""
    program = random_program(s, seed)
    plan = interpreter.compile_plan(program)
    assert len(plan.stages) == len(program.instrs)

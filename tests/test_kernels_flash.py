"""Flash-attention Pallas kernel vs the JAX chunked-attention oracle.

interpret=True executes the kernel body on CPU (the TPU lowering is the
deploy path).  Shape/dtype/GQA sweeps per the kernel-test convention.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd
from repro.models.attention import chunked_attention


def _rand_qkv(key, b, s, h, kh, d, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, s, h, d), dtype)
    k = jax.random.normal(k2, (b, s, kh, d), dtype)
    v = jax.random.normal(k3, (b, s, kh, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kh,d,bq,bk", [
    (1, 128, 4, 4, 16, 64, 64),     # MHA
    (2, 128, 4, 2, 16, 32, 64),     # GQA g=2
    pytest.param(1, 256, 6, 2, 8, 64, 128,
                 marks=pytest.mark.slow),  # GQA g=3, rectangular (heaviest)
    (1, 64, 8, 1, 32, 64, 32),      # MQA
])
def test_flash_matches_oracle(b, s, h, kh, d, bq, bk):
    q, k, v = _rand_qkv(jax.random.PRNGKey(0), b, s, h, kh, d)
    ref = chunked_attention(q, k, v, causal=True, chunk_q=64, chunk_k=64)
    got = flash_attention_fwd(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_non_causal():
    q, k, v = _rand_qkv(jax.random.PRNGKey(1), 2, 128, 4, 2, 16)
    ref = chunked_attention(q, k, v, causal=False, chunk_q=64, chunk_k=64)
    got = flash_attention_fwd(q, k, v, causal=False, block_q=64, block_k=64,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16():
    q, k, v = _rand_qkv(jax.random.PRNGKey(2), 1, 128, 4, 2, 16,
                        dtype=jnp.bfloat16)
    ref = chunked_attention(q, k, v, causal=True, chunk_q=64, chunk_k=64)
    got = flash_attention_fwd(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)

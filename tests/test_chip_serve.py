"""Chip-tier serving: equivalence + property suite.

Locks down the serving subsystem three ways:

1. **Equivalence** — for every ``networks.REGISTRY`` program, labels and
   logits served through :class:`ChipServer` (static batches, padding,
   queue scheduling) are bit-exact vs the offline ``InferencePlan``
   forward over the same frames — on 1 device AND on a
   ``jax.device_count()``-device serving mesh (run CI with
   ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to make the
   mesh path a real 4-way frame scatter; on a plain CPU host it degrades
   to 1 device and must still be bit-exact).
2. **Scheduler properties** (hypothesis) — exactly-once delivery,
   per-program FIFO order, single-program batches, and round-robin
   fairness (no lane starves while backlogged) under random submission /
   dispatch interleavings.
3. **Billing** — padding slots are billed as burned energy, and the
   multi-program chip bill composes per-program NetReports sanely.
"""

import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.chip import energy, interpreter, networks
from repro.distributed import sharding
from repro.serving import (ChipServer, FrameQueue, FrameRequest,
                           bursty_trace)


# ---------------------------------------------------------------------------
# Helpers / fixtures
# ---------------------------------------------------------------------------

def _frames(program, n, seed=0):
    io = program.instrs[0]
    return np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (n, io.height, io.width, io.in_channels),
        0, 2 ** io.bits))


def _artifact(program, seed=0):
    params = interpreter.init_params(jax.random.PRNGKey(seed), program)
    return interpreter.fold_params(params, program, packed=True)


def _offline(program, packed, frames):
    plan = interpreter.compile_plan(program)
    logits, labels = plan.forward(packed, jnp.asarray(frames),
                                  interpret=True)
    return np.asarray(logits), np.asarray(labels)


@pytest.fixture(scope="module")
def mnist_setup():
    program = networks.mnist5()
    packed = _artifact(program, seed=3)
    frames = _frames(program, 9, seed=11)
    logits, labels = _offline(program, packed, frames)
    return program, packed, frames, logits, labels


# ---------------------------------------------------------------------------
# 1. Equivalence: served == offline, single- and multi-device
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(networks.REGISTRY))
def test_served_bit_exact_vs_offline_plan(name):
    """Every registry program: ChipServer (static batch 2, with one padded
    slot) serves bit-identical labels/logits to the offline plan — through
    the plain path and through a jax.device_count()-device serving mesh."""
    program = networks.REGISTRY[name]()
    packed = _artifact(program)
    frames = _frames(program, 3, seed=7)          # 3 % 2 -> padding too
    logits_ref, labels_ref = _offline(program, packed, frames)

    mesh = sharding.serve_mesh()
    ndev = mesh.devices.size
    for m, batch in ((None, 2), (mesh, 2 * ndev)):
        server = ChipServer({name: program}, {name: packed},
                            batch=batch, mesh=m, interpret=True)
        rids = server.submit_many(name, frames)
        results = server.drain()
        assert [r.rid for r in results] == rids   # arrival order preserved
        np.testing.assert_array_equal(
            np.array([r.label for r in results]), labels_ref)
        np.testing.assert_array_equal(
            np.stack([r.logits for r in results]), logits_ref)
        assert server.queue.pending() == 0


def test_sharded_server_matches_unsharded(mnist_setup):
    """Mesh path vs plain path on the same artifact: identical results,
    whatever jax.device_count() is (1 on a plain CPU host, 4 in CI)."""
    program, packed, frames, logits_ref, labels_ref = mnist_setup
    mesh = sharding.serve_mesh()
    batch = 2 * mesh.devices.size
    plain = ChipServer({"m": program}, {"m": packed}, batch=batch,
                       interpret=True)
    shard = ChipServer({"m": program}, {"m": packed}, batch=batch,
                       mesh=mesh, interpret=True)
    for server in (plain, shard):
        server.submit_many("m", frames)
    res_p, res_s = plain.drain(), shard.drain()
    assert [r.label for r in res_p] == [r.label for r in res_s]
    np.testing.assert_array_equal(np.stack([r.logits for r in res_p]),
                                  np.stack([r.logits for r in res_s]))
    np.testing.assert_array_equal(
        np.array([r.label for r in res_s]), labels_ref)


@pytest.mark.filterwarnings("ignore:Some donated buffers were not usable")
def test_donated_frames_serve_fn_matches(mnist_setup):
    """The donated/streamed-buffer entry point is numerically identical
    (donation is a no-op on backends without buffer reuse — CPU warns)."""
    program, packed, frames, logits_ref, labels_ref = mnist_setup
    plan = interpreter.compile_plan(program)
    fn = plan.make_serve_fn(donate_frames=True, interpret=True)
    logits, labels = fn(packed, jnp.asarray(frames))
    np.testing.assert_array_equal(np.asarray(logits), logits_ref)
    np.testing.assert_array_equal(np.asarray(labels), labels_ref)


def test_scatter_frames_divisibility():
    """Indivisible batches are rejected on a multi-device mesh; any batch
    divides a 1-device mesh and scatters as a plain placement."""
    mesh = sharding.serve_mesh()
    n = mesh.devices.size
    if n > 1:
        with pytest.raises(ValueError, match="not divisible"):
            sharding.scatter_frames(mesh, jnp.zeros((n + 1, 4, 4, 1)))
    placed = sharding.scatter_frames(mesh, jnp.zeros((2 * n, 4, 4, 1)))
    assert placed.sharding.mesh.axis_names == (sharding.SERVE_AXIS,)


def test_server_guards():
    program = networks.mnist5()
    packed = _artifact(program)
    with pytest.raises(ValueError, match="!="):
        ChipServer({"a": program}, {"b": packed})
    with pytest.raises(ValueError, match="batch"):
        ChipServer({"a": program}, {"a": packed}, batch=0)
    server = ChipServer({"a": program}, {"a": packed}, batch=2,
                        interpret=True)
    with pytest.raises(ValueError, match="shape"):
        server.submit("a", np.zeros((3, 3, 1), np.int32))
    with pytest.raises(KeyError, match="not resident"):
        server.submit("ghost", np.zeros((14, 14, 1), np.int32))
    with pytest.raises(KeyError):
        server.queue.submit(FrameRequest(rid=0, program="ghost", frame=None))


def test_prefetch_serves_identical_results(multi_setup):
    """prefetch=True (stage batch N+1 while N runs) returns the exact
    result stream of the synchronous server: same labels/logits, same
    dispatch indices, same padding bill — the overlap is pure host-side
    pipelining, dispatch order never changes."""
    progs, arts = multi_setup
    frames = {n: _frames(p, 5, seed=30 + i)
              for i, (n, p) in enumerate(progs.items())}
    runs = {}
    for prefetch in (False, True):
        server = ChipServer(progs, arts, batch=2, interpret=True,
                            prefetch=prefetch)
        for i in range(5):
            for n in progs:
                server.submit(n, frames[n][i])
        runs[prefetch] = (server.drain(), server.stats())
    (res_s, stats_s), (res_p, stats_p) = runs[False], runs[True]
    assert [(r.rid, r.program, r.label, r.dispatch) for r in res_s] == \
           [(r.rid, r.program, r.label, r.dispatch) for r in res_p]
    for a, b in zip(res_s, res_p):
        np.testing.assert_array_equal(a.logits, b.logits)
    assert stats_s.served == stats_p.served
    assert stats_s.padded == stats_p.padded
    assert stats_s.dispatches == stats_p.dispatches


def test_prefetch_interleaved_with_submission(mnist_setup):
    """step()-at-a-time with new frames arriving between steps: every
    frame is still served exactly once, in arrival order per program."""
    program, packed, frames, _, labels_ref = mnist_setup
    server = ChipServer({"m": program}, {"m": packed}, batch=2,
                        interpret=True, prefetch=True)
    got = []
    for i in range(len(frames)):
        server.submit("m", frames[i])
        got.extend(server.step())
    got.extend(server.drain())
    assert [r.rid for r in got] == list(range(len(frames)))
    np.testing.assert_array_equal(np.array([r.label for r in got]),
                                  labels_ref)


def test_prefetch_depth_k_serves_identical_results(multi_setup):
    """prefetch=k for any depth (incl. deeper than the queue) returns the
    exact synchronous result stream — depth-k pipelining with async host
    fetch is pure overlap, dispatch order and billing never change."""
    progs, arts = multi_setup
    frames = {n: _frames(p, 7, seed=40 + i)
              for i, (n, p) in enumerate(progs.items())}
    runs = {}
    for depth in (0, 1, 2, 3, 16):
        server = ChipServer(progs, arts, batch=2, interpret=True,
                            prefetch=depth)
        for i in range(7):
            for n in progs:
                server.submit(n, frames[n][i])
        results = server.drain()
        stats = server.stats()
        runs[depth] = ([(r.rid, r.program, r.label, r.dispatch)
                        for r in results],
                       stats.served, stats.padded, stats.dispatches)
    first = runs[0]
    for depth, run in runs.items():
        assert run == first, f"depth {depth} diverged"


def test_prefetch_depth_k_interleaved_with_submission(mnist_setup):
    """Depth-3 pipeline with frames arriving between steps: every frame
    served exactly once, in arrival order."""
    program, packed, frames, _, labels_ref = mnist_setup
    server = ChipServer({"m": program}, {"m": packed}, batch=2,
                        interpret=True, prefetch=3)
    got = []
    for i in range(len(frames)):
        server.submit("m", frames[i])
        got.extend(server.step())
    got.extend(server.drain())
    assert [r.rid for r in got] == list(range(len(frames)))
    np.testing.assert_array_equal(np.array([r.label for r in got]),
                                  labels_ref)


def test_prefetch_bool_is_depth_one():
    """Back-compat: prefetch=True means a depth-1 pipeline."""
    program = networks.mnist5()
    packed = _artifact(program)
    server = ChipServer({"m": program}, {"m": packed}, batch=2,
                        interpret=True, prefetch=True)
    assert server.prefetch == 1
    with pytest.raises(ValueError, match="prefetch"):
        ChipServer({"m": program}, {"m": packed}, prefetch=-1)


def test_megakernel_server_matches_staged(mnist_setup):
    """megakernel=True serving (weight image resident, zero inter-layer
    HBM) is bit-exact vs the staged server — with and without prefetch."""
    program, packed, frames, logits_ref, labels_ref = mnist_setup
    for prefetch in (False, True):
        server = ChipServer({"m": program}, {"m": packed}, batch=2,
                            interpret=True, megakernel=True,
                            prefetch=prefetch)
        server.submit_many("m", frames)
        results = server.drain()
        np.testing.assert_array_equal(
            np.array([r.label for r in results]), labels_ref)
        np.testing.assert_array_equal(
            np.stack([r.logits for r in results]), logits_ref)


# ---------------------------------------------------------------------------
# 2. Scheduler properties (pure Python, no device work)
# ---------------------------------------------------------------------------

def _simulate(n_lanes, n_reqs, capacity, seed):
    """Random interleaving of submissions and dispatches; returns the
    dispatch trace [(lane, [rids], pending_before_dict)] and all rids."""
    rng = random.Random(seed)
    lanes = [f"p{i}" for i in range(n_lanes)]
    q = FrameQueue(lanes)
    rid = 0
    trace = []
    to_submit = n_reqs
    while to_submit or len(q):
        if to_submit and (rng.random() < 0.6 or not len(q)):
            lane = rng.choice(lanes)
            q.submit(FrameRequest(rid=rid, program=lane, frame=None))
            rid += 1
            to_submit -= 1
        else:
            before = {l: q.pending(l) for l in lanes}
            got = q.next_batch(capacity)
            assert got is not None
            name, reqs = got
            trace.append((name, [r.rid for r in reqs], before))
    assert q.next_batch(capacity) is None         # drained
    return trace, list(range(rid))


@settings(max_examples=25, deadline=None)
@given(n_lanes=st.integers(1, 4), n_reqs=st.integers(0, 40),
       capacity=st.integers(1, 5), seed=st.integers(0, 2 ** 16))
def test_queue_drain_exactly_once_property(n_lanes, n_reqs, capacity, seed):
    """Any submission/dispatch interleaving: every request is served
    exactly once, batches are single-program and <= capacity, and each
    lane's rids come out in FIFO order."""
    trace, all_rids = _simulate(n_lanes, n_reqs, capacity, seed)
    served = [r for (_, rids, _) in trace for r in rids]
    assert sorted(served) == all_rids             # exactly once, none lost
    assert all(len(rids) <= capacity and rids == sorted(rids)
               for (_, rids, _) in trace)
    per_lane = {}
    for name, rids, _ in trace:
        per_lane.setdefault(name, []).extend(rids)
    for name, rids in per_lane.items():
        assert rids == sorted(rids)               # per-lane FIFO


@settings(max_examples=25, deadline=None)
@given(n_lanes=st.integers(2, 4), n_reqs=st.integers(8, 40),
       capacity=st.integers(1, 3), seed=st.integers(0, 2 ** 16))
def test_round_robin_fairness_property(n_lanes, n_reqs, capacity, seed):
    """No starvation: a lane that was non-empty before some dispatch is
    itself dispatched within the next n_lanes dispatches (or the trace
    ends first) — the round-robin pointer can't pass over a waiting lane."""
    trace, _ = _simulate(n_lanes, n_reqs, capacity, seed)
    for i, (_, _, before) in enumerate(trace):
        waiting = [l for l, p in before.items() if p > 0]
        window = [name for (name, _, _) in trace[i:i + n_lanes]]
        for lane in waiting:
            if len(window) == n_lanes:            # full window available
                assert lane in window, (
                    f"lane {lane} waited non-empty through dispatches "
                    f"{i}..{i + n_lanes - 1}: {window}")


def test_round_robin_cycles_under_backlog():
    """All lanes backlogged -> dispatch order is a strict rotation."""
    lanes = ["a", "b", "c"]
    q = FrameQueue(lanes)
    for rid in range(12):
        q.submit(FrameRequest(rid=rid, program=lanes[rid % 3], frame=None))
    order = [q.next_batch(1)[0] for _ in range(12)]
    assert order == ["a", "b", "c"] * 4


def test_queue_skips_empty_lanes():
    q = FrameQueue(["a", "b"])
    q.submit(FrameRequest(rid=0, program="b", frame=None))
    name, reqs = q.next_batch(4)
    assert name == "b" and [r.rid for r in reqs] == [0]
    assert q.next_batch(4) is None


# ---------------------------------------------------------------------------
# 2b. FrameQueue under bursty admission (MMPP traces, variable-size takes)
# ---------------------------------------------------------------------------

def _bursty_simulate(lanes, n_reqs, seed, *, weights=None, max_take=5):
    """Admission driven by a seeded MMPP arrival trace (lane tags and
    timestamps from ``bursty_trace``), dispatches at a random VARIABLE
    size each time — the continuous-batching admission pattern.  Returns
    the dispatch trace [(lane, [rids], pending_before)]."""
    arr = bursty_trace(lanes, rate=200.0, n=n_reqs, seed=seed,
                       weights=weights)
    rng = random.Random(seed)
    q = FrameQueue(lanes)
    i = 0
    trace = []
    while i < len(arr) or len(q):
        if i < len(arr) and (rng.random() < 0.6 or not len(q)):
            q.submit(FrameRequest(rid=i, program=arr.lane[i], frame=None,
                                  t_submit=1.0 + float(arr.t[i])))
            i += 1
        else:
            before = {l: q.pending(l) for l in lanes}
            got = q.next_batch(rng.randint(1, max_take))
            assert got is not None
            trace.append((got[0], [r.rid for r in got[1]], before))
    assert q.next_batch(max_take) is None             # drained
    return arr, trace


@settings(max_examples=20, deadline=None)
@given(n_lanes=st.integers(2, 4), n_reqs=st.integers(8, 48),
       seed=st.integers(0, 2 ** 16))
def test_queue_fifo_under_bursty_variable_size_dispatches(n_lanes, n_reqs,
                                                          seed):
    """Bursty admission + variable-size dispatches: every request served
    exactly once and each lane's frames leave in exactly their arrival
    order — FIFO survives the dispatch size changing under the window."""
    lanes = [f"p{i}" for i in range(n_lanes)]
    arr, trace = _bursty_simulate(lanes, n_reqs, seed)
    served = [r for (_, rids, _) in trace for r in rids]
    assert sorted(served) == list(range(n_reqs))      # exactly once
    per_lane = {}
    for name, rids, _ in trace:
        per_lane.setdefault(name, []).extend(rids)
    for name, rids in per_lane.items():
        want = [j for j in range(n_reqs) if arr.lane[j] == name]
        assert rids == want                           # per-lane FIFO


@settings(max_examples=20, deadline=None)
@given(n_reqs=st.integers(16, 60), seed=st.integers(0, 2 ** 16))
def test_trickle_lane_never_starves_behind_burst_lane(n_reqs, seed):
    """One high-rate lane (92% of arrivals) and one trickle lane: the
    round-robin pointer still serves the trickle lane within 2 dispatches
    of it becoming backlogged, whatever the burst state does."""
    arr, trace = _bursty_simulate(["burst", "trickle"], n_reqs, seed,
                                  weights=[0.92, 0.08])
    n_lanes = 2
    for i, (_, _, before) in enumerate(trace):
        window = [name for (name, _, _) in trace[i:i + n_lanes]]
        if len(window) < n_lanes:
            continue
        for lane, pending in before.items():
            if pending > 0:
                assert lane in window, (
                    f"lane {lane} ({pending} pending) starved at dispatch "
                    f"{i}: window {window}")


@settings(max_examples=20, deadline=None)
@given(n_lanes=st.integers(1, 4), cap=st.integers(2, 6),
       n_reqs=st.integers(4, 40), seed=st.integers(0, 2 ** 16))
def test_drain_completeness_with_ragged_final_batches(n_lanes, cap, n_reqs,
                                                      seed):
    """Submit a whole bursty trace, then drain at a fixed capacity: every
    lane empties completely, and a lane whose count doesn't divide the
    capacity ends on exactly its ragged remainder — no frame is stranded
    waiting for a full batch."""
    lanes = [f"p{i}" for i in range(n_lanes)]
    arr = bursty_trace(lanes, rate=200.0, n=n_reqs, seed=seed)
    q = FrameQueue(lanes)
    for i in range(len(arr)):
        q.submit(FrameRequest(rid=i, program=arr.lane[i], frame=None,
                              t_submit=1.0 + float(arr.t[i])))
    sizes = {}
    served = []
    while True:
        got = q.next_batch(cap)
        if got is None:
            break
        name, reqs = got
        sizes.setdefault(name, []).append(len(reqs))
        served.extend(r.rid for r in reqs)
    assert sorted(served) == list(range(n_reqs))      # nothing stranded
    assert len(q) == 0
    counts = {l: sum(1 for x in arr.lane if x == l) for l in lanes}
    for lane, batch_sizes in sizes.items():
        assert all(s == cap for s in batch_sizes[:-1])
        rem = counts[lane] % cap
        assert batch_sizes[-1] == (rem if rem else cap)   # ragged tail


# ---------------------------------------------------------------------------
# 3. Multi-program batching + billing
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def multi_setup():
    """Two distinct resident programs sharing the mnist5 topology family:
    the 10-class classifier and a 2-class wake-up detector."""
    progs = {"mnist5": networks.mnist5(),
             "wake": networks.mnist5(classes=2)}
    arts = {n: _artifact(p, seed=i) for i, (n, p) in enumerate(progs.items())}
    return progs, arts


def test_multi_program_routing_bit_exact(multi_setup):
    """Frames interleaved across resident programs are each served by
    *their* program's plan, bit-exact vs that program's offline forward,
    and every dispatch is single-program (the array runs one instruction
    stream at a time)."""
    progs, arts = multi_setup
    assert interpreter.compile_plan(progs["mnist5"]) is not \
        interpreter.compile_plan(progs["wake"])   # genuinely two plans
    frames = {n: _frames(p, 5, seed=20 + i)
              for i, (n, p) in enumerate(progs.items())}
    oracle = {n: _offline(progs[n], arts[n], frames[n]) for n in progs}

    server = ChipServer(progs, arts, batch=2, interpret=True)
    for i in range(5):                            # interleave submissions
        for n in progs:
            server.submit(n, frames[n][i])
    results = server.drain()

    assert len(results) == 10
    by_prog = {n: [r for r in results if r.program == n] for n in progs}
    for n in progs:
        got = sorted(by_prog[n], key=lambda r: r.rid)
        np.testing.assert_array_equal(np.array([r.label for r in got]),
                                      oracle[n][1])
        np.testing.assert_array_equal(np.stack([r.logits for r in got]),
                                      oracle[n][0])
    # single-program dispatches
    for d in range(max(r.dispatch for r in results) + 1):
        progs_in_d = {r.program for r in results if r.dispatch == d}
        assert len(progs_in_d) <= 1
    stats = server.stats()
    assert stats.served == {"mnist5": 5, "wake": 5}
    assert stats.dispatches == 6                  # ceil(5/2) per program
    assert stats.padded == {"mnist5": 1, "wake": 1}


def test_padding_billed_not_served(mnist_setup):
    """A 5-frame load on batch=4 burns 3 padding slots: they show up in
    the energy bill (µJ per *served* frame rises) but never in results."""
    program, packed, frames, _, labels_ref = mnist_setup
    server = ChipServer({"m": program}, {"m": packed}, batch=4,
                        interpret=True)
    server.submit_many("m", frames[:5])
    results = server.drain()
    assert len(results) == 5
    stats = server.stats()
    assert stats.served == {"m": 5} and stats.padded == {"m": 3}
    per_inf = stats.chip.reports["m"].i2l_energy_per_inference * 1e6
    assert stats.chip.uj_per_frame == pytest.approx(per_inf * 8 / 5)
    np.testing.assert_array_equal(np.array([r.label for r in results]),
                                  labels_ref[:5])


def test_serve_report_mix_composition():
    """Mixed-program bill: µJ/frame is the frame-weighted mean of the
    constituents and frames/s is their harmonic composition — so the mix
    always lands between the per-program figures."""
    progs = {"mnist5": networks.mnist5(), "face": networks.face_detector()}
    reps = {n: energy.analyze_net(p) for n, p in progs.items()}
    rep = energy.serve_report(progs, {"mnist5": 30, "face": 10})
    uj = {n: r.i2l_energy_per_inference * 1e6 for n, r in reps.items()}
    fps = {n: r.inferences_per_s for n, r in reps.items()}
    want_uj = (30 * uj["mnist5"] + 10 * uj["face"]) / 40
    want_fps = 40 / (30 / fps["mnist5"] + 10 / fps["face"])
    assert rep.uj_per_frame == pytest.approx(want_uj)
    assert rep.frames_per_s == pytest.approx(want_fps)
    assert min(uj.values()) <= rep.uj_per_frame <= max(uj.values())
    assert min(fps.values()) <= rep.frames_per_s <= max(fps.values())
    assert rep.total_frames == 40

    empty = energy.serve_report(progs, {})
    assert empty.uj_per_frame == 0.0 and empty.frames_per_s == 0.0


# ---------------------------------------------------------------------------
# 4. Continuous batching: ragged dispatch sizes stay bit-exact
# ---------------------------------------------------------------------------

_RAGGED_CACHE = {}


def _ragged_setup(name):
    """Per-program artifact/oracle cache so hypothesis examples reuse the
    compiled plan instead of rebuilding it per draw."""
    if name not in _RAGGED_CACHE:
        program = networks.REGISTRY[name]()
        packed = _artifact(program)
        frames = _frames(program, 8, seed=13)
        _RAGGED_CACHE[name] = (program, packed, frames,
                               _offline(program, packed, frames))
    return _RAGGED_CACHE[name]


@settings(max_examples=10, deadline=None)
@given(name=st.sampled_from(sorted(networks.REGISTRY)),
       chunks=st.lists(st.integers(1, 4), min_size=1, max_size=4))
def test_continuous_ragged_sizes_bit_exact_vs_offline(name, chunks):
    """The acceptance contract for variable-size dispatch: whatever
    ragged batch sizes the continuous window launches (1, 2, 3-padded-
    to-4, 4), every served label/logit row is bit-exact vs the offline
    forward, for every REGISTRY program.  Unstamped submissions carry no
    deadline, so each step() dispatches exactly the chunk submitted
    before it — the chunk sizes ARE the dispatch sizes (bucketed)."""
    program, packed, frames, (logits_ref, labels_ref) = _ragged_setup(name)
    server = ChipServer({name: program}, {name: packed}, batch=4,
                        interpret=True, policy="continuous")
    sent = 0
    results = []
    for c in chunks:
        take = min(c, len(frames) - sent)
        for _ in range(take):
            server.submit(name, frames[sent], t_submit=0.0)   # unstamped
            sent += 1
        if take:
            got = server.step()
            assert got, "unstamped frames must dispatch immediately"
            results.extend(got)
    results.extend(server.drain())

    assert [r.rid for r in results] == list(range(sent))  # FIFO survived
    np.testing.assert_array_equal(
        np.array([r.label for r in results]), labels_ref[:sent])
    np.testing.assert_array_equal(
        np.stack([r.logits for r in results]), logits_ref[:sent])
    # billing closes: served + padded == billed slots (stats() asserts
    # through energy.serve_report), and only bucket slack was padded
    stats = server.stats()
    assert stats.served == {name: sent}
    assert stats.padding_ratio < 1.0

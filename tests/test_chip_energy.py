"""Energy-model validation against every published BinarEye number.

These are the paper's claims (Figs. 4-5, Table 1); the model must land
within the stated tolerance of each.  This is the EXPERIMENTS.md §Claims
table in executable form.
"""

import pytest

from repro.core.chip import energy, isa, networks


def rel(a, b):
    return abs(a - b) / abs(b)


# ---------------------------------------------------------------------------
# Fig. 4 anchors (core performance of separate instructions)
# ---------------------------------------------------------------------------

def test_layer1_500M_ops():
    l1 = energy.analyze_program(networks.cifar9(1))[1]
    assert rel(l1.ops, 500e6) < 0.02          # "500M binary operations"


def test_layer1_352_gops_at_6mhz():
    l1 = energy.analyze_program(networks.cifar9(1))[1]
    assert rel(l1.gops(6e6), 352) < 0.02      # "6MHz and 352GOPS"


def test_layer1_peak_230_tops_w():
    l1 = energy.analyze_program(networks.cifar9(1))[1]
    assert rel(l1.tops_per_w(), 230) < 0.02   # "up to 230TOPS/W"


def test_core_efficiency_drops_with_smaller_maps():
    """Fig. 4: efficiency falls as W x H shrinks (LD time dominates)."""
    layers = [l for l in energy.analyze_program(networks.cifar9(1))
              if l.kind == "cnn"]
    effs = [l.tops_per_w() for l in layers]
    assert all(e1 >= e2 for e1, e2 in zip(effs, effs[1:]))
    assert effs[-1] < 0.25 * effs[0]


def test_performance_range_90_to_2800_gops():
    p = networks.cifar9(1)
    assert rel(energy.peak_gops(p, energy.F_MAX), 2800) < 0.02
    assert rel(energy.peak_gops(p, energy.F_MIN), 90) < 0.03


# ---------------------------------------------------------------------------
# Fig. 5 / Table 1 anchors (I2L performance vs S)
# ---------------------------------------------------------------------------

TABLE1 = {
    # s: (ops/net, core uJ, i2l uJ, inf/s, power mW)
    1: (2.0e9, 13.82, 14.4, 150, 2.2),
    2: (0.5e9, 3.40, 3.47, 500, 1.8),
    4: (0.125e9, 0.89, 0.92, 1700, 1.6),
}


@pytest.mark.parametrize("s", [1, 2, 4])
def test_table1_ops_energy_throughput(s):
    ops, core_uj, i2l_uj, inf_s, p_mw = TABLE1[s]
    r = energy.analyze_net(networks.cifar9(s))
    assert rel(r.ops_per_inference, ops) < 0.03
    assert rel(r.core_energy_per_inference * 1e6, core_uj) < 0.05
    assert rel(r.i2l_energy_per_inference * 1e6, i2l_uj) < 0.07
    assert rel(r.inferences_per_s, inf_s) < 0.15
    assert rel(r.power_w * 1e3, p_mw) < 0.17


def test_quadratic_s_scaling():
    """Throughput and energy improve ~quadratically with S (Sec. II)."""
    r1 = energy.analyze_net(networks.cifar9(1))
    r4 = energy.analyze_net(networks.cifar9(4))
    speedup = r4.inferences_per_s / r1.inferences_per_s
    ewin = r1.i2l_energy_per_inference / r4.i2l_energy_per_inference
    assert 10 < speedup < 16        # ideal 16, minus fixed IO/LD overheads
    assert 12 < ewin < 16


def test_i2l_efficiency_range():
    """'145 TOPS/W I2L' (peak) down to ~95 across modes."""
    effs = [energy.analyze_net(networks.cifar9(s)).i2l_tops_per_w
            for s in (1, 2, 4)]
    assert max(effs) > 130 and min(effs) > 95


def test_edp_anchors():
    r2 = energy.analyze_net(networks.cifar9(2))
    r4 = energy.analyze_net(networks.cifar9(4))
    assert rel(r2.edp_ujs, 7e-3) < 0.15      # Table 1 S=2
    assert rel(r4.edp_ujs, 5e-4) < 0.15      # Table 1 S=4
    # S=1 entry (1e-2) is quoted at fmax latency
    r1 = energy.analyze_net(networks.cifar9(1))
    assert rel(r1.edp_ujs_at(energy.F_MAX), 1e-2) < 0.25


def test_mnist_energy_anchors():
    """MNIST Table 1: 0.20 uJ core / 0.21 uJ I2L @ S=4.  The exact topology
    is unpublished; the LD-energy floor pins it to 2 conv layers on a
    decimated input (see networks.mnist5), which lands within 5%/2%."""
    r = energy.analyze_net(networks.mnist5())
    assert rel(r.core_energy_per_inference * 1e6, 0.20) < 0.05
    assert rel(r.i2l_energy_per_inference * 1e6, 0.21) < 0.02


def test_always_on_battery_life():
    """'up to 33 days always-on on a 810 mWh AAA battery' at ~1 mW."""
    r = energy.analyze_net(networks.cifar9(4))
    # sliding-window duty cycle at ~1 mW budget
    hours = 810e-3 / 1e-3 / 24  # = 33.75 days at exactly 1 mW
    assert hours > 33
    assert r.power_w < 2e-3     # chip runs under 2 mW at Emin


def test_faces_tasks_use_documented_modes():
    assert networks.face_detector().s == 4
    assert networks.face_angles().s == 2
    assert networks.owner_detector().s == 1

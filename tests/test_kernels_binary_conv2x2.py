"""binary_conv2x2 Pallas kernel vs oracle + binarize_pack kernel tests."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import binarize
from repro.kernels import ref
from repro.kernels.binarize_pack import binarize_pack
from repro.kernels.binary_conv2x2 import binary_conv2x2


def _rand_signs(rng, shape):
    return rng.choice(np.array([-1.0, 1.0], np.float32), size=shape)


def _pack_weights(w_signs):
    """(F,2,2,C) +/-1 -> (F,4,Cw) uint32."""
    f, _, _, c = w_signs.shape
    return binarize.pack_signs(jnp.asarray(w_signs).reshape(f, 4, c), axis=-1)


CASES = [
    (4, 4, 32, 8),      # tiny map
    (32, 32, 64, 64),   # chip S=4 layer shape
    (32, 32, 256, 64),  # chip S=1 layer shape (256 ch)
    (31, 31, 128, 32),  # odd spatial, S=2 channels
    (8, 9, 40, 16),     # non-square, C not multiple of 32
]


@pytest.mark.parametrize("h,w,c,f", CASES)
def test_matches_oracle(h, w, c, f):
    rng = np.random.default_rng(h * 100 + w * 10 + c + f)
    a = _rand_signs(rng, (h, w, c))
    wgt = _rand_signs(rng, (f, 2, 2, c))
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    got = binary_conv2x2(a_words, _pack_weights(wgt), c=c, interpret=True)
    want = ref.binary_conv2x2_ref(jnp.asarray(a), jnp.asarray(wgt))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("bf", [8, 32, 64])
def test_f_tile_invariance(bf):
    rng = np.random.default_rng(11)
    a = _rand_signs(rng, (16, 16, 64))
    wgt = _rand_signs(rng, (96, 2, 2, 64))
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    got = binary_conv2x2(a_words, _pack_weights(wgt), c=64, bf=bf, interpret=True)
    want = ref.binary_conv2x2_ref(jnp.asarray(a), jnp.asarray(wgt))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(h=st.integers(2, 12), w=st.integers(2, 12), c=st.integers(1, 70),
       f=st.integers(1, 20), seed=st.integers(0, 2**31 - 1))
def test_property_random(h, w, c, f, seed):
    rng = np.random.default_rng(seed)
    a = _rand_signs(rng, (h, w, c))
    wgt = _rand_signs(rng, (f, 2, 2, c))
    a_words = binarize.pack_signs(jnp.asarray(a), axis=-1)
    got = binary_conv2x2(a_words, _pack_weights(wgt), c=c, bf=8, interpret=True)
    want = ref.binary_conv2x2_ref(jnp.asarray(a), jnp.asarray(wgt))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# binarize_pack kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k", [(1, 32), (5, 100), (300, 64), (256, 4096)])
def test_binarize_pack_matches_oracle(m, k):
    rng = np.random.default_rng(m + k)
    x = rng.normal(size=(m, k)).astype(np.float32)
    got = binarize_pack(jnp.asarray(x), interpret=True)
    want = ref.binarize_pack_ref(jnp.asarray(x))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(m=st.integers(1, 64), k=st.integers(1, 200), seed=st.integers(0, 2**31 - 1))
def test_binarize_pack_roundtrip(m, k, seed):
    """unpack(pack(sign(x))) == sign(x) for all shapes."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    words = binarize_pack(jnp.asarray(x), bm=16, interpret=True)
    signs = binarize.unpack_signs(words, k, axis=-1)
    np.testing.assert_array_equal(
        np.asarray(signs), np.asarray(binarize.hard_sign(jnp.asarray(x))))
